#!/usr/bin/env python3
"""Docs link check: every `DESIGN.md §N` / `EXPERIMENTS.md §Name`
reference in the source tree must resolve to a real section heading, and
every benchmark module must be mapped in EXPERIMENTS.md.

Run from the repo root:  python scripts/check_docs.py
Exit code 0 = all references resolve.
"""

from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SOURCE_DIRS = ["src", "tests", "benchmarks", "examples"]

DESIGN_REF = re.compile(r"DESIGN\.md\s+§(\d+)")
EXP_REF = re.compile(r"EXPERIMENTS\.md\s+§([\w-]+)")
HEADING = re.compile(r"^#{2,}\s+§([\w-]+)", re.M)


def _source_files():
    for d in SOURCE_DIRS:
        yield from (ROOT / d).rglob("*.py")


def _headings(md: pathlib.Path) -> set[str]:
    if not md.exists():
        return set()
    return set(HEADING.findall(md.read_text()))


def main() -> int:
    errors: list[str] = []

    design_secs = _headings(ROOT / "DESIGN.md")
    exp_secs = _headings(ROOT / "EXPERIMENTS.md")
    for must in ("DESIGN.md", "EXPERIMENTS.md", "README.md"):
        if not (ROOT / must).exists():
            errors.append(f"missing {must}")

    for f in _source_files():
        text = f.read_text()
        rel = f.relative_to(ROOT)
        for n in DESIGN_REF.findall(text):
            if n not in design_secs:
                errors.append(f"{rel}: cites DESIGN.md §{n} "
                              f"(have: {sorted(design_secs)})")
        for name in EXP_REF.findall(text):
            if name not in exp_secs:
                errors.append(f"{rel}: cites EXPERIMENTS.md §{name} "
                              f"(have: {sorted(exp_secs)})")

    exp_text = (ROOT / "EXPERIMENTS.md").read_text() \
        if (ROOT / "EXPERIMENTS.md").exists() else ""
    for bench in sorted((ROOT / "benchmarks").glob("bench_*.py")):
        if bench.name not in exp_text:
            errors.append(f"EXPERIMENTS.md does not map {bench.name}")

    if errors:
        print("docs check FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_refs = sum(len(DESIGN_REF.findall(f.read_text())) +
                 len(EXP_REF.findall(f.read_text()))
                 for f in _source_files())
    print(f"docs check OK: {n_refs} section references resolve; "
          f"DESIGN sections {sorted(design_secs)}; "
          f"EXPERIMENTS sections {sorted(exp_secs)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
