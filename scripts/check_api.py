#!/usr/bin/env python3
"""Public-surface check (DESIGN.md §9): `repro.api` is the one entry
point for secure-ANN functionality.

Two gates:
  1. every name in `repro.api.__all__` actually resolves (the lazy
     export table cannot rot);
  2. no example and no serve launcher imports a legacy secure-ANN
     constructor directly — `examples/*.py` and
     `src/repro/launch/serve.py` must reach the system through
     `repro.api` only.  (Tests and benchmarks may still reach inside;
     they exercise internals on purpose.)

Run from the repo root:  PYTHONPATH=src python scripts/check_api.py
Exit code 0 = surface intact.
"""

from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# Files that must speak only repro.api for secure-ANN functionality.
GUARDED = sorted((ROOT / "examples").glob("*.py")) + \
    [ROOT / "src" / "repro" / "launch" / "serve.py"]

# Legacy secure-ANN modules: any import of these (or a submodule) from a
# guarded file is a surface violation.
BANNED_MODULES = (
    "repro.core.ppanns",
    "repro.serving.search_engine",
    "repro.serving.runtime",
    "repro.serving.ann_server",
    "repro.serving.secure_scan",
)

# Legacy constructors re-exported by `repro.serving` / `repro.core`:
# importing them by name from an umbrella module is the same violation.
BANNED_NAMES = {
    "ppanns", "SecureSearchEngine", "SearchStats", "FlatScanFilter",
    "IVFScanFilter", "HNSWGraphFilter", "CollectionManager", "Collection",
    "MicroBatcher", "SlotLoop", "Scheduler",
    "MutableEncryptedStore", "DeltaAwareBackend",
    "DistributedSecureANN", "ShardedBackend", "QueueFullError",
    "TenantIsolationError", "build_secure_scan_step", "secure_scan",
}

# Names that MUST stay exported by repro.api — the placement-aware
# surface contract (DESIGN.md §10) on top of the resolve check.
REQUIRED_EXPORTS = {
    "PlacementSpec", "IndexSpec", "SearchParams", "SearchRequest",
    "SearchResult", "SecureAnnService", "DataOwnerClient", "QueryClient",
}

# serving.ann_server is a deprecated shim (DESIGN.md §10): nothing in
# the src tree may import it except the shim modules themselves.  Tests
# may (they parity-test the shim).
ANN_SERVER_SHIMS = {
    pathlib.Path("src/repro/serving/ann_server.py"),
    pathlib.Path("src/repro/api/mesh.py"),
    pathlib.Path("src/repro/serving/__init__.py"),
}


def _banned_module(mod: str) -> bool:
    return any(mod == b or mod.startswith(b + ".") for b in BANNED_MODULES)


def check_imports(path: pathlib.Path) -> list[str]:
    errors = []
    rel = path.relative_to(ROOT)
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if _banned_module(alias.name):
                    errors.append(f"{rel}:{node.lineno}: imports legacy "
                                  f"module {alias.name} (use repro.api)")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:                 # relative import — not repro.*
                continue
            if _banned_module(mod):
                errors.append(f"{rel}:{node.lineno}: imports from legacy "
                              f"module {mod} (use repro.api)")
            elif mod in ("repro.core", "repro.serving"):
                bad = sorted({a.name for a in node.names} & BANNED_NAMES)
                if bad:
                    errors.append(
                        f"{rel}:{node.lineno}: imports legacy "
                        f"constructor(s) {', '.join(bad)} from {mod} "
                        f"(use repro.api)")
    return errors


def check_api_exports() -> list[str]:
    sys.path.insert(0, str(ROOT / "src"))
    try:
        import repro.api as api
    except Exception as e:                          # noqa: BLE001
        return [f"import repro.api failed: {type(e).__name__}: {e}"]
    errors = []
    for name in api.__all__:
        try:
            getattr(api, name)
        except Exception as e:                      # noqa: BLE001
            errors.append(f"repro.api.{name} does not resolve: "
                          f"{type(e).__name__}: {e}")
    for name in sorted(REQUIRED_EXPORTS - set(api.__all__)):
        errors.append(f"repro.api must export {name} (placement-aware "
                      f"surface contract, DESIGN.md §10)")
    errors.extend(check_quantization_surface(api))
    errors.extend(check_obs_surface(api))
    errors.extend(check_sec_surface(api))
    errors.extend(check_graph_surface(api))
    errors.extend(check_resilience_surface(api))
    return errors


# Names that MUST stay exported by repro.graph — the batched graph-index
# surface contract (DESIGN.md §15).
REQUIRED_GRAPH_EXPORTS = {
    "CSRGraph", "GraphFilter", "beam_plan", "graph_topk", "traverse",
}


def check_graph_surface(api) -> list[str]:
    """The batched graph-index surface contract (DESIGN.md §15):
    repro.graph exports the CSR mirror + batched filter, and IndexSpec
    admits backend='graph' with quantization AND the hardened tier —
    the combinations the legacy per-query 'hnsw' backend rejects."""
    errors = []
    try:
        import repro.graph as graph
    except Exception as e:                          # noqa: BLE001
        return [f"import repro.graph failed: {type(e).__name__}: {e}"]
    for name in sorted(REQUIRED_GRAPH_EXPORTS):
        if not hasattr(graph, name):
            errors.append(f"repro.graph must export {name} (graph "
                          f"surface contract, DESIGN.md §15)")
    for kw in ({"quantization": "int8"},
               {"security_profile": "hardened"}):
        try:
            spec = api.IndexSpec(tenant="_gate", name="_gate", d=8,
                                 backend="graph", **kw)
            if api.IndexSpec.from_bytes(spec.to_bytes()) != spec:
                errors.append(f"IndexSpec(backend='graph', **{kw}) does "
                              f"not survive a wire round-trip")
        except Exception as e:                      # noqa: BLE001
            errors.append(f"IndexSpec must admit backend='graph' with "
                          f"{kw} (DESIGN.md §15): {type(e).__name__}: {e}")
    return errors


def check_quantization_surface(api) -> list[str]:
    """The quantized-ADC surface contract (DESIGN.md §11): IndexSpec
    carries the quantization knobs, rejects bad values, and round-trips
    them over the wire."""
    import dataclasses
    errors = []
    fields = {f.name for f in dataclasses.fields(api.IndexSpec)}
    for name in ("quantization", "refine_ratio", "pq_m"):
        if name not in fields:
            errors.append(f"IndexSpec must carry {name} (quantized ADC "
                          f"surface, DESIGN.md §11)")
    if errors:
        return errors
    try:
        spec = api.IndexSpec(tenant="_gate", name="_gate", d=8,
                             quantization="int8")
        spec2 = api.IndexSpec.from_bytes(spec.to_bytes())
        if spec2.quantization != "int8":
            errors.append("IndexSpec.quantization does not survive a "
                          "wire round-trip")
    except Exception as e:                          # noqa: BLE001
        errors.append(f"IndexSpec(quantization='int8') must construct "
                      f"and round-trip: {type(e).__name__}: {e}")
    for bad in ({"quantization": "int4"},
                {"quantization": "int8", "backend": "hnsw"}):
        try:
            api.IndexSpec(tenant="_gate", name="_gate", d=8, **bad)
            errors.append(f"IndexSpec must reject {bad}")
        except ValueError:
            pass
    return errors


# Names that MUST stay exported by repro.obs — the observability
# surface contract (DESIGN.md §13).
REQUIRED_OBS_EXPORTS = {
    "Observability", "TraceRecorder", "NullRecorder", "NULL_RECORDER",
    "Span", "child_span", "child_complete", "current",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "KernelProfiler", "profile_kernels", "instrument", "active_profiler",
    "start_metrics_server",
}


def check_obs_surface(api) -> list[str]:
    """The observability surface contract (DESIGN.md §13): repro.obs
    exports the tracing/metrics/profiling entry points, the service
    exposes the export methods, and SearchRequest carries trace_id over
    the wire."""
    import dataclasses
    errors = []
    try:
        import repro.obs as obs
    except Exception as e:                          # noqa: BLE001
        return [f"import repro.obs failed: {type(e).__name__}: {e}"]
    for name in sorted(REQUIRED_OBS_EXPORTS):
        if not hasattr(obs, name):
            errors.append(f"repro.obs must export {name} (observability "
                          f"surface contract, DESIGN.md §13)")
    for meth in ("metrics_text", "trace_events", "export_chrome_trace"):
        if not callable(getattr(api.SecureAnnService, meth, None)):
            errors.append(f"SecureAnnService must expose {meth}() "
                          f"(DESIGN.md §13)")
    fields = {f.name for f in dataclasses.fields(api.SearchRequest)}
    if "trace_id" not in fields:
        errors.append("SearchRequest must carry trace_id "
                      "(client-propagated correlation id, DESIGN.md §13)")
    return errors


# Names that MUST stay exported by repro.sec — the security-profile +
# leakage-harness surface contract (DESIGN.md §14).
REQUIRED_SEC_EXPORTS = {
    "SecurityProfile", "PROFILES", "SECURITY_PROFILE_NAMES",
    "DEFAULT_PROFILE", "get_profile",
    "AttackResult", "ServerView", "capture_server_view",
    "aspe_kpa_attack", "dce_kpa_attack", "adc_code_attack",
    "access_pattern_attack", "evaluate_profile",
}


def check_sec_surface(api) -> list[str]:
    """The security-profile surface contract (DESIGN.md §14): repro.sec
    exports the profile registry + leakage harness, and IndexSpec
    carries (validates, round-trips) `security_profile`."""
    import dataclasses
    errors = []
    try:
        import repro.sec as sec
    except Exception as e:                          # noqa: BLE001
        return [f"import repro.sec failed: {type(e).__name__}: {e}"]
    for name in sorted(REQUIRED_SEC_EXPORTS):
        if not hasattr(sec, name):
            errors.append(f"repro.sec must export {name} (security "
                          f"surface contract, DESIGN.md §14)")
    fields = {f.name for f in dataclasses.fields(api.IndexSpec)}
    if "security_profile" not in fields:
        return errors + ["IndexSpec must carry security_profile "
                         "(DESIGN.md §14)"]
    try:
        spec = api.IndexSpec(tenant="_gate", name="_gate", d=8,
                             security_profile="hardened")
        if api.IndexSpec.from_bytes(spec.to_bytes()) != spec:
            errors.append("IndexSpec.security_profile does not survive "
                          "a wire round-trip")
    except Exception as e:                          # noqa: BLE001
        errors.append(f"IndexSpec(security_profile='hardened') must "
                      f"construct and round-trip: {type(e).__name__}: {e}")
    for bad in ({"security_profile": "bogus"},
                {"security_profile": "hardened", "backend": "hnsw"}):
        try:
            api.IndexSpec(tenant="_gate", name="_gate", d=8, **bad)
            errors.append(f"IndexSpec must reject {bad}")
        except ValueError:
            pass
    return errors


# Names that MUST stay exported by repro.resilience — the fault-tolerant
# serving surface contract (DESIGN.md §16).
REQUIRED_RESILIENCE_EXPORTS = {
    "WriteAheadLog", "WalRecord", "WalCorruptionError",
    "AsyncCheckpointer", "recover", "RecoveryReport", "attach_wal",
    "ShardHealthRegistry", "FaultPlan", "InjectedFault", "SimulatedCrash",
    "EngineRetryPolicy", "RetryPolicy", "ResilientRunner",
    "StragglerWatchdog",
}


def check_resilience_surface(api) -> list[str]:
    """The fault-tolerance surface contract (DESIGN.md §16):
    repro.resilience exports the WAL/checkpoint/recovery/failover entry
    points, and the failover wire fields stay ADDITIVE — old payloads
    without them must keep decoding as healthy answers, and
    PlacementSpec.n_replicas must validate and round-trip."""
    import dataclasses

    import numpy as np
    errors = []
    try:
        import repro.resilience as resilience
    except Exception as e:                          # noqa: BLE001
        return [f"import repro.resilience failed: "
                f"{type(e).__name__}: {e}"]
    for name in sorted(REQUIRED_RESILIENCE_EXPORTS):
        if not hasattr(resilience, name):
            errors.append(f"repro.resilience must export {name} "
                          f"(resilience surface contract, DESIGN.md §16)")
    # additive wire fields: a stats dict WITHOUT the failover keys (a
    # pre-§16 peer's payload) must decode as a healthy answer
    from repro.serving.search_engine import SearchStats
    stats_fields = {f.name for f in dataclasses.fields(SearchStats)}
    for name in ("degraded", "n_shards_down"):
        if name not in stats_fields:
            errors.append(f"SearchStats must carry {name} "
                          f"(failover accounting, DESIGN.md §16)")
    if not errors:
        try:
            from repro.api.protocol import PROTOCOL_VERSION
            from repro.core.wireformat import pack
            old_stats = dataclasses.asdict(SearchStats(
                latency_s=0.0, filter_dist_evals=0, refine_comparisons=0,
                bytes_up=0, bytes_down=0, n_queries=1, backend="flat"))
            old_stats.pop("degraded")
            old_stats.pop("n_shards_down")
            res = api.SearchResult.from_bytes(pack(
                "search-result", PROTOCOL_VERSION,
                arrays={"ids": np.zeros((1, 1), np.int64)},
                meta={"stats": old_stats}))
            if res.degraded or res.stats.n_shards_down:
                errors.append("pre-resilience search-result payloads "
                              "must decode as healthy (additive wire "
                              "contract, DESIGN.md §16)")
        except Exception as e:                      # noqa: BLE001
            errors.append(f"pre-resilience search-result payload must "
                          f"decode: {type(e).__name__}: {e}")
    # PlacementSpec.n_replicas: validated, wire round-tripped, additive
    try:
        p = api.PlacementSpec(kind="sharded", n_shards=2, n_replicas=3)
        if api.PlacementSpec.from_bytes(p.to_bytes()) != p:
            errors.append("PlacementSpec.n_replicas does not survive a "
                          "wire round-trip")
        d = p.to_dict()
        d.pop("n_replicas")
        if api.PlacementSpec.from_dict(d).n_replicas != 1:
            errors.append("PlacementSpec.from_dict must default missing "
                          "n_replicas to 1 (additive wire contract)")
    except Exception as e:                          # noqa: BLE001
        errors.append(f"PlacementSpec(n_replicas=3) must construct and "
                      f"round-trip (DESIGN.md §16): "
                      f"{type(e).__name__}: {e}")
    for bad in ({"kind": "sharded", "n_shards": 2, "n_replicas": 0},
                {"kind": "single", "n_replicas": 2}):
        try:
            api.PlacementSpec(**bad)
            errors.append(f"PlacementSpec must reject {bad}")
        except ValueError:
            pass
    return errors


def check_ann_server_ban() -> list[str]:
    """No src module outside the shims may import the deprecated
    `serving.ann_server` path (absolute or relative)."""
    errors = []
    for path in sorted((ROOT / "src").rglob("*.py")):
        rel = path.relative_to(ROOT)
        if rel in ANN_SERVER_SHIMS:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                # `from pkg.serving import ann_server` names the module
                # as an alias, not in node.module — check both
                mods = [node.module or ""] \
                    + [a.name for a in node.names]
            for mod in mods:
                if mod == "ann_server" or mod.endswith(".ann_server"):
                    errors.append(
                        f"{rel}:{node.lineno}: imports deprecated "
                        f"ann_server (only the shims may; use "
                        f"serving.sharded / placement=)")
                    break
    return errors


def main() -> int:
    errors = check_api_exports()
    errors.extend(check_ann_server_ban())
    for f in GUARDED:
        errors.extend(check_imports(f))
    if errors:
        print("api surface check FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    import repro.api as api
    print(f"api surface check OK: {len(api.__all__)} public names "
          f"resolve; {len(GUARDED)} guarded files import only repro.api")
    return 0


if __name__ == "__main__":
    sys.exit(main())
