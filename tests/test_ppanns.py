"""End-to-end PP-ANNS system tests (paper §V, Algorithm 2)."""

import numpy as np
import pytest

from repro.core import dcpe, ppanns, secure_knn, dce
from repro.data import synth


@pytest.fixture(scope="module")
def system():
    ds = synth.make_dataset("deep1m", n=2000, n_queries=20, k_gt=50, seed=2)
    owner, user, server = ppanns.build_system(
        ds.base, beta_fraction=0.03, M=12, ef_construction=100, seed=7)
    return ds, owner, user, server


def test_filter_and_refine_recall(system):
    ds, owner, user, server = system
    k = 10
    found = []
    for q in ds.queries:
        c_sap, t_q = user.encrypt_query(q)
        ids, _ = server.search(c_sap, t_q, k, ratio_k=8, ef_search=128)
        found.append(ids)
    rec = synth.recall_at_k(np.stack(found), ds.gt, k)
    assert rec >= 0.9, f"recall {rec}"


def test_refine_improves_over_filter_only(system):
    """Fig. 6: filter-only (DCPE distances) recall <= full scheme recall."""
    ds, owner, user, server = system
    k = 10
    rec_full, rec_filter = [], []
    for q in ds.queries:
        c_sap, t_q = user.encrypt_query(q)
        full, _ = server.search(c_sap, t_q, k, ratio_k=8, ef_search=128)
        filt, _ = server.search(c_sap, t_q, k, ratio_k=8, ef_search=128,
                                refine="none")
        rec_full.append(full)
        rec_filter.append(filt)
    r_full = synth.recall_at_k(np.stack(rec_full), ds.gt, k)
    r_filt = synth.recall_at_k(np.stack(rec_filter), ds.gt, k)
    assert r_full >= r_filt


def test_tournament_refine_matches_heap(system):
    ds, owner, user, server = system
    k = 10
    for q in ds.queries[:5]:
        c_sap, t_q = user.encrypt_query(q)
        a, _ = server.search(c_sap, t_q, k, ratio_k=8, refine="heap")
        b, _ = server.search(c_sap, t_q, k, ratio_k=8, refine="tournament")
        # same candidate set + exact comparisons => same selected set
        # (order may differ; f32 near-ties may swap boundary elements)
        assert len(set(a.tolist()) & set(b.tolist())) >= k - 1


def test_server_sees_no_plaintext(system):
    """The server's stored state contains no plaintext vectors: DCPE
    ciphertexts differ from s*P by design noise; DCE ciphertexts live in a
    different dimension entirely."""
    ds, owner, user, server = system
    s = owner.keys.sap_key.s
    resid = np.linalg.norm(server.db.C_sap - s * ds.base, axis=1)
    assert (resid > 0).all()
    assert server.db.C_dce.shape[-1] == 2 * ds.d + 16


def test_linear_scan_heap_is_exact():
    ds = synth.make_dataset("deep1m", n=300, n_queries=3, k_gt=10, seed=3)
    owner = ppanns.DataOwner(d=ds.d, sap_beta=1.0, seed=1)
    db_dce = dce.encrypt(ds.base, owner.keys.dce_key, seed=5)
    user = ppanns.User(owner.share_keys())
    for qi, q in enumerate(ds.queries):
        _, t_q = user.encrypt_query(q)
        ids, ncmp = secure_knn.linear_scan_heap(
            db_dce.astype(np.float64), t_q.astype(np.float64), 5)
        assert set(ids.tolist()) == set(ds.gt[qi, :5].tolist())
        assert ncmp <= 300 * (2 * np.log2(5) + 2) + 500   # O(n log k)


def test_linear_scan_tournament_is_exact():
    ds = synth.make_dataset("deep1m", n=400, n_queries=2, k_gt=10, seed=4)
    owner = ppanns.DataOwner(d=ds.d, sap_beta=1.0, seed=2)
    db_dce = dce.encrypt(ds.base, owner.keys.dce_key, seed=6)
    user = ppanns.User(owner.share_keys())
    for qi, q in enumerate(ds.queries):
        _, t_q = user.encrypt_query(q)
        ids, _ = secure_knn.linear_scan_tournament(db_dce, t_q, 5, chunk=128)
        assert len(set(ids.tolist()) & set(ds.gt[qi, :5].tolist())) >= 4


def test_insert_and_delete_maintenance(system):
    ds, owner, user, server = system
    n0 = server.db.n
    newv = ds.queries[0] + 0.01      # a vector right next to query 0
    c_sap, c_dce = owner.encrypt_vector(newv, seed=999)
    node = server.insert(c_sap, c_dce)
    assert node == n0
    csq, tq = user.encrypt_query(ds.queries[0])
    ids, _ = server.search(csq, tq, 5, ratio_k=8, ef_search=128)
    assert node in ids               # the new vector is its nearest neighbor
    server.delete(node)
    ids2, _ = server.search(csq, tq, 5, ratio_k=8, ef_search=128)
    assert node not in ids2


def test_communication_cost_matches_paper(system):
    """§V-C: up = 36d + O(1) bytes (4d DCPE f32 + 4(2d+16) trapdoor f32 ...
    our f32 layout gives 4d + 4(2d+16) + 4 = 12d + 68 bytes; the paper's 36d
    assumes f64 + padding — we assert the O(d) shape and the download as
    the true serialized id size: int64 ids, 8 bytes each)."""
    ds, owner, user, server = system
    c_sap, t_q = user.encrypt_query(ds.queries[0])
    ids, stats = server.search(c_sap, t_q, 10)
    assert stats.bytes_up == 4 * ds.d + 4 * (2 * ds.d + 16) + 4
    assert stats.bytes_down == 8 * 10
