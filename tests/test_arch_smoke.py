"""Per-architecture smoke tests: reduced same-family configs run one
forward + one train-loss/grad step on CPU; output shapes and finiteness
asserted.  Full configs are exercised only by the dry-run (abstract)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model
from repro.models.config import ShapeConfig

B, S = 2, 64


def _smoke_model(arch):
    cfg = get_config(arch).smoke()
    return Model(cfg), cfg


def _batch(cfg, key, seq=S):
    s_text = seq - (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    tokens = jax.random.randint(key, (B, s_text), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(
            key, (B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    model, cfg = _smoke_model(arch)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits = jax.jit(model.forward)(params, batch)
    s_text = batch["tokens"].shape[1]
    assert logits.shape == (B, s_text, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_and_grads_finite(arch):
    model, cfg = _smoke_model(arch)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = _batch(cfg, key)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0
    finite = jax.tree.map(
        lambda g: bool(jnp.isfinite(g.astype(jnp.float32)).all()), grads)
    assert all(jax.tree.leaves(finite))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(prompt)) logits == forward(prompt + token) logits —
    the KV-cache / recurrent-state path must be numerically consistent."""
    model, cfg = _smoke_model(arch)
    # capacity_factor >= E/k guarantees no token drops, so the train-path
    # and decode-path MoE outputs agree exactly (drops are a train-only
    # throughput trade-off, not a correctness feature).
    cfg = dataclasses.replace(cfg, remat=False, moe_capacity_factor=8.0)
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    seq = 16
    batch = _batch(cfg, key, seq=seq + (cfg.n_vision_tokens or 0))
    tokens = batch["tokens"]

    # full forward logits at every position
    full = model.forward(params, batch)

    # prefill on the first seq-1 tokens, then one decode step
    t_max = tokens.shape[1] + (cfg.n_vision_tokens or 0) + 4
    cache = model.init_cache(B, t_max)
    pre_batch = dict(batch, tokens=tokens[:, :-1])
    logits_pre, cache = model.prefill(params, pre_batch, cache)
    logits_dec, cache = model.decode_step(params, tokens[:, -1:], cache)

    np.testing.assert_allclose(
        np.asarray(logits_pre, np.float32),
        np.asarray(full[:, -2], np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(full[:, -1], np.float32), rtol=2e-2, atol=2e-2)


def test_registry_complete():
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg = get_config(a)
        assert cfg.name == a
        assert cfg.smoke().d_model == 128


def test_moe_capacity_drop_and_combine():
    from repro.models import moe as moe_mod
    cfg = get_config("grok-1-314b").smoke()
    model = Model(cfg)
    assert cfg.n_experts > 0
    n_tok = B * S
    c = moe_mod.capacity(cfg, n_tok)
    assert c >= 4
    assert c <= n_tok * cfg.experts_per_token


def test_long_context_eligibility_flags():
    subq = {a for a in ARCHS if get_config(a).subquadratic}
    assert subq == {"zamba2-1.2b", "mamba2-370m"}
