"""Index-layer tests: HNSW recall/build, LSH, IVF, persistence, maintenance."""

import numpy as np
import pytest

from repro.core.hnsw import HNSW
from repro.core.ivf import IVFIndex
from repro.core.lsh import LSHIndex
from repro.data import synth


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("deep1m", n=3000, n_queries=30, k_gt=20, seed=1)


def test_hnsw_recall_beats_090(ds):
    idx = HNSW(dim=ds.d, M=12, ef_construction=100, seed=0)
    idx.build(ds.base)
    found = np.stack([idx.search(q, 10, ef=80)[0] for q in ds.queries])
    rec = synth.recall_at_k(found, ds.gt, 10)
    assert rec >= 0.9, f"recall {rec}"


def test_hnsw_search_returns_sorted_distances(ds):
    idx = HNSW(dim=ds.d, M=12, ef_construction=80, seed=0)
    idx.build(ds.base[:500])
    ids, dists = idx.search(ds.queries[0], 8, ef=64)
    assert (np.diff(dists) >= -1e-6).all()
    true = ((ds.base[:500][ids] - ds.queries[0]) ** 2).sum(1)
    np.testing.assert_allclose(dists, true, rtol=1e-4)


def test_hnsw_incremental_insert_matches_build(ds):
    a = HNSW(dim=ds.d, M=8, ef_construction=60, seed=3)
    a.build(ds.base[:400])
    for x in ds.base[400:500]:
        a.insert(x)
    found = np.stack([a.search(q, 10, ef=64)[0] for q in ds.queries])
    gt = synth.ground_truth(ds.base[:500], ds.queries, 10)
    assert synth.recall_at_k(found, gt, 10) >= 0.85


def test_hnsw_delete_repairs_graph(ds):
    idx = HNSW(dim=ds.d, M=8, ef_construction=60, seed=4)
    idx.build(ds.base[:300])
    gt_before = synth.ground_truth(ds.base[:300], ds.queries[:5], 3)
    victim = int(gt_before[0, 0])
    idx.delete(victim)
    ids, _ = idx.search(ds.queries[0], 5, ef=64)
    assert victim not in ids
    # remaining results still come from the true neighborhood
    alive = np.setdiff1d(np.arange(300), [victim])
    gt_after = synth.ground_truth(ds.base[:300][alive], ds.queries[:1], 5)
    mapped = set(alive[gt_after[0]].tolist())
    assert len(set(ids.tolist()) & mapped) >= 3


def test_hnsw_serialization_roundtrip(ds):
    idx = HNSW(dim=ds.d, M=8, ef_construction=60, seed=5)
    idx.build(ds.base[:300])
    clone = HNSW.from_arrays(idx.to_arrays())
    for q in ds.queries[:5]:
        a, _ = idx.search(q, 5, ef=50)
        b, _ = clone.search(q, 5, ef=50)
        assert (a == b).all()


def test_lsh_candidates_contain_neighbors(ds):
    idx = LSHIndex(dim=ds.d, n_tables=12, n_hashes=6, bucket_width=20.0, seed=0)
    idx.build(ds.base)
    hit = 0
    for qi, q in enumerate(ds.queries[:20]):
        cands = set(idx.query(q).tolist())
        hit += len(cands & set(ds.gt[qi, :10].tolist())) / 10
    assert hit / 20 > 0.5      # LSH needs many candidates — paper's point


def test_ivf_probe_recall(ds):
    idx = IVFIndex(n_clusters=32, n_iters=8, seed=0).build(ds.base)
    rec = 0.0
    for qi, q in enumerate(ds.queries[:20]):
        cands = set(idx.probe(q, nprobe=8).tolist())
        rec += len(cands & set(ds.gt[qi, :10].tolist())) / 10
    assert rec / 20 > 0.9
