"""Checkpointing (atomic commit, resume, elastic remesh) and fault
tolerance (failure injection + straggler mitigation)."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (cleanup_old, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.ft import ResilientRunner, RetryPolicy, StragglerWatchdog


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    r, manifest = restore_checkpoint(str(tmp_path), t)
    assert manifest["step"] == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_mid_save_leaves_no_corrupt_checkpoint(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    # simulate a crash: a stale tmp dir with partial contents
    tmp_dir = tmp_path / "step_00000002.tmp-9999"
    tmp_dir.mkdir()
    (tmp_dir / "arr_00000.npy").write_bytes(b"partial")
    assert latest_step(str(tmp_path)) == 1          # tmp dirs are invisible
    r, m = restore_checkpoint(str(tmp_path), t)
    assert m["step"] == 1
    cleanup_old(str(tmp_path), keep=3)
    assert not tmp_dir.exists()


def test_cleanup_keeps_newest(tmp_path):
    t = _tree()
    for s in [1, 2, 3, 4]:
        save_checkpoint(str(tmp_path), s, t)
    cleanup_old(str(tmp_path), keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore device_puts with the restoring mesh's shardings — the same
    path covers scale-up/down (elastic)."""
    from jax.sharding import PartitionSpec as P
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, t, mesh=None)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    r, _ = restore_checkpoint(str(tmp_path), t, mesh=mesh,
                              pspecs={"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(t["w"]))
    assert r["w"].sharding.spec == P("data", None)


def test_resilient_runner_recovers_from_injected_failures(tmp_path):
    """Steps fail at injected points; the runner restores the latest
    checkpoint and replays to completion with identical final state."""
    saves = {}

    def save_fn(step, state):
        saves[step] = state

    def restore_fn():
        step = max(saves)
        return step, saves[step]

    fail_at = {7, 13}
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if state + 1 in fail_at and calls["n"] not in getattr(
                step_fn, "_recovered", set()):
            fail_at.discard(state + 1)      # fail once per point
            raise RuntimeError("injected chip failure")
        return state + 1, {"loss": float(batch)}

    runner = ResilientRunner(step_fn, save_fn, restore_fn,
                             RetryPolicy(max_restarts=5),
                             checkpoint_every=5)
    save_fn(0, 0)
    state, step, _ = runner.run(0, 0, 20, get_batch=lambda s: s)
    assert state == 20 and step == 20
    assert runner.failures_seen == 2


def test_resilient_runner_gives_up_after_max_restarts():
    def step_fn(state, batch):
        raise RuntimeError("hard failure")

    runner = ResilientRunner(step_fn, lambda s, st: None, lambda: (0, 0),
                             RetryPolicy(max_restarts=2))
    with pytest.raises(RuntimeError):
        runner.run(0, 0, 5, get_batch=lambda s: s)
    assert runner.failures_seen == 3


def test_straggler_watchdog_redispatches():
    wd = StragglerWatchdog(factor=3.0, min_deadline_s=0.02)
    for _ in range(8):
        wd.observe(0.01)                     # healthy baseline

    def fast():
        return "ok"

    def slow():
        time.sleep(0.12)
        return "slow-result"

    results = wd.run_sharded([fast, fast, slow, fast],
                             fallback_fn=lambda i: f"backup-{i}")
    assert results == ["ok", "ok", "backup-2", "ok"]
    assert wd.redispatches == 1
