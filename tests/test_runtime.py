"""Online serving runtime: scheduler unit behaviour (flush micro-batcher
+ continuous slot loop) on virtual time, bucketed shapes, multi-tenant
routing, telemetry, and admission control (DESIGN.md §8, §12).

Every scheduler test here drives time through the injected
`VirtualClock` — no wall-clock sleeps, no timing-dependent assertions:
a deadline fires exactly when the test `advance()`s past it, and
`wait_for_waiters()` is the deterministic "the scheduler is parked on
its deadline" sync point.
"""

import threading
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np
import pytest

from repro.core import dcpe
from repro.data import synth
from repro.serving.runtime import (CollectionManager, MicroBatcher,
                                   QueueFullError, SlotLoop,
                                   TenantIsolationError, VirtualClock,
                                   batch_buckets, jit_cache_size)
from repro.serving.search_engine import SearchStats

K = 10
D = 24


def _fake_stats(nq):
    return SearchStats(latency_s=0.0, filter_dist_evals=0,
                       refine_comparisons=0, bytes_up=0, bytes_down=0,
                       n_queries=nq, backend="fake")


class FakeEngine:
    """Deterministic run_batch: ids[i] = round(Q[i, 0]) .. +k, recorded.
    The gate is the only synchronization — no sleeps anywhere."""

    def __init__(self):
        self.calls = []            # (batch_shape, k)
        self.seen_bases = []       # every request value ever computed
        self.gate = threading.Event()
        self.gate.set()

    def __call__(self, Q, T, k, ratio_k=8.0, ef_search=96):
        self.gate.wait(timeout=10.0)
        Q = np.atleast_2d(Q)
        self.calls.append((Q.shape, k))
        base = np.round(Q[:, 0]).astype(np.int64)
        self.seen_bases.extend(int(b) for b in base)
        ids = base[:, None] + np.arange(k)[None, :]
        return ids, _fake_stats(Q.shape[0])


def _req(i):
    return np.full(D, float(i), np.float32), np.zeros(2 * D + 16, np.float32)


# ------------------------------------------------------------- batcher unit


def test_batch_buckets_shapes():
    assert batch_buckets(32) == [1, 2, 4, 8, 16, 32]
    assert batch_buckets(24) == [1, 2, 4, 8, 16, 24]
    assert batch_buckets(1) == [1]


def test_coalesces_concurrent_requests_and_pads_to_bucket():
    eng = FakeEngine()
    eng.gate.clear()                       # hold the worker at the gate
    vc = VirtualClock()
    with MicroBatcher(eng, max_batch=8, max_wait_ms=40.0, clock=vc) as mb:
        futs = [mb.submit(*_req(i), K) for i in range(5)]
        vc.advance(0.041)                  # virtual deadline passes
        eng.gate.set()
        res = [f.result(timeout=10) for f in futs]
    for i, ids in enumerate(res):          # results scatter to the right
        np.testing.assert_array_equal(ids, i + np.arange(K))
    # 5 real requests ride one flush, padded to the 8-bucket
    flush_shapes = [s for s, _ in eng.calls]
    assert (8, D) in flush_shapes and len(flush_shapes) == 1


def test_full_batch_flushes_without_waiting_deadline():
    """max_batch compatible requests flush by SIZE: virtual time never
    advances, so any result proves the deadline was not involved."""
    eng = FakeEngine()
    eng.gate.clear()
    vc = VirtualClock()
    with MicroBatcher(eng, max_batch=4, max_wait_ms=10_000.0,
                      clock=vc) as mb:
        futs = [mb.submit(*_req(i), K) for i in range(4)]
        eng.gate.set()
        for f in futs:
            f.result(timeout=10)           # resolves at t=0 virtual
    assert vc.now() == 0.0
    assert eng.calls[0][0] == (4, D)


def test_deadline_flush_for_lone_request():
    """A lone request waits exactly until the virtual deadline: not
    flushed before the advance, flushed right after."""
    eng = FakeEngine()
    vc = VirtualClock()
    with MicroBatcher(eng, max_batch=32, max_wait_ms=30.0, clock=vc) as mb:
        fut = mb.submit(*_req(3), K)
        vc.wait_for_waiters(1)             # parked on the deadline
        assert not fut.done()
        vc.advance(0.029)                  # 29 ms: not yet due
        vc.wait_for_waiters(1)
        assert not fut.done()
        vc.advance(0.002)                  # past 30 ms: flush
        np.testing.assert_array_equal(fut.result(timeout=10),
                                      3 + np.arange(K))
    assert eng.calls[0][0] == (1, D)       # bucket 1, no padding waste


def test_mixed_k_requests_flush_as_separate_groups():
    eng = FakeEngine()
    eng.gate.clear()
    vc = VirtualClock()
    with MicroBatcher(eng, max_batch=8, max_wait_ms=30.0, clock=vc) as mb:
        f1 = [mb.submit(*_req(i), 5) for i in range(3)]
        f2 = [mb.submit(*_req(10 + i), 7) for i in range(3)]
        vc.advance(1.0)
        eng.gate.set()
        r1 = [f.result(timeout=10) for f in f1]
        r2 = [f.result(timeout=10) for f in f2]
    assert all(r.shape == (5,) for r in r1)
    assert all(r.shape == (7,) for r in r2)
    assert sorted(k for _, k in eng.calls) == [5, 7]


def test_backpressure_rejects_when_queue_full():
    eng = FakeEngine()
    eng.gate.clear()                       # wedge the worker
    vc = VirtualClock()
    mb = MicroBatcher(eng, max_batch=2, max_wait_ms=5.0, max_queue=3,
                      clock=vc)
    try:
        accepted = []
        with pytest.raises(QueueFullError):
            for i in range(20):
                accepted.append(mb.submit(*_req(i), K))
        assert len(accepted) >= 3          # queue capacity was usable
        eng.gate.set()
        vc.advance(1.0)
        for f in accepted:
            f.result(timeout=10)           # backlog drains after release
    finally:
        mb.close()


def test_search_timeout_discards_queued_request():
    """Regression: `search()` timing out used to leave the request
    queued — a dead future the scheduler later computed into, holding an
    admission-control slot the whole time.  The timeout must cancel the
    future AND free the queue slot."""
    eng = FakeEngine()
    eng.gate.clear()                       # worker wedges on request A
    vc = VirtualClock()
    mb = MicroBatcher(eng, max_batch=1, max_wait_ms=0.0, max_queue=2,
                      clock=vc)
    try:
        fut_a = mb.submit(*_req(1), K)     # taken by the worker (size=1)
        with pytest.raises(FutureTimeoutError):  # B stays queued behind A
            mb.search(*_req(2), K, timeout=0.05)
        # the timed-out request left the queue: both slots are free again
        with mb._cv:
            assert len(mb._pending) == 0
        fut_c = mb.submit(*_req(3), K)
        fut_d = mb.submit(*_req(4), K)     # full max_queue=2 available
        eng.gate.set()
        np.testing.assert_array_equal(fut_a.result(timeout=10),
                                      1 + np.arange(K))
        np.testing.assert_array_equal(fut_c.result(timeout=10),
                                      3 + np.arange(K))
        np.testing.assert_array_equal(fut_d.result(timeout=10),
                                      4 + np.arange(K))
        # the discarded request was never computed: only A, C, D flushed
        assert len(eng.calls) == 3
        assert 2 not in eng.seen_bases
    finally:
        mb.close()


def test_discard_after_completion_keeps_result():
    eng = FakeEngine()
    with MicroBatcher(eng, max_batch=1, max_wait_ms=0.0) as mb:
        fut = mb.submit(*_req(5), K)
        np.testing.assert_array_equal(fut.result(timeout=10),
                                      5 + np.arange(K))
        assert mb.discard(fut) is False    # too late: result stands
        np.testing.assert_array_equal(fut.result(timeout=0),
                                      5 + np.arange(K))


def test_malformed_request_cannot_doom_its_flush_or_the_scheduler():
    """A ragged request breaks its flush's batch assembly (np.stack), but
    per-request retry (DESIGN.md §16) re-runs each rider alone: the
    batchmate still gets its answer, the ragged request is answered at
    its own shape, and the worker thread keeps serving later requests."""
    eng = FakeEngine()
    eng.gate.clear()
    vc = VirtualClock()
    with MicroBatcher(eng, max_batch=8, max_wait_ms=20.0, clock=vc) as mb:
        good1 = mb.submit(*_req(1), K)
        bad = mb.submit(np.zeros(D + 3, np.float32),
                        np.zeros(2 * D + 16, np.float32), K)  # ragged Q
        vc.advance(0.021)
        eng.gate.set()
        np.testing.assert_array_equal(good1.result(timeout=10),
                                      1 + np.arange(K))   # batchmate survives
        np.testing.assert_array_equal(bad.result(timeout=10),
                                      0 + np.arange(K))   # solo, own shape
        solo_shapes = [s for s, _ in eng.calls]
        assert (1, D) in solo_shapes and (1, D + 3) in solo_shapes
        good2 = mb.submit(*_req(2), K)           # scheduler still alive
        vc.advance(0.021)
        np.testing.assert_array_equal(good2.result(timeout=10),
                                      2 + np.arange(K))


def test_cancelled_future_does_not_kill_scheduler():
    """A client cancelling its pending future must not crash the flush
    or the scheduler thread (InvalidStateError race regression)."""
    eng = FakeEngine()
    eng.gate.clear()
    vc = VirtualClock()
    with MicroBatcher(eng, max_batch=4, max_wait_ms=10.0, clock=vc) as mb:
        f1 = mb.submit(*_req(1), K)
        f2 = mb.submit(*_req(2), K)
        assert f1.cancel()                     # still pending: cancellable
        vc.advance(0.011)
        eng.gate.set()
        np.testing.assert_array_equal(f2.result(timeout=10),
                                      2 + np.arange(K))
        f3 = mb.submit(*_req(3), K)            # scheduler still alive
        vc.advance(0.011)
        np.testing.assert_array_equal(f3.result(timeout=10),
                                      3 + np.arange(K))


def test_engine_exception_propagates_to_futures():
    def boom(Q, T, k, **kw):
        raise RuntimeError("engine down")

    with MicroBatcher(boom, max_batch=1, max_wait_ms=5.0) as mb:
        fut = mb.submit(*_req(0), K)           # size-1 flush: no deadline
        with pytest.raises(RuntimeError, match="engine down"):
            fut.result(timeout=10)


def test_close_drains_pending_then_rejects():
    eng = FakeEngine()
    eng.gate.clear()                           # hold the first flush
    vc = VirtualClock()
    mb = MicroBatcher(eng, max_batch=4, max_wait_ms=2.0, clock=vc)
    futs = [mb.submit(*_req(i), K) for i in range(6)]
    eng.gate.set()
    mb.close()                                 # close drains, no deadline
    for f in futs:
        assert f.result(timeout=10) is not None
    with pytest.raises(RuntimeError):
        mb.submit(*_req(0), K)


# --------------------------------------------------- slot loop (continuous)


def test_slot_loop_serves_lone_request_with_no_deadline():
    """The continuous scheduler's whole point: a lone arrival is served
    immediately — virtual time stays at 0, nothing waits on a clock."""
    eng = FakeEngine()
    vc = VirtualClock()
    with SlotLoop(eng, max_batch=8, clock=vc) as sl:
        fut = sl.submit(*_req(3), K)
        np.testing.assert_array_equal(fut.result(timeout=10),
                                      3 + np.arange(K))
    assert vc.now() == 0.0
    assert eng.calls[0][0] == (8, D)           # the one table shape


def test_slot_loop_runs_one_shape_only():
    """Every step — lone request or full table — runs the (max_batch, d)
    slot-table shape: one executable, zero recompiles by construction."""
    eng = FakeEngine()
    eng.gate.clear()
    with SlotLoop(eng, max_batch=4, clock=VirtualClock()) as sl:
        futs = [sl.submit(*_req(i), K) for i in range(7)]
        eng.gate.set()
        for i, f in enumerate(futs):
            np.testing.assert_array_equal(f.result(timeout=10),
                                          i + np.arange(K))
    assert all(shape == (4, D) for shape, _ in eng.calls)
    assert len(eng.calls) >= 2                 # 7 requests > one table


def test_slot_loop_inserts_into_free_slots_and_emits():
    """Requests admitted while the table is partly full land in free
    rows; emitted rows free their slots for the next step."""
    eng = FakeEngine()
    eng.gate.clear()
    with SlotLoop(eng, max_batch=2, clock=VirtualClock()) as sl:
        futs = [sl.submit(*_req(i), K) for i in range(5)]
        eng.gate.set()
        res = [f.result(timeout=10) for f in futs]
        assert sl.n_active == 0                # all slots freed
    for i, ids in enumerate(res):
        np.testing.assert_array_equal(ids, i + np.arange(K))


def test_slot_loop_mixed_groups_step_separately():
    eng = FakeEngine()
    eng.gate.clear()
    with SlotLoop(eng, max_batch=8, clock=VirtualClock()) as sl:
        f1 = [sl.submit(*_req(i), 5) for i in range(3)]
        f2 = [sl.submit(*_req(10 + i), 7) for i in range(3)]
        eng.gate.set()
        r1 = [f.result(timeout=10) for f in f1]
        r2 = [f.result(timeout=10) for f in f2]
    assert all(r.shape == (5,) for r in r1)
    assert all(r.shape == (7,) for r in r2)
    assert sorted(set(k for _, k in eng.calls)) == [5, 7]


def test_slot_loop_backpressure_and_close():
    eng = FakeEngine()
    eng.gate.clear()
    sl = SlotLoop(eng, max_batch=2, max_queue=3, clock=VirtualClock())
    try:
        accepted = []
        with pytest.raises(QueueFullError):
            for i in range(20):
                accepted.append(sl.submit(*_req(i), K))
        assert len(accepted) >= 3
        eng.gate.set()
        for f in accepted:
            f.result(timeout=10)
    finally:
        sl.close()
    with pytest.raises(RuntimeError):
        sl.submit(*_req(0), K)


def test_slot_loop_telemetry_occupancy_and_sojourn():
    from repro.serving.runtime import CollectionTelemetry
    eng = FakeEngine()
    eng.gate.clear()
    tel = CollectionTelemetry()
    with SlotLoop(eng, max_batch=4, telemetry=tel,
                  clock=VirtualClock()) as sl:
        futs = [sl.submit(*_req(i), K) for i in range(4)]
        eng.gate.set()
        for f in futs:
            f.result(timeout=10)
    snap = tel.snapshot()
    assert snap["n_steps"] >= 1
    assert 0.0 < snap["slot_occupancy"] <= 1.0
    assert snap["n_requests"] == 4
    assert snap["p99_insert_to_emit_s"] >= 0.0


# --------------------------------------------------------- tenancy routing


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("deep1m", n=400, n_queries=6, k_gt=20,
                              seed=7, d=D)


@pytest.fixture()
def mgr(ds):
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    with CollectionManager(sap_beta=beta, max_wait_ms=3.0) as m:
        yield m


def test_strict_tenant_routing(mgr, ds):
    mgr.create_collection("acme", "docs", D, seed=1)
    mgr.create_collection("globex", "docs", D, seed=2)
    mgr.insert("acme", "docs", ds.base[:100])
    mgr.insert("globex", "docs", ds.base[100:200])
    # wrong tenant for an existing collection name -> isolation error
    with pytest.raises(TenantIsolationError):
        mgr.collection("initech", "docs")
    # unknown name raises the *same* error: "owned by someone else" and
    # "nonexistent" must be indistinguishable (no enumeration oracle)
    with pytest.raises(TenantIsolationError) as e_other:
        mgr.collection("initech", "docs")
    with pytest.raises(TenantIsolationError) as e_none:
        mgr.collection("initech", "no-such-thing")
    assert type(e_other.value) is type(e_none.value)
    assert isinstance(e_none.value, KeyError)      # still a lookup error
    # per-tenant keys differ: same name, independent crypto
    ka = mgr.collection("acme", "docs").owner.keys.dce_key.M3
    kg = mgr.collection("globex", "docs").owner.keys.dce_key.M3
    assert not np.allclose(ka, kg)
    # duplicate create rejected
    with pytest.raises(ValueError):
        mgr.create_collection("acme", "docs", D)


def test_default_seeds_yield_distinct_tenant_keys(mgr):
    """Two tenants that never pass a seed must still get different key
    material (regression: a shared default seed made keys collide)."""
    a = mgr.create_collection("t-a", "c", D)
    b = mgr.create_collection("t-b", "c", D)
    assert not np.allclose(a.owner.keys.dce_key.M3, b.owner.keys.dce_key.M3)


def test_unknown_scheduler_rejected(mgr):
    with pytest.raises(ValueError, match="unknown scheduler"):
        mgr.create_collection("acme", "bad-sched", D, scheduler="nope")


def test_submit_rejects_wrong_dimension_query(mgr, ds):
    col = mgr.create_collection("acme", "dims", D)
    col.insert(ds.base[:50])
    with pytest.raises(ValueError, match="query shapes"):
        col.submit(np.zeros(D + 1, np.float32),
                   np.zeros(2 * D + 16, np.float32), K)
    with pytest.raises(ValueError, match="query shapes"):
        col.submit(np.zeros(D, np.float32), np.zeros(7, np.float32), K)


def test_store_append_rejects_row_count_mismatch(mgr, ds):
    col = mgr.create_collection("acme", "wire", D)
    C_sap, C_dce = col.owner.encrypt_vectors(ds.base[:3])
    with pytest.raises(ValueError, match="ciphertext shapes"):
        col.insert_encrypted(C_sap, C_dce[:1])   # truncated wire payload
    col.insert_encrypted(C_sap, C_dce)           # matched payload is fine
    assert col.store.n_total == 3


def test_cross_tenant_trapdoors_never_touch_other_store(mgr, ds):
    """Routing is by (tenant, collection): tenant B's search runs only on
    B's ciphertexts even when A's collection shares the name."""
    a = mgr.create_collection("acme", "docs", D, seed=1)
    b = mgr.create_collection("globex", "docs", D, seed=2)
    a.insert(ds.base[:200])
    b.insert(ds.base[200:250])
    qa = a.new_user().encrypt_query(ds.queries[0])
    ids = mgr.search("acme", "docs", *qa, K, ef_search=96)
    assert (ids[ids >= 0] < 200).all()          # rows of A's store only
    ids_b = mgr.search("globex", "docs", *qa, K)   # wrong keys: garbage,
    assert ids_b.shape == (K,)                     # but never A's data


def test_empty_collection_returns_sentinels(mgr):
    mgr.create_collection("acme", "fresh", D)
    q, t = _req(0)
    ids = mgr.search("acme", "fresh", q, t, K)
    assert (ids == -1).all()


def test_empty_collection_continuous_returns_sentinels(mgr):
    mgr.create_collection("acme", "fresh-slot", D, scheduler="continuous")
    q, t = _req(0)
    ids = mgr.search("acme", "fresh-slot", q, t, K)
    assert (ids == -1).all()


def test_drop_collection(mgr, ds):
    mgr.create_collection("acme", "tmp", D)
    mgr.drop_collection("acme", "tmp")
    with pytest.raises(KeyError):
        mgr.collection("acme", "tmp")


# ------------------------------------------------- end-to-end + telemetry


def test_concurrent_clients_results_match_direct_engine(mgr, ds):
    col = mgr.create_collection("acme", "main", D, seed=3,
                                max_wait_ms=20.0, verify_parity=True)
    col.insert(ds.base)
    user = col.new_user()
    enc = [user.encrypt_query(q) for q in ds.queries]
    futs = [col.submit(c, t, K, ef_search=96) for c, t in enc]
    via_batcher = np.stack([f.result(timeout=30) for f in futs])
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    direct, _ = col.search_batch(Q, T, K, ef_search=96)
    np.testing.assert_array_equal(via_batcher, direct)
    snap = col.stats()
    assert snap["n_requests"] == len(enc)
    assert snap["batch_occupancy"] > 1.0        # coalescing happened
    assert snap["p99_latency_s"] >= snap["p50_latency_s"] > 0
    assert snap["n_alive"] == ds.n
    assert synth.recall_at_k(via_batcher, ds.gt, K) >= 0.8


def test_continuous_collection_matches_direct_engine(mgr, ds):
    """The slot loop through the full Collection path: parity-verified
    per slot against the engine, occupancy + sojourn telemetry."""
    col = mgr.create_collection("acme", "slot-main", D, seed=3,
                                scheduler="continuous", max_batch=8,
                                verify_parity=True)
    col.insert(ds.base)
    col.compact()
    user = col.new_user()
    enc = [user.encrypt_query(q) for q in ds.queries]
    futs = [col.submit(c, t, K, ef_search=96) for c, t in enc]
    via_slots = np.stack([f.result(timeout=30) for f in futs])
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    direct, _ = col.search_batch(Q, T, K, ef_search=96)
    np.testing.assert_array_equal(via_slots, direct)
    snap = col.stats()
    assert snap["scheduler"] == "continuous"
    assert snap["n_steps"] >= 1
    assert snap["slot_occupancy"] > 0.0
    assert synth.recall_at_k(via_slots, ds.gt, K) >= 0.8


def test_zero_recompiles_across_bucketed_batch_sizes(mgr, ds):
    """After warmup over the bucketed shapes, traffic at every batch size
    hits only cached executables (the acceptance criterion)."""
    col = mgr.create_collection("acme", "warm", D, seed=4, max_batch=8,
                                max_wait_ms=1.0)
    col.insert(ds.base)
    col.compact()
    col.warmup(K, ratio_k=8.0, ef_search=96)
    user = col.new_user()
    enc = [user.encrypt_query(q) for q in ds.queries]
    before = jit_cache_size()
    for B in (1, 2, 3, 5, 6, 4, 1):            # ragged arrival patterns
        Q = np.stack([enc[i % len(enc)][0] for i in range(B)])
        T = np.stack([enc[i % len(enc)][1] for i in range(B)])
        from repro.kernels.common import next_bucket
        b = next_bucket(B, maximum=8)
        Qp = np.concatenate([Q, np.repeat(Q[:1], b - B, 0)])
        Tp = np.concatenate([T, np.repeat(T[:1], b - B, 0)])
        col.search_batch(Qp, Tp, K, ratio_k=8.0, ef_search=96)
    assert jit_cache_size() == before
    # live ingestion: the first delta compiles its bucketed shapes once;
    # further insert bursts inside the same capacity bucket must not —
    # the refine sees the padded-capacity C_dce view, not raw n_total
    col.insert(ds.base[:4])
    q0, t0 = enc[0]
    col.search_batch(q0[None], t0[None], K, ratio_k=8.0, ef_search=96)
    settled = jit_cache_size()
    for _ in range(3):
        col.insert(ds.base[:4])
        col.search_batch(q0[None], t0[None], K, ratio_k=8.0, ef_search=96)
    assert jit_cache_size() == settled


def test_slot_loop_zero_recompiles_after_single_warmup(mgr, ds):
    """The continuous scheduler's compile story: ONE warmup step, then
    ragged arrival patterns all hit the one (max_batch, d) executable."""
    col = mgr.create_collection("acme", "slot-warm", D, seed=4,
                                scheduler="continuous", max_batch=8)
    col.insert(ds.base)
    col.compact()
    col.warmup(K, ratio_k=8.0, ef_search=96)   # one full-table step
    user = col.new_user()
    enc = [user.encrypt_query(q) for q in ds.queries]
    before = jit_cache_size()
    for burst in (1, 5, 2, 6, 1, 3):           # ragged arrival patterns
        futs = [col.submit(*enc[i % len(enc)], K, ef_search=96)
                for i in range(burst)]
        for f in futs:
            f.result(timeout=30)
    assert jit_cache_size() == before          # zero steady-state compiles


def test_telemetry_counts_rejects(ds):
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    col = None
    try:
        from repro.serving.runtime import Collection
        vc = VirtualClock()
        col = Collection("t", "c", D, sap_beta=beta, max_queue=1,
                         max_wait_ms=200.0, clock=vc)
        col.insert(ds.base[:50])
        user = col.new_user()
        q, t = user.encrypt_query(ds.queries[0])
        # the request sits in the queue until the (virtual) deadline, so
        # with max_queue=1 the second submit is shed deterministically
        fut = col.submit(q, t, K)
        with pytest.raises(QueueFullError):
            col.submit(q, t, K)
        vc.advance(0.21)                       # fire the deadline flush
        assert fut.result(timeout=30) is not None
        assert col.telemetry.snapshot()["n_rejected"] == 1
    finally:
        if col is not None:
            col.close()
