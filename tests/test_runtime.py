"""Online serving runtime: micro-batcher scheduling, bucketed shapes,
multi-tenant routing, telemetry, and admission control (DESIGN.md §8)."""

import threading
import time

import numpy as np
import pytest

from repro.core import dcpe
from repro.data import synth
from repro.serving.runtime import (CollectionManager, MicroBatcher,
                                   QueueFullError, TenantIsolationError,
                                   batch_buckets, jit_cache_size)
from repro.serving.search_engine import SearchStats

K = 10
D = 24


def _fake_stats(nq):
    return SearchStats(latency_s=0.0, filter_dist_evals=0,
                       refine_comparisons=0, bytes_up=0, bytes_down=0,
                       n_queries=nq, backend="fake")


class FakeEngine:
    """Deterministic run_batch: ids[i] = round(Q[i, 0]) .. +k, recorded."""

    def __init__(self, delay_s: float = 0.0):
        self.calls = []            # (batch_shape, k)
        self.delay_s = delay_s
        self.gate = threading.Event()
        self.gate.set()

    def __call__(self, Q, T, k, ratio_k=8.0, ef_search=96):
        self.gate.wait(timeout=10.0)
        if self.delay_s:
            time.sleep(self.delay_s)
        Q = np.atleast_2d(Q)
        self.calls.append((Q.shape, k))
        base = np.round(Q[:, 0]).astype(np.int64)
        ids = base[:, None] + np.arange(k)[None, :]
        return ids, _fake_stats(Q.shape[0])


def _req(i):
    return np.full(D, float(i), np.float32), np.zeros(2 * D + 16, np.float32)


# ------------------------------------------------------------- batcher unit


def test_batch_buckets_shapes():
    assert batch_buckets(32) == [1, 2, 4, 8, 16, 32]
    assert batch_buckets(24) == [1, 2, 4, 8, 16, 24]
    assert batch_buckets(1) == [1]


def test_coalesces_concurrent_requests_and_pads_to_bucket():
    eng = FakeEngine()
    eng.gate.clear()                       # hold the worker at the gate
    with MicroBatcher(eng, max_batch=8, max_wait_ms=40.0) as mb:
        futs = [mb.submit(*_req(i), K) for i in range(5)]
        eng.gate.set()
        res = [f.result(timeout=10) for f in futs]
    for i, ids in enumerate(res):          # results scatter to the right
        np.testing.assert_array_equal(ids, i + np.arange(K))
    # 5 real requests ride one flush, padded to the 8-bucket
    flush_shapes = [s for s, _ in eng.calls]
    assert (8, D) in flush_shapes and len(flush_shapes) == 1


def test_full_batch_flushes_without_waiting_deadline():
    eng = FakeEngine()
    eng.gate.clear()
    with MicroBatcher(eng, max_batch=4, max_wait_ms=10_000.0) as mb:
        futs = [mb.submit(*_req(i), K) for i in range(4)]
        eng.gate.set()
        t0 = time.monotonic()
        for f in futs:
            f.result(timeout=10)
        assert time.monotonic() - t0 < 5.0     # did not sit out 10 s
    assert eng.calls[0][0] == (4, D)


def test_deadline_flush_for_lone_request():
    eng = FakeEngine()
    with MicroBatcher(eng, max_batch=32, max_wait_ms=30.0) as mb:
        ids = mb.search(*_req(3), K, timeout=10)
    np.testing.assert_array_equal(ids, 3 + np.arange(K))
    assert eng.calls[0][0] == (1, D)           # bucket 1, no padding waste


def test_mixed_k_requests_flush_as_separate_groups():
    eng = FakeEngine()
    eng.gate.clear()
    with MicroBatcher(eng, max_batch=8, max_wait_ms=30.0) as mb:
        f1 = [mb.submit(*_req(i), 5) for i in range(3)]
        f2 = [mb.submit(*_req(10 + i), 7) for i in range(3)]
        eng.gate.set()
        r1 = [f.result(timeout=10) for f in f1]
        r2 = [f.result(timeout=10) for f in f2]
    assert all(r.shape == (5,) for r in r1)
    assert all(r.shape == (7,) for r in r2)
    assert sorted(k for _, k in eng.calls) == [5, 7]


def test_backpressure_rejects_when_queue_full():
    eng = FakeEngine()
    eng.gate.clear()                       # wedge the worker
    mb = MicroBatcher(eng, max_batch=2, max_wait_ms=5.0, max_queue=3)
    try:
        accepted = []
        with pytest.raises(QueueFullError):
            for i in range(20):
                accepted.append(mb.submit(*_req(i), K))
        assert len(accepted) >= 3          # queue capacity was usable
        eng.gate.set()
        for f in accepted:
            f.result(timeout=10)           # backlog drains after release
    finally:
        mb.close()


def test_malformed_request_fails_its_flush_not_the_scheduler():
    """A bad request's flush errors onto its futures; the worker thread
    survives and keeps serving later requests (liveness regression)."""
    eng = FakeEngine()
    eng.gate.clear()
    with MicroBatcher(eng, max_batch=8, max_wait_ms=20.0) as mb:
        good1 = mb.submit(*_req(1), K)
        bad = mb.submit(np.zeros(D + 3, np.float32),
                        np.zeros(2 * D + 16, np.float32), K)  # ragged Q
        eng.gate.set()
        with pytest.raises(ValueError):          # np.stack shape mismatch
            bad.result(timeout=10)
        with pytest.raises(ValueError):
            good1.result(timeout=10)             # same doomed flush
        good2 = mb.submit(*_req(2), K)           # scheduler still alive
        np.testing.assert_array_equal(good2.result(timeout=10),
                                      2 + np.arange(K))


def test_cancelled_future_does_not_kill_scheduler():
    """A client cancelling its pending future must not crash the flush
    or the scheduler thread (InvalidStateError race regression)."""
    eng = FakeEngine()
    eng.gate.clear()
    with MicroBatcher(eng, max_batch=4, max_wait_ms=10.0) as mb:
        f1 = mb.submit(*_req(1), K)
        f2 = mb.submit(*_req(2), K)
        assert f1.cancel()                     # still pending: cancellable
        eng.gate.set()
        np.testing.assert_array_equal(f2.result(timeout=10),
                                      2 + np.arange(K))
        f3 = mb.submit(*_req(3), K)            # scheduler still alive
        np.testing.assert_array_equal(f3.result(timeout=10),
                                      3 + np.arange(K))


def test_engine_exception_propagates_to_futures():
    def boom(Q, T, k, **kw):
        raise RuntimeError("engine down")

    with MicroBatcher(boom, max_batch=4, max_wait_ms=5.0) as mb:
        fut = mb.submit(*_req(0), K)
        with pytest.raises(RuntimeError, match="engine down"):
            fut.result(timeout=10)


def test_close_drains_pending_then_rejects():
    eng = FakeEngine(delay_s=0.01)
    mb = MicroBatcher(eng, max_batch=4, max_wait_ms=2.0)
    futs = [mb.submit(*_req(i), K) for i in range(6)]
    mb.close()
    for f in futs:
        assert f.result(timeout=10) is not None
    with pytest.raises(RuntimeError):
        mb.submit(*_req(0), K)


# --------------------------------------------------------- tenancy routing


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("deep1m", n=400, n_queries=6, k_gt=20,
                              seed=7, d=D)


@pytest.fixture()
def mgr(ds):
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    with CollectionManager(sap_beta=beta, max_wait_ms=3.0) as m:
        yield m


def test_strict_tenant_routing(mgr, ds):
    mgr.create_collection("acme", "docs", D, seed=1)
    mgr.create_collection("globex", "docs", D, seed=2)
    mgr.insert("acme", "docs", ds.base[:100])
    mgr.insert("globex", "docs", ds.base[100:200])
    # wrong tenant for an existing collection name -> isolation error
    with pytest.raises(TenantIsolationError):
        mgr.collection("initech", "docs")
    # unknown name raises the *same* error: "owned by someone else" and
    # "nonexistent" must be indistinguishable (no enumeration oracle)
    with pytest.raises(TenantIsolationError) as e_other:
        mgr.collection("initech", "docs")
    with pytest.raises(TenantIsolationError) as e_none:
        mgr.collection("initech", "no-such-thing")
    assert type(e_other.value) is type(e_none.value)
    assert isinstance(e_none.value, KeyError)      # still a lookup error
    # per-tenant keys differ: same name, independent crypto
    ka = mgr.collection("acme", "docs").owner.keys.dce_key.M3
    kg = mgr.collection("globex", "docs").owner.keys.dce_key.M3
    assert not np.allclose(ka, kg)
    # duplicate create rejected
    with pytest.raises(ValueError):
        mgr.create_collection("acme", "docs", D)


def test_default_seeds_yield_distinct_tenant_keys(mgr):
    """Two tenants that never pass a seed must still get different key
    material (regression: a shared default seed made keys collide)."""
    a = mgr.create_collection("t-a", "c", D)
    b = mgr.create_collection("t-b", "c", D)
    assert not np.allclose(a.owner.keys.dce_key.M3, b.owner.keys.dce_key.M3)


def test_submit_rejects_wrong_dimension_query(mgr, ds):
    col = mgr.create_collection("acme", "dims", D)
    col.insert(ds.base[:50])
    with pytest.raises(ValueError, match="query shapes"):
        col.submit(np.zeros(D + 1, np.float32),
                   np.zeros(2 * D + 16, np.float32), K)
    with pytest.raises(ValueError, match="query shapes"):
        col.submit(np.zeros(D, np.float32), np.zeros(7, np.float32), K)


def test_store_append_rejects_row_count_mismatch(mgr, ds):
    col = mgr.create_collection("acme", "wire", D)
    C_sap, C_dce = col.owner.encrypt_vectors(ds.base[:3])
    with pytest.raises(ValueError, match="ciphertext shapes"):
        col.insert_encrypted(C_sap, C_dce[:1])   # truncated wire payload
    col.insert_encrypted(C_sap, C_dce)           # matched payload is fine
    assert col.store.n_total == 3


def test_cross_tenant_trapdoors_never_touch_other_store(mgr, ds):
    """Routing is by (tenant, collection): tenant B's search runs only on
    B's ciphertexts even when A's collection shares the name."""
    a = mgr.create_collection("acme", "docs", D, seed=1)
    b = mgr.create_collection("globex", "docs", D, seed=2)
    a.insert(ds.base[:200])
    b.insert(ds.base[200:250])
    qa = a.new_user().encrypt_query(ds.queries[0])
    ids = mgr.search("acme", "docs", *qa, K, ef_search=96)
    assert (ids[ids >= 0] < 200).all()          # rows of A's store only
    ids_b = mgr.search("globex", "docs", *qa, K)   # wrong keys: garbage,
    assert ids_b.shape == (K,)                     # but never A's data


def test_empty_collection_returns_sentinels(mgr):
    mgr.create_collection("acme", "fresh", D)
    q, t = _req(0)
    ids = mgr.search("acme", "fresh", q, t, K)
    assert (ids == -1).all()


def test_drop_collection(mgr, ds):
    mgr.create_collection("acme", "tmp", D)
    mgr.drop_collection("acme", "tmp")
    with pytest.raises(KeyError):
        mgr.collection("acme", "tmp")


# ------------------------------------------------- end-to-end + telemetry


def test_concurrent_clients_results_match_direct_engine(mgr, ds):
    col = mgr.create_collection("acme", "main", D, seed=3,
                                max_wait_ms=20.0, verify_parity=True)
    col.insert(ds.base)
    user = col.new_user()
    enc = [user.encrypt_query(q) for q in ds.queries]
    futs = [col.submit(c, t, K, ef_search=96) for c, t in enc]
    via_batcher = np.stack([f.result(timeout=30) for f in futs])
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    direct, _ = col.search_batch(Q, T, K, ef_search=96)
    np.testing.assert_array_equal(via_batcher, direct)
    snap = col.stats()
    assert snap["n_requests"] == len(enc)
    assert snap["batch_occupancy"] > 1.0        # coalescing happened
    assert snap["p99_latency_s"] >= snap["p50_latency_s"] > 0
    assert snap["n_alive"] == ds.n
    assert synth.recall_at_k(via_batcher, ds.gt, K) >= 0.8


def test_zero_recompiles_across_bucketed_batch_sizes(mgr, ds):
    """After warmup over the bucketed shapes, traffic at every batch size
    hits only cached executables (the acceptance criterion)."""
    col = mgr.create_collection("acme", "warm", D, seed=4, max_batch=8,
                                max_wait_ms=1.0)
    col.insert(ds.base)
    col.compact()
    col.warmup(K, ratio_k=8.0, ef_search=96)
    user = col.new_user()
    enc = [user.encrypt_query(q) for q in ds.queries]
    before = jit_cache_size()
    for B in (1, 2, 3, 5, 6, 4, 1):            # ragged arrival patterns
        Q = np.stack([enc[i % len(enc)][0] for i in range(B)])
        T = np.stack([enc[i % len(enc)][1] for i in range(B)])
        from repro.kernels.common import next_bucket
        b = next_bucket(B, maximum=8)
        Qp = np.concatenate([Q, np.repeat(Q[:1], b - B, 0)])
        Tp = np.concatenate([T, np.repeat(T[:1], b - B, 0)])
        col.search_batch(Qp, Tp, K, ratio_k=8.0, ef_search=96)
    assert jit_cache_size() == before
    # live ingestion: the first delta compiles its bucketed shapes once;
    # further insert bursts inside the same capacity bucket must not —
    # the refine sees the padded-capacity C_dce view, not raw n_total
    col.insert(ds.base[:4])
    q0, t0 = enc[0]
    col.search_batch(q0[None], t0[None], K, ratio_k=8.0, ef_search=96)
    settled = jit_cache_size()
    for _ in range(3):
        col.insert(ds.base[:4])
        col.search_batch(q0[None], t0[None], K, ratio_k=8.0, ef_search=96)
    assert jit_cache_size() == settled


def test_telemetry_counts_rejects(ds):
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    col = None
    try:
        from repro.serving.runtime import Collection
        col = Collection("t", "c", D, sap_beta=beta, max_queue=1,
                         max_wait_ms=200.0)
        col.insert(ds.base[:50])
        user = col.new_user()
        q, t = user.encrypt_query(ds.queries[0])
        # requests sit in the queue during the deadline wait, so with
        # max_queue=1 the second concurrent submit is shed immediately
        fut = col.submit(q, t, K)
        with pytest.raises(QueueFullError):
            col.submit(q, t, K)
        assert fut.result(timeout=30) is not None
        assert col.telemetry.snapshot()["n_rejected"] == 1
    finally:
        if col is not None:
            col.close()
