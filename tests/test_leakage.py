"""Leakage measurement harness (repro.sec.leakage, DESIGN.md §14).

Pins the frontier's separations at the bench replay scale (n=2048,
d=32 — below that the leaked-subset baselines get noisy): the ASPE KPA
stays broken, the DCE sign-channel attack is at chance, the
access-pattern / ADC-code attacks succeed against pooled `perf` scans
and fail against the scan-oblivious `hardened` variants.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import dce
from repro.sec import (AttackResult, access_pattern_attack,
                       adc_code_attack, aspe_kpa_attack,
                       capture_server_view, dce_kpa_attack,
                       evaluate_profile)

# the replay scale bench_attacks uses; separations are pinned here
N, D, NQ = 2048, 32, 64


@pytest.fixture(scope="module")
def perf_view():
    return capture_server_view("perf", "ivf", None, n=N, d=D, nq=NQ,
                               seed=0)


@pytest.fixture(scope="module")
def hardened_results():
    return evaluate_profile("hardened", "ivf", "int8", n=N, d=D, nq=NQ,
                            seed=0)


def test_aspe_kpa_stays_broken():
    res = aspe_kpa_attack("linear", seed=0)
    assert res.attack == "aspe-kpa-linear"
    assert res.success > 0.99
    assert res.err < 1e-6 < res.baseline
    d = res.to_dict()
    assert d["attack"] == res.attack and d["success"] == res.success


def test_server_view_shapes(perf_view):
    v = perf_view
    assert v.profile == "perf" and v.backend == "ivf"
    assert v.C_sap.shape == (N, D) and v.Q_sap.shape == (NQ, D)
    cdim = dce.ciphertext_dim(D)
    assert v.C_dce.shape == (N, 4, cdim) and v.T_q.shape == (NQ, cdim)
    assert v.touched.shape == v.first_touched.shape == (NQ, N)
    assert v.touched.dtype == v.first_touched.dtype == np.bool_
    assert v.codes_decoded is None                    # f32 cell
    # pooled scans touch a strict subset; the first-probed cell is a
    # strict subset of that
    assert 0 < v.first_touched.sum() < v.touched.sum() < NQ * N
    assert (v.first_touched <= v.touched).all()


def test_dce_sign_channel_at_chance(perf_view):
    """The gated leak is the comparison *sign* stream only — the §III
    regression attack gets nothing from it (Thm 3/4's claim, measured).
    """
    res = dce_kpa_attack(perf_view)
    assert res.attack == "dce-kpa-sign"
    assert res.success <= 0.05


def test_access_pattern_leaks_under_perf(perf_view):
    """The frontier's trade: pooled IVF scans localize queries to their
    probed cells well above the zero-leakage baseline."""
    res = access_pattern_attack(perf_view)
    assert res.attack == "access-pattern"
    assert res.success >= 0.2
    assert 0 < res.err < res.baseline


def test_adc_attack_needs_quantized_cell(perf_view):
    with pytest.raises(ValueError, match="quantiz"):
        adc_code_attack(perf_view)


def test_hardened_at_chance_on_every_attack(hardened_results):
    assert [r.attack for r in hardened_results] == [
        "dce-kpa-sign", "access-pattern", "adc-code-pattern"]
    for r in hardened_results:
        assert isinstance(r, AttackResult)
        assert r.profile == "hardened" and r.backend == "ivf+int8"
        assert r.success <= 0.05, r


def test_oblivious_view_touches_everything():
    v = capture_server_view("hardened", "ivf", None, n=256, d=16, nq=4,
                            seed=0)
    # full-bucket scans: every resident row touched, no first-probed
    # ordering observable
    assert v.touched.all() and v.first_touched.all()


# ------------------------------------------------- graph backend (§15)


@pytest.fixture(scope="module")
def graph_views():
    return {p: capture_server_view(p, "graph", None, n=N, d=D, nq=NQ,
                                   seed=0) for p in ("perf", "hardened")}


def test_graph_scan_trace_is_the_access_pattern(graph_views):
    """The graph backend's view comes from the traversal's visited
    bitmap, not the IVF posting-list replay: a strict-subset,
    data-dependent trace at BOTH tiers (the bounded-hop `hardened`
    variant fixes hop/edge COUNTS, not gather ADDRESSES)."""
    for v in graph_views.values():
        assert v.touched.shape == (NQ, N)
        assert 0 < v.touched.sum() < NQ * N
        # one undifferentiated frontier stream: no order refinement
        np.testing.assert_array_equal(v.touched, v.first_touched)


def test_graph_perf_leaks_access_pattern(graph_views):
    res = access_pattern_attack(graph_views["perf"])
    assert res.backend == "graph"
    assert res.success >= 0.15
    assert 0 < res.err < res.baseline


def test_graph_hardened_is_the_intermediate_tier(graph_views):
    """The pinned frontier row: hardened-graph does NOT collapse to the
    zero-leakage baseline (unlike hardened-ivf's full-bucket scan) —
    the residual address stream keeps the localization attack alive.
    That is the leakage price of the bounded-hop tier, stated in
    DESIGN.md §15 and measured here."""
    res = access_pattern_attack(graph_views["hardened"])
    assert res.success > 0.05          # NOT at chance: intermediate tier
    # the sign channel stays at chance regardless of the scan shape
    assert dce_kpa_attack(graph_views["hardened"]).success <= 0.05
