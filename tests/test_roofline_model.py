"""Validate the analytic roofline models against XLA's own numbers in the
one regime where they are comparable: a single-layer, single-microbatch,
short-sequence config where no while-loop hides flops from
`cost_analysis()` (the layer scan still runs, but with trip count 1)."""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch import roofline
from repro.models.config import ModelConfig, ShapeConfig


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, json
    import jax
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.model import abstract_batch, batch_pspecs
    from repro.models.config import ShapeConfig
    from repro.sharding.rules import TRAIN_RULES
    from repro.training import OptConfig, abstract_train_state, \\
        build_train_step
    from repro.training.train_loop import train_state_pspecs
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"), n_layers=1, remat=False, dtype="float32")
    sc = ShapeConfig("t", "train", 512, 8)
    model = Model(cfg)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2), ("data", "model"))
    opt = OptConfig(kind="sgdm")
    step = build_train_step(model, opt, mesh, TRAIN_RULES, n_microbatches=1)
    st = abstract_train_state(model, opt)
    sspec = train_state_pspecs(model, opt, mesh, TRAIN_RULES)
    b = abstract_batch(cfg, sc)
    bspec = batch_pspecs(cfg, sc, mesh, TRAIN_RULES)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda s: isinstance(s, P))
    lowered = jax.jit(step, in_shardings=(ns(sspec), ns(bspec))).lower(st, b)
    c = lowered.compile()
    from repro.launch.dryrun import cost_analysis_dict
    flops = cost_analysis_dict(c).get("flops", -1) * 4  # per-device -> global
    print("RESULT:" + json.dumps({"hlo_flops": flops}))
""")


def test_exec_flops_matches_unhidden_hlo():
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][0]
    hlo = json.loads(line[len("RESULT:"):])["hlo_flops"]

    from repro.configs import get_config
    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"), n_layers=1, remat=False, dtype="float32")
    sc = ShapeConfig("t", "train", 512, 8)
    ana = roofline.exec_flops(cfg, sc)["total"]
    # remat=False -> 3 passes in the analytic model; HLO includes extras
    # (softmax, norms, optimizer) the model ignores — agree within 2x and
    # never under-estimate by much.
    ratio = ana / hlo
    assert 0.5 < ratio < 2.0, (ana, hlo, ratio)


def test_model_flops_definitions():
    from repro.configs import get_config
    from repro.launch.dryrun import model_flops
    from repro.models.config import SHAPES
    from repro.models import Model

    cfg = get_config("kimi-k2-1t-a32b")
    m = Model(cfg)
    # MoE: active params far below total; 6*N_active*D for train
    assert m.n_active_params() < 0.1 * m.n_params()
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    assert mf_train == pytest.approx(
        6.0 * m.n_active_params() * 256 * 4096, rel=1e-6)
    mf_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert mf_dec == pytest.approx(2.0 * m.n_active_params() * 128, rel=1e-6)


def test_roofline_terms_positive_and_dominant_valid():
    rows = roofline.table("results/dryrun", mesh_filter="1pod_256")
    if not rows:
        pytest.skip("no dry-run artifacts")
    for r in rows:
        assert r.compute_s > 0 and r.memory_s > 0 and r.collective_s >= 0
        assert r.dominant in ("compute", "memory", "collective")
        assert 0 < r.fraction_of_roofline() <= 1.0 + 1e-9, r
        if r.arch != "ppanns-scan":
            assert 0 < r.useful_ratio <= 1.0, r
