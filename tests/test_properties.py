"""Hypothesis property tests (DCE Theorem 3, DCPE Def. 3, Pallas kernels).

All hypothesis-driven sweeps live in this one module, guarded by
`pytest.importorskip`, so the deterministic tests in test_dce.py /
test_dcpe.py / test_kernels.py still run when `hypothesis` is absent
(it is a dev-only dependency; see requirements-dev.txt).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import dce, dcpe  # noqa: E402
from repro.kernels.dce_comp import ops as dce_ops  # noqa: E402
from repro.kernels.dce_comp import ref as dce_ref  # noqa: E402
from repro.kernels.l2_topk import ops as l2_ops  # noqa: E402
from repro.kernels.l2_topk import ref as l2_ref  # noqa: E402


def _exact_sq_dists(P, q):
    return ((P - q) ** 2).sum(-1)


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=0.01, max_value=100.0),
)
def test_dce_property_random_dims_and_scales(d, seed, scale):
    """Hypothesis sweep: arbitrary dims/scales/seeds preserve Theorem 3."""
    rng = np.random.default_rng(seed)
    key = dce.keygen(d, seed=seed)
    P = rng.standard_normal((12, d)) * scale
    q = rng.standard_normal((1, d)) * scale
    C = dce.encrypt(P, key, seed=seed + 1, dtype=np.float64)
    T = dce.trapgen(q, key, seed=seed + 2, dtype=np.float64)
    dist = _exact_sq_dists(P, q[0])
    Z = dce.pairwise_z_matrix(C, T[0])
    true = dist[:, None] - dist[None, :]
    rel = np.abs(true) / (np.abs(dist[:, None]) + np.abs(dist[None, :]) + 1e-30)
    ok = (np.sign(Z) == np.sign(true)) | (rel < 1e-9)
    assert ok.all()


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=2, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    beta=st.floats(min_value=0.1, max_value=8.0),
)
def test_dcpe_beta_dcp_property(d, seed, beta):
    """Def. 3: dist(o,q) < dist(p,q) - beta  =>  encrypted comparison agrees
    (metric distances; the +-s*beta/2 sandwich makes this deterministic)."""
    rng = np.random.default_rng(seed)
    key = dcpe.keygen(s=64.0, beta=beta)
    O = rng.standard_normal((30, d)) * 3
    P = rng.standard_normal((30, d)) * 3
    q = rng.standard_normal((1, d)) * 3
    C_O = dcpe.encrypt(O, key, seed=1).astype(np.float64)
    C_P = dcpe.encrypt(P, key, seed=2).astype(np.float64)
    C_q = dcpe.encrypt(q, key, seed=3).astype(np.float64)[0]
    d_o = np.linalg.norm(O - q, axis=1)
    d_p = np.linalg.norm(P - q, axis=1)
    e_o = np.linalg.norm(C_O - C_q, axis=1)
    e_p = np.linalg.norm(C_P - C_q, axis=1)
    sep = d_o < d_p - beta                      # beta-separated pairs
    assert (e_o[sep] < e_p[sep]).all()


@settings(max_examples=15, deadline=None)
@given(
    nq=st.integers(1, 40), n=st.integers(1, 200), d=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_l2_kernel_property(nq, n, d, seed):
    rng = np.random.default_rng(seed)
    Q = jnp.asarray(rng.standard_normal((nq, d)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    got = l2_ops.pairwise_sq_dists(Q, X, interpret=True)
    want = l2_ref.pairwise_sq_dists(Q, X)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def _make_cipher(n, d, seed):
    rng = np.random.default_rng(seed)
    key = dce.keygen(d, seed=seed)
    P = rng.standard_normal((n, d))
    q = rng.standard_normal((1, d))
    C = dce.encrypt(P, key, seed=seed + 1)
    T = dce.trapgen(q, key, seed=seed + 2)[0]
    return jnp.asarray(C), jnp.asarray(T)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 80), d=st.integers(2, 48),
       seed=st.integers(0, 2**31 - 1))
def test_z_matrix_property(n, d, seed):
    C, T = _make_cipher(n, d, seed=seed)
    got = dce_ops.z_matrix(C, T, interpret=True)
    want = dce_ref.z_matrix(C, T)
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-3 * float(np.abs(want).max() + 1))


# ---------------------------------------------------------------------------
# Quantized ADC filter (DESIGN.md §11).
# ---------------------------------------------------------------------------

from repro.core import adc  # noqa: E402
from repro.kernels.adc_topk import ops as adc_ops  # noqa: E402
from repro.kernels.adc_topk import ref as adc_ref  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 400), d=st.integers(4, 48),
       kp=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_sq_adc_kernel_property(n, d, kp, seed):
    """Hypothesis sweep: the fused int8 scan is bit-exact against the
    int32 oracle for arbitrary shapes/seeds."""
    rng = np.random.default_rng(seed)
    C = rng.standard_normal((n, d)).astype(np.float32) * 2.0
    Q = rng.standard_normal((3, d)).astype(np.float32) * 2.0
    cb = adc.SQCodebook.train(C)
    c8, cn = cb.encode(C)
    q8 = cb.encode_query(Q)
    dk, ik = adc_ops.sq_knn(jnp.asarray(q8), jnp.asarray(c8),
                            jnp.asarray(cn), kp, interpret=True,
                            use_kernel=True)
    dr, ir = adc_ref.sq_knn(q8, c8, cn, kp)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))


@settings(max_examples=8, deadline=None)
@given(n_clusters=st.integers(4, 12), seed=st.integers(0, 2**31 - 1),
       quant=st.sampled_from(["int8", "pq8"]))
def test_adc_filter_recall_property(n_clusters, seed, quant):
    """ADCFilter + exact refine holds recall@k >= 0.95 vs the exact
    engine on synthetic clustered data at the default refine_ratio
    (the ADC recall-oversampling model, core.adc)."""
    from repro.core import dcpe as dcpe_mod, ppanns
    from repro.serving.search_engine import SecureSearchEngine

    d, n, nq, k = 24, 800, 6, 10
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, d)) * 3.0
    base = (centers[rng.integers(0, n_clusters, n)]
            + rng.standard_normal((n, d)) * 0.2).astype(np.float32)
    queries = (centers[rng.integers(0, n_clusters, nq)]
               + rng.standard_normal((nq, d)) * 0.2).astype(np.float32)
    owner = ppanns.DataOwner(
        d=d, sap_beta=dcpe_mod.suggest_beta(base, fraction=0.03),
        sap_s=1024.0, seed=seed % 1000)
    C_sap, C_dce = owner.encrypt_vectors(base)
    user = ppanns.User(owner.share_keys(), seed=seed % 997)
    enc = [user.encrypt_query(q) for q in queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    exact = SecureSearchEngine(C_sap, C_dce, backend="flat")
    ids0, _ = exact.search_batch(Q, T, k, ratio_k=8.0)
    eng = SecureSearchEngine(C_sap, C_dce, backend="flat",
                             quantization=quant, seed=1)
    ids, _ = eng.search_batch(Q, T, k, ratio_k=8.0)
    recall = np.mean([len(set(ids0[i][ids0[i] >= 0])
                          & set(ids[i][ids[i] >= 0])) / k
                      for i in range(nq)])
    assert recall >= 0.95, (quant, recall)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 300), nq=st.integers(1, 6),
       kp=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_adc_exact_id_parity_when_unquantized(n, nq, kp, seed):
    """quantization=None must stay on the PR 4 f32 path bit-for-bit."""
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.standard_normal((n, 16)), jnp.float32)
    Q = jnp.asarray(rng.standard_normal((nq, 16)), jnp.float32)
    k = min(kp, n)
    d1, i1 = l2_ops.knn(Q, X, k, chunk=128, use_kernel=False)
    d2, i2 = l2_ref.knn(Q, X, k)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
