"""Quantized ADC filter path (DESIGN.md §11): codebooks, kernel parity,
engine recall/oversampling, runtime mutation semantics, sharded + ppcol
round trips, and the filter_bytes_scanned accounting."""

import dataclasses
import tempfile

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import adc, dcpe, ppanns
from repro.data import synth
from repro.kernels.adc_topk import ops as adc_ops
from repro.kernels.adc_topk import ref as adc_ref
from repro.kernels.l2_topk import ops as l2_ops
from repro.serving.search_engine import ADCFilter, SecureSearchEngine


def _clustered(n=1200, d=32, n_clusters=8, seed=0, spread=0.15):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, d)) * 3.0
    X = (centers[rng.integers(0, n_clusters, n)]
         + rng.standard_normal((n, d)) * spread)
    return X.astype(np.float32)


@pytest.fixture(scope="module")
def system():
    """Clustered corpus + encrypted system + batch of queries."""
    d, nq = 32, 8
    base = _clustered(n=1500, d=d, seed=0)
    queries = _clustered(n=nq, d=d, seed=1)
    beta = dcpe.suggest_beta(base, fraction=0.03)
    owner = ppanns.DataOwner(d=d, sap_beta=beta, sap_s=1024.0, seed=2)
    C_sap, C_dce = owner.encrypt_vectors(base)
    user = ppanns.User(owner.share_keys(), seed=3)
    enc = [user.encrypt_query(q) for q in queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    gt = np.asarray(l2_ops.knn(jnp.asarray(queries), jnp.asarray(base),
                               10, use_kernel=False)[1])
    return dict(base=base, C_sap=C_sap, C_dce=C_dce, Q=Q, T=T, gt=gt,
                owner=owner)


# ---------------------------------------------------------------------------
# Codebooks.
# ---------------------------------------------------------------------------

class TestCodebooks:
    def test_sq_roundtrip_error_bounded(self):
        C = _clustered()
        cb = adc.SQCodebook.train(C)
        codes, cn = cb.encode(C)
        assert codes.dtype == np.int8 and cn.dtype == np.int32
        # reconstruction error bounded by half a quantization step
        assert np.abs(cb.decode(codes) - C).max() <= cb.scale * 0.51
        np.testing.assert_array_equal(
            cn, (codes.astype(np.int64) ** 2).sum(1))

    def test_sq_arrays_roundtrip_bit_identical(self):
        cb = adc.SQCodebook.train(_clustered())
        cb2 = adc.SQCodebook.from_arrays(cb.to_arrays())
        np.testing.assert_array_equal(cb.offset, cb2.offset)
        assert cb.scale == cb2.scale and cb.trained_n == cb2.trained_n

    def test_pq_roundtrip_and_arrays(self):
        C = _clustered(d=32)
        cb = adc.PQCodebook.train(C, m=8, seed=0)
        codes = cb.encode(C)
        assert codes.shape == (C.shape[0], 8) and codes.dtype == np.uint8
        # PQ reconstruction is lossy but must beat a null model
        err = ((cb.decode(codes) - C) ** 2).sum(1).mean()
        null = ((C - C.mean(0)) ** 2).sum(1).mean()
        assert err < 0.5 * null
        cb2 = adc.PQCodebook.from_arrays(cb.to_arrays())
        np.testing.assert_array_equal(cb.centroids, cb2.centroids)
        np.testing.assert_array_equal(cb2.encode(C), codes)

    def test_pq_subspaces_divides(self):
        assert adc.pq_subspaces(128, 16) == 16
        assert adc.pq_subspaces(30, 16) == 15
        assert adc.pq_subspaces(7, 16) == 7

    def test_train_codebook_rejects_unknown(self):
        with pytest.raises(ValueError):
            adc.train_codebook(_clustered(), "int4")

    def test_default_refine_ratio(self):
        assert adc.default_refine_ratio(None) == 1.0
        assert adc.default_refine_ratio("pq8") > \
            adc.default_refine_ratio("int8") > 1.0


# ---------------------------------------------------------------------------
# Kernel parity (interpret mode vs oracle).
# ---------------------------------------------------------------------------

class TestKernelParity:
    @pytest.mark.parametrize("n,d,nq,kp", [(300, 24, 3, 20),
                                           (1000, 48, 9, 130)])
    def test_sq_kernel_exact_vs_oracle(self, n, d, nq, kp):
        rng = np.random.default_rng(n)
        C = rng.standard_normal((n, d)).astype(np.float32)
        Q = rng.standard_normal((nq, d)).astype(np.float32)
        cb = adc.SQCodebook.train(C)
        c8, cn = cb.encode(C)
        q8 = cb.encode_query(Q)
        dk, ik = adc_ops.sq_knn(jnp.asarray(q8), jnp.asarray(c8),
                                jnp.asarray(cn), kp, interpret=True,
                                use_kernel=True)
        dr, ir = adc_ref.sq_knn(q8, c8, cn, kp)
        # int32 math: the fused kernel is bit-exact against the oracle
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        # and the XLA fallback ranks identically (small d: the f32
        # surrogate stays integer-exact, kernels/adc_topk/ops.py)
        _, i_f = adc_ops.sq_knn(jnp.asarray(q8), jnp.asarray(c8),
                                jnp.asarray(cn), kp, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(ir))

    @pytest.mark.parametrize("n,d,m,kp", [(300, 24, 8, 20),
                                          (700, 32, 16, 130)])
    def test_pq_kernel_vs_oracle(self, n, d, m, kp):
        rng = np.random.default_rng(n)
        C = rng.standard_normal((n, d)).astype(np.float32)
        Q = rng.standard_normal((4, d)).astype(np.float32)
        cb = adc.PQCodebook.train(C, m=m, seed=0)
        codes_t = np.ascontiguousarray(cb.encode(C).T)
        lut = cb.lut(Q)
        dk, ik = adc_ops.pq_knn(jnp.asarray(lut), jnp.asarray(codes_t),
                                kp, interpret=True, use_kernel=True)
        dr, ir = adc_ref.pq_knn(lut, codes_t, kp)
        np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
        _, i_f = adc_ops.pq_knn(jnp.asarray(lut), jnp.asarray(codes_t),
                                kp, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(ir))

    def test_ok_mask_excludes_rows(self):
        """Invalid rows (tombstones / bucket padding) never rank ahead
        of valid ones — kernel and fallback agree."""
        rng = np.random.default_rng(7)
        C = rng.standard_normal((400, 16)).astype(np.float32)
        Q = rng.standard_normal((3, 16)).astype(np.float32)
        cb = adc.SQCodebook.train(C)
        c8, cn = cb.encode(C)
        q8 = cb.encode_query(Q)
        ok = np.ones(400, np.int32)
        ok[:150] = 0
        for use_kernel in (True, False):
            _, ids = adc_ops.sq_knn(
                jnp.asarray(q8), jnp.asarray(c8), jnp.asarray(cn), 30,
                ok=jnp.asarray(ok), interpret=True, use_kernel=use_kernel)
            assert (np.asarray(ids) >= 150).all()
        pq = adc.PQCodebook.train(C, m=8, seed=0)
        codes_t = np.ascontiguousarray(pq.encode(C).T)
        for use_kernel in (True, False):
            _, ids = adc_ops.pq_knn(
                jnp.asarray(pq.lut(Q)), jnp.asarray(codes_t), 30,
                ok=jnp.asarray(ok), interpret=True, use_kernel=use_kernel)
            assert (np.asarray(ids) >= 150).all()

    def test_exhausted_merge_emits_empty_slots_not_duplicates(self):
        """kp' beyond the valid-row count must yield -1 slots, never a
        duplicated alive id (kernel and fallback agree) — the refine
        would otherwise see one row in many candidate slots."""
        rng = np.random.default_rng(21)
        C = rng.standard_normal((40, 16)).astype(np.float32)
        Q = rng.standard_normal((2, 16)).astype(np.float32)
        cb = adc.SQCodebook.train(C)
        c8, cn = cb.encode(C)
        q8 = cb.encode_query(Q)
        ok = np.zeros(40, np.int32)
        ok[:12] = 1                         # only 12 valid rows, kp=30
        for use_kernel in (True, False):
            _, ids = adc_ops.sq_knn(
                jnp.asarray(q8), jnp.asarray(c8), jnp.asarray(cn), 30,
                ok=jnp.asarray(ok), interpret=True, use_kernel=use_kernel)
            ids = np.asarray(ids)
            assert (ids[:, :12] < 12).all() and (ids[:, :12] >= 0).all()
            assert (ids[:, 12:] == -1).all(), use_kernel
            for row in ids:                 # no duplicates among real ids
                real = row[row >= 0]
                assert len(set(real.tolist())) == real.size

    def test_pool_scans_match_dense_ranking(self):
        """Pool-scan results equal a dense oracle restricted to the
        pool."""
        rng = np.random.default_rng(9)
        C = rng.standard_normal((500, 16)).astype(np.float32)
        Q = rng.standard_normal((2, 16)).astype(np.float32)
        cb = adc.SQCodebook.train(C)
        c8, cn = cb.encode(C)
        q8 = cb.encode_query(Q)
        from repro.serving.search_engine import layout_pools
        pools = [rng.choice(500, size=200, replace=False) for _ in range(2)]
        cand, valid = layout_pools(2, pools, 15)
        ids, vout = adc_ops.sq_pool_scan(
            jnp.asarray(c8), jnp.asarray(cn), jnp.asarray(q8),
            jnp.asarray(cand), jnp.asarray(valid), 15)
        ids = np.asarray(ids)
        d_all = adc_ref.sq_dists(q8, c8, cn)
        for qi in range(2):
            pool_d = d_all[qi][pools[qi]]
            expect = pools[qi][np.argsort(pool_d, kind="stable")[:15]]
            np.testing.assert_array_equal(
                np.sort(d_all[qi][ids[qi]]), np.sort(d_all[qi][expect]))


# ---------------------------------------------------------------------------
# Engine: ADCFilter + refine.
# ---------------------------------------------------------------------------

class TestEngineADC:
    @pytest.mark.parametrize("quant", ["int8", "pq8"])
    @pytest.mark.parametrize("backend", ["flat", "ivf"])
    def test_recall_after_refine(self, system, quant, backend):
        """The acceptance recall model: ADC filter + exact refine holds
        recall@10 >= 0.95 on clustered data at the default
        refine_ratio."""
        eng = SecureSearchEngine(system["C_sap"], system["C_dce"],
                                 backend=backend, quantization=quant,
                                 seed=4)
        ids, stats = eng.search_batch(system["Q"], system["T"], 10,
                                      ratio_k=8.0)
        rec = synth.recall_at_k(np.asarray(ids), system["gt"], 10)
        assert rec >= 0.95, (quant, backend, rec)
        assert stats.backend == f"adc-{backend}-{quant}"

    def test_quantization_none_is_bit_identical(self, system):
        """quantization=None must leave the PR 4 path untouched."""
        a = SecureSearchEngine(system["C_sap"], system["C_dce"],
                               backend="flat")
        b = SecureSearchEngine(system["C_sap"], system["C_dce"],
                               backend="flat", quantization=None)
        ia, _ = a.search_batch(system["Q"], system["T"], 10)
        ib, _ = b.search_batch(system["Q"], system["T"], 10)
        np.testing.assert_array_equal(ia, ib)

    def test_bytes_scanned_shows_bandwidth_win(self, system):
        n, d = system["C_sap"].shape
        f32 = SecureSearchEngine(system["C_sap"], system["C_dce"],
                                 backend="flat")
        _, s0 = f32.search_batch(system["Q"], system["T"], 10)
        assert s0.filter_bytes_scanned == n * d * 4
        sq = SecureSearchEngine(system["C_sap"], system["C_dce"],
                                backend="flat", quantization="int8")
        _, s1 = sq.search_batch(system["Q"], system["T"], 10)
        assert s1.filter_bytes_scanned == n * (d + 4)
        pq = SecureSearchEngine(system["C_sap"], system["C_dce"],
                                backend="flat", quantization="pq8",
                                pq_m=16)
        _, s2 = pq.search_batch(system["Q"], system["T"], 10)
        assert s2.filter_bytes_scanned == n * 16
        assert s2.filter_bytes_scanned < s1.filter_bytes_scanned \
            < s0.filter_bytes_scanned

    def test_oversampling_ratio(self):
        f = ADCFilter("pq8")
        assert f.oversampled(80) == int(np.ceil(
            80 * adc.DEFAULT_REFINE_RATIO["pq8"]))
        g = ADCFilter("int8", refine_ratio=3.0)
        assert g.oversampled(10) == 30

    def test_engine_rejects_bad_combos(self, system):
        with pytest.raises(ValueError):
            SecureSearchEngine(system["C_sap"], system["C_dce"],
                               backend="hnsw", quantization="int8")
        with pytest.raises(ValueError):
            ADCFilter("int4")
        with pytest.raises(ValueError):
            ADCFilter("int8", kind="hnsw")


# ---------------------------------------------------------------------------
# Satellite: l2_topk merge rework stays exact + recompile-free.
# ---------------------------------------------------------------------------

class TestL2TopkMerge:
    def test_chunked_merge_matches_oracle(self):
        from repro.kernels.l2_topk import ref as l2_ref
        rng = np.random.default_rng(11)
        for n, chunk, k in [(999, 256, 17), (256, 256, 10), (40, 64, 50)]:
            X = rng.standard_normal((n, 24)).astype(np.float32)
            Q = rng.standard_normal((5, 24)).astype(np.float32)
            d1, i1 = l2_ops.knn(jnp.asarray(Q), jnp.asarray(X), k,
                                chunk=chunk, use_kernel=False)
            d2, i2 = l2_ref.knn(jnp.asarray(Q), jnp.asarray(X),
                                min(k, n))
            np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                       rtol=1e-5, atol=1e-4)
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    def test_no_recompile_across_repeat_calls(self):
        """jit_cache_size audit: repeated same-shape scans reuse the one
        executable (the scan-body rework must not leak recompiles)."""
        from repro.serving.runtime.telemetry import jit_cache_size
        rng = np.random.default_rng(12)
        X = jnp.asarray(rng.standard_normal((1000, 24)).astype(np.float32))
        Q = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
        l2_ops.knn(Q, X, 17, chunk=256)             # warm
        c0 = jit_cache_size()
        for _ in range(3):
            l2_ops.knn(Q, X, 17, chunk=256)
        assert jit_cache_size() == c0


# ---------------------------------------------------------------------------
# Runtime: mutation + compaction retrain + persistence.
# ---------------------------------------------------------------------------

def _service_system(n=900, d=24, nq=5, seed=0):
    from repro.api import (DataOwnerClient, IndexSpec, SearchParams,
                           SearchRequest, suggest_beta)
    base = _clustered(n=n, d=d, seed=seed)
    queries = _clustered(n=nq, d=d, seed=seed + 1)
    spec = IndexSpec(tenant="t", name="c", d=d,
                     sap_beta=suggest_beta(base, fraction=0.03),
                     seed=seed + 2)
    owner = DataOwnerClient(spec)
    C_sap, C_dce = owner.encrypt_vectors(base, seed=seed + 3)
    query = owner.query_client(seed=seed + 4).encrypt_queries(queries)
    req = lambda name: SearchRequest(                      # noqa: E731
        tenant="t", collection=name, query=query,
        params=SearchParams(k=10, ratio_k=8.0), coalesce=False)
    return spec, owner, C_sap, C_dce, req


class TestRuntimeADC:
    @pytest.mark.parametrize("quant,backend",
                             [("int8", "flat"), ("pq8", "ivf")])
    def test_mutation_semantics(self, quant, backend):
        from repro.api import SecureAnnService
        spec, owner, C_sap, C_dce, req = _service_system()
        spec = dataclasses.replace(spec, backend=backend,
                                   quantization=quant)
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            svc.insert("t", "c", C_sap, C_dce)
            r0 = svc.submit(req("c"))
            # insert: a near-duplicate of the current best must become
            # visible to the very next search
            best = int(r0.ids[0][0])
            dup_sap, dup_dce = owner.encrypt_vectors(
                np.atleast_2d(np.zeros(spec.d, np.float32)), seed=99)
            rows = svc.insert("t", "c", dup_sap, dup_dce)
            # delete: a returned id must never come back
            svc.delete("t", "c", [best])
            r1 = svc.submit(req("c"))
            assert not any(best in set(row) for row in r1.ids)
            # compact and re-check
            svc.compact("t", "c")
            r2 = svc.submit(req("c"))
            assert not any(best in set(row) for row in r2.ids)

    def test_compaction_retrains_after_doubling(self):
        from repro.api import SecureAnnService
        spec, owner, C_sap, C_dce, req = _service_system(n=300)
        spec = dataclasses.replace(spec, quantization="int8",
                                   compact_every=10 ** 9)
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            svc.insert("t", "c", C_sap, C_dce)
            svc.submit(req("c"))                   # first attach: train
            col = svc.collection("t", "c")
            cb0 = col._backend.adc_codebook
            assert cb0 is not None and cb0.trained_n == 300
            # small growth + compact: reuse (alive count < 2x)
            more = _clustered(n=30, d=spec.d, seed=9) * 5.0
            svc.insert("t", "c", *owner.encrypt_vectors(more, seed=5))
            svc.compact("t", "c")
            svc.submit(req("c"))
            assert col._backend.adc_codebook is cb0
            # double the corpus + compact: retrain (grid must follow
            # the drifted distribution)
            big = _clustered(n=600, d=spec.d, seed=10) * 5.0
            svc.insert("t", "c", *owner.encrypt_vectors(big, seed=6))
            svc.compact("t", "c")
            svc.submit(req("c"))
            cb1 = col._backend.adc_codebook
            assert cb1 is not cb0 and cb1.trained_n > cb0.trained_n

    def test_placeholder_codebook_retrains_on_first_real_rows(self):
        """Searching a fully-tombstoned quantized collection trains a
        degenerate placeholder codebook; the next attach with real rows
        must retrain it (not reuse the zero-spread grid) so recall
        recovers without waiting for a compaction."""
        from repro.api import SecureAnnService
        spec, owner, C_sap, C_dce, req = _service_system(n=200)
        spec = dataclasses.replace(spec, quantization="int8",
                                   compact_every=10 ** 9)
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            first = svc.insert("t", "c", C_sap[:4], C_dce[:4])
            svc.delete("t", "c", first)
            svc.submit(req("c"))            # attach over zero alive rows
            col = svc.collection("t", "c")
            assert col._backend.adc_codebook.trained_n == 0
            svc.insert("t", "c", C_sap[4:], C_dce[4:])
            r = svc.submit(req("c"))        # same main_gen: must retrain
            assert col._backend.adc_codebook.trained_n > 0
            exact = SecureSearchEngine(
                col.store.sap_view, col.store.dce_padded_view,
                backend="flat")
            ids0, _ = exact.search_batch(req("c").query.C_sap,
                                         req("c").query.T, 10)
            overlap = np.mean([
                len(set(a[a >= 0]) & set(b[b >= 0])) / 10
                for a, b in zip(np.asarray(ids0), r.ids)])
            assert overlap >= 0.9, overlap

    @pytest.mark.parametrize("quant,backend",
                             [("int8", "flat"), ("int8", "ivf"),
                              ("pq8", "flat"), ("pq8", "ivf")])
    def test_ppcol_roundtrip_bit_identical(self, quant, backend):
        """save/load: ids bit-identical, codebook and re-derived codes
        bit-identical (the .ppcol contract, DESIGN.md §11)."""
        from repro.api import SecureAnnService
        spec, owner, C_sap, C_dce, req = _service_system()
        spec = dataclasses.replace(spec, backend=backend,
                                   quantization=quant)
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            svc.insert("t", "c", C_sap, C_dce)
            svc.delete("t", "c", [3, 4])
            r0 = svc.submit(req("c"))
            with tempfile.TemporaryDirectory() as td:
                svc.save(td)
                svc2 = SecureAnnService.load(td)
            r1 = svc2.submit(req("c"))
            np.testing.assert_array_equal(r0.ids, r1.ids)
            b0 = svc.collection("t", "c")._backend
            b1 = svc2.collection("t", "c")._backend
            a0, a1 = b0.adc_codebook.to_arrays(), \
                b1.adc_codebook.to_arrays()
            assert set(a0) == set(a1)
            for k in a0:
                np.testing.assert_array_equal(np.asarray(a0[k]),
                                              np.asarray(a1[k]))
            if quant == "int8":
                np.testing.assert_array_equal(np.asarray(b0._adc_c8),
                                              np.asarray(b1._adc_c8))
            else:
                np.testing.assert_array_equal(
                    np.asarray(b0._adc_codes_t),
                    np.asarray(b1._adc_codes_t))
            svc2.close()

    def test_indexspec_quantization_validation(self):
        from repro.api import IndexSpec, WireFormatError  # noqa: F401
        with pytest.raises(ValueError):
            IndexSpec(tenant="t", name="c", d=8, quantization="int4")
        with pytest.raises(ValueError):
            IndexSpec(tenant="t", name="c", d=8, backend="hnsw",
                      quantization="int8")
        with pytest.raises(ValueError):
            IndexSpec(tenant="t", name="c", d=8, refine_ratio=2.0)
        spec = IndexSpec(tenant="t", name="c", d=8, quantization="pq8",
                         refine_ratio=4.0, pq_m=4)
        spec2 = spec.from_bytes(spec.to_bytes())
        assert spec2.quantization == "pq8" and spec2.refine_ratio == 4.0

    def test_searchstats_wire_carries_filter_bytes(self):
        from repro.api import SearchResult, SearchStats
        stats = SearchStats(latency_s=0.1, filter_dist_evals=10,
                            refine_comparisons=2, bytes_up=1,
                            bytes_down=2, filter_bytes_scanned=12345)
        res = SearchResult(ids=np.arange(4)[None], stats=stats)
        back = SearchResult.from_bytes(res.to_bytes())
        assert back.stats.filter_bytes_scanned == 12345
