"""repro.api: wire round-trips, the three-role flow, persistent
encrypted collections, and deprecation-shim parity (DESIGN.md §9).

Covers the protocol acceptance bar:
  * byte-level round-trips are bit-exact (Keys, EncryptedQuery,
    SearchRequest, SearchResult, EncryptedCorpus) and version/kind/
    dimension mismatches are refused;
  * an end-to-end owner/user/service flow returns exactly the ids of a
    directly-constructed `SecureSearchEngine.search_batch`;
  * a collection saved by `SecureAnnService.save` and reloaded in a
    fresh service returns bit-identical ids, for every backend;
  * the legacy shims (`ppanns.build_system`, `Server.search`) warn and
    stay id-identical to the typed path.
"""

import numpy as np
import pytest

from repro.api import (DataOwnerClient, DistributedSecureAnnService,
                       EncryptedCorpus, EncryptedQuery, IndexSpec, Keys,
                       Keystore, PlacementSpec, QueryClient, SearchParams,
                       SearchRequest, SearchResult, SecureAnnService,
                       WireFormatError, suggest_beta)
from repro.core import ppanns
from repro.core.wireformat import pack
from repro.data import synth
from repro.serving.search_engine import SearchStats, SecureSearchEngine

D = 16
N = 300


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("sift1m", n=N, n_queries=6, d=D, k_gt=10,
                              seed=0)


def _spec(ds, backend="flat", name="col", **kw):
    return IndexSpec(tenant="t", name=name, d=ds.d, backend=backend,
                     sap_beta=suggest_beta(ds.base, fraction=0.05),
                     seed=5, **kw)


# ---------------------------------------------------------------------------
# Wire round-trips.
# ---------------------------------------------------------------------------

def test_keys_roundtrip_bit_exact():
    owner = ppanns.DataOwner(d=17, sap_beta=2.0, seed=3)   # odd d: d_pad path
    keys = owner.keys
    clone = Keys.from_bytes(keys.to_bytes(), expect_d=17)
    k1, k2 = keys.dce_key, clone.dce_key
    for f in ("perm1", "perm2", "M1", "M1_inv", "M2", "M2_inv", "M3",
              "M3_inv", "r", "kv"):
        a, b = getattr(k1, f), getattr(k2, f)
        assert a.dtype == b.dtype and np.array_equal(a, b), f
    assert clone.sap_key.s == keys.sap_key.s
    assert clone.sap_key.beta == keys.sap_key.beta
    # identical keys + identical seed => identical ciphertexts
    P = np.random.default_rng(0).standard_normal((8, 17))
    from repro.core import dce, dcpe
    assert np.array_equal(dce.encrypt(P, k1, seed=9),
                          dce.encrypt(P, k2, seed=9))
    assert np.array_equal(dcpe.encrypt(P, keys.sap_key, seed=9),
                          dcpe.encrypt(P, clone.sap_key, seed=9))


def test_keys_refuse_mismatched_d_and_version():
    keys = ppanns.DataOwner(d=12, sap_beta=1.0, seed=1).keys
    data = keys.to_bytes()
    with pytest.raises(WireFormatError, match="d=12"):
        Keys.from_bytes(data, expect_d=24)
    # wrong wire version must be refused, not misparsed
    future = pack("ppanns-keys", ppanns.KEYS_WIRE_VERSION + 1, {}, {})
    with pytest.raises(WireFormatError, match="version"):
        Keys.from_bytes(future)
    # wrong kind too
    other = pack("encrypted-query", 1, {}, {})
    with pytest.raises(WireFormatError, match="kind"):
        Keys.from_bytes(other)
    with pytest.raises(WireFormatError):
        Keys.from_bytes(b"not an npz at all")


def test_query_request_result_roundtrips(ds):
    owner = DataOwnerClient(_spec(ds))
    user = owner.query_client()
    q = user.encrypt_queries(ds.queries[:3])
    q2 = EncryptedQuery.from_bytes(q.to_bytes())
    assert np.array_equal(q.C_sap, q2.C_sap)
    assert np.array_equal(q.T, q2.T)
    assert q2.C_sap.dtype == np.float32

    req = SearchRequest(tenant="t", collection="col", query=q,
                        params=SearchParams(k=7, ratio_k=4.0, ef_search=50),
                        coalesce=False)
    req2 = SearchRequest.from_bytes(req.to_bytes())
    assert req2.tenant == "t" and req2.collection == "col"
    assert req2.params == req.params and req2.coalesce is False
    assert np.array_equal(req2.query.T, q.T)

    stats = SearchStats(latency_s=0.5, filter_dist_evals=10,
                        refine_comparisons=20, bytes_up=30, bytes_down=40,
                        n_queries=3, backend="flat")
    res = SearchResult(ids=np.array([[1, -1], [2, 3], [4, 5]]), stats=stats)
    res2 = SearchResult.from_bytes(res.to_bytes())
    assert np.array_equal(res2.ids, res.ids) and res2.ids.dtype == np.int64
    assert res2.stats == stats
    assert [list(x) for x in res2.ids_lists()] == [[1], [2, 3], [4, 5]]


def test_corpus_and_spec_roundtrip(ds):
    spec = _spec(ds, backend="hnsw", hnsw_ef_construction=40)
    assert IndexSpec.from_bytes(spec.to_bytes()) == spec
    owner = DataOwnerClient(spec)
    corpus = owner.encrypt_corpus(ds.base[:50])
    c2 = EncryptedCorpus.from_bytes(corpus.to_bytes())
    assert np.array_equal(c2.C_sap, corpus.C_sap)
    assert np.array_equal(c2.C_dce, corpus.C_dce)
    assert c2.index is not None
    for k in corpus.index:
        assert np.array_equal(c2.index[k], corpus.index[k]), k
    with pytest.raises(WireFormatError):
        IndexSpec.from_bytes(corpus.to_bytes())          # kind mismatch


def test_invalid_protocol_payloads(ds):
    with pytest.raises(ValueError, match="trapdoors"):
        EncryptedQuery(C_sap=np.zeros((2, D), np.float32),
                       T=np.zeros((3, 2 * D + 16), np.float32))
    with pytest.raises(ValueError, match="trapdoor dim"):
        EncryptedQuery(C_sap=np.zeros((2, D), np.float32),
                       T=np.zeros((2, 7), np.float32))
    with pytest.raises(ValueError, match="backend"):
        IndexSpec(tenant="t", name="x", d=D, backend="annoy")
    with pytest.raises(ValueError, match="refine"):
        SearchParams(k=5, refine="heap")


# ---------------------------------------------------------------------------
# Three-role end-to-end flow.
# ---------------------------------------------------------------------------

def test_three_role_flow_matches_engine_exactly(ds, tmp_path):
    """Owner encrypts + exports keys; the service holds ciphertexts
    only; a user built from the keystore queries — ids must equal a
    directly-constructed SecureSearchEngine.search_batch."""
    spec = _spec(ds)
    owner = DataOwnerClient(spec)
    owner.export_keys(tmp_path / "keystore")
    C_sap, C_dce = owner.encrypt_vectors(ds.base, seed=11)

    user = QueryClient.from_keystore(tmp_path / "keystore", "t__col",
                                     expect_d=ds.d)
    query = user.encrypt_queries(ds.queries)
    params = SearchParams(k=8, ratio_k=6.0, ef_search=64)

    with SecureAnnService() as svc:
        svc.create_collection(spec)
        svc.insert("t", "col", C_sap, C_dce)
        # the service is keyless: plaintext ingestion is structurally
        # impossible and there are no keys to hand out
        col = svc.collection("t", "col")
        with pytest.raises(RuntimeError, match="keyless"):
            col.insert(ds.base[:2])
        with pytest.raises(RuntimeError, match="keyless"):
            col.new_user()

        res = svc.submit(SearchRequest(
            tenant="t", collection="col", query=query, params=params,
            coalesce=False))
        # coalesced single-query path agrees with the batch path
        res0 = svc.submit(SearchRequest(
            tenant="t", collection="col",
            query=user.encrypt_query(ds.queries[0]), params=params))
        engine = SecureSearchEngine(C_sap, C_dce, backend="flat")
        ids_ref, _ = engine.search_batch(query.C_sap, query.T, params.k,
                                         ratio_k=params.ratio_k,
                                         ef_search=params.ef_search)
        assert np.array_equal(res.ids, ids_ref)
        assert res0.stats.n_queries >= 1

    # the query client's ciphertexts came from round-tripped keys: they
    # must decrypt-compare correctly, which the exact-id match proves;
    # recall sanity on top
    assert synth.recall_at_k(res.ids, ds.gt, 8) > 0.6


# ---------------------------------------------------------------------------
# Persistent encrypted collections.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["flat", "ivf", "hnsw"])
def test_save_load_bit_identical(ds, tmp_path, backend):
    spec = _spec(ds, backend=backend, name=f"col-{backend}",
                 hnsw_ef_construction=40, n_partitions=8, nprobe=3)
    owner = DataOwnerClient(spec)
    corpus = owner.encrypt_corpus(ds.base)
    user = owner.query_client()
    query = user.encrypt_queries(ds.queries)
    req = SearchRequest(tenant="t", collection=spec.name, query=query,
                        params=SearchParams(k=9), coalesce=False)

    with SecureAnnService() as svc:
        svc.create_collection(spec, corpus=corpus)
        svc.submit(req)          # force the lazy filter-index build NOW:
        # mutations after the build must persist exactly (an IVF rebuilt
        # from today's survivors would not reproduce centroids fit over
        # the rows alive at build time)
        extra = svc.insert("t", spec.name,
                           *owner.encrypt_vectors(ds.base[:5], seed=77))
        svc.delete("t", spec.name, [int(extra[0]), 3])
        ids_before = svc.submit(req).ids
        svc.save(tmp_path / "snap")

    with SecureAnnService.load(tmp_path / "snap") as svc2:
        ids_after = svc2.submit(req).ids
        assert np.array_equal(ids_before, ids_after)
        assert 3 not in ids_after and int(extra[0]) not in ids_after
        # the reloaded service still serves mutations (keyless ingest)
        more = svc2.insert("t", spec.name,
                           *owner.encrypt_vectors(ds.queries[0][None],
                                                  seed=99))
        ids2 = svc2.submit(req).ids
        assert int(more[0]) in ids2[0]


def test_load_missing_dir_fails(tmp_path):
    with pytest.raises(FileNotFoundError):
        SecureAnnService.load(tmp_path / "nothing-here")


# ---------------------------------------------------------------------------
# Deprecation shims: warn + exact parity with the typed path.
# ---------------------------------------------------------------------------

def test_shims_warn_and_match_new_path(ds):
    beta = suggest_beta(ds.base, fraction=0.05)
    with pytest.warns(DeprecationWarning, match="repro.api"):
        owner_l, user_l, server = ppanns.build_system(
            ds.base, beta=beta, s=1024.0, seed=3)

    spec = IndexSpec(tenant="t", name="parity", d=ds.d, backend="hnsw",
                     sap_beta=beta, seed=3)
    owner = DataOwnerClient(spec)
    corpus = owner.encrypt_corpus(ds.base)
    # same seed schedule => byte-identical outsourced database
    assert np.array_equal(corpus.C_sap, np.asarray(server.db.C_sap))
    assert np.array_equal(corpus.C_dce, np.asarray(server.db.C_dce))

    user = owner.query_client()
    params = SearchParams(k=7, ratio_k=8.0, ef_search=96)
    with SecureAnnService() as svc:
        svc.create_collection(spec, corpus=corpus)
        for q in ds.queries[:3]:
            eq = user.encrypt_query(q)
            with pytest.warns(DeprecationWarning, match="repro.api"):
                ids_legacy, _ = server.search(eq.C_sap[0], eq.T[0], 7)
            res = svc.submit(SearchRequest(tenant="t", collection="parity",
                                           query=eq, params=params))
            assert np.array_equal(res.ids[0], ids_legacy)
        # batched shim parity too
        eq = user.encrypt_queries(ds.queries)
        ids_lb, _ = server.search_batch(eq.C_sap, eq.T, 7)
        res = svc.submit(SearchRequest(tenant="t", collection="parity",
                                       query=eq, params=params,
                                       coalesce=False))
        assert np.array_equal(res.ids, ids_lb)


# ---------------------------------------------------------------------------
# Mesh deployment wrapper — now a deprecation shim over placement=sharded.
# ---------------------------------------------------------------------------

def test_distributed_service_is_deprecated_shim_with_id_parity(ds):
    spec = _spec(ds)
    owner = DataOwnerClient(spec)
    corpus = owner.encrypt_corpus(ds.base)
    user = owner.query_client()
    query = user.encrypt_queries(ds.queries)
    with pytest.warns(DeprecationWarning, match="placement"):
        eng = DistributedSecureAnnService(corpus)
    with eng:
        res = eng.search(query, SearchParams(k=10))
    assert res.ids.shape == (len(ds.queries), 10)
    assert res.stats.backend == "sharded-flat"
    assert res.stats.n_queries == len(ds.queries)
    assert res.stats.bytes_down == res.ids.nbytes        # true int64 size
    assert synth.recall_at_k(res.ids, ds.gt, 10) > 0.8
    # id parity against the unified engine's exhaustive path AND against
    # a placement=sharded collection on the one service surface
    engine = SecureSearchEngine(corpus.C_sap, corpus.C_dce, backend="flat")
    ids_ref, _ = engine.search_batch(query.C_sap, query.T, 10)
    assert np.array_equal(res.ids, ids_ref)
    with SecureAnnService() as svc:
        svc.create_collection(spec, corpus=corpus,
                              placement=PlacementSpec(kind="sharded",
                                                      n_shards=1))
        res2 = svc.submit(SearchRequest(tenant="t", collection=spec.name,
                                        query=query,
                                        params=SearchParams(k=10),
                                        coalesce=False))
    assert np.array_equal(res2.ids, ids_ref)


# ---------------------------------------------------------------------------
# Keystore.
# ---------------------------------------------------------------------------

def test_keystore_custody(tmp_path, ds):
    store = Keystore(tmp_path / "ks")
    spec = _spec(ds)
    owner = DataOwnerClient(spec)
    owner.export_keys(store)
    assert store.names() == ["t__col"]
    # reconstructed owner encrypts identically (same keys, same seeds)
    owner2 = DataOwnerClient.from_keystore(spec, store)
    a = owner.encrypt_vectors(ds.base[:8], seed=4)
    b = owner2.encrypt_vectors(ds.base[:8], seed=4)
    assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    with pytest.raises(WireFormatError):
        store.load("t__col", expect_d=ds.d + 2)
    with pytest.raises(KeyError):
        store.load("nonexistent")
    with pytest.raises(ValueError):
        store.path("../escape")
