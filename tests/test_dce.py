"""Property tests for DCE (paper §IV, Theorem 3)."""

import numpy as np
import pytest

from repro.core import dce


def _exact_sq_dists(P, q):
    return ((P - q) ** 2).sum(-1)


@pytest.mark.parametrize("d", [2, 3, 7, 16, 96, 100, 128, 960])
def test_theorem3_sign_exactness(d):
    """sign(Z) == sign(dist(o,q) - dist(p,q)) for all pairs (Theorem 3)."""
    rng = np.random.default_rng(d)
    key = dce.keygen(d, seed=d)
    P = rng.standard_normal((40, d)) * rng.uniform(0.5, 5.0)
    Q = rng.standard_normal((4, d))
    C = dce.encrypt(P, key, seed=1, dtype=np.float64)
    T = dce.trapgen(Q, key, seed=2, dtype=np.float64)
    for qi in range(Q.shape[0]):
        dist = _exact_sq_dists(P, Q[qi])
        Z = dce.pairwise_z_matrix(C, T[qi])
        true = dist[:, None] - dist[None, :]
        ok = (np.sign(Z) == np.sign(true)) | (np.abs(true) < 1e-8)
        assert ok.all()


@pytest.mark.parametrize("d", [8, 128, 960])
def test_float32_server_side_sign_fidelity(d):
    """Server-side f32 comparisons keep the sign whenever the true distance
    gap is non-negligible (orthogonal-key conditioning, see dce.py)."""
    rng = np.random.default_rng(d + 1)
    key = dce.keygen(d, seed=d)
    P = rng.standard_normal((64, d))
    Q = rng.standard_normal((2, d))
    C = dce.encrypt(P, key, seed=1)           # float32
    T = dce.trapgen(Q, key, seed=2)
    for qi in range(2):
        dist = _exact_sq_dists(P, Q[qi])
        Z = dce.pairwise_z_matrix(C.astype(np.float32), T[qi])
        true = dist[:, None] - dist[None, :]
        gap = np.abs(true) / (np.abs(dist[:, None]) + np.abs(dist[None, :]) + 1e-9)
        meaningful = gap > 1e-3
        assert (np.sign(Z) == np.sign(true))[meaningful].all()


def test_z_scale_is_query_and_pair_dependent():
    """Z = 2 r_o r_p r_q (d_oq - d_pq): the multiplier varies per (o,p) pair
    — the scheme leaks the comparison *sign*, not the distance gap."""
    d = 16
    rng = np.random.default_rng(0)
    key = dce.keygen(d, seed=0)
    P = rng.standard_normal((20, d))
    q = rng.standard_normal((1, d))
    C = dce.encrypt(P, key, seed=1, dtype=np.float64)
    T = dce.trapgen(q, key, seed=2, dtype=np.float64)
    dist = _exact_sq_dists(P, q[0])
    Z = dce.pairwise_z_matrix(C, T[0])
    true = dist[:, None] - dist[None, :]
    mask = np.abs(true) > 1e-6
    ratio = Z[mask] / true[mask]
    assert ratio.min() > 0                       # positive multiplier ...
    assert ratio.max() / ratio.min() > 1.05      # ... but not a constant one


def test_ciphertext_shapes_and_cost_model():
    d = 100
    key = dce.keygen(d)
    P = np.random.default_rng(0).standard_normal((5, d))
    C = dce.encrypt(P, key)
    T = dce.trapgen(P[:2], key)
    assert C.shape == (5, 4, dce.ciphertext_dim(d))
    assert T.shape == (2, dce.ciphertext_dim(d))
    # paper §IV-B: DB ciphertext 8d+64 floats, trapdoor 2d+16, 4d+32 MACs
    assert 4 * dce.ciphertext_dim(d) == 8 * d + 64
    assert dce.mac_cost_per_comparison(d) == 4 * d + 32


def test_scores_vs_pivot_matches_distance_comp():
    d = 32
    rng = np.random.default_rng(7)
    key = dce.keygen(d, seed=7)
    P = rng.standard_normal((30, d))
    q = rng.standard_normal((1, d))
    C = dce.encrypt(P, key, seed=1, dtype=np.float64)
    T = dce.trapgen(q, key, seed=2, dtype=np.float64)[0]
    pivot = C[17]
    want = np.array([dce.distance_comp(C[i], pivot, T) for i in range(30)])
    got = dce.scores_vs_pivot(C[:, 0], C[:, 1], pivot[2], pivot[3], T)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_odd_dimension_padding():
    d = 13
    rng = np.random.default_rng(3)
    key = dce.keygen(d, seed=3)
    P = rng.standard_normal((10, d))
    q = rng.standard_normal((1, d))
    C = dce.encrypt(P, key, seed=1, dtype=np.float64)
    T = dce.trapgen(q, key, seed=2, dtype=np.float64)
    dist = _exact_sq_dists(P, q[0])
    Z = dce.pairwise_z_matrix(C, T[0])
    true = dist[:, None] - dist[None, :]
    ok = (np.sign(Z) == np.sign(true)) | (np.abs(true) < 1e-9)
    assert ok.all()
