"""Replicated shards + degraded-mode failover (repro.resilience,
DESIGN.md §16).

The availability contract: with `PlacementSpec.n_replicas > 1` each
shard group answers while at least one replica lives.  One dead replica
is INVISIBLE — bit-identical ids, `degraded=False`, zero new compiles on
the healthy path.  A fully-dead group degrades the answer instead of
failing it: searches keep returning exact ids over the alive shards'
rows, stamped `SearchResult.degraded` / `SearchStats.n_shards_down`,
and reviving the group restores bit-identical healthy answers.  The
degraded path itself compiles at most one new executable (the masked
flat scan) on its first use and zero thereafter.

Shard counts above the local device count skip; CI runs this file under
`XLA_FLAGS=--xla_force_host_platform_device_count=8` (resilience-smoke).
"""

import numpy as np
import pytest

import jax

from repro import resilience as R
from repro.api import PlacementSpec
from repro.api.protocol import PROTOCOL_VERSION, SearchResult
from repro.core import dcpe
from repro.core.wireformat import pack
from repro.data import synth
from repro.serving.runtime import Collection, VirtualClock, jit_cache_size
from repro.serving.search_engine import SearchStats

D = 16
N = 480
K = 8
N_SHARDS = 4
BACKENDS = ("flat", "ivf", "graph")


def _need_devices(n):
    if n > jax.device_count():
        pytest.skip(f"needs {n} devices, have {jax.device_count()} "
                    f"(run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# ShardHealthRegistry semantics (no devices needed).
# ---------------------------------------------------------------------------

class TestHealthRegistry:
    def test_replica_masking_and_group_down(self):
        h = R.ShardHealthRegistry(4, 2)
        assert h.healthy and not h.degraded
        h.kill(1, 0)
        assert h.n_replicas_down == 1 and h.n_groups_down == 0
        assert not h.degraded                 # replica 1 still serves
        assert h.serve_mask().tolist() == [True] * 4
        h.kill(1, 1)
        assert h.degraded and h.n_groups_down == 1
        assert h.serve_mask().tolist() == [True, False, True, True]
        h.revive(1, 0)
        assert not h.degraded and h.n_replicas_down == 1
        h.revive(1, 1)
        assert h.healthy

    def test_epoch_bumps_only_on_real_transitions(self):
        h = R.ShardHealthRegistry(2, 2)
        e0 = h.epoch
        h.kill(0, 0)
        e1 = h.epoch
        assert e1 != e0
        h.kill(0, 0)                          # idempotent: no new epoch
        assert h.epoch == e1
        h.revive(1, 1)                        # already up: no new epoch
        assert h.epoch == e1
        h.revive(0, 0)
        assert h.epoch != e1

    def test_bounds_and_snapshot(self):
        h = R.ShardHealthRegistry(2, 1)
        with pytest.raises(ValueError, match="out of range"):
            h.kill(2, 0)
        with pytest.raises(ValueError, match="out of range"):
            h.kill(0, 1)
        h.kill(1, 0)
        snap = h.snapshot()
        assert snap["n_groups_down"] == 1 and snap["n_replicas_down"] == 1
        assert snap["up"].tolist() == [[True], [False]]
        with pytest.raises(ValueError):
            R.ShardHealthRegistry(0, 1)


# ---------------------------------------------------------------------------
# Wire surface: additive fields, old payloads decode healthy.
# ---------------------------------------------------------------------------

def _stats(**kw):
    base = dict(latency_s=0.0, filter_dist_evals=0, refine_comparisons=0,
                bytes_up=0, bytes_down=0, n_queries=1, backend="flat")
    base.update(kw)
    return SearchStats(**base)


class TestWireSurface:
    def test_stats_default_healthy(self):
        s = _stats()
        assert s.n_shards_down == 0 and s.degraded is False

    def test_search_result_roundtrips_degraded(self):
        res = SearchResult(ids=np.arange(6).reshape(2, 3),
                           stats=_stats(degraded=True, n_shards_down=2))
        back = SearchResult.from_bytes(res.to_bytes())
        assert back.degraded is True
        assert back.stats.n_shards_down == 2
        np.testing.assert_array_equal(back.ids, res.ids)

    def test_pre_resilience_payload_decodes_healthy(self):
        """A peer from before DESIGN.md §16 omits the failover keys —
        the additive contract says that decodes as a healthy answer."""
        old_stats = {k: v for k, v in
                     vars(_stats()).items()
                     if k not in ("degraded", "n_shards_down")}
        data = pack("search-result", PROTOCOL_VERSION,
                    arrays={"ids": np.zeros((1, 3), np.int64)},
                    meta={"stats": old_stats})
        back = SearchResult.from_bytes(data)
        assert back.degraded is False
        assert back.stats.n_shards_down == 0

    def test_placement_n_replicas_validation(self):
        with pytest.raises(ValueError, match="n_replicas must be >= 1"):
            PlacementSpec(kind="sharded", n_shards=2, n_replicas=0)
        with pytest.raises(ValueError, match="single placement"):
            PlacementSpec(kind="single", n_replicas=2)

    def test_placement_n_replicas_roundtrip_and_default(self):
        p = PlacementSpec(kind="sharded", n_shards=2, n_replicas=3)
        assert PlacementSpec.from_dict(p.to_dict()) == p
        assert PlacementSpec.from_bytes(p.to_bytes()) == p
        assert p.resolve(8).n_replicas == 3
        # pre-§16 dict payloads omit the key -> default 1
        d = p.to_dict()
        d.pop("n_replicas")
        assert PlacementSpec.from_dict(d).n_replicas == 1


# ---------------------------------------------------------------------------
# End-to-end failover on a live sharded collection.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("sift1m", n=N, n_queries=4, d=D, k_gt=10,
                              seed=3)


def _collection(ds, backend):
    placement = PlacementSpec(kind="sharded", n_shards=N_SHARDS,
                              n_replicas=2).resolve(jax.device_count())
    kw = dict(n_partitions=8, nprobe=4) if backend == "ivf" else {}
    col = Collection("t", f"fo-{backend}", D,
                     sap_beta=dcpe.suggest_beta(ds.base, fraction=0.05),
                     seed=6, backend=backend, placement=placement,
                     max_batch=4, max_wait_ms=1.0, **kw)
    col.insert(ds.base)
    col.compact()
    return col


@pytest.mark.parametrize("backend", BACKENDS)
def test_failover_replica_group_revive(ds, backend):
    _need_devices(N_SHARDS)
    col = _collection(ds, backend)
    try:
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries]
        health = col.health
        assert health is not None and health.n_replicas == 2

        baseline = [col.search(*e, K) for e in enc]
        ids0, stats0 = col.search_batch(
            np.stack([e[0] for e in enc]), np.stack([e[1] for e in enc]), K)
        assert stats0.degraded is False and stats0.n_shards_down == 0

        # ---- one replica down: INVISIBLE -------------------------------
        health.kill(1, 1)
        for e, want in zip(enc, baseline):
            np.testing.assert_array_equal(col.search(*e, K), want)
        _, stats1 = col.search_batch(
            np.stack([e[0] for e in enc]), np.stack([e[1] for e in enc]), K)
        assert stats1.degraded is False and stats1.n_shards_down == 0

        # ---- whole group down: labelled partial answer -----------------
        health.kill(1, 0)
        bucket = col._backend._row_bucket(max(col.store.n_total, 1))
        per = bucket // N_SHARDS
        dead_rows = set(range(per, 2 * per))
        got, statsd = col.search_batch(
            np.stack([e[0] for e in enc]), np.stack([e[1] for e in enc]), K)
        assert statsd.degraded is True and statsd.n_shards_down == 1
        returned = set(int(i) for i in np.asarray(got).ravel() if i >= 0)
        assert returned, "degraded search returned nothing"
        assert not (returned & dead_rows), \
            "degraded answer leaked ids from the dead shard group"
        # deterministic: the degraded answer replays bit-identically,
        # through both the direct and the scheduled path
        got2, _ = col.search_batch(
            np.stack([e[0] for e in enc]), np.stack([e[1] for e in enc]), K)
        np.testing.assert_array_equal(got, got2)
        sched = [col.search(*e, K) for e in enc]
        for row, srow in zip(got, sched):
            np.testing.assert_array_equal(np.asarray(row), srow)

        # ---- zero steady-state recompiles in degraded mode -------------
        n_compiled = jit_cache_size()
        for e in enc:
            col.search(*e, K)
        col.search_batch(
            np.stack([e[0] for e in enc]), np.stack([e[1] for e in enc]), K)
        assert jit_cache_size() == n_compiled, \
            "degraded serving recompiled after its first masked call"

        # telemetry labels the degraded flushes
        assert col.telemetry.snapshot()["n_degraded_answers"] >= 1

        # ---- revive: bit-identical healthy answers ---------------------
        health.revive(1, 0)
        health.revive(1, 1)
        for e, want in zip(enc, baseline):
            np.testing.assert_array_equal(col.search(*e, K), want)
        _, statsr = col.search_batch(
            np.stack([e[0] for e in enc]), np.stack([e[1] for e in enc]), K)
        assert statsr.degraded is False and statsr.n_shards_down == 0
    finally:
        col.close()


def test_all_groups_down_returns_empty_not_crash(ds):
    _need_devices(N_SHARDS)
    col = _collection(ds, "graph")
    try:
        user = col.new_user()
        e = user.encrypt_query(ds.queries[0])
        for s in range(N_SHARDS):
            col.health.kill(s, 0)
            col.health.kill(s, 1)
        ids, stats = col.search_batch(e[0][None], e[1][None], K)
        assert stats.degraded is True
        assert stats.n_shards_down == N_SHARDS
        assert set(np.asarray(ids).ravel().tolist()) == {-1}
    finally:
        col.close()


# ---------------------------------------------------------------------------
# FaultPlan drives kill/revive/straggler deterministically.
# ---------------------------------------------------------------------------

def test_faultplan_kill_revive_through_scheduler(ds):
    _need_devices(N_SHARDS)
    col = _collection(ds, "flat")
    try:
        user = col.new_user()
        e = user.encrypt_query(ds.queries[0])
        plan = (R.FaultPlan()
                .kill_shard(at_call=2, shard=2, replica=0)
                .kill_shard(at_call=2, shard=2, replica=1)
                .revive_shard(at_call=4, shard=2)
                .revive_shard(at_call=4, shard=2, replica=1))
        plan.install(col)
        f1 = col.submit(*e, K, want_stats=True).result(timeout=30)
        assert f1[1].degraded is False          # call 1: healthy
        f2 = col.submit(*e, K, want_stats=True).result(timeout=30)
        assert f2[1].degraded is True           # call 2: group killed
        assert f2[1].n_shards_down == 1
        col.submit(*e, K).result(timeout=30)    # call 3: still degraded
        f4 = col.submit(*e, K, want_stats=True).result(timeout=30)
        assert f4[1].degraded is False          # call 4: revived
        np.testing.assert_array_equal(f4[0], f1[0])
    finally:
        col.close()


def test_faultplan_straggler_advances_virtual_clock():
    """The straggler event is a deterministic VirtualClock advance at
    engine call N — no real waiting, assertable to the exact second."""
    clock = VirtualClock()

    class _Sched:
        def _run_batch(self, *a, **kw):
            return "ok"

    class _Col:
        batcher = _Sched()

    col = _Col()
    plan = R.FaultPlan(clock=clock).straggler(at_call=2, delay_s=0.75)
    plan.install(col)
    col.batcher._run_batch()
    t1 = clock.now()
    col.batcher._run_batch()                    # straggles
    assert clock.now() == pytest.approx(t1 + 0.75)
    col.batcher._run_batch()
    assert clock.now() == pytest.approx(t1 + 0.75)
    assert plan.n_engine_calls == 3


def test_faultplan_engine_error_then_quarantine(ds):
    """An InjectedFault that outlives every retry attempt is quarantined
    to its own request — the seam the scheduler-level suite covers with
    a fake engine, proven here against the real one."""
    col = Collection("t", "fp-q", D, seed=2, max_batch=4, max_wait_ms=1.0)
    try:
        col.insert(np.random.default_rng(0).normal(
            size=(64, D)).astype(np.float32))
        user = col.new_user()
        e = user.encrypt_query(np.zeros(D, np.float32))
        # default retry = 2 attempts; error both -> quarantine
        plan = R.FaultPlan().engine_error(at_call=2, n=2)
        plan.install(col)
        ok1 = col.search(*e, K)                 # call 1 healthy
        with pytest.raises(R.InjectedFault):
            col.search(*e, K)                   # calls 2+3 both fault
        np.testing.assert_array_equal(col.search(*e, K), ok1)
        snap = col.telemetry.snapshot()
        assert snap["n_quarantined"] == 1
        assert snap["n_retries"] >= 1
    finally:
        col.close()
