"""CollectionTelemetry unit behaviour on VirtualClock (DESIGN.md §8, §13).

Telemetry never reads wall time — every window/percentile/sojourn figure
is driven here through the injected clock and asserted exactly.  The
metrics-registry exposition path is covered separately in test_obs.py;
these tests pin the snapshot math itself.
"""

from repro.obs import MetricsRegistry
from repro.serving.runtime import CollectionTelemetry, VirtualClock
from repro.serving.search_engine import SearchStats


def _stats(nq=1, dist=0, cmp=0, scanned=0, up=0, down=0, backend="fake"):
    return SearchStats(latency_s=0.0, filter_dist_evals=dist,
                       refine_comparisons=cmp, bytes_up=up,
                       bytes_down=down, n_queries=nq, backend=backend,
                       filter_bytes_scanned=scanned)


# ---------------------------------------------------------- percentiles


def test_percentile_empty_reservoir_is_zero():
    assert CollectionTelemetry._percentile([], 0.50) == 0.0
    assert CollectionTelemetry._percentile([], 0.99) == 0.0


def test_percentile_single_sample_is_that_sample():
    assert CollectionTelemetry._percentile([0.25], 0.50) == 0.25
    assert CollectionTelemetry._percentile([0.25], 0.99) == 0.25


def test_percentile_interior_rank():
    xs = sorted(float(i) for i in range(101))      # 0..100
    assert CollectionTelemetry._percentile(xs, 0.50) == 50.0
    assert CollectionTelemetry._percentile(xs, 0.99) == 99.0
    assert CollectionTelemetry._percentile(xs, 1.00) == 100.0


# ----------------------------------------------------------- QPS window


def test_qps_counts_only_requests_inside_window():
    vc = VirtualClock()
    tel = CollectionTelemetry(window_s=10.0, clock=vc)
    tel.record_flush(4, [0.01] * 4, _stats(nq=4), queue_depth=0)
    vc.advance(5.0)
    tel.record_flush(2, [0.01] * 2, _stats(nq=2), queue_depth=0)
    # span is capped at the observed lifetime (5 s), not the window
    snap = tel.snapshot()
    assert snap["qps"] == (4 + 2) / 5.0


def test_qps_window_prunes_after_quiet_gap():
    """A long quiet gap must age old flushes out of the window even when
    no record_flush runs afterwards — snapshot() prunes on read."""
    vc = VirtualClock()
    tel = CollectionTelemetry(window_s=10.0, clock=vc)
    tel.record_flush(8, [0.01] * 8, _stats(nq=8), queue_depth=0)
    vc.advance(100.0)                      # far past the 10 s window
    snap = tel.snapshot()
    assert snap["qps"] == 0.0
    assert len(tel._flushes) == 0          # actually pruned, not masked
    # fresh traffic after the gap counts alone, over the full window
    tel.record_flush(3, [0.01] * 3, _stats(nq=3), queue_depth=0)
    assert tel.snapshot()["qps"] == 3 / 10.0


def test_fresh_collection_single_flush_does_not_explode_qps():
    vc = VirtualClock()
    tel = CollectionTelemetry(window_s=60.0, clock=vc)
    vc.advance(0.5)
    tel.record_flush(1, [0.001], _stats(), queue_depth=0)
    assert tel.snapshot()["qps"] == 1 / 0.5


# ------------------------------------------------------- snapshot math


def test_snapshot_accumulates_search_stats_counters():
    """record_flush/record_step must SUM the engine's SearchStats cost
    counters across calls — not just remember the last backend."""
    vc = VirtualClock()
    tel = CollectionTelemetry(clock=vc)
    tel.record_flush(2, [0.01, 0.02],
                     _stats(nq=2, dist=100, cmp=50, scanned=4096,
                            up=10, down=20, backend="flat"),
                     queue_depth=1)
    tel.record_step(3, 8, [0.03] * 3, [0.01] * 3,
                    _stats(nq=3, dist=7, cmp=5, scanned=512,
                           up=1, down=2, backend="ivf"),
                    queue_depth=0)
    snap = tel.snapshot()
    assert snap["backend"] == "ivf"                # last engine call wins
    assert snap["filter_dist_evals"] == 107
    assert snap["refine_comparisons"] == 55
    assert snap["filter_bytes_scanned"] == 4608
    assert snap["bytes_up"] == 11
    assert snap["bytes_down"] == 22
    assert snap["n_batches"] == 1 and snap["n_steps"] == 1


def test_snapshot_latency_and_sojourn_reservoirs():
    vc = VirtualClock()
    tel = CollectionTelemetry(clock=vc)
    tel.record_flush(3, [0.01, 0.02, 0.03], _stats(nq=3), queue_depth=0)
    tel.record_step(2, 4, [0.5], [0.1, 0.2], _stats(nq=2),
                    queue_depth=0)
    # merged latency reservoir sorted: [0.01, 0.02, 0.03, 0.5]
    snap = tel.snapshot()
    assert snap["p50_latency_s"] == 0.03           # nearest-rank, n=4
    assert snap["p99_latency_s"] == 0.5            # step sojourns merge in
    assert snap["p50_insert_to_emit_s"] == 0.1
    assert snap["slot_occupancy"] == 0.5
    assert snap["batch_occupancy"] == 5 / 1        # batched reqs / flushes


def test_snapshot_counts_ingest_and_rejects():
    tel = CollectionTelemetry(clock=VirtualClock())
    tel.record_submit(queue_depth=3)
    tel.record_reject()
    tel.record_ingest(n_inserted=10)
    tel.record_ingest(n_deleted=2, compacted=True)
    snap = tel.snapshot()
    assert snap["n_requests"] == 1 and snap["n_rejected"] == 1
    assert snap["n_inserts"] == 10 and snap["n_deletes"] == 2
    assert snap["n_compactions"] == 1 and snap["queue_depth"] == 3


def test_telemetry_without_clock_uses_wall_time():
    tel = CollectionTelemetry()                    # no injected clock
    tel.record_flush(1, [0.01], _stats(), queue_depth=0)
    assert tel.snapshot()["n_batches"] == 1


# ----------------------------------------------- metrics registry wiring


def test_metrics_registry_mirrors_counters():
    vc = VirtualClock()
    reg = MetricsRegistry()
    tel = CollectionTelemetry(clock=vc, metrics=reg,
                              labels={"tenant": "t", "collection": "c"})
    tel.record_submit(queue_depth=2)
    tel.record_flush(2, [0.01, 0.02],
                     _stats(nq=2, dist=9, cmp=4, scanned=256, up=3,
                            down=6), queue_depth=0)
    lbl = {"tenant": "t", "collection": "c"}
    assert reg.get("ann_requests_total").value(**lbl) == 1
    assert reg.get("ann_batched_requests_total").value(**lbl) == 2
    assert reg.get("ann_filter_dist_evals_total").value(**lbl) == 9
    assert reg.get("ann_bytes_down_total").value(**lbl) == 6
    assert reg.get("ann_queue_depth").value(**lbl) == 0
    hist = reg.get("ann_request_latency_seconds")
    _, _, count = hist.snapshot(**lbl)
    assert count == 2
    text = reg.prometheus_text()
    assert 'ann_requests_total{tenant="t",collection="c"} 1' in text
