"""Placement-aware deployment (DESIGN.md §10): PlacementSpec wire
round-trips, sharded-vs-single exact-id parity across backends and shard
counts, sharded persistence + live ingestion, and the zero-recompile
contract.

Shard counts above the local device count skip; CI runs this file under
`XLA_FLAGS=--xla_force_host_platform_device_count=8` (the sharded-smoke
job) so the 2- and 8-shard cells execute there.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                       QueryClient, SearchParams, SearchRequest,
                       SecureAnnService, WireFormatError, suggest_beta)
from repro.core.wireformat import pack
from repro.data import synth

D = 16
N = 600
SHARD_COUNTS = (1, 2, 8)


def _need_devices(n_shards: int):
    if n_shards > jax.device_count():
        pytest.skip(f"needs {n_shards} devices, have {jax.device_count()} "
                    f"(run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("sift1m", n=N, n_queries=6, d=D, k_gt=10,
                              seed=0)


@pytest.fixture(scope="module")
def owner_and_query(ds):
    spec = IndexSpec(tenant="t", name="base", d=D,
                     sap_beta=suggest_beta(ds.base, fraction=0.05), seed=5)
    owner = DataOwnerClient(spec)
    C_sap, C_dce = owner.encrypt_vectors(ds.base, seed=11)
    user = owner.query_client()
    return spec, owner, C_sap, C_dce, user.encrypt_queries(ds.queries)


def _spec(base: IndexSpec, backend: str, name: str) -> IndexSpec:
    extra = dict(n_partitions=8, nprobe=3) if backend == "ivf" else {}
    return dataclasses.replace(base, name=name, backend=backend, **extra)


# ---------------------------------------------------------------------------
# Wire round-trips + validation.
# ---------------------------------------------------------------------------

def test_placement_wire_roundtrip():
    for pl in (PlacementSpec(),
               PlacementSpec(kind="sharded"),
               PlacementSpec(kind="sharded", data_axis="x", n_shards=4)):
        assert PlacementSpec.from_bytes(pl.to_bytes()) == pl
    assert PlacementSpec().kind == "single"
    assert PlacementSpec(kind="sharded", n_shards=4).is_sharded


def test_placement_rejects_unknown_kind_and_fields():
    with pytest.raises(ValueError, match="unknown placement kind"):
        PlacementSpec(kind="ring")
    # an unknown kind arriving over the wire is a WireFormatError, not a
    # misparse — same contract as every other protocol type
    payload = pack("placement-spec", 1, arrays={},
                   meta={"kind": "ring", "data_axis": "data",
                         "n_shards": 2})
    with pytest.raises(WireFormatError, match="unknown placement kind"):
        PlacementSpec.from_bytes(payload)
    with pytest.raises(WireFormatError, match="unknown fields"):
        PlacementSpec.from_dict({"kind": "single", "data_axis": "data",
                                 "n_shards": None, "rack": 3})
    with pytest.raises(WireFormatError, match="kind"):
        PlacementSpec.from_bytes(pack("index-spec", 1, {}, {}))
    with pytest.raises(ValueError, match="n_shards"):
        PlacementSpec(kind="single", n_shards=4)
    with pytest.raises(ValueError, match="n_shards must be"):
        PlacementSpec(kind="sharded", n_shards=0)


def test_placement_resolve_pins_device_count():
    pl = PlacementSpec(kind="sharded")
    assert pl.n_shards is None
    resolved = pl.resolve(4)
    assert resolved.n_shards == 4
    assert resolved.resolve(4) == resolved          # idempotent
    with pytest.raises(ValueError, match="device"):
        PlacementSpec(kind="sharded", n_shards=9).resolve(8)
    assert PlacementSpec().resolve(8) == PlacementSpec()


def test_sharded_rejects_hnsw_and_too_many_shards(ds, owner_and_query):
    spec, owner, *_ = owner_and_query
    hspec = dataclasses.replace(spec, name="h", backend="hnsw")
    with SecureAnnService() as svc:
        with pytest.raises(ValueError, match="does not shard"):
            svc.create_collection(hspec,
                                  placement=PlacementSpec(kind="sharded"))
        with pytest.raises(ValueError, match="device"):
            svc.create_collection(
                dataclasses.replace(spec, name="wide"),
                placement=PlacementSpec(kind="sharded",
                                        n_shards=jax.device_count() + 1))


# ---------------------------------------------------------------------------
# Sharded vs single-host exact-id parity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_sharded_matches_single_host_exactly(ds, owner_and_query, backend,
                                             n_shards):
    """The acceptance bar: a placement=sharded collection answers
    submit() with bit-identical ids to the single-device collection —
    batch path and coalesced single-query path both."""
    _need_devices(n_shards)
    spec0, owner, C_sap, C_dce, query = owner_and_query
    spec = _spec(spec0, backend, f"par-{backend}-{n_shards}")
    params = SearchParams(k=8, ratio_k=6.0)
    req = SearchRequest(tenant="t", collection=spec.name, query=query,
                        params=params, coalesce=False)

    def build(svc, placement):
        svc.create_collection(spec, placement=placement)
        svc.insert("t", spec.name, C_sap, C_dce)

    with SecureAnnService() as single:
        build(single, None)
        ids_single = single.submit(req).ids
        one_single = single.submit(SearchRequest(
            tenant="t", collection=spec.name,
            query=dataclasses.replace(query), params=params)).ids
    with SecureAnnService() as sharded:
        build(sharded, PlacementSpec(kind="sharded", n_shards=n_shards))
        res = sharded.submit(req)
        assert res.stats.backend == f"sharded-{backend}"
        np.testing.assert_array_equal(res.ids, ids_single)
        # the coalesced micro-batcher path over the sharded engine
        one = sharded.submit(SearchRequest(
            tenant="t", collection=spec.name,
            query=dataclasses.replace(query), params=params)).ids
        np.testing.assert_array_equal(one, one_single)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_sharded_live_ingestion_and_deletes(ds, owner_and_query, n_shards):
    """Inserts route to a shard with stable global ids and are visible
    to the next search; deleted ids never come back — identical
    semantics (and ids) to the single-device runtime."""
    _need_devices(n_shards)
    spec0, owner, C_sap, C_dce, query = owner_and_query
    spec = _spec(spec0, "flat", f"mut-{n_shards}")
    params = SearchParams(k=8)
    with SecureAnnService() as svc:
        svc.create_collection(spec, placement=PlacementSpec(
            kind="sharded", n_shards=n_shards))
        rows = svc.insert("t", spec.name, C_sap, C_dce)
        assert np.array_equal(rows, np.arange(N))      # stable global ids
        planted = svc.insert("t", spec.name,
                             *owner.encrypt_vectors(ds.queries[0][None],
                                                    seed=99))
        assert planted[0] == N                         # appended, stable
        req = SearchRequest(tenant="t", collection=spec.name,
                            query=dataclasses.replace(query),
                            params=params, coalesce=False)
        ids1 = svc.submit(req).ids
        assert int(planted[0]) in ids1[0]
        svc.delete("t", spec.name, planted)
        ids2 = svc.submit(req).ids
        assert int(planted[0]) not in ids2
        manifest = svc.collection("t", spec.name).shard_manifest()
        assert len(manifest) == n_shards
        assert manifest[-1]["row_stop"] == N + 1
        assert sum(m["row_stop"] - m["row_start"] for m in manifest) \
            == N + 1


@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_sharded_save_load_bit_identical(ds, owner_and_query, tmp_path,
                                         backend):
    """A sharded collection survives save/load: placement + per-shard
    manifest persist, and a reloaded service answers bit-identically
    (including post-build mutations, same bar as the single-host test)."""
    n_shards = min(2, jax.device_count())
    spec0, owner, C_sap, C_dce, query = owner_and_query
    spec = _spec(spec0, backend, f"snap-{backend}")
    req = SearchRequest(tenant="t", collection=spec.name, query=query,
                        params=SearchParams(k=8), coalesce=False)
    with SecureAnnService() as svc:
        svc.create_collection(spec, placement=PlacementSpec(
            kind="sharded", n_shards=n_shards))
        svc.insert("t", spec.name, C_sap, C_dce)
        svc.submit(req)              # force the lazy filter build NOW
        extra = svc.insert("t", spec.name,
                           *owner.encrypt_vectors(ds.base[:5], seed=77))
        svc.delete("t", spec.name, [int(extra[0]), 3])
        ids_before = svc.submit(req).ids
        svc.save(tmp_path / "snap")

    from repro.core.wireformat import unpack
    files = sorted((tmp_path / "snap").glob("*.ppcol"))
    assert len(files) == 1
    _, meta = unpack(files[0].read_bytes(), "encrypted-collection", 1)
    assert meta["placement"]["kind"] == "sharded"
    assert meta["placement"]["n_shards"] == n_shards
    assert len(meta["shard_manifest"]) == n_shards

    with SecureAnnService.load(tmp_path / "snap") as svc2:
        assert svc2.placement("t", spec.name).n_shards == n_shards
        ids_after = svc2.submit(req).ids
        np.testing.assert_array_equal(ids_before, ids_after)
        assert 3 not in ids_after and int(extra[0]) not in ids_after
        more = svc2.insert("t", spec.name,
                           *owner.encrypt_vectors(ds.queries[0][None],
                                                  seed=99))
        assert int(more[0]) in svc2.submit(req).ids[0]


# ---------------------------------------------------------------------------
# Zero recompiles after warmup.
# ---------------------------------------------------------------------------

def test_sharded_zero_recompiles_after_warmup(ds, owner_and_query):
    from repro.serving.runtime.telemetry import jit_cache_size
    n_shards = min(2, jax.device_count())
    spec0, owner, C_sap, C_dce, query = owner_and_query
    spec = _spec(spec0, "flat", "warm")
    with SecureAnnService() as svc:
        svc.create_collection(spec, placement=PlacementSpec(
            kind="sharded", n_shards=n_shards))
        svc.insert("t", spec.name, C_sap, C_dce)
        svc.warmup("t", spec.name, k=8)
        before = jit_cache_size()
        user = QueryClient(owner.keys, seed=7)
        for q in ds.queries:
            svc.submit(SearchRequest(tenant="t", collection=spec.name,
                                     query=user.encrypt_query(q),
                                     params=SearchParams(k=8)))
        assert jit_cache_size() == before, "steady-state traffic recompiled"


# ---------------------------------------------------------------------------
# Quantized ADC filter under sharded placement (DESIGN.md §11).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("quant", ["int8", "pq8"])
def test_sharded_adc_matches_single_device_adc(ds, owner_and_query, quant,
                                               n_shards):
    """A quantized sharded collection returns the same refined ids as
    the quantized single-device collection: the sharded ADC scan +
    all-gather merge sees the same surrogate distances (modulo merge
    order) and the exact refine pins the final order."""
    _need_devices(n_shards)
    spec0, owner, C_sap, C_dce, query = owner_and_query
    params = SearchParams(k=8, ratio_k=6.0)
    for backend in ("flat", "ivf"):
        spec = dataclasses.replace(
            spec0, name=f"adc-{quant}-{backend}-{n_shards}",
            backend=backend, quantization=quant,
            n_partitions=16, nprobe=16)
        req = SearchRequest(tenant="t", collection=spec.name, query=query,
                            params=params, coalesce=False)
        with SecureAnnService() as single:
            single.create_collection(spec)
            single.insert("t", spec.name, C_sap, C_dce)
            ids_single = single.submit(req).ids
        with SecureAnnService() as sharded:
            sharded.create_collection(spec, placement=PlacementSpec(
                kind="sharded", n_shards=n_shards))
            sharded.insert("t", spec.name, C_sap, C_dce)
            res = sharded.submit(req)
            assert res.stats.backend == f"sharded-adc-{backend}-{quant}"
            np.testing.assert_array_equal(res.ids, ids_single)


def test_sharded_adc_mutation_and_save_load(ds, owner_and_query, tmp_path):
    """Quantized sharded collections keep the runtime contracts: stable
    ids, deletes never returned, bit-identical ids after save/load."""
    n_shards = min(2, jax.device_count())
    spec0, owner, C_sap, C_dce, query = owner_and_query
    spec = dataclasses.replace(spec0, name="adc-mut",
                               quantization="int8")
    req = SearchRequest(tenant="t", collection=spec.name, query=query,
                        params=SearchParams(k=8), coalesce=False)
    with SecureAnnService() as svc:
        svc.create_collection(spec, placement=PlacementSpec(
            kind="sharded", n_shards=n_shards))
        svc.insert("t", spec.name, C_sap, C_dce)
        planted = svc.insert("t", spec.name,
                             *owner.encrypt_vectors(ds.queries[0][None],
                                                    seed=99))
        ids1 = svc.submit(req).ids
        assert int(planted[0]) in ids1[0]
        svc.delete("t", spec.name, planted)
        assert int(planted[0]) not in svc.submit(req).ids
        ids_before = svc.submit(req).ids
        svc.save(tmp_path / "snap")
    with SecureAnnService.load(tmp_path / "snap") as svc2:
        np.testing.assert_array_equal(svc2.submit(req).ids, ids_before)
