"""Distribution tests: rule resolution units + a real lower/compile of
dry-run cells on a small multi-device mesh (subprocess: jax pins the
device count at first init, so the 4-device world must be isolated)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.sharding.rules import (AxisRules, PURE_DP_TRAIN_RULES,
                                  TRAIN_RULES, resolve_spec)


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def P(*args):
    from jax.sharding import PartitionSpec
    return PartitionSpec(*args)


def test_resolve_divisibility_strict():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 40 heads don't divide 16 -> replicated under strict
    spec = resolve_spec(mesh, TRAIN_RULES, ("embed_fsdp", "heads"),
                        (5120, 40), strict=True)
    assert spec == P(None, None) or spec[1] is None
    # fused head dim 5120 divides -> sharded
    spec = resolve_spec(mesh, TRAIN_RULES, (None, "heads"),
                        (5120, 5120), strict=True)
    assert spec == P(None, "model")


def test_resolve_suffix_fallback():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # batch 256 < 512 -> falls back to ('data','model') = 256
    spec = resolve_spec(mesh, PURE_DP_TRAIN_RULES, ("act_batch", None),
                        (256, 64), strict=True)
    assert spec == P(("data", "model"), None)
    # batch 512 uses the full tuple
    spec = resolve_spec(mesh, PURE_DP_TRAIN_RULES, ("act_batch", None),
                        (512, 64), strict=True)
    assert spec == P(("pod", "data", "model"), None)


def test_resolve_no_axis_reuse():
    mesh = _FakeMesh({"data": 4, "model": 4})
    rules = AxisRules({"a": ("model",), "b": ("model",)})
    spec = resolve_spec(mesh, rules, ("a", "b"), (16, 16), strict=True)
    assert spec == P("model", None)        # model used once only


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json, sys
    import jax
    from repro.launch.dryrun import (cost_analysis_dict, lower_cell,
                                     parse_collectives)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 2), ("data", "model"))
    lowered, aux = lower_cell(sys.argv[1], sys.argv[2], mesh)
    compiled = lowered.compile()
    colls = parse_collectives(compiled.as_text())
    print("RESULT:" + json.dumps({
        "ok": True,
        "kinds": sorted(colls),
        "flops": cost_analysis_dict(compiled).get("flops", -1),
    }))
""")


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-1.7b", "decode_32k"),
    ("mamba2-370m", "long_500k"),
])
def test_lower_compile_on_small_mesh(arch, shape):
    """End-to-end SPMD check: real config, 4 fake devices, collectives
    present in the partitioned module."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC, arch, shape],
        capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, out.stdout[-1000:]
    res = json.loads(line[0][len("RESULT:"):])
    assert res["ok"]
    assert res["flops"] > 0


def test_int8_ring_allreduce_subprocess():
    """int8-wire ring all-reduce matches psum within quantization error."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.sharding.compression import int8_ring_allreduce
        import functools
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("data",))
        x = jnp.arange(4 * 103, dtype=jnp.float32).reshape(4, 103) / 7.0

        ring = shard_map(functools.partial(
            int8_ring_allreduce, axis_name="data"), mesh=mesh,
            in_specs=P("data", None), out_specs=P("data", None),
            check_rep=False)
        got = np.asarray(ring(x))
        want = np.asarray(x).sum(0, keepdims=True).repeat(4, 0)
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 0.02, err
        print("RESULT:ok", err)
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(
                   os.path.join(os.path.dirname(__file__), "..", "src")))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RESULT:ok" in out.stdout


def test_dryrun_artifacts_complete():
    """Every runnable (arch x shape) cell has a green artifact for BOTH
    meshes — the multi-pod dry-run deliverable."""
    res_dir = os.path.join(os.path.dirname(__file__), "..",
                           "results", "dryrun")
    if not os.path.isdir(res_dir):
        pytest.skip("dry-run artifacts not generated yet")
    from repro.launch.dryrun import all_cells
    missing, failed = [], []
    for arch, shape in all_cells():
        for mesh in ("1pod_256", "2pod_512"):
            fn = os.path.join(res_dir, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(fn):
                missing.append((arch, shape, mesh))
                continue
            with open(fn) as f:
                if not json.load(f).get("ok"):
                    failed.append((arch, shape, mesh))
    assert not missing, f"missing cells: {missing[:10]}"
    assert not failed, f"failed cells: {failed[:10]}"
