"""Secure-scan step correctness (the paper-technique dry-run cell) and the
bf16-filter hillclimb's recall-safety property."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dce, dcpe, ppanns
from repro.data import synth
from repro.launch.mesh import make_mesh
from repro.serving.secure_scan import (build_secure_scan_step,
                                       build_secure_scan_step_gspmd)


def _setup(n=1200, nq=8, seed=11):
    ds = synth.make_dataset("deep1m", n=n, n_queries=nq, k_gt=20, seed=seed)
    owner = ppanns.DataOwner(d=ds.d, sap_beta=0.5, seed=seed)
    C_sap = dcpe.encrypt(ds.base, owner.keys.sap_key, seed=seed + 1)
    C_dce = dce.encrypt(ds.base, owner.keys.dce_key, seed=seed + 2)
    user = ppanns.User(owner.share_keys())
    qs, ts = zip(*(user.encrypt_query(q) for q in ds.queries))
    return ds, C_sap, C_dce, np.stack(qs), np.stack(ts)


def test_shard_map_step_matches_gspmd_step():
    """Both formulations compute the same exact answer; they differ only
    in collective structure (EXPERIMENTS.md §Perf cell 3)."""
    ds, C_sap, C_dce, Q, T = _setup()
    mesh = make_mesh((1,), ("data",))
    a = build_secure_scan_step(mesh, k=10, k_prime=64)
    b = build_secure_scan_step_gspmd(mesh, k=10, k_prime=64)
    ids_a = np.asarray(jax.jit(a)(C_sap, C_dce, Q, T))
    ids_b = np.asarray(jax.jit(b)(C_sap, C_dce, Q, T))
    for ra, rb in zip(ids_a, ids_b):
        assert set(ra.tolist()) == set(rb.tolist())


def test_scan_step_recall():
    ds, C_sap, C_dce, Q, T = _setup()
    mesh = make_mesh((1,), ("data",))
    step = build_secure_scan_step(mesh, k=10, k_prime=64)
    ids = np.asarray(jax.jit(step)(C_sap, C_dce, Q, T))
    rec = synth.recall_at_k(ids, ds.gt, 10)
    assert rec >= 0.9, rec


def test_bf16_filter_preserves_recall():
    """§Perf cell 3 it.2: bf16 quantization of DCPE ciphertexts is ~1e-3
    of the SAP perturbation radius — candidate sets are unchanged."""
    ds, C_sap, C_dce, Q, T = _setup(n=2000, nq=10)
    kp = 64

    def cands(Cm, Qm):
        out = []
        for qi in range(Qm.shape[0]):
            d = ((Cm - Qm[qi]) ** 2).sum(1)
            out.append(set(np.argsort(d)[:kp].tolist()))
        return out

    c32 = cands(C_sap.astype(np.float32), Q.astype(np.float32))
    Cb = np.asarray(jnp.asarray(C_sap, jnp.bfloat16), np.float32)
    Qb = np.asarray(jnp.asarray(Q, jnp.bfloat16), np.float32)
    c16 = cands(Cb, Qb)
    overlap = np.mean([len(a & b) / kp for a, b in zip(c32, c16)])
    assert overlap >= 0.97, overlap
