"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracle.

Shape/dtype sweeps per the repo conventions; hypothesis-driven irregular
shape sweeps live in test_properties.py (dev-only dependency).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dce
from repro.kernels.dce_comp import ops as dce_ops
from repro.kernels.dce_comp import ref as dce_ref
from repro.kernels.l2_topk import ops as l2_ops
from repro.kernels.l2_topk import ref as l2_ref


# ---------------------------------------------------------------- l2_topk

@pytest.mark.parametrize("nq,n,d", [
    (1, 1, 2), (3, 17, 5), (8, 128, 64), (16, 300, 100),
    (128, 256, 128), (5, 1000, 960), (130, 513, 96),
])
def test_l2_kernel_matches_ref_shapes(nq, n, d):
    rng = np.random.default_rng(nq * 1000 + n + d)
    Q = jnp.asarray(rng.standard_normal((nq, d)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    got = l2_ops.pairwise_sq_dists(Q, X, interpret=True)
    want = l2_ref.pairwise_sq_dists(Q, X)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [
    (jnp.float32, 1e-4), (jnp.bfloat16, 0.3),
])
def test_l2_kernel_dtype_sweep(dtype, tol):
    rng = np.random.default_rng(0)
    Q = jnp.asarray(rng.standard_normal((9, 40)), dtype)
    X = jnp.asarray(rng.standard_normal((77, 40)), dtype)
    got = l2_ops.pairwise_sq_dists(Q, X, interpret=True)
    want = l2_ref.pairwise_sq_dists(Q.astype(jnp.float32),
                                    X.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("n,k,chunk", [(100, 5, 32), (1000, 10, 256),
                                       (257, 20, 64)])
def test_knn_streaming_matches_exact(n, k, chunk):
    rng = np.random.default_rng(n)
    Q = jnp.asarray(rng.standard_normal((7, 24)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((n, 24)), jnp.float32)
    dk, ik = l2_ops.knn(Q, X, k, chunk=chunk, interpret=True)
    dr, ir = l2_ref.knn(Q, X, k)
    np.testing.assert_allclose(dk, dr, rtol=1e-4, atol=1e-4)
    assert (ik == ir).mean() > 0.99     # ties may permute equal distances
    # distances at returned indices must match exactly
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(l2_ref.pairwise_sq_dists(Q, X)),
                           np.asarray(ik), axis=1),
        dr, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- dce_comp

def _make_cipher(n, d, seed):
    rng = np.random.default_rng(seed)
    key = dce.keygen(d, seed=seed)
    P = rng.standard_normal((n, d))
    q = rng.standard_normal((1, d))
    C = dce.encrypt(P, key, seed=seed + 1)
    T = dce.trapgen(q, key, seed=seed + 2)[0]
    dists = ((P - q[0]) ** 2).sum(-1)
    return jnp.asarray(C), jnp.asarray(T), dists


@pytest.mark.parametrize("n,d", [(4, 4), (60, 17), (128, 96),
                                 (200, 128), (50, 960)])
def test_z_matrix_kernel_matches_ref(n, d):
    C, T, _ = _make_cipher(n, d, seed=n + d)
    got = dce_ops.z_matrix(C, T, interpret=True)
    want = dce_ref.z_matrix(C, T)
    # Z is a difference of two large matmul terms (catastrophic-cancellation
    # by design: the randomness cancels); compare against the *gross* term
    # scale, which bounds f32 accumulation-order noise.
    gross = float(jnp.abs((C[:, 0, :] * T) @ C[:, 2, :].T).max())
    atol = 3e-6 * gross * np.sqrt(C.shape[-1]) + 1e-4
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=atol)


@pytest.mark.parametrize("n,d,k", [(64, 32, 10), (150, 100, 7)])
def test_tournament_topk_is_exact_knn(n, d, k):
    """The kernel-ranked top-k equals the true distance ordering (up to f32
    near-ties: any index swap must involve distances equal to ~1e-4 rel)."""
    C, T, dists = _make_cipher(n, d, seed=n)
    idx = np.asarray(dce_ops.top_k_by_wins(C, T, k, interpret=True))
    true = np.argsort(dists)[:k]
    got_d = np.sort(dists[idx])
    want_d = np.sort(dists[true])
    np.testing.assert_allclose(got_d, want_d, rtol=1e-4)


def test_kernel_blockspec_alignment():
    """Non-multiple-of-block shapes round-trip through padding unharmed."""
    C, T, dists = _make_cipher(130, 33, seed=9)
    Z = np.asarray(dce_ops.z_matrix(C, T, block=128, interpret=True))
    true = dists[:, None] - dists[None, :]
    ok = (np.sign(Z) == np.sign(true)) | (np.abs(true) < 1e-5)
    assert ok.mean() > 0.999
