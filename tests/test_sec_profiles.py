"""Security profiles (repro.sec, DESIGN.md §14): registry semantics,
IndexSpec wire round-trips, dummy/padding accounting, and the
acceptance bar — returned real ids bit-identical to `perf` under every
profile, across both schedulers, f32 and quantized ADC filters, and
single + sharded placement.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                       SearchParams, SearchRequest, SearchResult,
                       SecureAnnService, WireFormatError, suggest_beta)
from repro.data import synth
from repro.sec import (DEFAULT_PROFILE, PROFILES, SECURITY_PROFILE_NAMES,
                       SecurityProfile, get_profile)

D = 16
N = 600


def _need_devices(n_shards: int):
    if n_shards > jax.device_count():
        pytest.skip(f"needs {n_shards} devices, have {jax.device_count()} "
                    f"(run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=2)")


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("sift1m", n=N, n_queries=6, d=D, k_gt=10,
                              seed=0)


@pytest.fixture(scope="module")
def owner_and_query(ds):
    spec = IndexSpec(tenant="t", name="base", d=D,
                     sap_beta=suggest_beta(ds.base, fraction=0.05), seed=5)
    owner = DataOwnerClient(spec)
    C_sap, C_dce = owner.encrypt_vectors(ds.base, seed=11)
    user = owner.query_client()
    return spec, owner, C_sap, C_dce, user.encrypt_queries(ds.queries)


def _one(query):
    """Slice a batch EncryptedQuery down to its first query."""
    return dataclasses.replace(query, C_sap=query.C_sap[:1],
                               T=query.T[:1])


def _spec(base, profile, name, *, quant=None, scheduler="flush",
          backend="ivf"):
    extra = dict(n_partitions=8, nprobe=3) if backend == "ivf" else {}
    return dataclasses.replace(base, name=name, backend=backend,
                               scheduler=scheduler, max_batch=8,
                               quantization=quant,
                               security_profile=profile, **extra)


# ---------------------------------------------------------------------------
# Registry + result-width semantics.
# ---------------------------------------------------------------------------

def test_profile_registry():
    assert SECURITY_PROFILE_NAMES == ("perf", "balanced", "hardened",
                                      "oblivious-sketch")
    assert DEFAULT_PROFILE is PROFILES["perf"]
    p = get_profile("hardened")
    assert isinstance(p, SecurityProfile)
    assert get_profile(p) is p                      # idempotent
    with pytest.raises(ValueError, match="unknown security profile"):
        get_profile("bogus")


def test_profile_tier_monotonicity():
    """Each tier flattens at least what the previous one does."""
    perf, bal = get_profile("perf"), get_profile("balanced")
    hard, obl = get_profile("hardened"), get_profile("oblivious-sketch")
    assert not perf.pad_results and not perf.oblivious
    assert bal.pad_results and not bal.oblivious
    assert hard.pad_results and hard.oblivious
    assert obl.pad_results and obl.oblivious
    assert (perf.refine, bal.refine, hard.refine) == ("dce",) * 3
    assert obl.refine == "tee-sketch"


def test_result_width_buckets():
    perf, bal = get_profile("perf"), get_profile("balanced")
    assert perf.result_width(5) == 5                # exact under perf
    assert perf.result_width(100) == 100
    assert bal.result_width(5) == 16                # floor bucket
    assert bal.result_width(16) == 16
    assert bal.result_width(17) == 32               # next pow2
    assert get_profile("hardened").result_width(33) == 64


def test_tee_refine_cost_model():
    cost = get_profile("oblivious-sketch").tee_refine_cost(80, 32)
    assert cost["mode"] == "tee-sketch"
    assert cost["comparisons"] == 80 * 80
    # the multiplier is dominated by the 40x FHE comparison slowdown
    assert cost["est_cost_vs_dce_x"] > cost["fhe_comparison_slowdown_x"]


# ---------------------------------------------------------------------------
# IndexSpec wire round-trip + validation.
# ---------------------------------------------------------------------------

def test_indexspec_security_profile_wire_roundtrip():
    spec = IndexSpec(tenant="t", name="c", d=D,
                     security_profile="hardened")
    assert IndexSpec.from_bytes(spec.to_bytes()) == spec
    # additive wire versioning: payloads from before the field
    d = spec.to_dict()
    del d["security_profile"]
    assert IndexSpec.from_dict(d).security_profile == "perf"


def test_indexspec_rejects_bad_profiles():
    with pytest.raises(ValueError, match="security_profile"):
        IndexSpec(tenant="t", name="c", d=D, security_profile="bogus")
    # graph traversal is data-dependent by construction — no oblivious
    # variant exists for hnsw
    with pytest.raises(ValueError, match="scan-oblivious"):
        IndexSpec(tenant="t", name="c", d=D, backend="hnsw",
                  security_profile="hardened")
    # balanced never touches the scan, so hnsw is fine
    IndexSpec(tenant="t", name="c", d=D, backend="hnsw",
              security_profile="balanced")


# ---------------------------------------------------------------------------
# The acceptance bar: real ids bit-identical to perf under every
# profile — both schedulers, f32 + quantized ADC filters.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["flush", "continuous"])
@pytest.mark.parametrize("quant", [None, "int8"])
def test_cross_profile_id_parity(ds, owner_and_query, scheduler, quant):
    spec0, owner, C_sap, C_dce, query = owner_and_query
    params = SearchParams(k=8, ratio_k=6.0)
    got = {}
    for profile in ("perf", "balanced", "hardened"):
        spec = _spec(spec0, profile, f"par-{profile}", quant=quant,
                     scheduler=scheduler)
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            svc.insert("t", spec.name, C_sap, C_dce)
            batch = svc.submit(SearchRequest(
                tenant="t", collection=spec.name, query=query,
                params=params, coalesce=False))
            one = svc.submit(SearchRequest(          # scheduler path
                tenant="t", collection=spec.name, query=_one(query),
                params=params))
        # padding profiles widen the id matrix to the pow2 bucket...
        width = get_profile(profile).result_width(params.k)
        assert batch.k == width and one.k == width
        got[profile] = (batch.ids_lists(), [one.ids_lists()[0]])
    for profile in ("balanced", "hardened"):
        for ref, ids in zip(got["perf"], got[profile]):
            # ...but the real ids are bit-identical to perf
            for a, b in zip(ref, ids):
                np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("n_shards", [1, 2])
def test_cross_profile_parity_sharded(ds, owner_and_query, n_shards):
    _need_devices(n_shards)
    spec0, owner, C_sap, C_dce, query = owner_and_query
    params = SearchParams(k=8, ratio_k=6.0)
    got = {}
    for profile in ("perf", "hardened"):
        spec = _spec(spec0, profile, f"sh-{profile}")
        with SecureAnnService() as svc:
            svc.create_collection(spec, placement=PlacementSpec(
                kind="sharded", n_shards=n_shards))
            svc.insert("t", spec.name, C_sap, C_dce)
            res = svc.submit(SearchRequest(
                tenant="t", collection=spec.name, query=query,
                params=params, coalesce=False))
            assert res.stats.backend == "sharded-ivf"
            got[profile] = res.ids_lists()
    for a, b in zip(got["perf"], got["hardened"]):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Dummy-query + padded-byte accounting (telemetry, DESIGN.md §14).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["flush", "continuous"])
def test_dummy_and_padding_accounting(ds, owner_and_query, scheduler):
    spec0, owner, C_sap, C_dce, query = owner_and_query
    params = SearchParams(k=8, ratio_k=6.0)
    for profile in ("perf", "balanced", "hardened"):
        # flush: a lone balanced request sits alone in bucket 1 (0
        # dummies); hardened pads every flush to max_batch (7).  The
        # continuous slot table is always full-shape, so any dummy-
        # padding profile accounts all 7 unoccupied slots there.
        want_dummies = 0 if profile == "perf" else \
            7 if (profile == "hardened" or scheduler == "continuous") else 0
        spec = _spec(spec0, profile, f"acct-{profile}",
                     scheduler=scheduler)
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            svc.insert("t", spec.name, C_sap, C_dce)
            res = svc.submit(SearchRequest(      # lone coalesced query
                tenant="t", collection=spec.name, query=_one(query),
                params=params))
            st = svc.stats("t", spec.name)
        assert res.stats.n_dummy_queries == want_dummies
        assert st["n_dummy_queries"] == want_dummies
        assert st["security_profile"] == profile
        if get_profile(profile).pad_results:
            # k=8 -> 16-column bucket: 8 pad cols x 8 bytes recorded
            assert st["padded_result_bytes"] > 0
        else:
            assert st["padded_result_bytes"] == 0


def test_padded_result_wire_roundtrip(ds, owner_and_query):
    spec0, owner, C_sap, C_dce, query = owner_and_query
    spec = _spec(spec0, "balanced", "wire-bal")
    with SecureAnnService() as svc:
        svc.create_collection(spec)
        svc.insert("t", spec.name, C_sap, C_dce)
        res = svc.submit(SearchRequest(
            tenant="t", collection=spec.name, query=query,
            params=SearchParams(k=8, ratio_k=6.0), coalesce=False))
    assert res.k == 16 and (res.ids[:, 8:] == -1).all()
    rt = SearchResult.from_bytes(res.to_bytes())
    np.testing.assert_array_equal(rt.ids, res.ids)
    for a, b in zip(rt.ids_lists(), res.ids_lists()):
        np.testing.assert_array_equal(a, b)      # -1 padding stripped
        assert (a >= 0).all() and len(a) <= 8
