"""Serving tests: LM generate loop + distributed secure ANN engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import dce, dcpe, ppanns
from repro.data import synth
from repro.models import Model
from repro.serving import DistributedSecureANN, LMServer


def test_lm_generate_greedy_consistent_with_forward():
    cfg = dataclasses.replace(get_config("qwen3-1.7b").smoke(), remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size, jnp.int32)
    out = server.generate({"tokens": toks}, max_new_tokens=4)
    assert out.shape == (2, 4)
    # first generated token == argmax of the forward logits at last position
    full = model.forward(params, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(jnp.argmax(full[:, -1], -1)))


def test_lm_generate_ssm_family():
    cfg = dataclasses.replace(get_config("mamba2-370m").smoke(), remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size, jnp.int32)
    out = server.generate({"tokens": toks}, max_new_tokens=3)
    assert out.shape == (2, 3)


def test_distributed_secure_ann_matches_exact():
    ds = synth.make_dataset("deep1m", n=1500, n_queries=10, k_gt=20, seed=5)
    owner = ppanns.DataOwner(d=ds.d, sap_beta=0.5, seed=3)
    C_sap = dcpe.encrypt(ds.base, owner.keys.sap_key, seed=4)
    C_dce = dce.encrypt(ds.base, owner.keys.dce_key, seed=5)
    user = ppanns.User(owner.share_keys())

    eng = DistributedSecureANN(C_sap, C_dce, mesh=None)
    Q_sap, T_q = [], []
    for q in ds.queries:
        cs, tq = user.encrypt_query(q)
        Q_sap.append(cs)
        T_q.append(tq)
    ids = eng.query_batch(np.stack(Q_sap), np.stack(T_q), k=10, ratio_k=8)
    rec = synth.recall_at_k(ids, ds.gt, 10)
    assert rec >= 0.9, rec


def test_distributed_secure_ann_on_mesh():
    """Single-device mesh exercises the sharded code path end-to-end."""
    ds = synth.make_dataset("deep1m", n=700, n_queries=5, k_gt=10, seed=6)
    owner = ppanns.DataOwner(d=ds.d, sap_beta=0.5, seed=4)
    C_sap = dcpe.encrypt(ds.base, owner.keys.sap_key, seed=7)
    C_dce = dce.encrypt(ds.base, owner.keys.dce_key, seed=8)
    user = ppanns.User(owner.share_keys())
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    eng = DistributedSecureANN(C_sap, C_dce, mesh=mesh)
    assert eng.n_padded % 1 == 0
    cs, tq = user.encrypt_query(ds.queries[0])
    ids = eng.query_batch(cs[None], tq[None], k=5, ratio_k=10)
    assert len(set(ids[0].tolist()) & set(ds.gt[0, :5].tolist())) >= 4
