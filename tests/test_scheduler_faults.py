"""Fault injection against both serving schedulers (DESIGN.md §12, §16).

The liveness contract: a fault — the engine raising mid-step, a client
cancelling a request that is already being computed, `close()` landing
while a drain is in flight — never takes the scheduler down.  Under the
default `EngineRetryPolicy` a transient batch failure is recovered
per-request (each rider re-runs individually at an already-compiled
shape); under `max_attempts=1` the pre-resilience batch-wide failure is
restored.  Either way the scheduler thread survives, later requests are
served correctly, and nothing wedges.  A *poison* query — one that
fails every attempt — is quarantined alone: its batchmates still get
their results (the regression this file pins down).
"""

import threading
from concurrent.futures import CancelledError

import jax
import numpy as np
import pytest

from repro.core import dcpe
from repro.data import synth
from repro.serving.runtime import Collection, MicroBatcher, SlotLoop
from repro.serving.runtime.batcher import EngineRetryPolicy
from repro.serving.search_engine import SearchStats

D = 18
K = 5
KINDS = ("flush", "continuous")

# restores the pre-resilience contract: a failed batch fails its riders
NO_RETRY = EngineRetryPolicy(max_attempts=1)


class FaultyEngine:
    """Deterministic ids (base = round(Q[i,0]), +arange(k)) with fault
    hooks: `fail_next` raises once; `poison` (a set of query bases)
    raises whenever a poisoned query rides the call — including its own
    retries; `in_call`/`gate` expose the window while a step computes."""

    def __init__(self):
        self.fail_next = False
        self.poison = set()
        self.in_call = threading.Event()
        self.gate = threading.Event()
        self.gate.set()
        self.n_calls = 0

    def __call__(self, Q, T, k, ratio_k=8.0, ef_search=96):
        self.in_call.set()
        try:
            self.gate.wait(timeout=10.0)
            self.n_calls += 1
            Q = np.atleast_2d(Q)
            base = np.round(Q[:, 0]).astype(np.int64)
            if self.fail_next:
                self.fail_next = False
                raise RuntimeError("injected engine fault")
            if self.poison & set(base.tolist()):
                raise RuntimeError("poison query fault")
            ids = base[:, None] + np.arange(k)[None, :]
            return ids, SearchStats(latency_s=0.0, filter_dist_evals=0,
                                    refine_comparisons=0, bytes_up=0,
                                    bytes_down=0, n_queries=Q.shape[0],
                                    backend="faulty")
        finally:
            self.in_call.clear()


def _mk(kind, eng, **kw):
    # real clock on purpose: these tests assert resolution and liveness,
    # never timing, and the flush deadline must fire on its own here
    kw.setdefault("max_batch", 4)
    if kind == "flush":
        return MicroBatcher(eng, max_wait_ms=5.0, **kw)
    return SlotLoop(eng, **kw)


def _req(i):
    return np.full(D, float(i), np.float32), np.zeros(2 * D + 16, np.float32)


@pytest.mark.parametrize("kind", KINDS)
def test_transient_fault_recovered_per_request(kind):
    """Default policy: a one-shot batch failure is invisible to the
    riders — each re-runs individually and resolves with exact ids."""
    eng = FaultyEngine()
    eng.gate.clear()
    with _mk(kind, eng) as sched:
        eng.fail_next = True
        futs = [sched.submit(*_req(i), K) for i in (1, 2)]
        eng.gate.set()
        for i, fut in zip((1, 2), futs):
            np.testing.assert_array_equal(fut.result(timeout=10),
                                          i + np.arange(K))
        assert sched.n_retries == 2          # one retry per rider
        assert sched.n_quarantined == 0
        if kind == "continuous":
            assert sched.n_active == 0


@pytest.mark.parametrize("kind", KINDS)
def test_poison_query_quarantined_alone(kind):
    """THE batch-blast regression: a query that fails every attempt is
    quarantined with its own exception; its batchmates still answer."""
    eng = FaultyEngine()
    eng.poison = {2}
    eng.gate.clear()
    with _mk(kind, eng) as sched:
        futs = {i: sched.submit(*_req(i), K) for i in (1, 2, 3)}
        eng.gate.set()
        with pytest.raises(RuntimeError, match="poison query fault"):
            futs[2].result(timeout=10)
        for i in (1, 3):                     # batchmates unharmed
            np.testing.assert_array_equal(futs[i].result(timeout=10),
                                          i + np.arange(K))
        assert sched.n_quarantined == 1
        # quarantine is terminal for that request only: new submits of
        # non-poison queries keep working
        np.testing.assert_array_equal(
            sched.submit(*_req(7), K).result(timeout=10), 7 + np.arange(K))


@pytest.mark.parametrize("kind", KINDS)
def test_engine_fault_fails_only_that_step_no_retry(kind):
    """max_attempts=1: the pre-resilience contract — a raising step
    fails exactly the futures riding it; the worker survives and the
    very next step succeeds (slots/buckets freed)."""
    eng = FaultyEngine()
    eng.gate.clear()
    with _mk(kind, eng, retry_policy=NO_RETRY) as sched:
        eng.fail_next = True
        doomed = [sched.submit(*_req(i), K) for i in (1, 2)]
        eng.gate.set()
        for fut in doomed:
            with pytest.raises(RuntimeError, match="injected engine fault"):
                fut.result(timeout=10)
        ok = sched.submit(*_req(3), K)          # scheduler still alive,
        np.testing.assert_array_equal(ok.result(timeout=10),
                                      3 + np.arange(K))
        if kind == "continuous":                # and its slots were freed
            assert sched.n_active == 0


@pytest.mark.parametrize("kind", KINDS)
def test_repeated_faults_never_wedge_the_scheduler(kind):
    eng = FaultyEngine()
    with _mk(kind, eng, retry_policy=NO_RETRY) as sched:
        for i in range(1, 6):
            eng.fail_next = True
            with pytest.raises(RuntimeError):
                sched.submit(*_req(i), K).result(timeout=10)
            good = sched.submit(*_req(10 + i), K).result(timeout=10)
            np.testing.assert_array_equal(good, 10 + i + np.arange(K))


@pytest.mark.parametrize("kind", KINDS)
def test_cancel_racing_emission(kind):
    """cancel() landing while the request's step is mid-computation: the
    emission path hits an already-cancelled future and must shrug it off
    — no InvalidStateError escapes, the next request is served."""
    eng = FaultyEngine()
    with _mk(kind, eng) as sched:
        for i in range(1, 8):                   # repeat: widen the race
            eng.gate.clear()
            fut = sched.submit(*_req(i), K)
            assert eng.in_call.wait(timeout=10)  # step is computing NOW
            fut.cancel()                         # race the emission
            eng.gate.set()
            ok = sched.submit(*_req(100 + i), K)
            np.testing.assert_array_equal(ok.result(timeout=10),
                                          100 + i + np.arange(K))
            assert fut.done()                    # cancelled or resolved,
            if not fut.cancelled():              # never leaked pending
                assert fut.result(timeout=0).shape == (K,)


@pytest.mark.parametrize("kind", KINDS)
def test_close_during_drain_serves_or_fails_never_wedges(kind):
    """close() while a step is wedged in the engine: the drain finishes
    once the engine returns, every accepted future resolves, close()
    returns, and later submits are rejected cleanly."""
    eng = FaultyEngine()
    eng.gate.clear()
    sched = _mk(kind, eng)
    futs = [sched.submit(*_req(i), K) for i in range(1, 7)]
    closer = threading.Thread(target=sched.close)
    closer.start()
    assert eng.in_call.wait(timeout=10)         # close raced a live step
    eng.gate.set()
    closer.join(timeout=30)
    assert not closer.is_alive(), "close() wedged during drain"
    for i, fut in enumerate(futs, start=1):
        assert fut.done()
        np.testing.assert_array_equal(fut.result(timeout=0),
                                      i + np.arange(K))
    with pytest.raises(RuntimeError):
        sched.submit(*_req(99), K)


@pytest.mark.parametrize("kind", KINDS)
def test_cancelled_requests_dropped_by_close(kind):
    """Requests still queued when close() lands are drained; requests a
    client discarded first stay cancelled — exactly-once either way."""
    eng = FaultyEngine()
    eng.gate.clear()
    sched = _mk(kind, eng, max_batch=1)
    kept = sched.submit(*_req(1), K)
    dropped = sched.submit(*_req(2), K)
    sched.discard(dropped)
    eng.gate.set()
    sched.close()
    np.testing.assert_array_equal(kept.result(timeout=0), 1 + np.arange(K))
    assert dropped.cancelled()
    with pytest.raises(CancelledError):
        dropped.result(timeout=0)


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        EngineRetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        EngineRetryPolicy(backoff_s=-1.0)


@pytest.mark.parametrize("kind", KINDS)
def test_parity_assertion_never_retried(kind):
    """AssertionError is a deterministic bug (verify_parity), not a
    transient fault: no retry, the failure propagates immediately."""

    def bad_engine(Q, T, k, ratio_k=8.0, ef_search=96):
        raise AssertionError("parity mismatch")

    with _mk(kind, bad_engine) as sched:
        with pytest.raises(AssertionError, match="parity mismatch"):
            sched.submit(*_req(1), K).result(timeout=10)
        assert sched.n_retries == 0


# ---------------------------------------------------------------------------
# Real engines, single + sharded placement: inject a one-shot fault into
# the collection's _run_batch and require transparent recovery with
# exact ids (DESIGN.md §16: the fault is invisible to the client).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("sift1m", n=250, n_queries=5, k_gt=10,
                              seed=4, d=D)


@pytest.mark.parametrize("placement_kind", ["single", "sharded"])
@pytest.mark.parametrize("kind", KINDS)
def test_real_engine_fault_recovery(ds, kind, placement_kind):
    placement = None
    if placement_kind == "sharded":
        from repro.api import PlacementSpec
        placement = PlacementSpec(kind="sharded",
                                  n_shards=min(2, jax.device_count()))
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    col = Collection("t", f"flt-{kind}-{placement_kind}", D, sap_beta=beta,
                     seed=9, scheduler=kind, max_batch=4, max_wait_ms=2.0,
                     placement=placement)
    try:
        col.insert(ds.base)
        col.compact()
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries]
        baseline = [col.search(*e, K) for e in enc]

        real = col.batcher._run_batch
        state = {"armed": True}

        def faulty(Q, T, k, **kw):
            if state["armed"]:
                state["armed"] = False
                raise RuntimeError("injected mid-stream fault")
            return real(Q, T, k, **kw)

        col.batcher._run_batch = faulty
        # default retry: the one-shot fault is recovered per-request —
        # the whole stream answers bit-identically to the baseline and
        # the client never sees the exception
        for e, want in zip(enc, baseline):
            np.testing.assert_array_equal(col.search(*e, K), want)
        assert col.telemetry.snapshot()["n_retries"] >= 1
    finally:
        col.close()
