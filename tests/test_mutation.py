"""Index-mutation semantics (ISSUE 2 satellite): HNSW insert/delete after
build preserves recall and never returns deleted ids, and the engine
parity guarantee (looped == batched) survives a mutation sequence on
every filter backend, through the runtime's delta-aware store
(DESIGN.md §8).
"""

import numpy as np
import pytest

from repro.core import dcpe
from repro.core.hnsw import HNSW
from repro.data import synth
from repro.serving.runtime import Collection

K = 10
BACKENDS = ["flat", "ivf", "hnsw"]


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("deep1m", n=700, n_queries=10, k_gt=30,
                              seed=11, d=32)


def _collection(ds, backend, **kw):
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    kw.setdefault("compact_every", 10_000)     # explicit compaction only
    if backend == "ivf":
        kw.setdefault("n_partitions", 16)
        kw.setdefault("nprobe", 8)
    if backend == "hnsw":
        kw.setdefault("hnsw_M", 12)
        kw.setdefault("hnsw_ef_construction", 100)
    return Collection("t0", "c0", ds.d, backend=backend, sap_beta=beta,
                      seed=11, **kw)


def _enc_queries(col, queries):
    user = col.new_user()
    qs, ts = zip(*(user.encrypt_query(q) for q in queries))
    return np.stack(qs), np.stack(ts)


# ---------------------------------------------------------------- core HNSW


def test_hnsw_mutation_sequence_preserves_recall(ds):
    """build -> insert burst -> delete burst: recall against the exact
    ground truth of the surviving set stays high, deleted ids never
    surface (plaintext graph level, paper §V-D)."""
    idx = HNSW(dim=ds.d, M=12, ef_construction=100, seed=2)
    idx.build(ds.base[:500])
    for x in ds.base[500:600]:
        idx.insert(x)
    deleted = list(range(0, 60, 2)) + list(range(500, 530))
    for node in deleted:
        idx.delete(node)
    alive = np.setdiff1d(np.arange(600), deleted)
    gt = synth.ground_truth(ds.base[alive], ds.queries, K)
    found = np.stack([idx.search(q, K, ef=96)[0] for q in ds.queries])
    assert not np.isin(found, deleted).any()
    mapped_gt = alive[gt]
    rec = np.mean([len(set(f) & set(g)) / K
                   for f, g in zip(found.tolist(), mapped_gt.tolist())])
    assert rec >= 0.8, rec


def test_hnsw_delete_then_reinsert_region(ds):
    """Deleting a whole neighborhood and inserting replacements keeps the
    graph navigable (repair + incremental insert compose)."""
    idx = HNSW(dim=ds.d, M=12, ef_construction=100, seed=3)
    idx.build(ds.base[:300])
    victims = synth.ground_truth(ds.base[:300], ds.queries[:1], 5)[0]
    for v in victims:
        idx.delete(int(v))
    new_nodes = [idx.insert(ds.queries[0] + 1e-3 * ds.base[i, 0])
                 for i in range(3)]
    ids, _ = idx.search(ds.queries[0], 5, ef=96)
    assert not np.isin(ids, victims).any()
    assert set(new_nodes) <= set(ids.tolist())


# ------------------------------------------------- engine-level, per backend


@pytest.mark.parametrize("backend", BACKENDS)
def test_mutation_semantics_per_backend(ds, backend):
    """Searches issued after insert/delete see inserts immediately and
    never return deleted ids — across all three filter backends."""
    col = _collection(ds, backend)
    try:
        col.insert(ds.base[:600])
        Q, T = _enc_queries(col, ds.queries)
        # a planted duplicate of query 0 must be returned as a neighbor
        new = col.insert(ds.queries[0][None])
        ids, _ = col.search_batch(Q[:1], T[:1], K, ratio_k=8, ef_search=128)
        assert new[0] in ids[0], (backend, new, ids)
        # delete it (plus a true neighbor): neither may ever come back
        victim = int(ds.gt[1, 0])
        col.delete([int(new[0]), victim])
        ids2, _ = col.search_batch(Q[:4], T[:4], K, ratio_k=8,
                                   ef_search=128)
        assert not np.isin(ids2, [int(new[0]), victim]).any(), backend
        # surviving results still have high recall
        rec = synth.recall_at_k(ids2, ds.gt[:4], K)
        assert rec >= 0.7, (backend, rec)
    finally:
        col.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_after_mutation_sequence(ds, backend):
    """Looped batch-of-one == batched, exactly, after a mutation sequence
    (insert burst, deletes, second insert burst, compaction)."""
    col = _collection(ds, backend)
    try:
        col.insert(ds.base[:500])
        col.delete(np.arange(0, 40, 4))
        col.insert(ds.base[500:640])
        col.delete(np.arange(520, 540, 3))
        col.compact()
        col.insert(ds.base[640:700])          # fresh delta after compact
        Q, T = _enc_queries(col, ds.queries)
        batched, stats = col.search_batch(Q, T, K, ratio_k=6)
        assert stats.backend == backend
        for qi in range(Q.shape[0]):
            single, _ = col.search_batch(Q[qi: qi + 1], T[qi: qi + 1], K,
                                         ratio_k=6)
            np.testing.assert_array_equal(batched[qi], single[0],
                                          err_msg=f"{backend} q{qi}")
    finally:
        col.close()


@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_compaction_preserves_results(ds, backend):
    """Promoting delta -> main changes acceleration state, not answers
    (flat exactly; IVF up to probe-set drift, bounded by recall)."""
    col = _collection(ds, backend)
    try:
        col.insert(ds.base[:400])
        col.compact()
        col.insert(ds.base[400:650])          # large live delta
        col.delete([5, 405])
        Q, T = _enc_queries(col, ds.queries)
        before, _ = col.search_batch(Q, T, K, ratio_k=8, ef_search=128)
        col.compact()
        after, _ = col.search_batch(Q, T, K, ratio_k=8, ef_search=128)
        if backend == "flat":
            for b, a in zip(before.tolist(), after.tolist()):
                assert set(b) == set(a)
        else:
            rec = synth.recall_at_k(after, ds.gt, K)
            assert rec >= 0.7, rec
        assert not np.isin(after, [5, 405]).any()
    finally:
        col.close()


def test_delete_unknown_id_raises(ds):
    col = _collection(ds, "flat")
    try:
        col.insert(ds.base[:20])
        with pytest.raises(KeyError):
            col.delete([100])
        col.delete([3])
        with pytest.raises(KeyError):          # double delete
            col.delete([3])
    finally:
        col.close()


def test_delete_batch_with_bad_id_is_atomic(ds):
    """A batch containing one invalid id mutates nothing, and the
    collection keeps serving correct results afterwards."""
    col = _collection(ds, "flat")
    try:
        col.insert(ds.base[:200])
        col.compact()
        Q, T = _enc_queries(col, ds.queries[:2])
        victim = int(ds.gt[0, 0])
        with pytest.raises(KeyError):
            col.delete([victim, 999_999])       # second id is bogus
        assert col.store.n_alive == 200         # nothing was tombstoned
        ids, _ = col.search_batch(Q, T, K, ratio_k=8, ef_search=128)
        assert victim in ids[0]                 # victim survived intact
        with pytest.raises(KeyError):
            col.delete([victim, victim])        # duplicate in one batch
        assert col.store.alive_view[victim]
    finally:
        col.close()


def test_flat_delta_candidates_are_globally_distance_sorted(ds):
    """The engine's refine="none" baseline takes cand[:, :k] directly,
    so the flat backend must merge its main and delta scan blocks by
    distance — a delta row nearer than the k-th main row has to appear
    in the first k columns (regression: blocks were concatenated)."""
    col = _collection(ds, "flat")
    try:
        col.insert(ds.base[:300])
        col.compact()
        planted = col.insert(ds.queries[0][None])   # delta: exact match
        user = col.new_user()
        cq, tq = user.encrypt_query(ds.queries[0])
        ids, _ = col._engine.search(cq, tq, K, ratio_k=8, refine="none")
        assert planted[0] in ids, ids
    finally:
        col.close()


def test_ivf_recovers_after_base_region_fully_deleted(ds):
    """Tombstoning every row in the built region must not blind the IVF
    backend to later inserts (regression: ivf stayed None forever)."""
    col = _collection(ds, "ivf")
    try:
        first = col.insert(ds.base[:64])
        col.compact()
        Q, T = _enc_queries(col, ds.queries[:1])
        col.search_batch(Q, T, K)               # builds ivf over main
        col.delete(first)                       # kill the whole base
        planted = col.insert(ds.queries[0][None])
        ids, _ = col.search_batch(Q, T, K, ratio_k=8)
        assert planted[0] in ids[0]
        assert not np.isin(ids, first).any()
    finally:
        col.close()
