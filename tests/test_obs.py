"""End-to-end observability: exact span trees on VirtualClock for both
schedulers and both placements, Prometheus exposition, Chrome-trace
export, kernel profiling, and disabled-mode no-op guarantees
(DESIGN.md §13).

Every tree test is a SCRIPTED interleaving on the injected
`VirtualClock`: the recorder runs on the same clock instance as the
scheduler, so structure, attributes, AND virtual timestamps are
asserted exactly — no sleeps, no tolerance windows.
"""

import dataclasses
import json
import re
import threading
import urllib.request

import jax
import numpy as np
import pytest

from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                       SearchParams, SearchRequest, SecureAnnService,
                       suggest_beta)
from repro.core import dcpe
from repro.data import synth
from repro.obs import (NULL_RECORDER, MetricsRegistry, Observability,
                       TraceRecorder, child_span, current,
                       profile_kernels, start_metrics_server)
from repro.obs import profiler as obs_profiler
from repro.serving.runtime import (Collection, SlotLoop, VirtualClock)
from repro.serving.search_engine import SearchStats

D = 24
K = 5


@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("sift1m", n=200, n_queries=4, d=D,
                              k_gt=K, seed=0)


def _shape(node):
    """Span tree -> (name, [child shapes]) for exact assertions."""
    return (node["name"], [_shape(c) for c in node["children"]])


def _collection(ds, name, vc, rec, **kw):
    col = Collection("t", name, D,
                     sap_beta=dcpe.suggest_beta(ds.base, fraction=0.05),
                     seed=1, clock=vc, tracer=rec, **kw)
    col.insert(ds.base[:64])
    return col


# ------------------------------------------------- flush scheduler tree


def test_flush_two_request_interleaving_exact_tree(ds):
    """Scripted interleaving: r0 parks on the (never-reached) deadline,
    r1 arrives 1 virtual ms later and completes the size-2 bucket — one
    flush serves both.  The full span forest is asserted exactly."""
    vc = VirtualClock()
    rec = TraceRecorder(clock=vc)
    col = _collection(ds, "c", vc, rec, max_batch=2,
                      max_wait_ms=10_000.0)
    try:
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries[:2]]
        f0 = col.submit(*enc[0], K)
        vc.wait_for_waiters(1)             # worker parked on deadline
        vc.advance(0.001)
        f1 = col.submit(*enc[1], K)        # fills the bucket: size flush
        r0, r1 = f0.result(timeout=30), f1.result(timeout=30)
        assert r0.shape == (K,) and r1.shape == (K,)
    finally:
        col.close()

    assert sorted(rec.trace_ids()) == ["t/c:b0", "t/c:i0", "t/c:r0",
                                       "t/c:r1"]
    # ingest trace: one root insert span, no compaction at 64 rows
    (ins,) = rec.tree("t/c:i0")
    assert _shape(ins) == ("insert", [])
    assert ins["attrs"]["n_rows"] == 64
    assert ins["attrs"]["compacted"] is False

    # batch trace: flush root -> filter + refine engine children
    (flush,) = rec.tree("t/c:b0")
    assert _shape(flush) == ("flush", [("filter", []), ("refine", [])])
    assert flush["attrs"]["n_real"] == 2
    assert flush["attrs"]["bucket"] == 2
    assert flush["attrs"]["backend"] == "flat"
    assert flush["attrs"]["n_queries"] == 2
    assert flush["attrs"]["filter_dist_evals"] > 0
    assert flush["attrs"]["filter_bytes_scanned"] > 0
    filt, ref = flush["children"]
    assert filt["attrs"]["nq"] == 2
    assert filt["attrs"]["dist_evals"] == \
        flush["attrs"]["filter_dist_evals"]
    assert ref["attrs"]["comparisons"] == \
        flush["attrs"]["refine_comparisons"]

    # request traces: admission -> queue -> flush -> emit, exact times
    (req0,) = rec.tree("t/c:r0")
    assert _shape(req0) == ("request",
                            [("queue", []), ("flush", []), ("emit", [])])
    assert req0["attrs"]["scheduler"] == "microbatcher"
    assert req0["attrs"]["k"] == K
    assert req0["attrs"]["backend"] == "flat"      # closed with stats
    q0, fl0, em0 = req0["children"]
    assert (q0["t_start"], q0["t_end"]) == (0.0, 0.001)
    assert (fl0["t_start"], fl0["t_end"]) == (0.001, 0.001)
    assert (em0["t_start"], em0["t_end"]) == (0.001, 0.001)
    assert fl0["attrs"]["batch"] == "t/c:b0"       # request -> batch link
    assert (req0["t_start"], req0["t_end"]) == (0.0, 0.001)

    (req1,) = rec.tree("t/c:r1")
    q1 = req1["children"][0]
    assert (q1["t_start"], q1["t_end"]) == (0.001, 0.001)
    assert req1["children"][1]["attrs"]["batch"] == "t/c:b0"


# -------------------------------------------- continuous scheduler tree


def test_continuous_scheduler_exact_tree(ds):
    """Two sequential requests through the slot loop: each gets its own
    step trace; the request tree swaps `flush` for `slot` (occupancy)."""
    vc = VirtualClock()
    rec = TraceRecorder(clock=vc)
    col = _collection(ds, "s", vc, rec, scheduler="continuous",
                      max_batch=2)
    try:
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries[:2]]
        assert col.submit(*enc[0], K).result(timeout=30).shape == (K,)
        assert col.submit(*enc[1], K).result(timeout=30).shape == (K,)
    finally:
        col.close()

    for i in range(2):
        (req,) = rec.tree(f"t/s:r{i}")
        assert _shape(req) == ("request", [("queue", []), ("slot", []),
                                           ("emit", [])])
        assert req["attrs"]["scheduler"] == "slotloop"
        slot = req["children"][1]
        assert slot["attrs"]["batch"] == f"t/s:s{i}"
        (step,) = rec.tree(f"t/s:s{i}")
        assert _shape(step) == ("step", [("filter", []), ("refine", [])])
        assert step["attrs"]["n_active"] == 1
        assert step["attrs"]["capacity"] == 2


def test_slot_loop_shared_step_interleaving():
    """Scripted interleaving on the bare slot loop: A stalls in step s0;
    B and C are admitted while s0 is in flight and ride step s1
    TOGETHER — the slot spans name the shared step trace."""
    entered, gate = threading.Event(), threading.Event()
    calls = []

    def eng(Q, T, k, ratio_k=8.0, ef_search=96):
        entered.set()
        gate.wait(timeout=10.0)
        Q = np.atleast_2d(Q)
        calls.append(Q.shape)
        ids = np.round(Q[:, 0]).astype(np.int64)[:, None] + np.arange(k)
        return ids, SearchStats(latency_s=0.0, filter_dist_evals=0,
                                refine_comparisons=0, bytes_up=0,
                                bytes_down=0, n_queries=Q.shape[0],
                                backend="fake")

    def req(i):
        return np.full(D, float(i), np.float32), np.zeros(2 * D + 16,
                                                          np.float32)

    vc = VirtualClock()
    rec = TraceRecorder(clock=vc)
    with SlotLoop(eng, max_batch=4, d=D, cdim=2 * D + 16, clock=vc,
                  name="nm", tracer=rec) as sl:
        fa = sl.submit(*req(1), K)
        assert entered.wait(timeout=10.0)  # A's step s0 is in flight
        entered.clear()
        fb = sl.submit(*req(2), K)         # queued during s0
        fc = sl.submit(*req(3), K)         # queued during s0
        gate.set()
        for i, f in zip((1, 2, 3), (fa, fb, fc)):
            np.testing.assert_array_equal(f.result(timeout=10),
                                          i + np.arange(K))

    def batch_of(tid):
        (tree,) = rec.tree(tid)
        assert _shape(tree) == ("request", [("queue", []), ("slot", []),
                                            ("emit", [])])
        return tree["children"][1]["attrs"]["batch"]

    assert batch_of("nm:r0") == "nm:s0"
    assert batch_of("nm:r1") == "nm:s1"    # B and C share one step
    assert batch_of("nm:r2") == "nm:s1"
    (s1,) = rec.tree("nm:s1")
    assert s1["attrs"]["n_active"] == 2


# ------------------------------------------------- sharded placement


def test_sharded_placement_emits_per_shard_spans(ds):
    """Sharded placement: the filter span carries one retroactive child
    per shard with that shard's row range and live count."""
    n_shards = min(2, jax.device_count())
    vc = VirtualClock()
    rec = TraceRecorder(clock=vc)
    col = _collection(ds, "sh", vc, rec, max_batch=2, max_wait_ms=5.0,
                      placement=PlacementSpec(kind="sharded",
                                              n_shards=n_shards))
    try:
        user = col.new_user()
        fut = col.submit(*user.encrypt_query(ds.queries[0]), K)
        vc.wait_for_waiters(1)
        vc.advance(0.01)                   # past the 5 ms deadline
        assert fut.result(timeout=60).shape == (K,)
    finally:
        col.close()

    (flush,) = rec.tree("t/sh:b0")
    expect_shards = [(f"shard{i}", []) for i in range(n_shards)]
    assert _shape(flush) == ("flush", [("filter", expect_shards),
                                       ("refine", [])])
    shards = flush["children"][0]["children"]
    assert [s["attrs"]["shard"] for s in shards] == list(range(n_shards))
    assert sum(s["attrs"]["n_alive"] for s in shards) == 64
    assert shards[-1]["attrs"]["row_stop"] >= 64


# ------------------------------------------ service surface + exports


def test_service_obs_surface_and_exports(ds, tmp_path):
    spec = IndexSpec(tenant="t", name="svc", d=D,
                     sap_beta=suggest_beta(ds.base, fraction=0.05),
                     max_wait_ms=4.0, seed=3)
    owner = DataOwnerClient(spec)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    query = owner.query_client().encrypt_queries(ds.queries)
    with SecureAnnService(obs=True) as svc:
        assert isinstance(svc.obs, Observability)
        svc.create_collection(spec)
        svc.insert("t", "svc", C_sap, C_dce)
        res = svc.submit(SearchRequest(
            tenant="t", collection="svc", query=query,
            params=SearchParams(k=K), coalesce=False))
        assert res.ids.shape == (len(ds.queries), K)
        # client-propagated correlation id names the request trace
        one = dataclasses.replace(
            query, C_sap=query.C_sap[0], T=query.T[0])
        svc.submit(SearchRequest(tenant="t", collection="svc", query=one,
                                 params=SearchParams(k=K),
                                 trace_id="corr-42"))
        assert "corr-42" in svc.obs.recorder.trace_ids()
        (req,) = svc.obs.recorder.tree("corr-42")
        assert req["name"] == "request"

        text = svc.metrics_text()
        out = tmp_path / "trace.json"
        svc.export_chrome_trace(str(out))
        events = svc.trace_events()

    # prometheus exposition parses line-by-line
    sample = re.compile(r'^[a-zA-Z_:][a-zA-Z0-9_:]*'
                        r'(\{[^{}]*\})? (\+Inf|[-+0-9.eE]+)$')
    for line in text.strip().splitlines():
        assert line.startswith("#") or sample.match(line), line
    assert "ann_requests_total" in text
    assert 'ann_request_latency_seconds_bucket' in text
    # histogram buckets are cumulative and end at +Inf == _count
    buckets = re.findall(
        r'ann_request_latency_seconds_bucket\{[^}]*collection="svc"'
        r'[^}]*le="([^"]+)"\} (\d+)', text)
    counts = [int(c) for _, c in buckets]
    assert counts == sorted(counts) and buckets[-1][0] == "+Inf"
    (count,) = re.findall(
        r'ann_request_latency_seconds_count\{[^}]*collection="svc"'
        r'[^}]*\} (\d+)', text)
    assert int(count) == counts[-1]

    # chrome trace loads as JSON with well-formed events
    data = json.loads(out.read_text())
    assert data["traceEvents"]
    for ev in data["traceEvents"]:
        assert ev["ph"] in ("X", "M", "i")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and "ts" in ev
    names = {e["args"]["name"] for e in data["traceEvents"]
             if e["ph"] == "M"}
    assert "corr-42" in names              # traces become named threads
    assert any(e["kind"] == "span" for e in events)


def test_service_obs_disabled_is_inert(ds):
    with SecureAnnService() as svc:
        assert svc.obs is None
        assert svc.metrics_text().startswith("# observability disabled")
        assert svc.trace_events() == []
        with pytest.raises(RuntimeError):
            svc.export_chrome_trace("/tmp/nope.json")


def test_trace_id_wire_roundtrip(ds):
    spec = IndexSpec(tenant="t", name="w", d=D, sap_beta=1.0, seed=0)
    query = DataOwnerClient(spec).query_client().encrypt_queries(
        ds.queries[:1])
    req = SearchRequest(tenant="t", collection="w", query=query,
                        params=SearchParams(k=3), trace_id="abc")
    assert SearchRequest.from_bytes(req.to_bytes()).trace_id == "abc"
    bare = dataclasses.replace(req, trace_id=None)
    assert SearchRequest.from_bytes(bare.to_bytes()).trace_id is None


def test_start_metrics_server_scrape():
    class Source:
        def metrics_text(self):
            return "demo_metric 1\n"

    server = start_metrics_server(Source(), 0)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read()
        assert body == b"demo_metric 1\n"
    finally:
        server.shutdown()


# ------------------------------------------------------ kernel profiler


def test_profiler_records_host_calls_not_traced_calls():
    """instrument() wrappers record fenced wall time + bytes for host
    calls, and stay out of the way inside jit traces (Tracer args)."""
    import jax.numpy as jnp

    calls = []

    def fn(x):
        calls.append(type(x).__name__)
        return x * 2.0

    wrapped = obs_profiler.instrument("test.fn", fn)
    x = jnp.ones((8, 4), jnp.float32)
    assert obs_profiler.active_profiler() is None
    with profile_kernels() as prof:
        wrapped(x)                         # host call: recorded
        jax.jit(wrapped)(x)                # trace-time call: skipped
        assert obs_profiler.active_profiler() is prof
    assert obs_profiler.active_profiler() is None
    summary = prof.summary()
    assert summary["test.fn"]["calls"] == 1
    assert summary["test.fn"]["total_bytes"] == x.nbytes
    assert summary["test.fn"]["total_s"] > 0
    assert len(calls) == 2                 # fn itself ran both times


def test_profiler_covers_engine_kernels(ds):
    """A real search under profile_kernels() attributes device time to
    the filter kernel entry point."""
    col = Collection("t", "prof", D, sap_beta=1.0, seed=1, max_batch=2,
                     max_wait_ms=1.0)
    try:
        col.insert(ds.base[:64])
        user = col.new_user()
        with profile_kernels() as prof:
            col.search(*user.encrypt_query(ds.queries[0]), K)
        assert prof.total_seconds("l2_topk") > 0
        assert prof.total_bytes("l2_topk") > 0
    finally:
        col.close()


# ------------------------------------------------------- disabled mode


def test_disabled_mode_is_noop(ds):
    """No tracer attached: child_span hands out the one shared no-op
    span, no ambient context exists, and nothing records."""
    assert current() is None
    sp = child_span("anything", x=1)
    assert sp is child_span("other")       # the same shared instance
    with sp as s:
        s.set(y=2)
    with NULL_RECORDER.span("op", "tid") as s:
        s.set(z=3)                         # ingest-path fallback CM
    assert NULL_RECORDER.spans() == []
    assert NULL_RECORDER.tree("tid") == []

    col = Collection("t", "off", D, sap_beta=1.0, seed=1, max_batch=2,
                     max_wait_ms=1.0)
    try:
        col.insert(ds.base[:32])
        user = col.new_user()
        ids = col.search(*user.encrypt_query(ds.queries[0]), K)
        assert ids.shape == (K,)           # untraced path serves fine
        assert current() is None
    finally:
        col.close()
