"""Crash-safe ingestion: WAL, background checkpoints, recovery
(repro.resilience, DESIGN.md §16).

The durability contract under test: **acked means recoverable** — a
mutation whose call returned has been fsync'd to the WAL, and
`recover()` (checkpoint + WAL-tail replay through the public ingestion
methods) reconstructs bit-identical store state and bit-identical
search ids after a kill at ANY point.  The seeded kill-restart sweep at
the bottom drives random interleavings of insert/delete/compact/
checkpoint with a crash injected around a random fsync, across
flat/ivf/graph backends and both schedulers, and compares the recovered
collection against an oracle that applied exactly the acknowledged ops.
"""

import os
import threading

import numpy as np
import pytest

from repro import resilience as R
from repro.serving.runtime import Collection, VirtualClock

D = 8


def _rows(rng, n):
    return rng.normal(size=(n, D)).astype(np.float32)


# ---------------------------------------------------------------------------
# WAL unit behaviour: framing, rotation, torn tails, truncation.
# ---------------------------------------------------------------------------

class TestWal:
    def test_append_replay_round_trip(self, tmp_path):
        w = R.WriteAheadLog(tmp_path)
        a = {"C_sap": np.arange(12, dtype=np.float32).reshape(3, 4),
             "C_dce": np.ones((3, 4, 2), np.float32)}
        assert w.append("insert", a) == 1
        assert w.append("delete", {"rows": np.array([1], np.int64)}) == 2
        assert w.append("compact") == 3
        w.close()
        w2 = R.WriteAheadLog(tmp_path)
        recs = list(w2.replay())
        assert [(r.seq, r.op) for r in recs] == \
            [(1, "insert"), (2, "delete"), (3, "compact")]
        np.testing.assert_array_equal(recs[0].arrays["C_sap"], a["C_sap"])
        np.testing.assert_array_equal(recs[0].arrays["C_dce"], a["C_dce"])
        assert w2.last_seq == 3          # appends continue the sequence
        assert w2.append("compact") == 4
        w2.close()

    def test_segment_rotation_and_replay_order(self, tmp_path):
        w = R.WriteAheadLog(tmp_path, segment_bytes=2048)
        for i in range(40):
            w.append("insert", {"C_sap": np.full((2, D), i, np.float32),
                                "C_dce": np.zeros((2, 4, 2), np.float32)})
        segs = sorted(p for p in os.listdir(tmp_path)
                      if p.endswith(".seg"))
        assert len(segs) > 1, "rotation never triggered"
        w.close()
        w2 = R.WriteAheadLog(tmp_path, segment_bytes=2048)
        seqs = [r.seq for r in w2.replay()]
        assert seqs == list(range(1, 41))
        w2.close()

    def test_torn_tail_dropped_and_physically_truncated(self, tmp_path):
        w = R.WriteAheadLog(tmp_path)
        w.append("compact")
        w.append("compact")
        w.close()
        seg = sorted(tmp_path.glob("wal-*.seg"))[-1]
        good = seg.stat().st_size
        with open(seg, "ab") as f:       # simulate a torn final frame
            f.write(b"PWAL\x01\x02garbage")
        w2 = R.WriteAheadLog(tmp_path)
        assert [r.seq for r in w2.replay()] == [1, 2]
        assert seg.stat().st_size == good, "torn tail not truncated"
        assert w2.append("compact") == 3   # and the log keeps going
        w2.close()

    def test_corruption_in_non_final_segment_raises(self, tmp_path):
        w = R.WriteAheadLog(tmp_path, segment_bytes=512)
        for _ in range(20):
            w.append("insert", {"C_sap": np.zeros((1, D), np.float32),
                                "C_dce": np.zeros((1, 4, 2), np.float32)})
        w.close()
        first = sorted(tmp_path.glob("wal-*.seg"))[0]
        raw = bytearray(first.read_bytes())
        raw[len(raw) // 2] ^= 0xFF       # flip a payload bit mid-segment
        first.write_bytes(bytes(raw))
        # mid-log damage can never be a torn tail: reopen/replay refuses
        with pytest.raises(R.WalCorruptionError):
            list(R.WriteAheadLog(tmp_path, segment_bytes=512).replay())

    def test_truncate_through_drops_whole_prefix_segments(self, tmp_path):
        w = R.WriteAheadLog(tmp_path, segment_bytes=512)
        for _ in range(30):
            w.append("insert", {"C_sap": np.zeros((1, D), np.float32),
                                "C_dce": np.zeros((1, 4, 2), np.float32)})
        n_before = len(list(tmp_path.glob("wal-*.seg")))
        assert n_before > 2
        removed = w.truncate_through(15)
        assert removed >= 1
        assert len(list(tmp_path.glob("wal-*.seg"))) < n_before
        # only whole prefix segments go: every record after seq 15
        # survives (some earlier ones may too — truncation is lazy)
        seqs = [r.seq for r in w.replay()]
        assert seqs == list(range(seqs[0], 31)) and seqs[0] <= 16
        w.close()

    def test_replay_after_seq_skips_prefix(self, tmp_path):
        w = R.WriteAheadLog(tmp_path)
        for _ in range(5):
            w.append("compact")
        assert [r.seq for r in w.replay(after_seq=3)] == [4, 5]
        w.close()


# ---------------------------------------------------------------------------
# Collection + WAL + checkpoint integration.
# ---------------------------------------------------------------------------

def _fresh(seed=11, backend="flat", **kw):
    kw.setdefault("compact_every", 64)
    return Collection("t", "c", D, seed=seed, backend=backend, **kw)


class TestRecovery:
    def test_wal_only_recovery_bit_identical(self, tmp_path):
        rng = np.random.default_rng(0)
        col = _fresh()
        wal = R.WriteAheadLog(tmp_path)
        R.attach_wal(col, wal)
        col.insert(_rows(rng, 40))
        col.delete([1, 7])
        col.compact()
        col.insert(_rows(rng, 10))
        dig = col.store.state_digest()
        wal.close()
        col.close()
        col2, rep = R.recover(lambda: _fresh(), wal_dir=tmp_path)
        assert not rep.had_checkpoint
        assert rep.n_replayed == 4
        assert col2.store.state_digest() == dig
        assert col2.telemetry.snapshot()["n_wal_replayed"] == 4
        col2.close()

    def test_checkpoint_plus_tail_replay(self, tmp_path):
        rng = np.random.default_rng(1)
        ck = tmp_path / "col.ppcol"
        wd = tmp_path / "wal"
        col = _fresh()
        wal = R.WriteAheadLog(wd)
        R.attach_wal(col, wal)
        col.insert(_rows(rng, 30))
        R.AsyncCheckpointer(col, ck).checkpoint()   # truncates the WAL
        col.insert(_rows(rng, 5))                   # tail beyond it
        col.delete([3])
        dig = col.store.state_digest()
        wal.close()
        col.close()
        col2, rep = R.recover(lambda: _fresh(), checkpoint_path=ck,
                              wal_dir=wd)
        assert rep.had_checkpoint and rep.checkpoint_seq == 1
        assert rep.n_replayed == 2                  # tail only
        assert col2.store.state_digest() == dig
        col2.close()

    def test_async_checkpoint_never_blocks_serving(self, tmp_path):
        """trigger() returns immediately (the copy-on-write snapshot is
        the only locked part); searches proceed while the worker
        serializes and fsyncs in the background."""
        rng = np.random.default_rng(2)
        col = _fresh()
        col.insert(_rows(rng, 64))
        u = col.new_user()
        cq, tq = u.encrypt_query(_rows(rng, 1)[0])
        want, _ = col.search_batch(cq[None], tq[None], 3)
        cp = R.AsyncCheckpointer(col, tmp_path / "c.ppcol")
        t = cp.trigger()
        assert isinstance(t, threading.Thread)
        got, _ = col.search_batch(cq[None], tq[None], 3)  # not blocked
        np.testing.assert_array_equal(want, got)
        cp.join()
        assert (tmp_path / "c.ppcol").exists()
        assert col.telemetry.snapshot()["n_checkpoints"] == 1
        col.close()

    def test_checkpoint_every_n_ops(self, tmp_path):
        rng = np.random.default_rng(3)
        col = _fresh()
        cp = R.AsyncCheckpointer(col, tmp_path / "c.ppcol",
                                 every_n_ops=10)
        col.insert(_rows(rng, 8))
        cp.note_ops(8)
        assert not (tmp_path / "c.ppcol").exists()
        col.insert(_rows(rng, 8))
        cp.note_ops(8)                  # crosses the threshold
        cp.join()
        assert (tmp_path / "c.ppcol").exists()
        col.close()

    @pytest.mark.parametrize("mode,survives", [
        ("crash_before_fsync", False), ("crash_after_fsync", True)])
    def test_crash_around_fsync(self, tmp_path, mode, survives):
        """before-fsync: the torn record was never acked and recovery
        drops it.  after-fsync: durable-but-unacked — recovery replays
        it (at-least-once on unacked ops)."""
        rng = np.random.default_rng(4)
        col = _fresh()
        wal = R.WriteAheadLog(tmp_path)
        R.attach_wal(col, wal)
        plan = R.FaultPlan()
        getattr(plan, mode)(at_record=2)
        plan.install(col)
        col.insert(_rows(rng, 20))                  # record 1: acked
        with pytest.raises(R.SimulatedCrash):
            col.insert(_rows(rng, 6))               # record 2: crash
        col.close()
        col2, rep = R.recover(lambda: _fresh(), wal_dir=tmp_path)
        assert col2.store.n_total == (26 if survives else 20)
        assert rep.n_replayed == (2 if survives else 1)
        col2.close()


# ---------------------------------------------------------------------------
# Seeded kill-restart durability sweep: random interleavings of
# insert/delete/compact/checkpoint with a crash at a random WAL record,
# across backends x schedulers.  Zero acknowledged-write loss, and the
# recovered collection answers bit-identically to an oracle that
# applied exactly the acknowledged (plus durable-unacked) ops.
# ---------------------------------------------------------------------------

def _apply_ops(col, ops):
    for op, arg in ops:
        if op == "insert":
            col.insert_encrypted(*arg)
        elif op == "delete":
            col.delete(arg)
        elif op == "compact":
            col.compact()


@pytest.mark.parametrize("backend", ["flat", "ivf", "graph"])
@pytest.mark.parametrize("sched", ["flush", "continuous"])
def test_kill_restart_sweep(tmp_path, backend, sched):
    seed0 = {"flat": 100, "ivf": 200, "graph": 300}[backend]
    for case in range(2):
        seed = seed0 + case
        rng = np.random.default_rng(seed)
        base = tmp_path / f"case{case}"
        wd, ck = base / "wal", base / "col.ppcol"

        def fresh():
            return _fresh(seed=7, backend=backend, scheduler=sched,
                          max_wait_ms=0.5, compact_every=48)

        col = fresh()
        wal = R.WriteAheadLog(wd)
        R.attach_wal(col, wal)
        owner = col.owner
        cp = R.AsyncCheckpointer(col, ck)

        # random op script; crash at a random WAL record inside it
        n_ops = int(rng.integers(6, 12))
        crash_at = int(rng.integers(2, n_ops + 1))
        mode = ("crash_before_fsync", "crash_after_fsync")[
            int(rng.integers(2))]
        plan = R.FaultPlan()
        getattr(plan, mode)(at_record=crash_at)
        plan.install(col)

        applied, crashed_op = [], None
        for i in range(n_ops + 3):       # a few extra: crash must land
            r = rng.random()
            if r < 0.55 or col.store.n_alive < 4:
                enc = owner.encrypt_vectors(
                    _rows(rng, int(rng.integers(4, 16))))
                op = ("insert", enc)
            elif r < 0.75:
                alive = np.flatnonzero(col.store.alive_view)
                pick = rng.choice(alive, size=min(2, alive.size),
                                  replace=False)
                op = ("delete", sorted(int(x) for x in pick))
            elif r < 0.9:
                op = ("compact", None)
            else:
                cp.checkpoint()          # durable; not a WAL op
                continue
            try:
                _apply_ops(col, [op])
                applied.append(op)       # acked
            except R.SimulatedCrash:
                crashed_op = op
                break
        assert crashed_op is not None, "crash never landed"
        col.close()

        # recover from disk; oracle replays exactly the acked ops (plus
        # the durable-but-unacked crashed op in after-fsync mode)
        col2, rep = R.recover(
            fresh, checkpoint_path=ck if ck.exists() else None,
            wal_dir=wd)
        oracle = fresh()
        expect = applied + ([crashed_op]
                            if mode == "crash_after_fsync" else [])
        _apply_ops(oracle, expect)
        assert col2.store.state_digest() == oracle.store.state_digest(), \
            f"seed {seed}: acknowledged-write loss ({mode})"

        # bit-identical post-recovery search ids, through the scheduler
        user = oracle.new_user()
        for qi in range(3):
            q = _rows(rng, 1)[0]
            cq, tq = user.encrypt_query(q)
            np.testing.assert_array_equal(
                col2.search(cq, tq, 5), oracle.search(cq, tq, 5),
                err_msg=f"seed {seed} query {qi} diverged after recovery")
        col2.close()
        oracle.close()


# ---------------------------------------------------------------------------
# Clock-seam runner port (the retired repro.ft surface).
# ---------------------------------------------------------------------------

class TestRunnerPort:
    def test_ft_shim_warns_and_reexports(self):
        import importlib
        import repro.ft.runner as shim
        with pytest.warns(DeprecationWarning):
            importlib.reload(shim)
        assert shim.ResilientRunner is R.ResilientRunner
        assert shim.RetryPolicy is R.RetryPolicy
        from repro.ft import StragglerWatchdog
        assert StragglerWatchdog is R.StragglerWatchdog

    def test_backoff_runs_on_virtual_clock(self):
        """Restart backoff consumes VIRTUAL seconds — no real sleeping
        (the whole point of the clock-seam port)."""
        clock = VirtualClock()
        calls = {"n": 0}
        ckpt = {"step": 0, "state": 0}

        def step(state, batch):
            calls["n"] += 1
            if calls["n"] == 3:
                raise RuntimeError("transient")
            return state + batch, {"loss": 0.0}

        runner = R.ResilientRunner(
            step,
            save_fn=lambda s, st: ckpt.update(step=s, state=st),
            restore_fn=lambda: (ckpt["step"], ckpt["state"]),
            policy=R.RetryPolicy(max_restarts=2, backoff_s=5.0),
            checkpoint_every=2, clock=clock)

        done = {}

        def drive():
            done["out"] = runner.run(0, 0, 6, get_batch=lambda s: 1)

        t = threading.Thread(target=drive)
        t.start()
        clock.wait_for_waiters(1)        # runner parked in backoff
        clock.advance(5.0)
        t.join(timeout=10)
        assert not t.is_alive()
        state, step_n, _ = done["out"]
        assert (state, step_n) == (6, 6)   # replay healed the failure
        assert runner.restarts == 1

    def test_straggler_watchdog_redispatches_on_virtual_clock(self):
        clock = VirtualClock()
        wd = R.StragglerWatchdog(factor=3.0, clock=clock)
        for _ in range(8):
            wd.observe(0.01)

        def fast():
            return "ok"

        def slow():
            clock.advance(1.0)          # a shard 100x the median
            return "slow"

        out = wd.run_sharded([fast, slow, fast],
                             fallback_fn=lambda i: f"backup{i}")
        assert out == ["ok", "backup1", "ok"]
        assert wd.redispatches == 1

    def test_sleep_on_virtual_clock(self):
        clock = VirtualClock()
        woke = threading.Event()

        def sleeper():
            R.sleep_on(clock, 2.0)
            woke.set()

        t = threading.Thread(target=sleeper)
        t.start()
        clock.wait_for_waiters(1)
        assert not woke.is_set()
        clock.advance(2.0)
        t.join(timeout=10)
        assert woke.is_set()
