"""Tests for the AME baseline (paper §III-C)."""

import numpy as np
import pytest

from repro.core import ame, dce


@pytest.mark.parametrize("d", [4, 16, 100])
def test_comparison_sign_exactness(d):
    rng = np.random.default_rng(d)
    key = ame.keygen(d, seed=d)
    P = rng.standard_normal((24, d))
    Q = rng.standard_normal((2, d))
    U, V = ame.encrypt(P, key, dtype=np.float64)
    W = ame.trapgen(Q, key, dtype=np.float64)
    for qi in range(2):
        dist = ((P - Q[qi]) ** 2).sum(-1)
        Z = ame.compare(U[:, None], V[None, :], W[qi])
        true = dist[:, None] - dist[None, :]
        ok = (np.sign(Z) == np.sign(true)) | (np.abs(true) < 1e-8)
        assert ok.all()


def test_ciphertext_shapes_match_paper():
    """32 vectors per DB vector, 16 matrices per query, all in R^(2d+6)."""
    d = 10
    m = 2 * d + 6
    key = ame.keygen(d)
    P = np.random.default_rng(0).standard_normal((3, d))
    U, V = ame.encrypt(P, key)
    W = ame.trapgen(P[:1], key)
    assert U.shape == (3, 16, m) and V.shape == (3, 16, m)   # 32 vectors
    assert W.shape == (1, 16, m, m)                          # 16 matrices
    assert key.Ma.shape[0] + key.Mb.shape[0] == 32           # 32 key matrices


def test_cost_model_vs_dce():
    """AME per-comparison MACs = 64 d^2 + 416 d + 672 (paper: +676): O(d^2)
    vs DCE's 4d+32 = O(d) — the asymmetry behind Fig. 6's >=100x speedup."""
    for d in [96, 128, 960]:
        c_ame = ame.mac_cost_per_comparison(d)
        c_dce = dce.mac_cost_per_comparison(d)
        assert c_ame == 64 * d * d + 416 * d + 672
        assert c_ame / c_dce > 15 * d / 4       # superlinear separation
