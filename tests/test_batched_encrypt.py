"""Jitted batched DCPE/DCE encryption (owner-side ingestion path):
correctness vs the numpy reference and interop inside one database."""

import numpy as np
import pytest

from repro.core import dce, dcpe, ppanns
from repro.data import synth


@pytest.fixture(scope="module")
def P():
    rng = np.random.default_rng(4)
    return rng.standard_normal((192, 48)).astype(np.float32)


def test_dcpe_jax_perturbation_within_ball(P):
    key = dcpe.keygen(s=512.0, beta=1.5)
    C = np.asarray(dcpe.encrypt_jax(P, key, seed=9))
    assert C.shape == P.shape and C.dtype == np.float32
    pert = np.linalg.norm(C - key.s * P, axis=1)
    assert (pert <= key.s * key.beta / 4.0 + 1e-3).all()
    assert pert.std() > 0                     # fresh noise per row


def test_dcpe_jax_preserves_distance_comparisons(P):
    key = dcpe.keygen(s=1024.0, beta=0.5)
    C = np.asarray(dcpe.encrypt_jax(P, key, seed=1))
    q, a, b = P[0], P[1], P[2]
    cq, ca, cb = C[0], C[1], C[2]
    da, db = ((a - q) ** 2).sum(), ((b - q) ** 2).sum()
    if abs(np.sqrt(da) - np.sqrt(db)) > key.beta:   # beta-DCP regime
        assert (da < db) == (((ca - cq) ** 2).sum() < ((cb - cq) ** 2).sum())


@pytest.mark.parametrize("d", [48, 47])        # even + odd (zero-pad) dims
def test_dce_jax_signs_match_true_distances(P, d):
    key = dce.keygen(d, seed=2)
    X = P[:64, :d].copy()
    q = P[64, :d].copy()
    C = np.asarray(dce.encrypt_jax(X, key, seed=3))
    assert C.shape == (64, 4, dce.ciphertext_dim(d))
    T = dce.trapgen(q[None], key, seed=4)[0]
    td = ((X - q) ** 2).sum(1)
    Z = dce.pairwise_z_matrix(C, T)
    sep = np.abs(td[:, None] - td[None, :]) > 1e-3
    off = ~np.eye(64, dtype=bool)
    want = td[:, None] < td[None, :]
    assert ((Z < 0) == want)[sep & off].all()


def test_dce_jax_interops_with_numpy_ciphertexts(P):
    """Rows encrypted by the numpy path and the jitted path under the same
    key live in one database: DistanceComp across the boundary stays
    sign-correct (live ingestion appends to a numpy-encrypted main)."""
    d = P.shape[1]
    key = dce.keygen(d, seed=5)
    C = np.concatenate([dce.encrypt(P[:96], key, seed=6),
                        np.asarray(dce.encrypt_jax(P[96:], key, seed=7))])
    q = np.zeros(d, np.float32)
    T = dce.trapgen(q[None], key, seed=8)[0]
    td = (P * P).sum(1)
    Z = dce.pairwise_z_matrix(C, T)
    n = P.shape[0]
    mixed = (np.arange(n)[:, None] < 96) ^ (np.arange(n)[None, :] < 96)
    sep = np.abs(td[:, None] - td[None, :]) > 1e-3
    want = td[:, None] < td[None, :]
    assert ((Z < 0) == want)[mixed & sep].all()


def test_data_owner_encrypt_vectors_bucketed(P):
    owner = ppanns.DataOwner(d=P.shape[1], sap_beta=1.0, seed=6)
    before = dce._encrypt_jax_core._cache_size()
    for m in (5, 7, 8, 3):                    # all land in the 8-bucket
        C_sap, C_dce = owner.encrypt_vectors(P[:m])
        assert C_sap.shape == (m, P.shape[1])
        assert C_dce.shape == (m, 4, dce.ciphertext_dim(P.shape[1]))
    assert dce._encrypt_jax_core._cache_size() == before + 1
    # fresh randomness per call: same plaintext, different ciphertext
    a, _ = owner.encrypt_vectors(P[:4])
    b, _ = owner.encrypt_vectors(P[:4])
    assert not np.allclose(a, b)


def test_encrypt_vectors_pads_with_real_rows_not_zeros(P, monkeypatch):
    """Bucket padding must replicate real rows: zero-row padding shrinks
    the batch-wide DCE randomization scale sqrt(mean(hat^2)), silently
    weakening the Eq. 2 blinding noise for the real rows."""
    owner = ppanns.DataOwner(d=P.shape[1], sap_beta=1.0, seed=9)
    captured = {}
    orig = dce.encrypt_jax

    def spy(X, key, seed):
        captured["X"] = np.asarray(X)
        return orig(X, key, seed)

    monkeypatch.setattr(ppanns.dce, "encrypt_jax", spy)
    C_sap, C_dce = owner.encrypt_vectors(P[:1])
    X = captured["X"]
    assert X.shape[0] == 8                      # minimum bucket
    np.testing.assert_allclose(                 # pad rows replicate row 0,
        X[1:], np.broadcast_to(X[:1], X[1:].shape))   # so scale is exact
    assert C_sap.shape == (1, P.shape[1])


def test_encrypt_vectors_concurrent_calls_never_share_noise(P):
    """The seed counter is atomic: parallel ingestion threads must draw
    distinct noise (identical noise across two batches would let the
    server recover scaled plaintext differences by subtraction)."""
    import threading

    owner = ppanns.DataOwner(d=P.shape[1], sap_beta=1.0, seed=8)
    out = []
    lock = threading.Lock()

    def worker():
        c, _ = owner.encrypt_vectors(P[:4])
        with lock:
            out.append(c)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(len(out)):
        for j in range(i + 1, len(out)):
            assert not np.allclose(out[i], out[j])


def test_end_to_end_search_over_jax_encrypted_database():
    """A database ingested entirely through the batched path is searchable
    at the same recall as the reference pipeline."""
    ds = synth.make_dataset("deep1m", n=500, n_queries=6, k_gt=20,
                            seed=13, d=32)
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    owner = ppanns.DataOwner(d=32, sap_beta=beta, seed=13)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    from repro.serving.search_engine import SecureSearchEngine
    eng = SecureSearchEngine(C_sap, C_dce, backend="flat")
    user = ppanns.User(owner.share_keys())
    Q, T = zip(*(user.encrypt_query(q) for q in ds.queries))
    ids, _ = eng.search_batch(np.stack(Q), np.stack(T), 10, ratio_k=8)
    assert synth.recall_at_k(ids, ds.gt, 10) >= 0.85
