"""repro.graph: the device-resident batched CSR graph index (DESIGN.md §15).

Pins the subsystem's contracts:

  * the CSR mirror round-trips the owner-built HNSW bit-identically,
    deletes and incremental row refreshes included;
  * the batched lockstep traversal returns ids identical to the
    per-query host walk at fixed ef — the host walk stays as the
    parity oracle the batched filter is measured against;
  * the ADC-quantized variant keeps recall; the oblivious variant is
    bit-identical to the perf variant with CONSTANT hop/edge counts;
  * the Pallas frontier kernel (interpret mode off-TPU) matches the
    XLA walk;
  * mutations through the delta store: tombstones never surface, new
    rows are reachable before compaction, and the steady state is
    recompile-free on both schedulers;
  * sharded collections serve per-shard subgraphs with exact
    batched-vs-looped parity and snapshot persistence;
  * the spec/wire surface: `backend="graph"` is admitted where the
    legacy per-query "hnsw" backend stays rejected, and the new
    SearchStats fields are additive (old payloads decode to 0).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.api import IndexSpec, PlacementSpec
from repro.core import dcpe, ppanns
from repro.core.hnsw import HNSW
from repro.data import synth
from repro.graph import CSRGraph, GraphFilter, beam_plan
from repro.kernels.graph_expand import ops as graph_ops
from repro.serving.runtime import Collection
from repro.serving.runtime.telemetry import jit_cache_size
from repro.serving.search_engine import (HNSWGraphFilter, SearchStats,
                                         SecureSearchEngine)

K = 10


@pytest.fixture(scope="module")
def setup():
    ds = synth.make_dataset("deep1m", n=800, n_queries=8, k_gt=30, seed=21,
                            d=32)
    owner, user, server = ppanns.build_system(
        ds.base, beta_fraction=0.03, M=12, ef_construction=100, seed=21)
    qs, ts = zip(*(user.encrypt_query(q) for q in ds.queries))
    return ds, server, np.stack(qs), np.stack(ts)


# ---------------------------------------------------------------------------
# CSR mirror: bit-identical round trip with the host HNSW.
# ---------------------------------------------------------------------------

def _assert_arrays_equal(a: dict, b: dict):
    assert set(a) == set(b)
    for k in a:
        assert np.asarray(a[k]).dtype == np.asarray(b[k]).dtype, k
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]), k)


def test_csr_round_trip_bit_identical_with_deletes():
    rng = np.random.default_rng(3)
    h = HNSW(16, M=8, ef_construction=60, seed=3)
    h.build(rng.standard_normal((200, 16)).astype(np.float32))
    h.delete(5)
    h.delete(17)
    g = CSRGraph.from_hnsw(h)
    _assert_arrays_equal(g.to_arrays(), h.to_arrays())
    # arrays → HNSW → arrays is the identity too (persistence path)
    h2 = HNSW.from_arrays(g.to_arrays())
    _assert_arrays_equal(h2.to_arrays(), h.to_arrays())


def test_csr_incremental_refresh_matches_full_rebuild():
    rng = np.random.default_rng(4)
    h = HNSW(16, M=8, ef_construction=60, seed=4)
    h.build(rng.standard_normal((150, 16)).astype(np.float32))
    g = CSRGraph.from_hnsw(h, R=256)
    assert g.fits(h)
    # one insert dirties the new node and every node it linked to (their
    # lists changed, possibly pruned) — the ingest layer's changed-row rule
    node = h.insert(rng.standard_normal(16).astype(np.float32))
    dirty = {node}
    for lev in range(h.levels[node] + 1):
        dirty.update(np.asarray(h.links[lev][node]).tolist())
    # one delete dirties the row and the repaired in-neighbors
    dirty.add(30)
    dirty.update(h.delete(30))
    g.refresh_rows(h, sorted(dirty))
    g.refresh_meta(h)
    fresh = CSRGraph.from_hnsw(h, R=g.R, LU=g.LU)
    np.testing.assert_array_equal(g.neigh0, fresh.neigh0)
    np.testing.assert_array_equal(g.neigh_up, fresh.neigh_up)
    np.testing.assert_array_equal(g.levels, fresh.levels)
    np.testing.assert_array_equal(g.X, fresh.X)
    assert g.entry == fresh.entry and g.n == fresh.n


# ---------------------------------------------------------------------------
# Batched filter vs the host-walk parity oracle.
# ---------------------------------------------------------------------------

def test_batched_filter_matches_host_walk_oracle(setup):
    """The acceptance property: GraphFilter ids == per-query host walk
    ids at fixed ef, exactly (the equivalence argument in graph.traverse)."""
    ds, server, Q, T = setup
    C_sap, C_dce = server.db.C_sap, server.db.C_dce
    eng_g = SecureSearchEngine(
        C_sap, C_dce, backend=GraphFilter(server.db.index, use_kernel=False))
    eng_h = SecureSearchEngine(
        C_sap, C_dce, backend=HNSWGraphFilter(server.db.index))
    with pytest.warns(DeprecationWarning, match="parity oracle"):
        host, _ = eng_h.search_batch(Q, T, K, ratio_k=8, ef_search=128)
    batched, st = eng_g.search_batch(Q, T, K, ratio_k=8, ef_search=128)
    np.testing.assert_array_equal(batched, host)
    assert st.backend == "graph"
    assert st.n_hops > 0 and st.n_edges_scanned > 0
    assert synth.recall_at_k(batched, ds.gt, K) >= 0.9


def test_batched_matches_per_query(setup):
    ds, server, Q, T = setup
    eng = SecureSearchEngine(
        server.db.C_sap, server.db.C_dce,
        backend=GraphFilter(server.db.index, use_kernel=False))
    whole, _ = eng.search_batch(Q, T, K, ratio_k=8, ef_search=128)
    for i in range(len(Q)):
        one, _ = eng.search_batch(Q[i:i + 1], T[i:i + 1], K, ratio_k=8,
                                  ef_search=128)
        np.testing.assert_array_equal(whole[i], one[0])


def test_int8_quantized_graph_recall(setup):
    ds, server, Q, T = setup
    gf = GraphFilter(server.db.index, quantization="int8", use_kernel=False)
    eng = SecureSearchEngine(server.db.C_sap, server.db.C_dce, backend=gf)
    ids, st = eng.search_batch(Q, T, K, ratio_k=8, ef_search=128)
    assert st.backend == "adc-graph-int8"
    assert synth.recall_at_k(ids, ds.gt, K) >= 0.8
    # surrogate scoring reads code bytes, not f32 rows
    assert 0 < gf.last_filter_bytes < gf.last_n_edges_scanned * ds.d * 4


def test_oblivious_bit_identical_with_constant_accounting(setup):
    ds, server, Q, T = setup
    perf = GraphFilter(server.db.index, use_kernel=False)
    obl = GraphFilter(server.db.index, use_kernel=False, oblivious=True)
    perf.attach(server.db.C_sap)
    obl.attach(server.db.C_sap)

    def ids(gf, Qb):
        c, v, _ = gf.candidates(Qb, 32, 128)
        return np.where(v, c, -1)

    np.testing.assert_array_equal(ids(obl, Q[:4]), ids(perf, Q[:4]))
    h1, e1 = obl.last_n_hops, obl.last_n_edges_scanned
    ids(obl, Q[4:8])                       # different queries, same shape
    assert (obl.last_n_hops, obl.last_n_edges_scanned) == (h1, e1)
    assert h1 >= perf.last_n_hops          # bounded-hop pads, never trims
    # the residual leak is the ADDRESS stream: the visited bitmap stays
    # data-dependent (sec.leakage scores it; the intermediate tier)
    tr = obl.last_scan_trace
    assert tr.dtype == np.bool_ and tr.shape[0] == 4
    assert 0 < tr.sum() < tr.size


def test_pallas_kernel_interpret_matches_xla(setup):
    ds, server, Q, T = setup
    gf = GraphFilter(server.db.index, use_kernel=False)
    gf.attach(server.db.C_sap)
    kp = 32
    ef_eff, ef_cap, max_hops = beam_plan(kp, 64)
    args = (gf._neigh0, gf._neigh_up, gf._ok, gf._db,
            gf._query_operand(np.asarray(Q[:4], np.float32)),
            np.int32(gf.csr.entry), np.int32(ef_eff))
    kw = dict(kp=kp, ef_cap=ef_cap, max_hops=max_hops, quant="f32")
    c_xla, *_ = graph_ops.graph_topk(*args, use_kernel=False, **kw)
    c_pal, *_ = graph_ops.graph_topk(*args, use_kernel=True, interpret=True,
                                     **kw)
    np.testing.assert_array_equal(np.asarray(c_xla), np.asarray(c_pal))


# ---------------------------------------------------------------------------
# Spec / engine admission surface.
# ---------------------------------------------------------------------------

def _spec(**kw):
    return IndexSpec(tenant="t", name="g", d=16, sap_beta=1.0, seed=0, **kw)


def test_spec_admits_graph_where_hnsw_is_rejected():
    # graph takes quantization and the hardened tier; the legacy
    # per-query host walk still rejects both
    _spec(backend="graph", quantization="int8")
    _spec(backend="graph", security_profile="hardened")
    with pytest.raises(ValueError, match="quantization"):
        _spec(backend="hnsw", quantization="int8")
    with pytest.raises(ValueError, match="graph"):
        _spec(backend="hnsw", security_profile="hardened")


def test_engine_rejects_graph_as_string(setup):
    ds, server, Q, T = setup
    with pytest.raises(ValueError, match="GraphFilter"):
        SecureSearchEngine(server.db.C_sap, server.db.C_dce,
                           backend="graph")


def test_search_stats_new_fields_are_additive():
    """Old wire payloads carry no n_hops/n_edges_scanned: decoding them
    into the new dataclass must default both to 0, not fail."""
    flds = {f.name: f for f in dataclasses.fields(SearchStats)}
    assert flds["n_hops"].default == 0
    assert flds["n_edges_scanned"].default == 0


# ---------------------------------------------------------------------------
# Mutations through the delta store, on both schedulers.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheduler", ["flush", "continuous"])
def test_graph_delta_lifecycle(scheduler):
    ds = synth.make_dataset("deep1m", n=400, n_queries=6, k_gt=10, seed=7,
                            d=16)
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    col = Collection("t0", f"g-{scheduler}", ds.d, backend="graph",
                     sap_beta=beta, seed=7, scheduler=scheduler,
                     compact_every=10_000, hnsw_M=8,
                     hnsw_ef_construction=60)
    try:
        col.insert(ds.base)
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries]
        Q = np.stack([c for c, _ in enc])
        T = np.stack([t for _, t in enc])
        dead = []

        def cycle(i):
            new = int(col.insert(ds.queries[i][None])[0])
            ids, st = col.search_batch(Q, T, K, ratio_k=8, ef_search=96)
            # the delta row is reachable BEFORE any compaction
            assert new in ids[i]
            assert st.n_hops > 0 and st.n_edges_scanned > 0
            # scheduler-path parity with the direct engine call
            fut = col.submit(*enc[i], K, ef_search=96)
            one, _ = col.search_batch(Q[i:i + 1], T[i:i + 1], K,
                                      ef_search=96)
            np.testing.assert_array_equal(fut.result(timeout=30), one[0])
            victim = int(ds.gt[i, 0])
            col.delete([new, victim])
            dead.extend([new, victim])
            ids2, _ = col.search_batch(Q, T, K, ratio_k=8, ef_search=96)
            # tombstones never surface, with or without compaction
            assert not np.isin(ids2, dead).any()
            return ids2

        cycle(0)
        warm = jit_cache_size()             # one warmup cycle compiles all
        for i in (1, 2):
            cycle(i)
        assert jit_cache_size() == warm     # steady state: zero recompiles
        col.compact()
        ids3 = cycle(3)
        assert synth.recall_at_k(ids3, ds.gt, K) >= 0.5
        snap = col.stats()
        assert snap["n_hops"] > 0 and snap["n_edges_scanned"] > 0
    finally:
        col.close()


# ---------------------------------------------------------------------------
# Sharded: per-shard subgraphs, exact parity, persistence.
# ---------------------------------------------------------------------------

def test_sharded_graph_parity_and_snapshot():
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices (run under XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    ds = synth.make_dataset("deep1m", n=500, n_queries=6, k_gt=10, seed=11,
                            d=16)
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    pl = PlacementSpec(kind="sharded", n_shards=2).resolve(
        jax.device_count())

    def make(**kw):
        return Collection("t0", "shg", ds.d, backend="graph",
                          sap_beta=beta, seed=11, placement=pl,
                          compact_every=10_000, hnsw_M=8,
                          hnsw_ef_construction=60, **kw)

    col = make()
    try:
        col.insert(ds.base)
        user = col.new_user()
        qs, ts = zip(*(user.encrypt_query(q) for q in ds.queries))
        Q, T = np.stack(qs), np.stack(ts)
        ids, st = col.search_batch(Q, T, K, ratio_k=8, ef_search=96)
        assert st.backend == "sharded-graph"
        assert st.n_hops > 0
        assert synth.recall_at_k(ids, ds.gt, K) >= 0.6
        for i in range(len(Q)):                       # batched == looped
            one, _ = col.search_batch(Q[i:i + 1], T[i:i + 1], K,
                                      ratio_k=8, ef_search=96)
            np.testing.assert_array_equal(ids[i], one[0])
        arrays, book = col.snapshot()
        col2 = make()
        try:
            col2.load_snapshot(
                arrays["C_sap"], arrays["C_dce"], alive=arrays["alive"],
                n_main=book["n_main"], main_gen=book["main_gen"],
                graph_arrays={k[len("graph__"):]: v
                              for k, v in arrays.items()
                              if k.startswith("graph__")})
            ids2, _ = col2.search_batch(Q, T, K, ratio_k=8, ef_search=96)
            np.testing.assert_array_equal(ids, ids2)
        finally:
            col2.close()
    finally:
        col.close()
