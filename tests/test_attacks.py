"""KPA attacks on ASPE variants (paper §III-A, Thm 1-2, Cor 1-2).

These tests *are* the reproduction of the paper's negative results: every
ASPE variant that leaks a transformation of distances yields full plaintext
recovery from a small leaked subset.
"""

import numpy as np
import pytest

from repro.core import aspe, attacks


@pytest.mark.parametrize("transform", ["linear", "exp", "log"])
def test_thm1_cor12_full_recovery(transform):
    res = attacks.attack_roundtrip(d=12, n=80, nq=30, transform=transform)
    assert res["query_err"] < 1e-6
    assert res["db_err"] < 1e-6


def test_thm2_square_variant_recovery():
    res = attacks.attack_roundtrip(d=8, n=100, nq=60, transform="square")
    assert res["query_err"] < 1e-6
    assert res["db_err"] < 1e-6


def test_leak_counts_match_paper():
    """Thm 1 needs d+2 plaintexts; Thm 2 needs O(d^2) (we use the full-rank
    variant of the paper's 0.5d^2+2.5d+3 feature count — see attacks.py)."""
    d = 8
    assert attacks.square_feature_dim(d) == d * (d - 1) // 2 + 3 * d + 2
    rng = np.random.default_rng(0)
    key = aspe.keygen(d)
    P = rng.standard_normal((d + 1, d))     # one too few
    L = aspe.leak(aspe.encrypt_db(P, key),
                  aspe.encrypt_query(P[:3], key), key, "linear")
    with pytest.raises(ValueError):
        attacks.recover_queries_linear(P, L, "linear")


def test_aspe_leak_is_comparison_faithful():
    """Sanity: ASPE variants do order distances correctly (they fail on
    *security*, not correctness — that is the paper's point)."""
    d = 16
    rng = np.random.default_rng(5)
    key = aspe.keygen(d, seed=5)
    P = rng.standard_normal((50, d))
    q = rng.standard_normal((1, d))
    L = aspe.leak(aspe.encrypt_db(P, key),
                  aspe.encrypt_query(q, key), key, "linear")[:, 0]
    dist = ((P - q[0]) ** 2).sum(-1)
    assert (np.argsort(L) == np.argsort(dist)).all()
