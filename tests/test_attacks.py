"""KPA attacks on ASPE variants (paper §III-A, Thm 1-2, Cor 1-2).

These tests *are* the reproduction of the paper's negative results: every
ASPE variant that leaks a transformation of distances yields full plaintext
recovery from a small leaked subset.
"""

import numpy as np
import pytest

from repro.core import aspe, attacks, dce

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                  # optional dep
    HAVE_HYPOTHESIS = False


@pytest.mark.parametrize("transform", ["linear", "exp", "log"])
def test_thm1_cor12_full_recovery(transform):
    res = attacks.attack_roundtrip(d=12, n=80, nq=30, transform=transform)
    assert res["query_err"] < 1e-6
    assert res["db_err"] < 1e-6


def test_thm2_square_variant_recovery():
    res = attacks.attack_roundtrip(d=8, n=100, nq=60, transform="square")
    assert res["query_err"] < 1e-6
    assert res["db_err"] < 1e-6


def test_leak_counts_match_paper():
    """Thm 1 needs d+2 plaintexts; Thm 2 needs O(d^2) (we use the full-rank
    variant of the paper's 0.5d^2+2.5d+3 feature count — see attacks.py)."""
    d = 8
    assert attacks.square_feature_dim(d) == d * (d - 1) // 2 + 3 * d + 2
    rng = np.random.default_rng(0)
    key = aspe.keygen(d)
    P = rng.standard_normal((d + 1, d))     # one too few
    L = aspe.leak(aspe.encrypt_db(P, key),
                  aspe.encrypt_query(P[:3], key), key, "linear")
    with pytest.raises(ValueError):
        attacks.recover_queries_linear(P, L, "linear")


def test_aspe_leak_is_comparison_faithful():
    """Sanity: ASPE variants do order distances correctly (they fail on
    *security*, not correctness — that is the paper's point)."""
    d = 16
    rng = np.random.default_rng(5)
    key = aspe.keygen(d, seed=5)
    P = rng.standard_normal((50, d))
    q = rng.standard_normal((1, d))
    L = aspe.leak(aspe.encrypt_db(P, key),
                  aspe.encrypt_query(q, key), key, "linear")[:, 0]
    dist = ((P - q[0]) ** 2).sum(-1)
    assert (np.argsort(L) == np.argsort(dist)).all()


# ---------------------------------------------------------------------------
# Normalized attack success (repro.sec, DESIGN.md §14).
# ---------------------------------------------------------------------------

def test_normalized_success_endpoints():
    assert attacks.normalized_success(0.0, 2.0) == 1.0       # exact recovery
    assert attacks.normalized_success(2.0, 2.0) == 0.0       # at chance
    assert attacks.normalized_success(5.0, 2.0) == 0.0       # worse: clamped
    assert attacks.normalized_success(1.0, 0.0) == 0.0       # degenerate
    assert 0.0 < attacks.normalized_success(1.0, 2.0) < 1.0


def test_random_guess_error_scales_with_data():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((64, 8))
    base = attacks.random_guess_error(X)
    assert base > 0
    assert attacks.random_guess_error(10.0 * X) == pytest.approx(
        10.0 * base, rel=1e-9)


@pytest.mark.parametrize("transform", ["linear", "exp", "log", "square"])
def test_attack_report_normalized_broken(transform):
    """Every ASPE transform attack scores ~1.0 success in normalized
    units — the BENCH_attacks 'BROKEN' rows, gated at unit scale."""
    d = 8 if transform == "square" else 12
    rep = attacks.attack_report(d=d, n=100, nq=60, transform=transform)
    assert rep["query_success"] > 0.999
    assert rep["db_success"] > 0.999
    assert rep["query_baseline"] > 0
    assert rep["query_err"] < 1e-6


# ---------------------------------------------------------------------------
# DCE comparisons expose only signs (Thm 3/4 as a property).
# ---------------------------------------------------------------------------

def _dce_sign_case(seed: int, d: int, enc_seed: int):
    rng = np.random.default_rng(seed)
    key = dce.keygen(d, seed=seed)
    o, p, q = rng.standard_normal((3, d))
    true_gap = float(((o - q) ** 2).sum() - ((p - q) ** 2).sum())
    zs = []
    for s in range(5):                       # 5 fresh re-encryptions
        C = dce.encrypt(np.stack([o, p]), key, seed=enc_seed + s,
                        dtype=np.float64)
        T = dce.trapgen(q[None], key, seed=enc_seed + 100 + s,
                        dtype=np.float64)[0]
        zs.append(float(dce.distance_comp(C[0], C[1], T)))
    return true_gap, np.asarray(zs)


def _assert_signs_only(true_gap: float, zs: np.ndarray):
    scale = max(abs(true_gap), 1.0)
    if abs(true_gap) > 1e-6 * scale:
        # the sign is faithful under every fresh encryption...
        assert (np.sign(zs) == np.sign(true_gap)).all()
        # ...but the magnitude is re-randomized per encryption (fresh
        # r_o r_p r_q each time), so magnitudes carry no stable value
        rel_spread = np.abs(zs).std() / np.abs(zs).mean()
        assert rel_spread > 1e-3


def test_dce_comparisons_expose_only_signs_fixed_cases():
    for seed in range(8):
        true_gap, zs = _dce_sign_case(seed, d=6 + seed % 3, enc_seed=seed)
        _assert_signs_only(true_gap, zs)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), d=st.integers(2, 12),
           enc_seed=st.integers(0, 2 ** 16))
    def test_dce_comparisons_expose_only_signs_property(seed, d, enc_seed):
        true_gap, zs = _dce_sign_case(seed, d, enc_seed)
        _assert_signs_only(true_gap, zs)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_dce_comparisons_expose_only_signs_property():
        pass
