"""Training substrate tests: optimizer math, schedules, microbatching,
loss-decrease end-to-end, data pipeline determinism/resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.loader import TokenStream
from repro.models import Model
from repro.training import OptConfig, build_train_step, init_train_state
from repro.training.optimizer import (clip_by_global_norm, cosine_schedule,
                                      global_norm, make_optimizer)


def test_cosine_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(cfg, s)) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(cfg.lr * cfg.min_lr_frac, rel=1e-2)


@pytest.mark.parametrize("kind", ["adamw", "adafactor", "sgdm"])
def test_optimizer_reduces_quadratic(kind):
    cfg = OptConfig(kind=kind, lr=0.1, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, grad_clip=1e9)
    opt = make_optimizer(cfg)
    target = {"w": jnp.asarray([1.0, -2.0, 3.0]),
              "b": jnp.asarray([[0.5, -0.5], [1.0, 2.0]])}
    params = jax.tree.map(jnp.zeros_like, target)
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    l0 = float(loss(params))
    for step in range(150):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, step)
    assert float(loss(params)) < 0.05 * l0


def test_bf16_optimizer_state_dtype():
    cfg = OptConfig(kind="adamw", state_dtype="bfloat16")
    opt = make_optimizer(cfg)
    params = {"w": jnp.ones((4, 4))}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.ones((4, 4))}
    p2, st2 = opt.update(g, st, params, 0)
    assert p2["w"].dtype == params["w"].dtype
    assert st2["v"]["w"].dtype == jnp.bfloat16


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(1000.0), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("qwen3-1.7b").smoke()
    model = Model(cfg)
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=100,
                        weight_decay=0.0)
    key = jax.random.PRNGKey(0)
    state = init_train_state(model, opt_cfg, key)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8)
    batch = {k: jnp.asarray(v) for k, v in stream.next().items()}

    s1, m1 = jax.jit(build_train_step(model, opt_cfg))(state, batch)
    s4, m4 = jax.jit(build_train_step(model, opt_cfg, n_microbatches=4))(
        state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 5e-3      # f32 accumulation-order noise


def test_loss_decreases_end_to_end():
    """The e2e sanity bar: a small LM learns the Markov corpus."""
    cfg = get_config("qwen3-1.7b").smoke()
    model = Model(cfg)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                        weight_decay=0.0)
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(1))
    step_fn = jax.jit(build_train_step(model, opt_cfg))
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32,
                         batch_size=8, markov_temp=0.3)
    losses = []
    for _ in range(40):
        batch = {k: jnp.asarray(v) for k, v in stream.next().items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < 0.7 * np.mean(losses[:5]), losses


def test_token_stream_determinism_and_resume():
    a = TokenStream(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    b1 = [a.next() for _ in range(3)]
    st = a.state()
    b2 = a.next()
    resumed = TokenStream.from_state(st, vocab_size=100, seq_len=16,
                                     batch_size=4)
    b2r = resumed.next()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    fresh = TokenStream(vocab_size=100, seq_len=16, batch_size=4, seed=7)
    np.testing.assert_array_equal(b1[0]["tokens"], fresh.next()["tokens"])


def test_token_stream_shards_are_disjoint_and_cover():
    full = TokenStream(vocab_size=50, seq_len=8, batch_size=8, seed=3,
                       n_shards=1, shard=0)
    s0 = TokenStream(vocab_size=50, seq_len=8, batch_size=8, seed=3,
                     n_shards=2, shard=0)
    s1 = TokenStream(vocab_size=50, seq_len=8, batch_size=8, seed=3,
                     n_shards=2, shard=1)
    b0, b1 = s0.next(), s1.next()
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
