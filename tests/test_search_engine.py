"""Unified search engine: batched/per-query parity across every filter
backend, uniform SearchStats, and cross-entry-point agreement.

The acceptance property (ISSUE 1): `Server.search` looped over queries
and the batched engine return *identical* ids on a fixed-seed synthetic
dataset for flat, IVF, and HNSW backends — the refine path is the same
jitted batched tournament either way (batch-of-one vs batch-of-nq).
"""

import numpy as np
import pytest

from repro.core import dce, dcpe, ppanns
from repro.data import synth
from repro.serving.search_engine import (FlatScanFilter, HNSWGraphFilter,
                                         IVFScanFilter, SearchStats,
                                         SecureSearchEngine)

K = 10


@pytest.fixture(scope="module")
def setup():
    ds = synth.make_dataset("deep1m", n=1200, n_queries=8, k_gt=30, seed=21)
    owner, user, server = ppanns.build_system(
        ds.base, beta_fraction=0.03, M=12, ef_construction=100, seed=21)
    qs, ts = zip(*(user.encrypt_query(q) for q in ds.queries))
    return ds, server, np.stack(qs), np.stack(ts)


def _engines(server):
    C_sap, C_dce = server.db.C_sap, server.db.C_dce
    return {
        "flat": SecureSearchEngine(C_sap, C_dce, backend="flat"),
        "ivf": SecureSearchEngine(C_sap, C_dce, backend="ivf",
                                  n_partitions=16, nprobe=6),
        "hnsw": SecureSearchEngine(
            C_sap, C_dce, backend=HNSWGraphFilter(server.db.index)),
    }


@pytest.mark.parametrize("backend", ["flat", "ivf", "hnsw"])
def test_batched_matches_per_query(setup, backend):
    """Engine batch == engine looped batch-of-one, exactly, per backend."""
    ds, server, Q, T = setup
    eng = _engines(server)[backend]
    batched, stats = eng.search_batch(Q, T, K, ratio_k=6)
    for qi in range(Q.shape[0]):
        single, sstats = eng.search(Q[qi], T[qi], K, ratio_k=6)
        np.testing.assert_array_equal(batched[qi], single)
        assert sstats.backend == stats.backend == backend


def test_server_search_loop_matches_batched(setup):
    """The acceptance check: looped Server.search (per-query wrapper) ==
    Server.search_batch == the engine's batched path."""
    ds, server, Q, T = setup
    batched, _ = server.search_batch(Q, T, K, ratio_k=6)
    looped = np.stack([server.search(Q[qi], T[qi], K, ratio_k=6)[0]
                       for qi in range(Q.shape[0])])
    np.testing.assert_array_equal(batched, looped)


def test_flat_and_hnsw_agree_on_final_ids(setup):
    """Different filters, same refine: on an easy ratio_k both candidate
    supersets contain the true top-k, so final ids coincide as sets."""
    ds, server, Q, T = setup
    engs = _engines(server)
    flat, _ = engs["flat"].search_batch(Q, T, K, ratio_k=8)
    hnsw, _ = engs["hnsw"].search_batch(Q, T, K, ratio_k=8, ef_search=128)
    agree = np.mean([len(set(a) & set(b)) / K
                     for a, b in zip(flat.tolist(), hnsw.tolist())])
    assert agree >= 0.9, agree


@pytest.mark.parametrize("backend", ["flat", "ivf", "hnsw"])
def test_recall(setup, backend):
    ds, server, Q, T = setup
    eng = _engines(server)[backend]
    ids, _ = eng.search_batch(Q, T, K, ratio_k=8, ef_search=128)
    rec = synth.recall_at_k(ids, ds.gt, K)
    assert rec >= 0.85, (backend, rec)


@pytest.mark.parametrize("backend", ["flat", "ivf", "hnsw"])
def test_stats_populated_and_consistent(setup, backend):
    ds, server, Q, T = setup
    eng = _engines(server)[backend]
    nq = Q.shape[0]
    ids, stats = eng.search_batch(Q, T, K, ratio_k=6)
    assert isinstance(stats, SearchStats)
    assert stats.n_queries == nq and stats.backend == backend
    assert stats.latency_s > 0
    assert stats.filter_dist_evals > 0
    assert stats.refine_comparisons > 0
    assert stats.bytes_up == Q.nbytes + T.nbytes + 4 * nq
    assert stats.bytes_down == ids.nbytes == 8 * ids.size   # int64 ids
    # single-query stats carry the paper's §V-C communication shape
    _, s1 = eng.search(Q[0], T[0], K, ratio_k=6)
    assert s1.bytes_up == 4 * ds.d + 4 * (2 * ds.d + 16) + 4
    assert s1.bytes_down == 8 * K


def test_heap_refine_selects_same_set(setup):
    """Paper heap refine and batched tournament pick the same k ids from
    the same candidates (both exact; order may differ — heap is unordered)."""
    ds, server, Q, T = setup
    for qi in range(3):
        a, _ = server.search(Q[qi], T[qi], K, ratio_k=6, refine="heap")
        b, _ = server.search(Q[qi], T[qi], K, ratio_k=6, refine="tournament")
        assert len(set(a.tolist()) & set(b.tolist())) >= K - 1


def test_filter_only_mode_batched(setup):
    ds, server, Q, T = setup
    eng = _engines(server)["flat"]
    ids, stats = eng.search_batch(Q, T, K, ratio_k=6, refine="none")
    assert ids.shape == (Q.shape[0], K)
    assert stats.refine_comparisons == 0
    # flat filter-only == exact NN on *DCPE ciphertexts*: high recall
    assert synth.recall_at_k(ids, ds.gt, K) >= 0.5


def test_engine_matches_distributed_scan(setup):
    """The engine's flat path and the mesh server compute the same answer
    (same filter math, same shared refine)."""
    from repro.serving.ann_server import DistributedSecureANN
    ds, server, Q, T = setup
    eng = _engines(server)["flat"]
    ids_e, _ = eng.search_batch(Q, T, K, ratio_k=6)
    dist = DistributedSecureANN(np.asarray(server.db.C_sap),
                                np.asarray(server.db.C_dce))
    ids_d = dist.query_batch(Q, T, K, ratio_k=6)
    for a, b in zip(ids_e.tolist(), ids_d.tolist()):
        assert set(a) == set(b)


def test_update_database_after_insert(setup):
    """Engine state refresh mirrors §V-D maintenance: shrinking the
    database re-attaches the backend and the batched path never returns
    ids outside the new database."""
    ds, server, Q, T = setup
    C_sap, C_dce = np.asarray(server.db.C_sap), np.asarray(server.db.C_dce)
    eng = SecureSearchEngine(C_sap, C_dce, backend="flat")
    eng.update_database(C_sap[: ds.n - 1], C_dce[: ds.n - 1])
    ids1, _ = eng.search_batch(Q[:1], T[:1], K)
    assert eng.n == ds.n - 1
    assert (ids1 < ds.n - 1).all()


def test_underfilled_candidates_use_sentinel_not_id_zero():
    """A query with fewer than k real candidates gets -1 fill, never a
    fabricated id 0 (regression: zero-padded cand slots used to leak)."""
    rng = np.random.default_rng(3)
    P = rng.standard_normal((6, 16)).astype(np.float32)   # tiny database
    owner, user, server = ppanns.build_system(P, beta_fraction=0.05, seed=3)
    cq, tq = user.encrypt_query(P[4])
    k = 10                                                # k > n
    ids, _ = server.search(cq, tq, k)
    real = ids[ids >= 0]
    assert len(set(real.tolist())) == len(real) == 6      # all 6, no dupes
    assert (ids[6:] == -1).all()
    ids_f, _ = server.search(cq, tq, k, refine="none")
    assert (ids_f[ids_f >= 0] < 6).all() and (ids_f[6:] == -1).all()
    # same (nq, k) contract for the flat backend and the mesh server
    from repro.serving.ann_server import DistributedSecureANN
    C_sap, C_dce = server.db.C_sap, server.db.C_dce
    flat = SecureSearchEngine(C_sap, C_dce, backend="flat")
    ids2, _ = flat.search(cq, tq, k)
    assert ids2.shape == (k,) and (ids2[6:] == -1).all()
    np.testing.assert_array_equal(ids2[:6], ids[:6])
    dist = DistributedSecureANN(np.asarray(C_sap), np.asarray(C_dce))
    ids3 = dist.query_batch(cq[None], tq[None], k)
    assert ids3.shape == (1, k) and (ids3[0, 6:] == -1).all()
    np.testing.assert_array_equal(ids3[0, :6], ids[:6])
