"""Property tests for DCPE / Scale-and-Perturb (paper §III-B, Def. 3)."""

import numpy as np
import pytest

from repro.core import dcpe


@pytest.mark.parametrize("d", [8, 96, 128, 960])
def test_perturbation_radius_bound(d):
    """||C_p - s p|| <= s*beta/4 — Algorithm 1 draws lambda in that ball."""
    rng = np.random.default_rng(d)
    P = rng.standard_normal((200, d))
    key = dcpe.keygen(s=1024.0, beta=2.0)
    C = dcpe.encrypt(P, key, seed=0).astype(np.float64)
    radius = np.linalg.norm(C - key.s * P, axis=1)
    assert (radius <= key.s * key.beta / 4.0 + 1e-3).all()


def test_distance_approximation_sandwich():
    """s*dist - s*beta/2 <= enc_dist <= s*dist + s*beta/2."""
    rng = np.random.default_rng(0)
    d = 32
    key = dcpe.keygen(s=128.0, beta=1.5)
    P = rng.standard_normal((100, d))
    q = rng.standard_normal((1, d))
    C = dcpe.encrypt(P, key, seed=1).astype(np.float64)
    Cq = dcpe.encrypt(q, key, seed=2).astype(np.float64)[0]
    true = key.s * np.linalg.norm(P - q, axis=1)
    enc = np.linalg.norm(C - Cq, axis=1)
    slack = key.s * key.beta / 2.0 + 1e-3
    assert (enc <= true + slack).all() and (enc >= true - slack).all()


def test_beta_bounds_and_suggestion():
    rng = np.random.default_rng(1)
    P = rng.standard_normal((50, 16)) * 2
    lo, hi = dcpe.beta_bounds(P)
    assert 0 < lo < hi
    b = dcpe.suggest_beta(P, fraction=0.05)
    assert lo <= b <= hi


def test_same_dim_and_cost_as_plaintext():
    """DCPE ciphertexts keep dimension d — filter-phase distances cost the
    same as plaintext distances (paper §III-B)."""
    P = np.random.default_rng(2).standard_normal((7, 48))
    C = dcpe.encrypt(P, dcpe.keygen(beta=1.0), seed=0)
    assert C.shape == P.shape
