"""Scheduler invariants under randomized interleavings (DESIGN.md §12).

Both schedulers — the flush `MicroBatcher` and the continuous `SlotLoop`
— must satisfy the same contract under ANY interleaving of submit /
cancel / discard / clock-advance / engine-stall / close:

  1. every accepted request resolves exactly once (result, error, or
     acknowledged cancellation — never silently dropped, never doubly
     delivered);
  2. a resolved result carries exactly the ids of ITS query — no
     cross-request row mixing, regardless of which slot/bucket the
     request rode in;
  3. every shed request is counted: telemetry `n_rejected` equals the
     number of `QueueFullError`s clients observed;
  4. the two schedulers are bit-identical on real engines: the same
     request stream gets the same ids from "flush" and "continuous",
     across backends and placements.

Interleavings are driven by `hypothesis` when it is installed, and fall
back to a fixed seed sweep of the same generator otherwise — the test
body is identical either way (a seeded RNG program).
"""

import dataclasses
import threading
from concurrent.futures import CancelledError

import jax
import numpy as np
import pytest

from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                       SearchParams, SearchRequest, SecureAnnService,
                       suggest_beta)
from repro.core import dcpe
from repro.data import synth
from repro.serving.runtime import (Collection, CollectionTelemetry,
                                   MicroBatcher, QueueFullError, SlotLoop,
                                   VirtualClock)
from repro.serving.search_engine import SearchStats

D = 20
K = 6
KINDS = ("flush", "continuous")

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True

    def seeded(fn):
        """Drive the seeded-RNG test body with hypothesis-chosen seeds."""
        return settings(max_examples=15, deadline=None,
                        suppress_health_check=list(HealthCheck))(
            given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))(fn))
except ImportError:                      # hypothesis not installed: the
    HAVE_HYPOTHESIS = False              # same program over fixed seeds

    def seeded(fn):
        return pytest.mark.parametrize("seed", range(12))(fn)


class RecordingEngine:
    """Deterministic fake engine: ids[i] = 100*round(Q[i,0]) .. +k.

    Unique bases per request make assertion (2) exact: any cross-request
    row mixing shows up as a wrong id block.  The gate is the only
    synchronization — the driver uses it to stall a step mid-flight."""

    def __init__(self):
        self.gate = threading.Event()
        self.gate.set()
        self.calls = []

    def __call__(self, Q, T, k, ratio_k=8.0, ef_search=96):
        self.gate.wait(timeout=10.0)
        Q = np.atleast_2d(Q)
        self.calls.append(Q.shape)
        base = 100 * np.round(Q[:, 0]).astype(np.int64)
        ids = base[:, None] + np.arange(k)[None, :]
        return ids, SearchStats(latency_s=0.0, filter_dist_evals=0,
                                refine_comparisons=0, bytes_up=0,
                                bytes_down=0, n_queries=Q.shape[0],
                                backend="fake")


def _expected(i, k):
    return 100 * i + np.arange(k)


def _make_scheduler(kind, eng, clock, telemetry, max_batch, max_queue):
    if kind == "flush":
        return MicroBatcher(eng, max_batch=max_batch, max_queue=max_queue,
                            max_wait_ms=8.0, telemetry=telemetry,
                            clock=clock)
    return SlotLoop(eng, max_batch=max_batch, max_queue=max_queue,
                    telemetry=telemetry, clock=clock)


def _drive(kind, seed):
    """One randomized interleaving; returns nothing, asserts the contract."""
    rng = np.random.default_rng(seed)
    eng = RecordingEngine()
    clock = VirtualClock()
    tel = CollectionTelemetry()
    max_batch = int(rng.integers(1, 9))
    max_queue = int(rng.integers(1, 12))
    sched = _make_scheduler(kind, eng, clock, tel, max_batch, max_queue)
    accepted = []                       # (request index, future)
    done_counts = {}                    # id(fut) -> done-callback fires
    n_rejected = 0
    nxt = 1                             # request index 0 never used
    try:
        for _ in range(int(rng.integers(25, 60))):
            op = rng.choice(["submit", "submit", "submit", "submit",
                             "discard", "cancel", "advance", "stall"])
            if op == "submit":
                q = np.full(D, float(nxt), np.float32)
                t = np.zeros(2 * D + 16, np.float32)
                k = K if rng.random() < 0.7 else K + 2  # two param groups
                try:
                    fut = sched.submit(q, t, k)
                except QueueFullError:
                    n_rejected += 1
                else:
                    accepted.append((nxt, k, fut))
                    done_counts[id(fut)] = 0
                    fut.add_done_callback(
                        lambda f: done_counts.__setitem__(
                            id(f), done_counts[id(f)] + 1))
                nxt += 1
            elif op == "discard" and accepted:
                _, _, fut = accepted[int(rng.integers(len(accepted)))]
                sched.discard(fut)      # cancel + free the queue slot
            elif op == "cancel" and accepted:
                _, _, fut = accepted[int(rng.integers(len(accepted)))]
                fut.cancel()            # raw client-side cancel race
            elif op == "advance":
                clock.advance(float(rng.uniform(0.0, 0.02)))
            elif op == "stall":
                if eng.gate.is_set() and rng.random() < 0.5:
                    eng.gate.clear()    # wedge the next step mid-flight
                else:
                    eng.gate.set()
    finally:
        eng.gate.set()                  # release any wedged step, then
        sched.close()                   # drain everything deterministically

    for i, k, fut in accepted:
        assert fut.done(), f"request {i} never resolved"
        assert done_counts[id(fut)] == 1, \
            f"request {i} resolved {done_counts[id(fut)]} times"
        if fut.cancelled():
            continue                    # acknowledged cancellation
        try:
            ids = fut.result(timeout=0)
        except CancelledError:          # pragma: no cover - raced cancel
            continue
        np.testing.assert_array_equal(       # any mismatch here would be
            ids, _expected(i, k))            # cross-request row mixing
    assert tel.snapshot()["n_rejected"] == n_rejected


@pytest.mark.parametrize("kind", KINDS)
@seeded
def test_random_interleavings_uphold_contract(kind, seed):
    _drive(kind, seed)


@pytest.mark.parametrize("kind", KINDS)
def test_every_request_resolves_under_heavy_stall(kind):
    """Dense variant of the contract: a long stall while the queue fills
    past capacity, then one release — nothing lost, rejects counted."""
    eng = RecordingEngine()
    tel = CollectionTelemetry()
    sched = _make_scheduler(kind, eng, VirtualClock(), tel,
                            max_batch=3, max_queue=4)
    eng.gate.clear()
    accepted, n_rejected = [], 0
    try:
        for i in range(1, 30):
            try:
                accepted.append((i, sched.submit(
                    np.full(D, float(i), np.float32),
                    np.zeros(2 * D + 16, np.float32), K)))
            except QueueFullError:
                n_rejected += 1
        assert n_rejected > 0           # the stall really backed it up
    finally:
        eng.gate.set()
        sched.close()
    for i, fut in accepted:
        np.testing.assert_array_equal(fut.result(timeout=0),
                                      _expected(i, K))
    assert tel.snapshot()["n_rejected"] == n_rejected


# ---------------------------------------------------------------------------
# The same contract over a REAL collection: randomized submit / ingest /
# discard interleavings while the engine recompiles and deltas compact.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ds():
    return synth.make_dataset("deep1m", n=300, n_queries=8, k_gt=10,
                              seed=2, d=D)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("seed", [3, 11])
def test_interleaved_ingest_and_search_on_real_collection(ds, kind, seed):
    rng = np.random.default_rng(seed)
    beta = dcpe.suggest_beta(ds.base, fraction=0.03)
    vc = VirtualClock()
    col = Collection("t", f"mix-{kind}-{seed}", D, sap_beta=beta, seed=1,
                     scheduler=kind, max_batch=4, max_queue=64,
                     max_wait_ms=5.0, compact_every=64, clock=vc)
    try:
        col.insert(ds.base[:100])
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in ds.queries]
        accepted, cursor = [], 100
        for _ in range(18):
            op = rng.choice(["submit", "submit", "insert", "advance",
                             "discard"])
            if op == "submit":
                fut = col.submit(*enc[int(rng.integers(len(enc)))], K)
                accepted.append(fut)
            elif op == "insert" and cursor < ds.n:
                step = int(rng.integers(1, 8))
                col.insert(ds.base[cursor:cursor + step])
                cursor += step
            elif op == "advance":
                vc.advance(float(rng.uniform(0.0, 0.01)))
            elif op == "discard" and accepted:
                col.batcher.discard(
                    accepted[int(rng.integers(len(accepted)))])
    finally:
        col.close()                     # drains every queued request
    n_total = col.store.n_total
    for fut in accepted:
        assert fut.done()
        if fut.cancelled():
            continue
        ids = fut.result(timeout=0)
        assert ids.shape == (K,)
        assert (ids < n_total).all()    # rows of THIS store only
        assert (ids >= 0).all()         # 100+ rows alive: no sentinels


# ---------------------------------------------------------------------------
# Cross-scheduler bit-identity on real engines: flat/ivf x single/sharded.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus(ds):
    spec = IndexSpec(tenant="t", name="base", d=D,
                     sap_beta=suggest_beta(ds.base, fraction=0.05), seed=5)
    owner = DataOwnerClient(spec)
    C_sap, C_dce = owner.encrypt_vectors(ds.base, seed=11)
    query = owner.query_client().encrypt_queries(ds.queries)
    return spec, C_sap, C_dce, query


@pytest.mark.parametrize("placement_kind", ["single", "sharded"])
@pytest.mark.parametrize("backend", ["flat", "ivf"])
def test_schedulers_bit_identical_on_real_engines(corpus, backend,
                                                  placement_kind):
    """The tentpole acceptance bar: for the same request stream, the
    flush micro-batcher and the continuous slot loop return bit-identical
    ids — batch path and coalesced per-request path, on flat and IVF,
    single-device and sharded placement."""
    spec0, C_sap, C_dce, query = corpus
    n_shards = min(2, jax.device_count())
    placement = (None if placement_kind == "single"
                 else PlacementSpec(kind="sharded", n_shards=n_shards))
    extra = dict(n_partitions=8, nprobe=3) if backend == "ivf" else {}
    params = SearchParams(k=8, ratio_k=6.0)
    got = {}
    for sched in ("flush", "continuous"):
        spec = dataclasses.replace(
            spec0, name=f"par-{backend}-{placement_kind}-{sched}",
            backend=backend, scheduler=sched, max_batch=8, **extra)
        with SecureAnnService() as svc:
            svc.create_collection(spec, placement=placement)
            svc.insert("t", spec.name, C_sap, C_dce)
            batch = svc.submit(SearchRequest(
                tenant="t", collection=spec.name, query=query,
                params=params, coalesce=False)).ids
            coalesced = svc.submit(SearchRequest(
                tenant="t", collection=spec.name,
                query=dataclasses.replace(query), params=params)).ids
        got[sched] = (batch, coalesced)
    np.testing.assert_array_equal(got["flush"][0], got["continuous"][0])
    np.testing.assert_array_equal(got["flush"][1], got["continuous"][1])
