"""Fig. 8 — data-owner encryption cost per vector: DCPE < DCE << AME."""

from __future__ import annotations

import numpy as np

from repro.core import ame, dce, dcpe

from .common import row, timeit


def run(n: int = 2000, d: int = 128) -> list[str]:
    rng = np.random.default_rng(0)
    P = rng.standard_normal((n, d)).astype(np.float32)
    rows = []

    sap = dcpe.keygen(s=1024.0, beta=2.0)
    t, _ = timeit(lambda: dcpe.encrypt(P, sap, seed=1))
    rows.append(row("fig8/dcpe_enc", 1e6 * t / n, f"d={d}"))

    dk = dce.keygen(d, seed=0)
    t, _ = timeit(lambda: dce.encrypt(P, dk, seed=1))
    rows.append(row("fig8/dce_enc", 1e6 * t / n,
                    f"d={d} cipher={4 * dce.ciphertext_dim(d)}floats"))
    t, _ = timeit(lambda: dce.trapgen(P[:200], dk, seed=2))
    rows.append(row("fig8/dce_trapgen(user)", 1e6 * t / 200, f"d={d}"))

    ak = ame.keygen(d, seed=0)
    na = min(n, 200)                       # AME is ~50x slower; subsample
    t, _ = timeit(lambda: ame.encrypt(P[:na], ak, seed=1), repeats=1)
    rows.append(row("fig8/ame_enc", 1e6 * t / na,
                    f"d={d} cipher=32x{2 * d + 6}floats"))
    t, _ = timeit(lambda: ame.trapgen(P[:20], ak, seed=2), repeats=1)
    rows.append(row("fig8/ame_trapgen(user)", 1e6 * t / 20,
                    f"d={d} 16 matrices"))
    return rows
