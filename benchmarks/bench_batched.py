"""Batched-engine throughput: flat / IVF / HNSW filter backends at
several batch sizes (EXPERIMENTS.md §Perf cell 2).

Not a paper figure — the paper serves queries one at a time; this table
is the systems extension showing what the unified batched engine
(DESIGN.md §2) buys: one jitted refine per batch instead of a per-query
loop, with identical ids to the per-query path."""

from __future__ import annotations

import numpy as np

from repro.data import synth
from repro.serving.search_engine import (HNSWGraphFilter, SecureSearchEngine)

from .common import row, system, timeit


def run(n: int = 6000, batches=(1, 8, 32), k: int = 10) -> list[str]:
    nq = max(batches)
    ds, owner, user, server = system("sift1m", n, nq, beta_fraction=0.03)
    enc = [user.encrypt_query(q) for q in ds.queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])

    engines = {
        "flat": SecureSearchEngine(server.db.C_sap, server.db.C_dce,
                                   backend="flat"),
        "ivf": SecureSearchEngine(server.db.C_sap, server.db.C_dce,
                                  backend="ivf", n_partitions=64, nprobe=8),
        "hnsw": SecureSearchEngine(server.db.C_sap, server.db.C_dce,
                                   backend=HNSWGraphFilter(server.db.index)),
    }

    rows = []
    for name, eng in engines.items():
        for B in batches:
            t, (ids, stats) = timeit(
                eng.search_batch, Q[:B], T[:B], k,
                ratio_k=8, ef_search=128, repeats=2)
            rec = synth.recall_at_k(ids, ds.gt[:B], k)
            rows.append(row(
                f"batched/{name}/B={B}", 1e6 * t / B,
                f"qps={B / t:.1f} recall={rec:.3f} "
                f"dist_evals={stats.filter_dist_evals} "
                f"cmp={stats.refine_comparisons}"))
    return rows
