"""Batched-engine throughput: flat / IVF / HNSW filter backends at
several batch sizes (EXPERIMENTS.md §Perf cell 2), driven through the
public API (`repro.api`, DESIGN.md §9).

Not a paper figure — the paper serves queries one at a time; this table
is the systems extension showing what the unified batched engine
(DESIGN.md §2) buys: one jitted refine per batch instead of a per-query
loop, with identical ids to the per-query path.  One owner-encrypted
corpus backs three collections, one per filter backend; every search is
a typed `SearchRequest` (coalesce=False: straight to the locked engine
call, no micro-batching in the measurement)."""

from __future__ import annotations

import dataclasses

from repro.api import (DataOwnerClient, EncryptedQuery, IndexSpec,
                       SearchParams, SearchRequest, SecureAnnService,
                       suggest_beta)
from repro.data import synth

from .common import dataset, row, timeit


def run(n: int = 6000, batches=(1, 8, 32), k: int = 10) -> list[str]:
    nq = max(batches)
    ds = dataset("sift1m", n, nq)
    spec = IndexSpec(tenant="bench", name="batched-hnsw", d=ds.d,
                     backend="hnsw",
                     sap_beta=suggest_beta(ds.base, fraction=0.03),
                     hnsw_M=16, hnsw_ef_construction=120, seed=0)
    owner = DataOwnerClient(spec)
    corpus = owner.encrypt_corpus(ds.base)
    user = owner.query_client()
    query = user.encrypt_queries(ds.queries)
    params = SearchParams(k=k, ratio_k=8, ef_search=128)

    rows = []
    with SecureAnnService() as svc:
        for backend in ("flat", "ivf", "hnsw"):
            bspec = dataclasses.replace(spec, name=f"batched-{backend}",
                                        backend=backend)
            svc.create_collection(bspec, corpus=corpus)
            for B in batches:
                req = SearchRequest(
                    tenant=bspec.tenant, collection=bspec.name,
                    query=EncryptedQuery(C_sap=query.C_sap[:B],
                                         T=query.T[:B]),
                    params=params, coalesce=False)
                t, res = timeit(svc.submit, req, repeats=2)
                rec = synth.recall_at_k(res.ids, ds.gt[:B], k)
                rows.append(row(
                    f"batched/{backend}/B={B}", 1e6 * t / B,
                    f"qps={B / t:.1f} recall={rec:.3f} "
                    f"dist_evals={res.stats.filter_dist_evals} "
                    f"cmp={res.stats.refine_comparisons}"))
    return rows
