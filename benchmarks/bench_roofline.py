"""§Roofline — three-term roofline per (arch x shape) from the dry-run
artifacts (single-pod).  Requires results/dryrun/*.json (run
`python -m repro.launch.dryrun --all --both-meshes` first); cells without
artifacts are skipped with a note."""

from __future__ import annotations

import os

from repro.launch import roofline

from .common import row


def run(results_dir: str = "results/dryrun") -> list[str]:
    if not os.path.isdir(results_dir):
        return [row("roofline/missing", 0.0,
                    "run python -m repro.launch.dryrun --all first")]
    rows = []
    for r in roofline.table(results_dir, mesh_filter="1pod_256"):
        rows.append(row(
            f"roofline/{r.arch}/{r.shape}", 1e6 * max(
                r.compute_s, r.memory_s, r.collective_s),
            f"compute={r.compute_s:.3g}s memory={r.memory_s:.3g}s "
            f"coll={r.collective_s:.3g}s dom={r.dominant} "
            f"roofline={100 * r.fraction_of_roofline():.1f}%"))
    return rows
