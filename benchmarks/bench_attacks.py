"""§III (Thm 1-2, Cor 1-2) — KPA attacks break every ASPE variant.

Reported as recovery error + attack wall time; DCE/AME by contrast leak
only comparison signs (no analogous linear system exists)."""

from __future__ import annotations

from repro.core import attacks

from .common import row, timeit


def run() -> list[str]:
    rows = []
    for tr, d in [("linear", 16), ("exp", 16), ("log", 16), ("square", 8)]:
        t, res = timeit(
            lambda tr=tr, d=d: attacks.attack_roundtrip(
                d=d, n=120, nq=60, transform=tr), repeats=1)
        rows.append(row(f"sec3/aspe-{tr}-kpa", 1e6 * t,
                        f"d={d} query_err={res['query_err']:.1e} "
                        f"db_err={res['db_err']:.1e} BROKEN"))
    return rows
