"""Leakage-vs-QPS frontier (EXPERIMENTS.md §Attacks, DESIGN.md §14).

Two halves, one suite:

§III rows — the KPA attacks that break every ASPE variant, now reported
as *normalized* attack success (1.0 = recovery to numerical precision,
0.0 = no better than guessing a fresh sample from the data
distribution) instead of raw recovery error, so "BROKEN" is a number
comparable across transforms and dimensions.

Frontier rows — every security profile × filter backend cell serves the
same encrypted corpus through the real `repro.api` service path and
reports, side by side:
  * measured QPS of the served search (batched submits through
    `SecureAnnService.submit`, result padding and scan variant
    included), and
  * the leakage column: `repro.sec.leakage` replays the server's view
    under that profile and scores the revived DCE sign-KPA, the
    access-pattern query-localization attack, and (quantized cells) the
    ADC-code distinguisher, each normalized against its zero-leakage
    baseline.

The output is the leakage-vs-QPS frontier: "perf" is fastest and leaks
query localization through its pooled scans; "hardened" pays the
full-bucket scan cost and measurably leaks nothing the attacks can
use; "oblivious-sketch" additionally prices the TEE/FHE refine that
would close the remaining magnitude channel (cost model, not served).

Writes `BENCH_attacks.json` at the repo root (the attack-suite
trajectory record) in addition to the harness's results-dir copy.

  PYTHONPATH=src python -m benchmarks.bench_attacks --smoke

exits non-zero unless ASPE recovery stays broken-level (success >=
0.9), the DCE/ADC/access-pattern attacks all fail under "hardened"
(success <= 0.05), the pooled "perf" tier measurably leaks (access-
pattern success >= 0.2 — a frontier with nothing to trade is not a
frontier), and "balanced" costs at most 25% QPS vs "perf" — the
`sec-smoke` CI gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro import api
from repro.core import dcpe, ppanns
from repro.data import synth
from repro.sec import (SECURITY_PROFILE_NAMES, aspe_kpa_attack,
                       evaluate_profile, get_profile)

from .common import row, timeit

K = 10
# frontier grid: every profile × (f32 IVF, int8-quantized ADC IVF)
BACKENDS = (("ivf", None), ("ivf", "int8"))
# leakage replay scale (repro.sec.leakage defaults, kept explicit here)
LEAK_N, LEAK_D, LEAK_NQ = 2048, 32, 64

ASPE_BROKEN_GATE = 0.9      # ASPE recovery must stay at broken level
HARDENED_LEAK_GATE = 0.05   # every attack at-chance under "hardened"
PERF_LEAK_GATE = 0.2        # pooled scans must measurably leak
BALANCED_QPS_GATE = 0.75    # balanced >= 75% of perf throughput

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _setup(n: int, d: int, nq: int, seed: int = 0):
    ds = synth.make_dataset("sift1m", n=n, n_queries=nq, d=d, k_gt=K,
                            seed=seed)
    beta = dcpe.suggest_beta(ds.base, fraction=0.01)
    owner = ppanns.DataOwner(d=d, sap_beta=beta, sap_s=1024.0, seed=seed)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    user = ppanns.User(owner.share_keys(), seed=seed + 1)
    enc = [user.encrypt_query(q) for q in ds.queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    return ds, C_sap, C_dce, Q, T


def _measure_qps(profile: str, backend: str, quantization: str | None,
                 C_sap, C_dce, Q, T, *, seed: int, repeats: int) -> float:
    """Served QPS of one frontier cell: batched queries through the real
    `SecureAnnService.submit` path (profile-selected scan variant +
    result padding included)."""
    d = C_sap.shape[1]
    nq = Q.shape[0]
    kw = {"quantization": quantization} if quantization else {}
    spec = api.IndexSpec(tenant="bench", name=f"{profile}-{backend}",
                         d=d, backend=backend, seed=seed,
                         security_profile=profile, **kw)
    with api.SecureAnnService() as svc:
        svc.create_collection(spec)
        svc.insert("bench", spec.name, C_sap, C_dce)
        req = api.SearchRequest(
            tenant="bench", collection=spec.name,
            query=api.EncryptedQuery(C_sap=Q, T=T),
            params=api.SearchParams(k=K))
        t, _ = timeit(lambda: svc.submit(req), repeats=repeats)
    return nq / t


def _cell(profile: str, backend: str, quantization: str | None,
          qps: float, leaks: list) -> tuple[str, dict]:
    label = backend if not quantization else f"{backend}+{quantization}"
    by_attack = {r.attack: r.success for r in leaks}
    derived = " ".join([f"qps={qps:.1f}"] +
                       [f"{a}={s:.3f}" for a, s in by_attack.items()])
    prof = get_profile(profile)
    if prof.refine == "tee-sketch":
        cost = prof.tee_refine_cost(int(8.0 * K), LEAK_D)
        derived += f" tee_refine_cost_x={cost['est_cost_vs_dce_x']:.0f}"
    return (row(f"attacks/frontier/{profile}/{label}", 1e6 / qps, derived),
            {"profile": profile, "backend": label, "qps": qps,
             "attacks": by_attack})


def run(n: int = 16_384, d: int = 64, nq: int = 64, seed: int = 0,
        repeats: int = 3, write_root_json: bool = True) -> list[str]:
    rows = []
    # -- §III: ASPE is broken, in normalized units --------------------
    aspe_results = []
    for tr, dd in [("linear", 16), ("exp", 16), ("log", 16), ("square", 8)]:
        t, res = timeit(lambda tr=tr, dd=dd: aspe_kpa_attack(
            tr, d=dd, n=120, nq=60, seed=seed), repeats=1)
        aspe_results.append(res)
        rows.append(row(
            f"attacks/aspe-{tr}-kpa", 1e6 * t,
            f"d={dd} success={res.success:.4f} err={res.err:.1e} "
            f"baseline={res.baseline:.2f} BROKEN"))
    # -- the frontier: profile × backend ------------------------------
    ds, C_sap, C_dce, Q, T = _setup(n, d, nq, seed)
    frontier = []
    for profile in SECURITY_PROFILE_NAMES:
        for backend, quant in BACKENDS:
            qps = _measure_qps(profile, backend, quant, C_sap, C_dce,
                               Q, T, seed=seed, repeats=repeats)
            leaks = evaluate_profile(profile, backend, quant, n=LEAK_N,
                                     d=LEAK_D, nq=LEAK_NQ, seed=seed)
            r, cell = _cell(profile, backend, quant, qps, leaks)
            rows.append(r)
            frontier.append(cell)
    if write_root_json:
        _write_root_json(rows, aspe_results, frontier, n, d, nq)
    return rows


def _write_root_json(rows, aspe_results, frontier, n, d, nq):
    """The repo-root BENCH_attacks.json: the leakage-vs-QPS frontier
    record sessions diff against (the harness also writes its own copy
    under results/bench)."""
    from .run import provenance
    payload = {
        "suite": "attacks",
        "unix_time": time.time(),
        "config": {"n": n, "d": d, "nq": nq, "k": K,
                   "leak_n": LEAK_N, "leak_d": LEAK_D, "leak_nq": LEAK_NQ},
        "provenance": provenance(),
        "aspe": [r.to_dict() for r in aspe_results],
        "frontier": frontier,
        "rows": [{"name": r.split(",", 2)[0],
                  "us_per_call": float(r.split(",", 2)[1]),
                  "derived": r.split(",", 2)[2]} for r in rows],
    }
    (_ROOT / "BENCH_attacks.json").write_text(
        json.dumps(payload, indent=2) + "\n")


def _smoke(n: int = 4096, d: int = 32, nq: int = 32, seed: int = 0) -> int:
    """The `sec-smoke` CI gate (module docstring for the bars)."""
    ok = True
    aspe = aspe_kpa_attack("linear", seed=seed)
    print(row("attacks-smoke/aspe-linear", 0.0,
              f"success={aspe.success:.4f}"), flush=True)
    if aspe.success < ASPE_BROKEN_GATE:
        print(f"# SMOKE FAIL: ASPE KPA success {aspe.success:.3f} < "
              f"{ASPE_BROKEN_GATE} — the strawman should stay broken")
        ok = False
    for profile in ("perf", "hardened"):
        for backend, quant in BACKENDS:
            leaks = evaluate_profile(profile, backend, quant, n=LEAK_N,
                                     d=LEAK_D, nq=LEAK_NQ, seed=seed)
            label = backend if not quant else f"{backend}+{quant}"
            for r in leaks:
                print(row(f"attacks-smoke/{profile}/{label}/{r.attack}",
                          0.0, f"success={r.success:.3f}"), flush=True)
                if profile == "hardened" \
                        and r.success > HARDENED_LEAK_GATE:
                    print(f"# SMOKE FAIL: {r.attack} success "
                          f"{r.success:.3f} > {HARDENED_LEAK_GATE} "
                          f"under hardened/{label}")
                    ok = False
                if profile == "perf" and r.attack == "access-pattern" \
                        and r.success < PERF_LEAK_GATE:
                    print(f"# SMOKE FAIL: access-pattern success "
                          f"{r.success:.3f} < {PERF_LEAK_GATE} under "
                          f"perf/{label} — nothing measured to trade")
                    ok = False
    ds, C_sap, C_dce, Q, T = _setup(n, d, nq, seed)
    qps = {p: _measure_qps(p, "ivf", None, C_sap, C_dce, Q, T,
                           seed=seed, repeats=2)
           for p in ("perf", "balanced")}
    print(row("attacks-smoke/qps/perf", 1e6 / qps["perf"],
              f"qps={qps['perf']:.1f}"), flush=True)
    print(row("attacks-smoke/qps/balanced", 1e6 / qps["balanced"],
              f"qps={qps['balanced']:.1f} "
              f"ratio={qps['balanced'] / qps['perf']:.3f}"), flush=True)
    if qps["balanced"] < BALANCED_QPS_GATE * qps["perf"]:
        print(f"# SMOKE FAIL: balanced qps {qps['balanced']:.1f} < "
              f"{BALANCED_QPS_GATE} x perf qps {qps['perf']:.1f}")
        ok = False
    if ok:
        print("# smoke OK: ASPE broken, hardened at-chance on every "
              "attack, perf leak measured, balanced within "
              f"{100 * (1 - BALANCED_QPS_GATE):.0f}% of perf QPS")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: ASPE broken + hardened leaks nothing "
                         "+ balanced QPS within 25% of perf")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(_smoke())
    for r in run(n=32_768 if args.full else 16_384):
        print(r)


if __name__ == "__main__":
    main()
