"""Fig. 7 / Fig. 9 — ours vs the baseline system shapes, with server-side
and user-side cost split plus communication bytes.

Baselines (crypto cores replaced by cost-faithful stand-ins; DESIGN.md §7):
  * RS-SANN  — LSH index on the server; server returns AES-encrypted
    candidates; the USER decrypts and refines locally.  Costs: large
    download + user-side distance pass.
  * PRI-ANN  — LSH index, candidates fetched by PIR: server-side cost is
    a full linear pass over the database PER QUERY (that is what
    single-server PIR costs); user refines.
  * PACM-ANN — graph index walked BY THE USER via PIR: each hop is a PIR
    fetch (linear server pass) + round trip.
  * linear-scan-DCE — our encryption without the index (paper §IV end).
"""

from __future__ import annotations

import numpy as np

from repro.core import secure_knn
from repro.core.lsh import LSHIndex
from repro.data import synth

from .common import row, system, timeit


def _xor_stream(buf: np.ndarray) -> np.ndarray:
    """AES stand-in: one cheap pass over the bytes (cost model, not crypto)."""
    b = buf.view(np.uint8)
    return (b ^ np.uint8(0x5A))


def run(n: int = 6000, nq: int = 10) -> list[str]:
    ds, owner, user, server = system("sift1m", n, nq)
    k = 10
    enc = [user.encrypt_query(q) for q in ds.queries[:nq]]
    rows = []

    # ---- ours (server-side only; user cost = trapgen, measured separately)
    def ours():
        return np.stack([server.search(cs, tq, k, ratio_k=8,
                                       ef_search=128)[0]
                         for cs, tq in enc])
    t, found = timeit(ours, repeats=1)
    rec = synth.recall_at_k(found, ds.gt[:nq], k)
    rows.append(row("fig7/ours(hnsw-dce)", 1e6 * t / nq,
                    f"recall={rec:.3f} qps={nq / t:.1f} side=server"))
    t_user, _ = timeit(lambda: [user.encrypt_query(q)
                                for q in ds.queries[:nq]], repeats=1)
    rows.append(row("fig9/ours_user", 1e6 * t_user / nq,
                    "trapgen+dcpe O(d^2)"))

    # ---- RS-SANN: LSH on server, user decrypts + refines
    lsh = LSHIndex(dim=ds.d, n_tables=12, n_hashes=6, bucket_width=20.0,
                   seed=0)
    lsh.build(ds.base)
    enc_db = _xor_stream(ds.base.copy())          # "AES" at rest

    def rs_sann():
        out, down, t_user_acc = [], 0, 0.0
        import time as _t
        for qi in range(nq):
            cands = lsh.query(ds.queries[qi])
            if len(cands) == 0:
                cands = np.arange(min(100, n))
            blob = enc_db.reshape(n, -1)[cands]   # server sends ciphertexts
            down += blob.nbytes
            t0 = _t.perf_counter()
            dec = (blob ^ np.uint8(0x5A)).view(np.float32).reshape(
                len(cands), ds.d)                 # user decrypts
            dist = ((dec - ds.queries[qi]) ** 2).sum(1)
            out.append(cands[np.argsort(dist)[:k]])
            t_user_acc += _t.perf_counter() - t0
        pad = [np.pad(o, (0, k - len(o)), constant_values=-1) for o in out]
        return np.stack(pad), down, t_user_acc
    t, (found, down, t_user_rs) = timeit(rs_sann, repeats=1)
    rec = synth.recall_at_k(found, ds.gt[:nq], k)
    rows.append(row("fig7/rs-sann", 1e6 * t / nq,
                    f"recall={rec:.3f} qps={nq / t:.1f} "
                    f"down_bytes={down // nq} user_us={1e6 * t_user_rs / nq:.0f}"))

    # ---- PRI-ANN: LSH + PIR fetch (PIR = linear pass over DB per query)
    def pri_ann():
        out = []
        for qi in range(nq):
            cands = lsh.query(ds.queries[qi])
            if len(cands) == 0:
                cands = np.arange(min(100, n))
            _ = _xor_stream(ds.base)              # PIR server linear pass
            dec = ds.base[cands]                  # user-side plaintexts
            dist = ((dec - ds.queries[qi]) ** 2).sum(1)
            out.append(cands[np.argsort(dist)[:k]])
        pad = [np.pad(o, (0, k - len(o)), constant_values=-1) for o in out]
        return np.stack(pad)
    t, found = timeit(pri_ann, repeats=1)
    rec = synth.recall_at_k(found, ds.gt[:nq], k)
    rows.append(row("fig7/pri-ann", 1e6 * t / nq,
                    f"recall={rec:.3f} qps={nq / t:.1f} pir=linear-pass"))

    # ---- PACM-ANN: user-driven graph walk, one PIR fetch per hop
    plain_index = server.db.index           # graph shape proxy

    def pacm_ann():
        out = []
        for qi in range(nq):
            hops = 0
            # greedy beam walk, each hop = PIR fetch of neighbors+vectors
            cur = plain_index.entry
            visited = {cur}
            frontier = [cur]
            best = []
            for _ in range(24):               # bounded hops
                _ = _xor_stream(ds.base)      # PIR linear pass per hop
                hops += 1
                neigh = plain_index.links[0][frontier[0]]
                cand = [int(x) for x in neigh if int(x) not in visited]
                if not cand:
                    break
                d = ((ds.base[cand] - ds.queries[qi]) ** 2).sum(1)
                order = np.argsort(d)
                best.extend(cand)
                visited.update(cand)
                frontier = [cand[int(order[0])]]
            d = ((ds.base[best] - ds.queries[qi]) ** 2).sum(1)
            ids = np.asarray(best)[np.argsort(d)[:k]]
            out.append(np.pad(ids, (0, k - len(ids)), constant_values=-1))
        return np.stack(out)
    t, found = timeit(pacm_ann, repeats=1)
    rec = synth.recall_at_k(found, ds.gt[:nq], k)
    rows.append(row("fig7/pacm-ann", 1e6 * t / nq,
                    f"recall={rec:.3f} qps={nq / t:.1f} pir-per-hop"))

    # ---- linear-scan DCE (no index)
    sub = min(n, 3000)
    def scan():
        ids, _ = secure_knn.linear_scan_tournament(
            server.db.C_dce[:sub], enc[0][1], k, chunk=512)
        return ids
    t, _ = timeit(scan, repeats=1)
    rows.append(row("fig7/linear-scan-dce", 1e6 * t,
                    f"n={sub} per-query (no index)"))
    return rows
