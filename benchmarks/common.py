"""Shared benchmark helpers: timing, dataset cache, CSV row emission."""

from __future__ import annotations

import functools
import time

import numpy as np

from repro.core import ppanns
from repro.data import synth


def timeit(fn, *args, repeats: int = 3, **kw):
    """Median wall time (s) of fn(*args) over repeats (1 warmup)."""
    fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


@functools.lru_cache(maxsize=4)
def dataset(name: str = "sift1m", n: int = 8000, nq: int = 30,
            seed: int = 0):
    return synth.make_dataset(name, n=n, n_queries=nq, k_gt=100, seed=seed)


@functools.lru_cache(maxsize=2)
def system(name: str = "sift1m", n: int = 8000, nq: int = 30,
           beta_fraction: float = 0.03, seed: int = 0):
    ds = dataset(name, n, nq, seed)
    owner, user, server = ppanns.build_system(
        ds.base, beta_fraction=beta_fraction, M=16, ef_construction=120,
        seed=seed)
    return ds, owner, user, server


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
