"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6] [--full]

Prints `name,us_per_call,derived` CSV rows (scaffold convention).
Default sizes are CPU-feasible; --full enlarges toward paper scale.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    from . import (bench_attacks, bench_baselines, bench_batched,
                   bench_beta, bench_encrypt, bench_kernels, bench_ratio_k,
                   bench_refine, bench_roofline, bench_scalability)

    suites = {
        "fig4_beta": lambda: bench_beta.run(
            n=20000 if args.full else 6000),
        "fig5_ratio_k": lambda: bench_ratio_k.run(
            n=20000 if args.full else 8000),
        "fig6_refine": lambda: bench_refine.run(
            n=20000 if args.full else 6000),
        "fig7_9_baselines": lambda: bench_baselines.run(
            n=20000 if args.full else 6000),
        "fig8_encrypt": lambda: bench_encrypt.run(),
        "fig10_scalability": lambda: bench_scalability.run(
            sizes=(10000, 20000, 40000, 80000) if args.full
            else (5000, 10000, 20000, 40000)),
        "batched_engine": lambda: bench_batched.run(
            n=20000 if args.full else 6000),
        "sec3_attacks": lambda: bench_attacks.run(),
        "kernels": lambda: bench_kernels.run(),
        "roofline": lambda: bench_roofline.run(),
    }

    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            for r in fn():
                print(r, flush=True)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception as e:                      # noqa: BLE001
            failed.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
