"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig6] [--full]
      [--out-dir results/bench]

Prints `name,us_per_call,derived` CSV rows (scaffold convention) and
writes one machine-readable `BENCH_<suite>.json` per completed suite to
`--out-dir` — the perf-trajectory record that later sessions diff
against (EXPERIMENTS.md §Perf).
Default sizes are CPU-feasible; --full enlarges toward paper scale.
"""

from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import subprocess
import sys
import time


def provenance() -> dict:
    """Attribution stamp for every BENCH_<suite>.json: which commit,
    when, and on what software/hardware the numbers were taken — without
    it the perf trajectory (history.jsonl) cannot be diffed meaningfully
    across sessions."""
    info: dict = {
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        info["git_sha"] = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent.parent,
            capture_output=True, text=True, timeout=10,
            check=True).stdout.strip()
    except Exception:                               # noqa: BLE001
        info["git_sha"] = None
    import numpy as np
    info["numpy_version"] = np.__version__
    try:
        import jax
        dev = jax.devices()[0]
        info["jax_version"] = jax.__version__
        info["device"] = (f"{dev.platform}:"
                          f"{getattr(dev, 'device_kind', 'unknown')}")
        info["n_devices"] = jax.device_count()
    except Exception:                               # noqa: BLE001
        info["jax_version"] = info["device"] = None
    return info


def _parse_row(r: str) -> dict:
    """'name,us,derived...' -> dict (derived may itself contain commas)."""
    name, us, derived = r.split(",", 2)
    try:
        us_val = float(us)
    except ValueError:
        us_val = None
    return {"name": name, "us_per_call": us_val, "derived": derived}


def write_suite_json(out_dir: pathlib.Path, suite: str, rows: list[str],
                     wall_s: float, full: bool) -> pathlib.Path:
    """BENCH_<suite>.json holds the latest run; history.jsonl accumulates
    every run (one JSON object per line) — that append-only log is the
    perf trajectory later sessions diff against."""
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"BENCH_{suite}.json"
    payload = {
        "suite": suite,
        "unix_time": time.time(),
        "wall_s": round(wall_s, 3),
        "full": full,
        "provenance": provenance(),
        "rows": [_parse_row(r) for r in rows],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    with (out_dir / "history.jsonl").open("a") as fh:
        fh.write(json.dumps(payload) + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out-dir", default="results/bench",
                    help="directory for BENCH_<suite>.json records")
    args = ap.parse_args()

    from . import (bench_attacks, bench_baselines, bench_batched,
                   bench_beta, bench_encrypt, bench_filter, bench_graph,
                   bench_kernels, bench_profile, bench_ratio_k,
                   bench_refine, bench_resilience, bench_roofline,
                   bench_runtime, bench_scalability)

    suites = {
        "fig4_beta": lambda: bench_beta.run(
            n=20000 if args.full else 6000),
        "fig5_ratio_k": lambda: bench_ratio_k.run(
            n=20000 if args.full else 8000),
        "fig6_refine": lambda: bench_refine.run(
            n=20000 if args.full else 6000),
        "fig7_9_baselines": lambda: bench_baselines.run(
            n=20000 if args.full else 6000),
        "fig8_encrypt": lambda: bench_encrypt.run(),
        "fig10_scalability": lambda: bench_scalability.run(
            sizes=(10000, 20000, 40000, 80000) if args.full
            else (5000, 10000, 20000, 40000)),
        # mesh-sharded placement over 1/2/8 simulated devices (runs in a
        # subprocess so the forced device count cannot leak into the
        # other suites' jax state) — DESIGN.md §10
        "sharded": lambda: bench_scalability.run_sharded(
            n=16000 if args.full else 6000),
        # quantized ADC filter path: f32 vs int8 vs pq8 (DESIGN.md §11);
        # also writes the repo-root BENCH_filter.json trajectory record
        "filter": lambda: bench_filter.run(
            sizes=(10_000, 100_000, 200_000) if args.full
            else (10_000, 100_000)),
        # batched CSR graph traversal vs the per-query host walk over
        # one identical owner-built HNSW (DESIGN.md §15); also writes
        # the repo-root BENCH_graph.json trajectory record.  The hard
        # gate (batched > host-walk QPS + id parity) lives in
        # `python -m benchmarks.bench_graph --smoke` (CI)
        # (no --full enlargement: the owner-side host build is pure
        # Python and 200k would dominate the whole harness's wall time)
        "graph": lambda: bench_graph.run(sizes=(10_000, 100_000)),
        # span-level filter/refine stage timing + kernel-level op timing
        # per backend (DESIGN.md §13); also writes the repo-root
        # BENCH_profile.json trajectory record.  The hard gate (obs
        # overhead <= 5%) lives in
        # `python -m benchmarks.bench_profile --smoke` (CI)
        "profile": lambda: bench_profile.run(
            sizes=(10_000, 100_000, 200_000) if args.full
            else (10_000, 100_000)),
        "batched_engine": lambda: bench_batched.run(
            n=20000 if args.full else 6000),
        # measurement only — the hard smoke gate (occupancy/recompiles)
        # lives in `python -m benchmarks.bench_runtime --smoke` (CI)
        "runtime": lambda: bench_runtime.run(
            n=20000 if args.full else 6000, smoke=False),
        # flush vs continuous slot-table scheduler under Poisson arrivals
        # (DESIGN.md §12); also writes the repo-root BENCH_runtime.json
        # trajectory record.  The hard gate lives in
        # `python -m benchmarks.bench_runtime --sweep --smoke` (CI)
        "runtime_sweep": lambda: bench_runtime.run_sweep(
            n=20000 if args.full else 6000, smoke=False),
        # normalized ASPE KPA rows + the security-profile
        # leakage-vs-QPS frontier (DESIGN.md §14); also writes the
        # repo-root BENCH_attacks.json trajectory record.  The hard
        # gate (hardened at-chance, balanced <= 25% QPS cost) lives
        # in `python -m benchmarks.bench_attacks --smoke` (CI)
        "attacks": lambda: bench_attacks.run(
            n=32_768 if args.full else 16_384),
        # recovery-time vs WAL length, checkpoint-interval vs replay
        # cost, failover QPS healthy vs dead-replica (DESIGN.md §16);
        # also writes the repo-root BENCH_resilience.json trajectory
        # record.  The hard gate (digest-identical recovery, invisible
        # replica failover) lives in
        # `python -m benchmarks.bench_resilience --smoke` (CI)
        "resilience": lambda: bench_resilience.run(
            n_records=(100, 400, 1600) if args.full
            else (50, 200, 800)),
        "kernels": lambda: bench_kernels.run(),
        "roofline": lambda: bench_roofline.run(),
    }

    out_dir = pathlib.Path(args.out_dir)
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = list(fn())
            for r in rows:
                print(r, flush=True)
            wall = time.time() - t0
            path = write_suite_json(out_dir, name, rows, wall, args.full)
            print(f"# {name} done in {wall:.1f}s -> {path}", flush=True)
        except Exception as e:                      # noqa: BLE001
            failed.append(name)
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
