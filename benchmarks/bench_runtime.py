"""Online-runtime serving bench: closed-loop throughput and open-loop
(Poisson arrivals) latency per micro-batching policy (EXPERIMENTS.md
§Perf cell 4, DESIGN.md §8).

Closed loop: N concurrent single-query clients, each issuing queries
back-to-back through the micro-batcher, against the per-query baseline
(batch-of-one engine calls).  Reports the coalescing win (throughput
ratio, batch occupancy) and asserts the warmup contract (zero jit
recompiles across bucketed shapes during measurement).

Open loop: queries arrive on a Poisson process at a rate set relative to
the measured closed-loop capacity; each batching policy (max_wait_ms,
max_batch) trades p99 sojourn latency against throughput.

  PYTHONPATH=src python -m benchmarks.bench_runtime --smoke
exits non-zero if occupancy <= 1, recompiles != 0, or throughput
regresses egregiously (< 0.5x the per-query baseline; the raw speedup
is reported but not gated tightly — wall-clock ratios are noise-prone
on shared CI runners) — the CI smoke gate.

Scheduler sweep (DESIGN.md §12): Poisson open-loop arrival-rate sweep of
the continuous slot loop against the flush micro-batcher, p50/p99
sojourn + slot occupancy + recompile audit per (rate, scheduler) cell;
writes the repo-root `BENCH_runtime.json` trajectory record.

  PYTHONPATH=src python -m benchmarks.bench_runtime --sweep [--smoke]
with --smoke additionally gates: zero slot-loop recompiles in steady
state, high slot occupancy at the highest rate, and slot-loop p99 no
worse than the flush batcher at the highest rate (with CI-noise slack)
— the continuous-smoke CI job.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time

import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, SecureAnnService,
                       suggest_beta)
from repro.core import dce
from repro.data import synth
from repro.serving.runtime import (CollectionTelemetry, MicroBatcher,
                                   SlotLoop, jit_cache_size)

from .common import row

_ROOT = pathlib.Path(__file__).resolve().parent.parent

K = 10
EF = 96
RATIO_K = 8.0


def _build_service(n: int, d: int, n_queries: int, seed: int = 0):
    """Spec-driven construction through the public API: keyless service,
    owner-side encryption, typed queries.  Returns the runtime
    collection handle too — the policy sweep below benchmarks batcher
    internals, which is observability access the API sanctions."""
    ds = synth.make_dataset("sift1m", n=n, n_queries=n_queries, d=d,
                            k_gt=K, seed=seed)
    spec = IndexSpec(tenant="bench", name="runtime", d=d, backend="flat",
                     sap_beta=suggest_beta(ds.base, fraction=0.03),
                     seed=seed, max_batch=32, max_wait_ms=2.0)
    svc = SecureAnnService()
    svc.create_collection(spec)
    owner = DataOwnerClient(spec)
    svc.insert("bench", "runtime", *owner.encrypt_vectors(ds.base))
    svc.compact("bench", "runtime")
    user = owner.query_client()
    enc = [(eq.C_sap[0], eq.T[0])
           for eq in (user.encrypt_query(q) for q in ds.queries)]
    col = svc.collection("bench", "runtime")
    return ds, svc, col, enc


def _closed_loop(batcher, enc, n_clients: int, per_client: int):
    """n_clients threads issue queries back-to-back; returns (qps, span)."""
    errs = []

    def client(ci):
        try:
            for j in range(per_client):
                c, t = enc[(ci * per_client + j) % len(enc)]
                batcher.search(c, t, K, ratio_k=RATIO_K, ef_search=EF,
                               timeout=120.0)
        except Exception as exc:               # noqa: BLE001
            errs.append(exc)

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(n_clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    span = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return n_clients * per_client / span, span


def _open_loop(col, policy: tuple[float, int], enc, rate_qps: float,
               n_requests: int):
    """Poisson arrivals at rate_qps through a fresh batcher with the given
    (max_wait_ms, max_batch) policy; returns (p50, p99, achieved_qps)."""
    max_wait_ms, max_batch = policy
    batcher = MicroBatcher(col._run_batch, max_batch=max_batch,
                           max_wait_ms=max_wait_ms, max_queue=4096,
                           name="openloop")
    return _open_loop_on(batcher, enc, rate_qps, n_requests)


def _open_loop_on(batcher, enc, rate_qps: float, n_requests: int):
    """Drive a ready scheduler (flush or continuous) with Poisson
    arrivals; closes it afterwards.  Returns (p50, p99, achieved_qps)."""
    rng = np.random.default_rng(1)
    gaps = rng.exponential(1.0 / rate_qps, size=n_requests)
    lat: list[float] = []
    lock = threading.Lock()
    try:
        batcher.warmup(enc[0][0], enc[0][1], K, ratio_k=RATIO_K,
                       ef_search=EF)

        def waiter(fut, t_arrival):
            fut.result(timeout=300.0)
            with lock:
                lat.append(time.perf_counter() - t_arrival)

        waiters = []
        t0 = time.perf_counter()
        for i in range(n_requests):
            time.sleep(gaps[i])
            c, t = enc[i % len(enc)]
            fut = batcher.submit(c, t, K, ratio_k=RATIO_K, ef_search=EF)
            th = threading.Thread(target=waiter,
                                  args=(fut, time.perf_counter()))
            th.start()
            waiters.append(th)
        for th in waiters:
            th.join()
        span = time.perf_counter() - t0
    finally:
        batcher.close()
    lat.sort()
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
    return p50, p99, n_requests / span


def run(n: int = 20_000, d: int = 64, n_clients: int = 16,
        per_client: int = 8, smoke: bool = False) -> list[str]:
    if smoke:
        n, d, n_clients, per_client = 4000, 48, 8, 6
    _, svc, col, enc = _build_service(n, d, n_queries=32)
    rows = []
    try:
        # --- per-query baseline: batch-of-one engine calls, no batching
        n_base = n_clients * per_client
        col.search_batch(enc[0][0][None], enc[0][1][None], K,
                         ratio_k=RATIO_K, ef_search=EF)       # warm
        t0 = time.perf_counter()
        for i in range(n_base):
            c, t = enc[i % len(enc)]
            col.search_batch(c[None], t[None], K, ratio_k=RATIO_K,
                             ef_search=EF)
        qps_base = n_base / (time.perf_counter() - t0)
        rows.append(row("runtime/per_query_baseline", 1e6 / qps_base,
                        f"qps={qps_base:.1f}"))

        # --- closed loop through the micro-batcher, recompile-audited
        col.warmup(K, ratio_k=RATIO_K, ef_search=EF)
        cache_before = jit_cache_size()
        qps, _ = _closed_loop(col.batcher, enc, n_clients, per_client)
        recompiles = jit_cache_size() - cache_before
        snap = col.stats()
        occ = snap["batch_occupancy"]
        rows.append(row(
            f"runtime/closed_loop/clients={n_clients}", 1e6 / qps,
            f"qps={qps:.1f} speedup={qps / qps_base:.2f} "
            f"occupancy={occ:.2f} recompiles={recompiles} "
            f"p99_ms={1e3 * snap['p99_latency_s']:.1f}"))

        # --- open loop: Poisson arrivals, policy sweep
        policies = ([(0.5, 8), (4.0, 32)] if smoke
                    else [(0.0, 8), (1.0, 16), (4.0, 32), (16.0, 32)])
        rate = 0.6 * qps
        n_req = 48 if smoke else 256
        for policy in policies:
            p50, p99, aqps = _open_loop(col, policy, enc, rate, n_req)
            rows.append(row(
                f"runtime/poisson/wait={policy[0]}ms/max_batch={policy[1]}",
                1e6 / aqps,
                f"qps={aqps:.1f} p50_ms={1e3 * p50:.1f} "
                f"p99_ms={1e3 * p99:.1f} rate={rate:.1f}"))

        if smoke:
            # gate on the structural properties (near-deterministic);
            # the raw speedup is noise-prone on shared CI runners, so it
            # only fails on an egregious (2x) regression
            ok = (occ > 1.0 and recompiles == 0
                  and qps > 0.5 * qps_base)
            rows.append(row("runtime/smoke_gate", 0.0,
                            f"ok={ok} occupancy={occ:.2f} "
                            f"recompiles={recompiles} "
                            f"speedup={qps / qps_base:.2f}"))
            if not ok:
                raise AssertionError(
                    f"smoke gate failed: occupancy={occ} "
                    f"recompiles={recompiles} qps={qps} base={qps_base}")
    finally:
        svc.close()
    return rows


def _sweep_scheduler(kind: str, col, telemetry, max_batch: int):
    """A fresh scheduler of the given kind over the collection's engine,
    with its own telemetry (occupancy / sojourn / reject accounting)."""
    if kind == "flush":
        return MicroBatcher(col._run_batch, max_batch=max_batch,
                            max_wait_ms=2.0, max_queue=8192,
                            telemetry=telemetry, name=f"sweep-{kind}")
    return SlotLoop(col._run_batch, max_batch=max_batch, max_queue=8192,
                    d=col.d, cdim=dce.ciphertext_dim(col.d),
                    telemetry=telemetry, name=f"sweep-{kind}")


def run_sweep(n: int = 20_000, d: int = 64, smoke: bool = False,
              write_root_json: bool = True) -> list[str]:
    """Poisson open-loop sweep: flush vs continuous at several arrival
    rates (fractions of the measured per-query capacity, highest above
    it).  Smoke gates (CI): the slot loop recompiles nothing in steady
    state, fills its table at the highest rate, and its p99 sojourn is
    no worse than the flush batcher's there (modulo CI-noise slack)."""
    max_batch = 16
    if smoke:
        n, d = 4000, 48
    fracs = (0.5, 1.3) if smoke else (0.25, 0.5, 0.9, 1.3)
    n_req = 96 if smoke else 192
    _, svc, col, enc = _build_service(n, d, n_queries=32)
    rows, cells = [], []
    try:
        # per-query capacity proxy: batch-of-one engine calls
        col.search_batch(enc[0][0][None], enc[0][1][None], K,
                         ratio_k=RATIO_K, ef_search=EF)        # warm
        t0 = time.perf_counter()
        n_base = 64
        for i in range(n_base):
            c, t = enc[i % len(enc)]
            col.search_batch(c[None], t[None], K, ratio_k=RATIO_K,
                             ef_search=EF)
        qps_base = n_base / (time.perf_counter() - t0)
        rows.append(row("runtime_sweep/per_query_capacity", 1e6 / qps_base,
                        f"qps={qps_base:.1f}"))

        # batched capacity: one slot-table step serves up to max_batch
        # rows, so arrival rates must be set against the FULL-TABLE step
        # rate (per-query capacity would never fill the table)
        Qb = np.stack([enc[i % len(enc)][0] for i in range(max_batch)])
        Tb = np.stack([enc[i % len(enc)][1] for i in range(max_batch)])
        col.search_batch(Qb, Tb, K, ratio_k=RATIO_K, ef_search=EF)  # warm
        reps = 8
        t0 = time.perf_counter()
        for _ in range(reps):
            col.search_batch(Qb, Tb, K, ratio_k=RATIO_K, ef_search=EF)
        qps_batched = reps * max_batch / (time.perf_counter() - t0)
        rows.append(row("runtime_sweep/batched_capacity",
                        1e6 / qps_batched, f"qps={qps_batched:.1f} "
                        f"max_batch={max_batch}"))

        for frac in fracs:
            rate = frac * qps_batched
            for kind in ("flush", "continuous"):
                tel = CollectionTelemetry()
                sched = _sweep_scheduler(kind, col, tel, max_batch)
                sched.warmup(enc[0][0], enc[0][1], K, ratio_k=RATIO_K,
                             ef_search=EF)
                cache_before = jit_cache_size()
                p50, p99, aqps = _open_loop_on(sched, enc, rate, n_req)
                recompiles = jit_cache_size() - cache_before
                snap = tel.snapshot()
                occ = (snap["slot_occupancy"] if kind == "continuous"
                       else snap["batch_occupancy"] / max_batch)
                cells.append({"frac": frac, "rate_qps": round(rate, 1),
                              "scheduler": kind,
                              "p50_ms": round(1e3 * p50, 3),
                              "p99_ms": round(1e3 * p99, 3),
                              "achieved_qps": round(aqps, 1),
                              "occupancy": round(occ, 3),
                              "recompiles": recompiles,
                              "n_rejected": snap["n_rejected"]})
                rows.append(row(
                    f"runtime_sweep/rate={frac:.2f}x/{kind}", 1e6 / aqps,
                    f"qps={aqps:.1f} p50_ms={1e3 * p50:.1f} "
                    f"p99_ms={1e3 * p99:.1f} occupancy={occ:.2f} "
                    f"recompiles={recompiles}"))

        top = {c["scheduler"]: c for c in cells
               if c["frac"] == max(fracs)}
        slot, flush = top["continuous"], top["flush"]
        gates = {
            # ONE executable serves the whole sweep: any recompile in
            # steady state breaks the slot-table contract
            "slot_zero_recompiles": all(
                c["recompiles"] == 0 for c in cells
                if c["scheduler"] == "continuous"),
            # above capacity the table must actually fill
            "slot_occupancy_at_top_rate": slot["occupancy"],
            "slot_occupancy_ok": slot["occupancy"] >= 0.5,
            # the headline: continuous batching does not lose tail
            # latency to the flush deadline where it matters most
            # (1.2x + 10ms slack for shared-runner noise)
            "slot_p99_ok": (slot["p99_ms"]
                            <= 1.2 * flush["p99_ms"] + 10.0),
        }
        rows.append(row(
            "runtime_sweep/gate", 0.0,
            f"ok={all(v for k, v in gates.items() if k.endswith('ok') or k == 'slot_zero_recompiles')} "
            f"slot_recompiles_zero={gates['slot_zero_recompiles']} "
            f"occupancy={slot['occupancy']:.2f} "
            f"p99_slot_ms={slot['p99_ms']:.1f} "
            f"p99_flush_ms={flush['p99_ms']:.1f}"))
        if write_root_json:
            _write_sweep_json(cells, gates, qps_base, qps_batched, n, d,
                              max_batch, n_req, smoke)
        if smoke:
            failed = [k for k in ("slot_zero_recompiles",
                                  "slot_occupancy_ok", "slot_p99_ok")
                      if not gates[k]]
            if failed:
                raise AssertionError(
                    f"continuous-smoke gate failed: {failed}; "
                    f"slot={slot} flush={flush}")
    finally:
        svc.close()
    return rows


def _write_sweep_json(cells, gates, qps_base, qps_batched, n, d,
                      max_batch, n_req, smoke):
    """Repo-root BENCH_runtime.json: the runtime-suite trajectory record
    sessions diff against (the harness also writes its own copy under
    results/bench)."""
    from .run import provenance
    payload = {
        "suite": "runtime_sweep",
        "unix_time": time.time(),
        "config": {"n": n, "d": d, "k": K, "ratio_k": RATIO_K,
                   "ef_search": EF, "max_batch": max_batch,
                   "n_requests_per_cell": n_req, "smoke": smoke,
                   "per_query_capacity_qps": round(qps_base, 1),
                   "batched_capacity_qps": round(qps_batched, 1)},
        "provenance": provenance(),
        "sweep": cells,
        "gates": gates,
    }
    (_ROOT / "BENCH_runtime.json").write_text(
        json.dumps(payload, indent=2) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + hard gate (CI)")
    ap.add_argument("--sweep", action="store_true",
                    help="flush-vs-continuous Poisson arrival-rate sweep")
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    rows = (run_sweep(n=args.n, smoke=args.smoke) if args.sweep
            else run(n=args.n, smoke=args.smoke))
    for r in rows:
        print(r, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
