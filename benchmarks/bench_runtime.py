"""Online-runtime serving bench: closed-loop throughput and open-loop
(Poisson arrivals) latency per micro-batching policy (EXPERIMENTS.md
§Perf cell 4, DESIGN.md §8).

Closed loop: N concurrent single-query clients, each issuing queries
back-to-back through the micro-batcher, against the per-query baseline
(batch-of-one engine calls).  Reports the coalescing win (throughput
ratio, batch occupancy) and asserts the warmup contract (zero jit
recompiles across bucketed shapes during measurement).

Open loop: queries arrive on a Poisson process at a rate set relative to
the measured closed-loop capacity; each batching policy (max_wait_ms,
max_batch) trades p99 sojourn latency against throughput.

  PYTHONPATH=src python -m benchmarks.bench_runtime --smoke
exits non-zero if occupancy <= 1, recompiles != 0, or throughput
regresses egregiously (< 0.5x the per-query baseline; the raw speedup
is reported but not gated tightly — wall-clock ratios are noise-prone
on shared CI runners) — the CI smoke gate.
"""

from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, SecureAnnService,
                       suggest_beta)
from repro.data import synth
from repro.serving.runtime import MicroBatcher, jit_cache_size

from .common import row

K = 10
EF = 96
RATIO_K = 8.0


def _build_service(n: int, d: int, n_queries: int, seed: int = 0):
    """Spec-driven construction through the public API: keyless service,
    owner-side encryption, typed queries.  Returns the runtime
    collection handle too — the policy sweep below benchmarks batcher
    internals, which is observability access the API sanctions."""
    ds = synth.make_dataset("sift1m", n=n, n_queries=n_queries, d=d,
                            k_gt=K, seed=seed)
    spec = IndexSpec(tenant="bench", name="runtime", d=d, backend="flat",
                     sap_beta=suggest_beta(ds.base, fraction=0.03),
                     seed=seed, max_batch=32, max_wait_ms=2.0)
    svc = SecureAnnService()
    svc.create_collection(spec)
    owner = DataOwnerClient(spec)
    svc.insert("bench", "runtime", *owner.encrypt_vectors(ds.base))
    svc.compact("bench", "runtime")
    user = owner.query_client()
    enc = [(eq.C_sap[0], eq.T[0])
           for eq in (user.encrypt_query(q) for q in ds.queries)]
    col = svc.collection("bench", "runtime")
    return ds, svc, col, enc


def _closed_loop(batcher, enc, n_clients: int, per_client: int):
    """n_clients threads issue queries back-to-back; returns (qps, span)."""
    errs = []

    def client(ci):
        try:
            for j in range(per_client):
                c, t = enc[(ci * per_client + j) % len(enc)]
                batcher.search(c, t, K, ratio_k=RATIO_K, ef_search=EF,
                               timeout=120.0)
        except Exception as exc:               # noqa: BLE001
            errs.append(exc)

    threads = [threading.Thread(target=client, args=(ci,))
               for ci in range(n_clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    span = time.perf_counter() - t0
    if errs:
        raise errs[0]
    return n_clients * per_client / span, span


def _open_loop(col, policy: tuple[float, int], enc, rate_qps: float,
               n_requests: int):
    """Poisson arrivals at rate_qps through a fresh batcher with the given
    (max_wait_ms, max_batch) policy; returns (p50, p99, achieved_qps)."""
    max_wait_ms, max_batch = policy
    rng = np.random.default_rng(1)
    gaps = rng.exponential(1.0 / rate_qps, size=n_requests)
    lat: list[float] = []
    lock = threading.Lock()
    batcher = MicroBatcher(col._run_batch, max_batch=max_batch,
                           max_wait_ms=max_wait_ms, max_queue=4096,
                           name="openloop")
    try:
        batcher.warmup(enc[0][0], enc[0][1], K, ratio_k=RATIO_K,
                       ef_search=EF)

        def waiter(fut, t_arrival):
            fut.result(timeout=300.0)
            with lock:
                lat.append(time.perf_counter() - t_arrival)

        waiters = []
        t0 = time.perf_counter()
        for i in range(n_requests):
            time.sleep(gaps[i])
            c, t = enc[i % len(enc)]
            fut = batcher.submit(c, t, K, ratio_k=RATIO_K, ef_search=EF)
            th = threading.Thread(target=waiter,
                                  args=(fut, time.perf_counter()))
            th.start()
            waiters.append(th)
        for th in waiters:
            th.join()
        span = time.perf_counter() - t0
    finally:
        batcher.close()
    lat.sort()
    p50 = lat[len(lat) // 2]
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))]
    return p50, p99, n_requests / span


def run(n: int = 20_000, d: int = 64, n_clients: int = 16,
        per_client: int = 8, smoke: bool = False) -> list[str]:
    if smoke:
        n, d, n_clients, per_client = 4000, 48, 8, 6
    _, svc, col, enc = _build_service(n, d, n_queries=32)
    rows = []
    try:
        # --- per-query baseline: batch-of-one engine calls, no batching
        n_base = n_clients * per_client
        col.search_batch(enc[0][0][None], enc[0][1][None], K,
                         ratio_k=RATIO_K, ef_search=EF)       # warm
        t0 = time.perf_counter()
        for i in range(n_base):
            c, t = enc[i % len(enc)]
            col.search_batch(c[None], t[None], K, ratio_k=RATIO_K,
                             ef_search=EF)
        qps_base = n_base / (time.perf_counter() - t0)
        rows.append(row("runtime/per_query_baseline", 1e6 / qps_base,
                        f"qps={qps_base:.1f}"))

        # --- closed loop through the micro-batcher, recompile-audited
        col.warmup(K, ratio_k=RATIO_K, ef_search=EF)
        cache_before = jit_cache_size()
        qps, _ = _closed_loop(col.batcher, enc, n_clients, per_client)
        recompiles = jit_cache_size() - cache_before
        snap = col.stats()
        occ = snap["batch_occupancy"]
        rows.append(row(
            f"runtime/closed_loop/clients={n_clients}", 1e6 / qps,
            f"qps={qps:.1f} speedup={qps / qps_base:.2f} "
            f"occupancy={occ:.2f} recompiles={recompiles} "
            f"p99_ms={1e3 * snap['p99_latency_s']:.1f}"))

        # --- open loop: Poisson arrivals, policy sweep
        policies = ([(0.5, 8), (4.0, 32)] if smoke
                    else [(0.0, 8), (1.0, 16), (4.0, 32), (16.0, 32)])
        rate = 0.6 * qps
        n_req = 48 if smoke else 256
        for policy in policies:
            p50, p99, aqps = _open_loop(col, policy, enc, rate, n_req)
            rows.append(row(
                f"runtime/poisson/wait={policy[0]}ms/max_batch={policy[1]}",
                1e6 / aqps,
                f"qps={aqps:.1f} p50_ms={1e3 * p50:.1f} "
                f"p99_ms={1e3 * p99:.1f} rate={rate:.1f}"))

        if smoke:
            # gate on the structural properties (near-deterministic);
            # the raw speedup is noise-prone on shared CI runners, so it
            # only fails on an egregious (2x) regression
            ok = (occ > 1.0 and recompiles == 0
                  and qps > 0.5 * qps_base)
            rows.append(row("runtime/smoke_gate", 0.0,
                            f"ok={ok} occupancy={occ:.2f} "
                            f"recompiles={recompiles} "
                            f"speedup={qps / qps_base:.2f}"))
            if not ok:
                raise AssertionError(
                    f"smoke gate failed: occupancy={occ} "
                    f"recompiles={recompiles} qps={qps} base={qps_base}")
    finally:
        svc.close()
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + hard gate (CI)")
    ap.add_argument("--n", type=int, default=20_000)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for r in run(n=args.n, smoke=args.smoke):
        print(r, flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
