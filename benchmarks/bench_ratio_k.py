"""Fig. 5 — effect of Ratio_k (= k'/k) on recall and QPS.

Larger k' refines more candidates: recall rises, QPS falls."""

from __future__ import annotations

import numpy as np

from repro.data import synth

from .common import row, system, timeit


def run(n: int = 8000, nq: int = 25) -> list[str]:
    ds, owner, user, server = system("sift1m", n, nq)
    k = 10
    enc = [user.encrypt_query(q) for q in ds.queries]
    rows = []
    for ratio in [1, 2, 4, 8, 16]:
        def search_all():
            return np.stack([
                server.search(cs, tq, k, ratio_k=ratio, ef_search=160)[0]
                for cs, tq in enc])

        t, found = timeit(search_all, repeats=1)
        rec = synth.recall_at_k(found, ds.gt, k)
        rows.append(row(f"fig5/ratio_k={ratio}", 1e6 * t / nq,
                        f"recall@{k}={rec:.3f} qps={nq / t:.1f}"))
    return rows
