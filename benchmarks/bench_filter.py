"""Quantized ADC filter suite (EXPERIMENTS.md §Perf, DESIGN.md §11).

Grid: n x {flat, ivf} x {f32, int8, pq8}.  Per cell it reports the
filter-phase latency/QPS (the backend `candidates` call — the part the
quantization accelerates), recall@10 of the *full* filter-and-refine
pipeline against plaintext ground truth, and the engine's
`filter_bytes_scanned` (the bandwidth win, measured not estimated).

The f32 cells run the engine exactly as PR 1-4 ship it; the quantized
cells run the ADC backends exactly as `IndexSpec.quantization` ships
them — so every ratio in the output is a ratio between *served paths*,
not between synthetic microloops.

Writes `BENCH_filter.json` at the repo root (the filter-suite perf
trajectory record) in addition to the harness's results-dir copy.

  PYTHONPATH=src python -m benchmarks.bench_filter --smoke

exits non-zero if the int8 flat filter is slower than the f32 flat
scan at the largest n, or if the int8 cell's end-to-end recall@10
drops below 0.95 — the `adc-smoke` CI gate.  (pq8 recall is reported,
not gated here: its 0.95 contract is pinned at property-test scale in
tests/test_adc.py; at 100k it trades recall for the larger bandwidth
cut.)
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import dcpe, ppanns
from repro.data import synth
from repro.serving.search_engine import SecureSearchEngine

from .common import row, timeit

K = 10
RATIO_K = 8.0
NQ = 16
QUANTS = (None, "int8", "pq8")
RECALL_GATE = 0.95

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _setup(n: int, d: int, nq: int, seed: int = 0):
    ds = synth.make_dataset("sift1m", n=n, n_queries=nq, d=d, k_gt=K,
                            seed=seed)
    # fraction=0.01: at n=100k the clustered-gaussian neighbor gaps are
    # tiny, and the acceptance bar (recall@10 >= 0.95 *after refine*)
    # needs the DCPE noise below them — the beta/recall trade itself is
    # fig4_beta's subject, not this suite's
    beta = dcpe.suggest_beta(ds.base, fraction=0.01)
    owner = ppanns.DataOwner(d=d, sap_beta=beta, sap_s=1024.0, seed=seed)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    user = ppanns.User(owner.share_keys(), seed=seed + 1)
    enc = [user.encrypt_query(q) for q in ds.queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    return ds, C_sap, C_dce, Q, T


def _bench_cell(C_sap, C_dce, Q, T, gt, *, backend: str,
                quantization: str | None, seed: int, repeats: int):
    kw = {}
    if backend == "ivf":
        kw = dict(n_partitions=min(256, max(8, C_sap.shape[0] // 256)),
                  nprobe=16, seed=seed)
    elif quantization is not None:
        kw = dict(seed=seed)            # the f32 flat scan is seedless
    if quantization == "pq8":
        # large-n PQ configuration: finer subspaces + heavier
        # oversampling (the IndexSpec knobs exist for exactly this —
        # clustered 100k corpora have neighbor gaps below the default
        # m=16 cell size; 32 bytes/vector still cuts bandwidth 16x)
        kw.update(pq_m=32, refine_ratio=8.0)
    eng = SecureSearchEngine(C_sap, C_dce, backend=backend,
                             quantization=quantization, **kw)
    eng._ensure_attached()
    kp = int(RATIO_K * K)
    t_filter, _ = timeit(lambda: eng.backend.candidates(Q, kp, 96),
                         repeats=repeats)
    ids, stats = eng.search_batch(Q, T, K, ratio_k=RATIO_K)
    rec = synth.recall_at_k(np.asarray(ids), gt, K)
    return t_filter, rec, stats.filter_bytes_scanned


def run(sizes=(10_000, 100_000), d: int = 128, nq: int = NQ,
        repeats: int = 3, seed: int = 0,
        write_root_json: bool = True) -> list[str]:
    rows = []
    cells = {}
    for n in sizes:
        ds, C_sap, C_dce, Q, T = _setup(n, d, nq, seed)
        for backend in ("flat", "ivf"):
            for quant in QUANTS:
                label = quant or "f32"
                t, rec, nbytes = _bench_cell(
                    C_sap, C_dce, Q, T, ds.gt, backend=backend,
                    quantization=quant, seed=seed, repeats=repeats)
                cells[(n, backend, label)] = (t, rec, nbytes)
                base = cells.get((n, backend, "f32"))
                speed = base[0] / t if base else float("nan")
                bw = base[2] / nbytes if base else float("nan")
                rows.append(row(
                    f"filter/n={n}/{backend}/{label}",
                    1e6 * t / nq,
                    f"qps={nq / t:.1f} recall@{K}={rec:.3f} "
                    f"bytes_scanned={nbytes} speedup_x{speed:.2f} "
                    f"bandwidth_x{bw:.2f}"))
    if write_root_json:
        _write_root_json(rows, sizes, d, nq)
    return rows


def _write_root_json(rows: list[str], sizes, d: int, nq: int):
    """The repo-root BENCH_filter.json: the filter-suite trajectory
    record sessions diff against (the harness also writes its own copy
    under results/bench)."""
    from .run import provenance
    payload = {
        "suite": "filter",
        "unix_time": time.time(),
        "config": {"sizes": list(sizes), "d": d, "nq": nq, "k": K,
                   "ratio_k": RATIO_K},
        "provenance": provenance(),
        "rows": [{"name": r.split(",", 2)[0],
                  "us_per_call": float(r.split(",", 2)[1]),
                  "derived": r.split(",", 2)[2]} for r in rows],
    }
    (_ROOT / "BENCH_filter.json").write_text(
        json.dumps(payload, indent=2) + "\n")


def _smoke(n: int = 100_000, d: int = 128, nq: int = 8,
           seed: int = 0) -> int:
    """CI gate: int8 must not be slower than the f32 flat scan at the
    full size, and the int8 cell must hold recall@10 >= 0.95 through
    the exact refine (pq8 is reported, not gated — module docstring)."""
    ds, C_sap, C_dce, Q, T = _setup(n, d, nq, seed)
    results = {}
    for quant in QUANTS:
        label = quant or "f32"
        t, rec, nbytes = _bench_cell(C_sap, C_dce, Q, T, ds.gt,
                                     backend="flat", quantization=quant,
                                     seed=seed, repeats=2)
        results[label] = (t, rec, nbytes)
        print(row(f"filter-smoke/n={n}/flat/{label}", 1e6 * t / nq,
                  f"recall@{K}={rec:.3f} bytes={nbytes}"), flush=True)
    ok = True
    if results["int8"][0] > results["f32"][0]:
        print(f"# SMOKE FAIL: int8 filter slower than f32 "
              f"({results['int8'][0]:.3f}s vs {results['f32'][0]:.3f}s)")
        ok = False
    # the acceptance recall bar is on int8 (pq8 trades recall for a
    # 32x bandwidth cut at default refine_ratio; its >= 0.95 gate runs
    # at property-test scale in tests/test_adc.py)
    if results["int8"][1] < RECALL_GATE:
        print(f"# SMOKE FAIL: int8 recall@{K}="
              f"{results['int8'][1]:.3f} < {RECALL_GATE}")
        ok = False
    if ok:
        speed = results["f32"][0] / results["int8"][0]
        print(f"# smoke OK: int8 {speed:.2f}x faster than f32, "
              f"recall gate {RECALL_GATE} held")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: int8 >= f32 speed + recall >= 0.95")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(_smoke())
    for r in run(sizes=(10_000, 100_000) if not args.full
                 else (10_000, 100_000, 200_000)):
        print(r)


if __name__ == "__main__":
    main()
