"""Kernel microbenchmarks: Pallas (interpret on CPU) wrappers vs jnp
reference — the per-call cost table for the two hot-spot kernels.
(On CPU the interpret path is slower than jnp; the table documents call
overhead + validates wiring.  TPU timing comes from the roofline cells.)"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dce
from repro.kernels.dce_comp import ops as dce_ops, ref as dce_ref
from repro.kernels.l2_topk import ops as l2_ops, ref as l2_ref

from .common import row, timeit


def run() -> list[str]:
    rng = np.random.default_rng(0)
    rows = []
    Q = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    X = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)

    t, _ = timeit(lambda: l2_ref.pairwise_sq_dists(Q, X).block_until_ready())
    rows.append(row("kern/l2_ref_jnp", 1e6 * t, "64x4096xd128"))
    t, _ = timeit(lambda: l2_ops.pairwise_sq_dists(
        Q, X, interpret=True).block_until_ready())
    rows.append(row("kern/l2_pallas_interp", 1e6 * t, "64x4096xd128"))
    t, _ = timeit(lambda: l2_ops.knn(Q, X, 10)[0].block_until_ready())
    rows.append(row("kern/knn_streaming", 1e6 * t, "k=10 chunk=4096"))

    key = dce.keygen(128, seed=0)
    P = rng.standard_normal((512, 128))
    C = jnp.asarray(dce.encrypt(P, key, seed=1))
    T = jnp.asarray(dce.trapgen(P[:1], key, seed=2)[0])
    t, _ = timeit(lambda: dce_ref.z_matrix(C, T).block_until_ready())
    rows.append(row("kern/dce_z_ref_jnp", 1e6 * t, "512x512 pairs d=128"))
    t, _ = timeit(lambda: dce_ops.z_matrix(
        C, T, interpret=True).block_until_ready())
    rows.append(row("kern/dce_z_pallas_interp", 1e6 * t, "512x512 pairs"))
    t, _ = timeit(lambda: dce_ops.top_k_by_wins(
        C, T, 10, use_kernel=False).block_until_ready())
    rows.append(row("kern/dce_tournament_topk", 1e6 * t, "512 cands k=10"))
    return rows
