"""Fig. 6 — HNSW-DCE (ours) vs HNSW-AME vs HNSW(filter-only) vs plaintext
HNSW.  Same filter phase everywhere; the refine SDC method differs:
DCE is O(d) per comparison, AME is O(d^2) — the >=100x refine gap."""

from __future__ import annotations

import numpy as np

from repro.core import ame, secure_knn
from repro.core.hnsw import HNSW
from repro.data import synth

from .common import row, system, timeit


def run(n: int = 6000, nq: int = 15) -> list[str]:
    ds, owner, user, server = system("sift1m", n, nq)
    k, ratio = 10, 8
    enc = [user.encrypt_query(q) for q in ds.queries[:nq]]
    rows = []

    # ---- ours: HNSW-DCE (heap refine, then tournament refine)
    for refine in ["heap", "tournament", "none"]:
        def search_all(refine=refine):
            return np.stack([
                server.search(cs, tq, k, ratio_k=ratio, ef_search=128,
                              refine=refine)[0] for cs, tq in enc])
        t, found = timeit(search_all, repeats=1)
        rec = synth.recall_at_k(found, ds.gt[:nq], k)
        name = {"heap": "hnsw-dce(heap)", "tournament": "hnsw-dce(mxu)",
                "none": "hnsw(filter-only)"}[refine]
        rows.append(row(f"fig6/{name}", 1e6 * t / nq,
                        f"recall@{k}={rec:.3f} qps={nq / t:.1f}"))

    # ---- HNSW-AME: same filter, AME refine (O(d^2) per comparison)
    ame_key = ame.keygen(ds.d, seed=11)
    U, V = ame.encrypt(ds.base, ame_key, seed=12)
    W = ame.trapgen(ds.queries[:nq], ame_key, seed=13)

    def ame_refine_all():
        out = []
        for qi, (cs, _tq) in enumerate(enc):
            cand, _ = server.db.index.search(cs, ratio * k, ef=128)
            # same heap walk as the paper's refine, AME comparator:
            # further(i, j) <=> compare(U_i, V_j, W_q) > 0
            ids = list(cand[:k])
            # track the current worst with pairwise AME comparisons
            def worst_of(members):
                w = members[0]
                for m in members[1:]:
                    if float(ame.compare(U[m], V[w], W[qi])) > 0:
                        w = m
                return w
            worst = worst_of(ids)
            for c in cand[k:]:
                if float(ame.compare(U[worst], V[c], W[qi])) > 0:
                    ids[ids.index(worst)] = int(c)
                    worst = worst_of(ids)
            out.append(np.asarray(ids))
        return np.stack(out)

    t, found = timeit(ame_refine_all, repeats=1)
    rec = synth.recall_at_k(found, ds.gt[:nq], k)
    rows.append(row("fig6/hnsw-ame", 1e6 * t / nq,
                    f"recall@{k}={rec:.3f} qps={nq / t:.1f}"))

    # ---- plaintext HNSW reference
    plain = HNSW(dim=ds.d, M=16, ef_construction=120, seed=5)
    plain.build(ds.base)

    def plain_all():
        return np.stack([plain.search(q, k, ef=128)[0]
                         for q in ds.queries[:nq]])
    t, found = timeit(plain_all, repeats=1)
    rec = synth.recall_at_k(found, ds.gt[:nq], k)
    rows.append(row("fig6/hnsw-plaintext", 1e6 * t / nq,
                    f"recall@{k}={rec:.3f} qps={nq / t:.1f}"))
    return rows
