"""Fault-tolerance bench: recovery time, checkpoint-vs-replay tradeoff,
degraded-mode serving cost (EXPERIMENTS.md §Perf cell 10, DESIGN.md §16).

Three curves:

  * recovery-time vs WAL length — kill-restart with a WAL-only log of N
    acked insert records: `recover()` wall time and replayed rows/s,
    plus the bit-identical `state_digest` check that makes the number
    mean something;
  * checkpoint-interval vs replay-cost — same op stream, background
    `.ppcol` checkpoints every I ops: how the checkpoint knob trades
    recovery replay length (and time) against checkpoint write traffic;
  * failover QPS — closed-loop sharded search throughput healthy vs one
    replica dead (must be bit-identical and ~free) vs a whole shard
    group dead (degraded=True answers from the alive shards).  Skips on
    a single-device host; CI runs it under
    `XLA_FLAGS=--xla_force_host_platform_device_count=8`.

Writes `BENCH_resilience.json` at the repo root (the resilience-suite
perf trajectory record) in addition to the harness's results-dir copy.

  PYTHONPATH=src python -m benchmarks.bench_resilience --smoke

exits non-zero if any recovery is not digest-identical to the killed
state, if checkpointing fails to shorten replay, or (with >= 2 devices)
if one-dead-replica answers are not bit-identical to healthy — the
`resilience-smoke` CI gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import time

import numpy as np

from repro import resilience as R
from repro.serving.runtime import Collection

from .common import row

_ROOT = pathlib.Path(__file__).resolve().parent.parent

D = 32
ROWS_PER_RECORD = 32
K = 10


def _factory(seed=5, **kw):
    kw.setdefault("compact_every", 4096)
    return lambda: Collection("bench", "resil", D, seed=seed,
                              use_kernel=False, **kw)


def _ingest(col, rng, n_records, cp=None):
    for _ in range(n_records):
        col.insert(rng.normal(size=(ROWS_PER_RECORD, D))
                   .astype(np.float32))
        if cp is not None:
            cp.note_ops(1)


# ---------------------------------------------------------------------------
# Curve 1: recovery-time vs WAL length (no checkpoint).
# ---------------------------------------------------------------------------

def bench_recovery(n_records_list, seed=5):
    rows, ok = [], True
    for n_records in n_records_list:
        with tempfile.TemporaryDirectory() as td:
            rng = np.random.default_rng(seed)
            col = _factory(seed)()
            wal = R.WriteAheadLog(pathlib.Path(td) / "wal")
            R.attach_wal(col, wal)
            _ingest(col, rng, n_records)
            dig = col.store.state_digest()
            wal.close()
            col.close()                      # "kill"
            t0 = time.perf_counter()
            col2, rep = R.recover(_factory(seed),
                                  wal_dir=pathlib.Path(td) / "wal")
            dt = time.perf_counter() - t0
            identical = col2.store.state_digest() == dig
            ok &= identical
            n_rows = n_records * ROWS_PER_RECORD
            rows.append(row(
                f"resilience/recover/wal={n_records}",
                1e6 * dt / max(n_records, 1),
                f"recovery_s={dt:.3f} rows_per_s={n_rows / dt:.0f} "
                f"n_replayed={rep.n_replayed} digest_ok={identical}"))
            col2.close()
    return rows, ok


# ---------------------------------------------------------------------------
# Curve 2: checkpoint-interval vs replay-cost.
# ---------------------------------------------------------------------------

def bench_checkpoint_interval(n_records, intervals, seed=5):
    rows, replayed, ok = [], {}, True
    for interval in intervals:
        with tempfile.TemporaryDirectory() as td:
            td = pathlib.Path(td)
            rng = np.random.default_rng(seed)
            col = _factory(seed)()
            wal = R.WriteAheadLog(td / "wal")
            R.attach_wal(col, wal)
            cp = None
            if interval is not None:
                cp = R.AsyncCheckpointer(col, td / "col.ppcol",
                                         every_n_ops=interval)
            t0 = time.perf_counter()
            _ingest(col, rng, n_records, cp=cp)
            if cp is not None:
                cp.join()
            ingest_dt = time.perf_counter() - t0
            dig = col.store.state_digest()
            wal.close()
            col.close()
            ckpt = td / "col.ppcol"
            t0 = time.perf_counter()
            col2, rep = R.recover(
                _factory(seed), wal_dir=td / "wal",
                checkpoint_path=ckpt if ckpt.exists() else None)
            dt = time.perf_counter() - t0
            identical = col2.store.state_digest() == dig
            ok &= identical
            label = "none" if interval is None else str(interval)
            replayed[label] = rep.n_replayed
            n_ck = cp.n_checkpoints if cp is not None else 0
            rows.append(row(
                f"resilience/ckpt-interval={label}",
                1e6 * dt / max(n_records, 1),
                f"recovery_s={dt:.3f} n_replayed={rep.n_replayed} "
                f"n_checkpoints={n_ck} ingest_s={ingest_dt:.3f} "
                f"digest_ok={identical}"))
            col2.close()
    # checkpointing must shorten replay vs the WAL-only baseline
    base = replayed.get("none")
    if base is not None:
        ok &= all(v < base for k, v in replayed.items() if k != "none")
    return rows, ok


# ---------------------------------------------------------------------------
# Curve 3: failover QPS (healthy / replica-dead / group-dead).
# ---------------------------------------------------------------------------

def bench_failover(n=4096, nq=16, n_loops=8, seed=5):
    import jax
    from repro.api import PlacementSpec
    n_shards = min(4, jax.device_count())
    if n_shards < 2:
        return [row("resilience/failover", float("nan"),
                    "skipped=single-device "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
                ], True, True
    placement = PlacementSpec(kind="sharded", n_shards=n_shards,
                              n_replicas=2).resolve(jax.device_count())
    rng = np.random.default_rng(seed)
    col = _factory(seed, placement=placement)()
    try:
        col.insert(rng.normal(size=(n, D)).astype(np.float32))
        col.compact()
        user = col.new_user()
        enc = [user.encrypt_query(q) for q in
               rng.normal(size=(nq, D)).astype(np.float32)]
        Q = np.stack([e[0] for e in enc])
        T = np.stack([e[1] for e in enc])

        def qps():
            col.search_batch(Q, T, K)            # warm the current mode
            t0 = time.perf_counter()
            for _ in range(n_loops):
                ids, stats = col.search_batch(Q, T, K)
            dt = time.perf_counter() - t0
            return n_loops * nq / dt, np.asarray(ids), stats

        rows = []
        healthy_qps, healthy_ids, _ = qps()
        rows.append(row("resilience/failover/healthy",
                        1e6 / healthy_qps, f"qps={healthy_qps:.1f} "
                        f"n_shards={n_shards} n_replicas=2"))
        col.health.kill(1, 1)                    # one replica: invisible
        rqps, rids, rstats = qps()
        replica_identical = (np.array_equal(rids, healthy_ids)
                             and not rstats.degraded)
        rows.append(row("resilience/failover/one-replica-dead",
                        1e6 / rqps,
                        f"qps={rqps:.1f} vs_healthy_x{rqps / healthy_qps:.2f} "
                        f"ids_identical={replica_identical}"))
        col.health.kill(1, 0)                    # whole group: degraded
        dqps, dids, dstats = qps()
        degraded_ok = bool(dstats.degraded and dstats.n_shards_down == 1
                           and (dids >= -1).all())
        rows.append(row("resilience/failover/one-group-dead",
                        1e6 / dqps,
                        f"qps={dqps:.1f} vs_healthy_x{dqps / healthy_qps:.2f} "
                        f"degraded={bool(dstats.degraded)} "
                        f"n_shards_down={dstats.n_shards_down}"))
        return rows, replica_identical, degraded_ok
    finally:
        col.close()


# ---------------------------------------------------------------------------
# Harness entry points.
# ---------------------------------------------------------------------------

def run(n_records=(50, 200, 800), ckpt_records=300,
        intervals=(None, 100, 25), write_root_json=True) -> list[str]:
    rows1, _ = bench_recovery(n_records)
    rows2, _ = bench_checkpoint_interval(ckpt_records, intervals)
    rows3, _, _ = bench_failover()
    rows = rows1 + rows2 + rows3
    if write_root_json:
        _write_root_json(rows, n_records, ckpt_records, intervals)
    return rows


def _write_root_json(rows, n_records, ckpt_records, intervals):
    """The repo-root BENCH_resilience.json: the resilience-suite
    trajectory record sessions diff against (the harness also writes
    its own copy under results/bench)."""
    from .run import provenance
    payload = {
        "suite": "resilience",
        "unix_time": time.time(),
        "config": {"d": D, "rows_per_record": ROWS_PER_RECORD,
                   "wal_lengths": list(n_records),
                   "ckpt_records": ckpt_records,
                   "ckpt_intervals": [i if i is not None else "none"
                                      for i in intervals]},
        "provenance": provenance(),
        "rows": [{"name": r.split(",", 2)[0],
                  "us_per_call": float(r.split(",", 2)[1]),
                  "derived": r.split(",", 2)[2]} for r in rows],
    }
    (_ROOT / "BENCH_resilience.json").write_text(
        json.dumps(payload, indent=2) + "\n")


def _smoke() -> int:
    """CI gate: every recovery digest-identical, checkpoints shorten
    replay, one-dead-replica answers bit-identical to healthy."""
    ok = True
    rows, rec_ok = bench_recovery((30, 120))
    for r in rows:
        print(r, flush=True)
    if not rec_ok:
        print("# SMOKE FAIL: WAL recovery not digest-identical "
              "(acked-write loss)")
        ok = False
    rows, ck_ok = bench_checkpoint_interval(120, (None, 40))
    for r in rows:
        print(r, flush=True)
    if not ck_ok:
        print("# SMOKE FAIL: checkpointing did not shorten replay "
              "(or checkpointed recovery diverged)")
        ok = False
    rows, replica_ok, degraded_ok = bench_failover(n=2048, nq=8,
                                                   n_loops=4)
    for r in rows:
        print(r, flush=True)
    if not replica_ok:
        print("# SMOKE FAIL: one dead replica changed answers "
              "(must be invisible)")
        ok = False
    if not degraded_ok:
        print("# SMOKE FAIL: group-down answers not labelled degraded")
        ok = False
    if ok:
        print("# smoke OK: digest-identical recovery, checkpointed "
              "replay shorter, replica failover invisible")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: zero acked-write loss + invisible "
                         "replica failover")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(_smoke())
    for r in run(n_records=(100, 400, 1600) if args.full
                 else (50, 200, 800)):
        print(r)


if __name__ == "__main__":
    main()
