"""Fig. 10 — scalability: latency vs database size at fixed recall,
plus the `sharded` suite: the same filter-and-refine pipeline
row-sharded over 1/2/8 simulated devices (DESIGN.md §10).

The paper sweeps 25M..100M; CPU-scaled here to 5k..40k with the same
sublinearity check (HNSW latency ~ O(log n)).  Alongside the paper's
per-query walk we time the unified engine's batched path (DESIGN.md §2):
same HNSW filter, one jitted refine for the whole batch.

The sharded suite needs more than one XLA device, which must be forced
*before* jax initializes — so `run_sharded()` re-executes this module in
a subprocess with `XLA_FLAGS=--xla_force_host_platform_device_count=8`
and collects its rows (`python -m benchmarks.bench_scalability
--sharded` runs the measurement directly)."""

from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from repro.core import ppanns
from repro.data import synth

from .common import row, timeit


def run(sizes=(5000, 10000, 20000, 40000), nq: int = 15) -> list[str]:
    rows = []
    lat = {}
    for n in sizes:
        ds = synth.make_dataset("sift1m", n=n, n_queries=nq, k_gt=20, seed=2)
        owner, user, server = ppanns.build_system(
            ds.base, beta_fraction=0.03, M=16, ef_construction=100, seed=2)
        enc = [user.encrypt_query(q) for q in ds.queries]

        def search_all():
            return np.stack([server.search(cs, tq, 10, ratio_k=8,
                                           ef_search=128)[0]
                             for cs, tq in enc])
        t, found = timeit(search_all, repeats=1)
        rec = synth.recall_at_k(found, ds.gt, 10)
        lat[n] = t / nq
        rows.append(row(f"fig10/n={n}", 1e6 * t / nq,
                        f"recall={rec:.3f} qps={nq / t:.1f}"))

        Q = np.stack([c for c, _ in enc])
        T = np.stack([tq for _, tq in enc])
        tb, (found_b, _) = timeit(server.search_batch, Q, T, 10,
                                  ratio_k=8, ef_search=128, repeats=1)
        np.testing.assert_array_equal(found_b, found)   # engine parity
        rows.append(row(f"fig10/batched/n={n}", 1e6 * tb / nq,
                        f"qps={nq / tb:.1f} speedup_x{t / tb:.2f}"))
    # sublinearity: latency growth should be far below linear in n
    n0, n1 = sizes[0], sizes[-1]
    growth = lat[n1] / lat[n0]
    rows.append(row("fig10/sublinearity", 0.0,
                    f"nx{n1 // n0} latency x{growth:.2f} (linear would be "
                    f"x{n1 // n0})"))
    return rows


# ---------------------------------------------------------------------------
# sharded suite — one service surface, deployment as a parameter.
# ---------------------------------------------------------------------------

def _run_sharded_inproc(n: int, nq: int, shards=(1, 2, 8)) -> list[str]:
    """Batched submit() latency per (backend, shard count) + exact-id
    parity against the single-device placement.  Requires enough XLA
    devices; see `run_sharded` for the subprocess wrapper."""
    import dataclasses

    import jax

    from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                           SearchParams, SearchRequest, SecureAnnService,
                           suggest_beta)

    ds = synth.make_dataset("sift1m", n=n, n_queries=nq, d=64, k_gt=10,
                            seed=3)
    base = IndexSpec(tenant="bench", name="base", d=64,
                     sap_beta=suggest_beta(ds.base, fraction=0.03), seed=3)
    owner = DataOwnerClient(base)
    C_sap, C_dce = owner.encrypt_vectors(ds.base, seed=11)
    query = owner.query_client().encrypt_queries(ds.queries)
    params = SearchParams(k=10, ratio_k=8.0)

    rows = []
    for backend in ("flat", "ivf"):
        extra = dict(n_partitions=64, nprobe=8) if backend == "ivf" else {}
        spec = dataclasses.replace(base, backend=backend,
                                   name=backend, **extra)
        req = SearchRequest(tenant="bench", collection=spec.name,
                            query=query, params=params, coalesce=False)
        # the single-device placement is the parity reference AND the
        # baseline row every sharded cell is compared against
        with SecureAnnService() as svc:
            svc.create_collection(spec)
            svc.insert("bench", spec.name, C_sap, C_dce)
            svc.submit(req)                             # build + compile
            t, res = timeit(svc.submit, req, repeats=3)
            ref_ids = res.ids
            rec = synth.recall_at_k(ref_ids, ds.gt, 10)
            rows.append(row(f"sharded/{backend}/single", 1e6 * t / nq,
                            f"recall={rec:.3f} qps={nq / t:.1f} n={n}"))
        for n_shards in shards:
            if n_shards > jax.device_count():
                rows.append(row(f"sharded/{backend}/shards={n_shards}",
                                0.0, "SKIPPED: not enough devices"))
                continue
            with SecureAnnService() as svc:
                svc.create_collection(spec, placement=PlacementSpec(
                    kind="sharded", n_shards=n_shards))
                svc.insert("bench", spec.name, C_sap, C_dce)
                svc.submit(req)                         # build + compile
                t, res = timeit(svc.submit, req, repeats=3)
                # bit-identical to the single-device placement
                np.testing.assert_array_equal(res.ids, ref_ids)
                rows.append(row(
                    f"sharded/{backend}/shards={n_shards}", 1e6 * t / nq,
                    f"qps={nq / t:.1f} n={n} parity=exact-vs-single"))
    return rows


def run_sharded(n: int = 6000, nq: int = 16) -> list[str]:
    """Re-exec this module with 8 forced host devices and collect the
    sharded suite rows (jax pins its device count at first init, so the
    flag cannot be set in-process once any other suite has run)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_scalability", "--sharded",
         "--n", str(n), "--nq", str(nq)],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded subprocess failed:\n"
                           f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    return [l for l in proc.stdout.splitlines()
            if l.startswith("sharded/")]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--n", type=int, default=6000)
    ap.add_argument("--nq", type=int, default=16)
    args = ap.parse_args()
    for r in (_run_sharded_inproc(args.n, args.nq) if args.sharded
              else run()):
        print(r, flush=True)
