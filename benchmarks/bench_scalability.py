"""Fig. 10 — scalability: latency vs database size at fixed recall.

The paper sweeps 25M..100M; CPU-scaled here to 5k..40k with the same
sublinearity check (HNSW latency ~ O(log n)).  Alongside the paper's
per-query walk we time the unified engine's batched path (DESIGN.md §2):
same HNSW filter, one jitted refine for the whole batch."""

from __future__ import annotations

import numpy as np

from repro.core import ppanns
from repro.data import synth

from .common import row, timeit


def run(sizes=(5000, 10000, 20000, 40000), nq: int = 15) -> list[str]:
    rows = []
    lat = {}
    for n in sizes:
        ds = synth.make_dataset("sift1m", n=n, n_queries=nq, k_gt=20, seed=2)
        owner, user, server = ppanns.build_system(
            ds.base, beta_fraction=0.03, M=16, ef_construction=100, seed=2)
        enc = [user.encrypt_query(q) for q in ds.queries]

        def search_all():
            return np.stack([server.search(cs, tq, 10, ratio_k=8,
                                           ef_search=128)[0]
                             for cs, tq in enc])
        t, found = timeit(search_all, repeats=1)
        rec = synth.recall_at_k(found, ds.gt, 10)
        lat[n] = t / nq
        rows.append(row(f"fig10/n={n}", 1e6 * t / nq,
                        f"recall={rec:.3f} qps={nq / t:.1f}"))

        Q = np.stack([c for c, _ in enc])
        T = np.stack([tq for _, tq in enc])
        tb, (found_b, _) = timeit(server.search_batch, Q, T, 10,
                                  ratio_k=8, ef_search=128, repeats=1)
        np.testing.assert_array_equal(found_b, found)   # engine parity
        rows.append(row(f"fig10/batched/n={n}", 1e6 * tb / nq,
                        f"qps={nq / tb:.1f} speedup_x{t / tb:.2f}"))
    # sublinearity: latency growth should be far below linear in n
    n0, n1 = sizes[0], sizes[-1]
    growth = lat[n1] / lat[n0]
    rows.append(row("fig10/sublinearity", 0.0,
                    f"nx{n1 // n0} latency x{growth:.2f} (linear would be "
                    f"x{n1 // n0})"))
    return rows
