"""Fig. 4 — effect of the DCPE beta on filter-phase recall.

beta=0 means no noise (plaintext-equivalent filter); larger beta adds
privacy and lowers the recall ceiling of the filter phase (k'=k).  The
paper tunes beta per dataset so the filter ceiling sits near 0.5."""

from __future__ import annotations

import numpy as np

from repro.core import dcpe, hnsw as hnsw_mod
from repro.data import synth

from .common import dataset, row, timeit


def run(n: int = 6000, nq: int = 25) -> list[str]:
    ds = dataset("sift1m", n, nq)
    k = 10
    lo, hi = dcpe.beta_bounds(ds.base)
    rows = []
    for frac in [0.0, 0.01, 0.03, 0.1, 0.3]:
        beta = lo + frac * (hi - lo) if frac > 0 else 0.0
        key = dcpe.keygen(s=1024.0, beta=max(beta, 1e-9))
        C = dcpe.encrypt(ds.base, key, seed=1) if frac > 0 \
            else (key.s * ds.base).astype(np.float32)
        Cq = dcpe.encrypt(ds.queries, key, seed=2) if frac > 0 \
            else (key.s * ds.queries).astype(np.float32)
        idx = hnsw_mod.HNSW(dim=ds.d, M=16, ef_construction=120, seed=3)
        idx.build(C)

        def search_all():
            return np.stack([idx.search(cq, k, ef=96)[0] for cq in Cq])

        t, found = timeit(search_all, repeats=1)
        rec = synth.recall_at_k(found, ds.gt, k)
        rows.append(row(f"fig4/beta_frac={frac:g}", 1e6 * t / nq,
                        f"filter_recall@{k}={rec:.3f} beta={beta:.3g}"))
    return rows
