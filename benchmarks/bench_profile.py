"""Kernel/stage profiling suite (EXPERIMENTS.md §Perf, DESIGN.md §13).

Per backend cell (flat f32, int8, pq8) and corpus size, the engine runs
under BOTH observability layers at once:

  * a `TraceRecorder` ambient span, so the engine's own `filter` /
    `refine` child spans time the two stages and carry the measured
    `bytes_scanned` / `comparisons` attributes;
  * `profile_kernels()`, so the instrumented Pallas/XLA kernel entry
    points (`l2_topk.knn`, `adc_topk.*`) report block-until-ready-fenced
    per-call device time and bytes touched at the op level.

The two views must agree: the kernel time is attributed WITHIN the
filter span.  Writes `BENCH_profile.json` at the repo root (the
profiling trajectory record) plus the harness's results-dir copy.

  PYTHONPATH=src python -m benchmarks.bench_profile --smoke

exits non-zero if serving throughput with full observability attached
(tracer + metrics) drops more than OVERHEAD_GATE (5%) below the
obs-disabled baseline, best-of-3 rounds each — the `obs-smoke` CI gate
for the "near-free" contract (DESIGN.md §13).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.core import dcpe, ppanns
from repro.data import synth
from repro.obs import Observability, TraceRecorder, profile_kernels
from repro.serving.runtime import Collection
from repro.serving.search_engine import SecureSearchEngine

from .common import row

K = 10
RATIO_K = 8.0
NQ = 16
QUANTS = (None, "int8", "pq8")
OVERHEAD_GATE = 0.05

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _setup(n: int, d: int, nq: int, seed: int = 0):
    ds = synth.make_dataset("sift1m", n=n, n_queries=nq, d=d, k_gt=K,
                            seed=seed)
    beta = dcpe.suggest_beta(ds.base, fraction=0.01)
    owner = ppanns.DataOwner(d=d, sap_beta=beta, sap_s=1024.0, seed=seed)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    user = ppanns.User(owner.share_keys(), seed=seed + 1)
    enc = [user.encrypt_query(q) for q in ds.queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    return ds, C_sap, C_dce, Q, T


def _profile_cell(C_sap, C_dce, Q, T, *, quantization: str | None,
                  seed: int, repeats: int):
    """One (backend, n) cell: span-level filter/refine seconds + bytes
    and kernel-level op seconds + bytes, averaged over `repeats` calls."""
    kw = dict(seed=seed) if quantization is not None else {}
    if quantization == "pq8":
        kw.update(pq_m=32, refine_ratio=8.0)
    eng = SecureSearchEngine(C_sap, C_dce, backend="flat",
                             quantization=quantization, **kw)
    eng.search_batch(Q, T, K, ratio_k=RATIO_K)       # warmup/compile
    rec = TraceRecorder()
    with profile_kernels() as prof:
        for i in range(repeats):
            with rec.span("profile", f"cell:{i}"):
                eng.search_batch(Q, T, K, ratio_k=RATIO_K)
    stages = {"filter": [], "refine": []}
    attrs = {}
    for sp in rec.spans():
        if sp.name in stages:
            stages[sp.name].append(sp.duration)
            attrs[sp.name] = sp.attrs
    kernel_prefix = "adc_topk" if quantization else "l2_topk"
    return {
        "filter_s": sum(stages["filter"]) / repeats,
        "refine_s": sum(stages["refine"]) / repeats,
        "filter_bytes": int(attrs["filter"].get("bytes_scanned", 0)),
        "refine_comparisons": int(attrs["refine"].get("comparisons", 0)),
        "kernel": kernel_prefix,
        "kernel_s": prof.total_seconds(kernel_prefix) / repeats,
        "kernel_bytes": prof.total_bytes(kernel_prefix) // max(repeats, 1),
    }


def run(sizes=(10_000, 100_000), d: int = 128, nq: int = NQ,
        repeats: int = 3, seed: int = 0,
        write_root_json: bool = True) -> list[str]:
    rows = []
    for n in sizes:
        ds, C_sap, C_dce, Q, T = _setup(n, d, nq, seed)
        for quant in QUANTS:
            label = quant or "f32"
            c = _profile_cell(C_sap, C_dce, Q, T, quantization=quant,
                              seed=seed, repeats=repeats)
            rows.append(row(
                f"profile/n={n}/flat/{label}/filter",
                1e6 * c["filter_s"] / nq,
                f"bytes_scanned={c['filter_bytes']} "
                f"kernel={c['kernel']} "
                f"kernel_us_per_call={1e6 * c['kernel_s'] / nq:.1f} "
                f"kernel_bytes={c['kernel_bytes']}"))
            rows.append(row(
                f"profile/n={n}/flat/{label}/refine",
                1e6 * c["refine_s"] / nq,
                f"comparisons={c['refine_comparisons']}"))
    if write_root_json:
        _write_root_json(rows, sizes, d, nq)
    return rows


def _write_root_json(rows: list[str], sizes, d: int, nq: int):
    """The repo-root BENCH_profile.json: the profiling trajectory record
    sessions diff against (the harness also writes its own copy under
    results/bench)."""
    from .run import provenance
    payload = {
        "suite": "profile",
        "unix_time": time.time(),
        "config": {"sizes": list(sizes), "d": d, "nq": nq, "k": K,
                   "ratio_k": RATIO_K},
        "provenance": provenance(),
        "rows": [{"name": r.split(",", 2)[0],
                  "us_per_call": float(r.split(",", 2)[1]),
                  "derived": r.split(",", 2)[2]} for r in rows],
    }
    (_ROOT / "BENCH_profile.json").write_text(
        json.dumps(payload, indent=2) + "\n")


# --------------------------------------------------- obs overhead smoke


def _serve_round(col, enc) -> float:
    """One closed-loop round: every query submitted (asynchronously)
    through the scheduler; returns queries/second."""
    t0 = time.perf_counter()
    futs = [col.submit(c, t, K) for c, t in enc]
    for f in futs:
        f.result(timeout=120)
    return len(enc) / (time.perf_counter() - t0)


def _overhead_qps(ds, obs_on: bool, *, seed: int, n_req: int,
                  rounds: int) -> float:
    """Best-of-`rounds` serving throughput with observability on/off.
    Same seed both ways: identical keys, corpus, and queries."""
    kw = {}
    if obs_on:
        obs = Observability()
        kw = dict(tracer=obs.recorder, metrics=obs.metrics)
    beta = dcpe.suggest_beta(ds.base, fraction=0.01)
    col = Collection("bench", f"ov-{int(obs_on)}", ds.base.shape[1],
                     sap_beta=beta, seed=seed, max_batch=8,
                     max_wait_ms=0.5, max_queue=4 * n_req, **kw)
    try:
        col.insert(ds.base)
        user = col.new_user()
        enc = [user.encrypt_query(ds.queries[i % len(ds.queries)])
               for i in range(n_req)]
        col.warmup(K)
        _serve_round(col, enc)                       # warm the path
        return max(_serve_round(col, enc) for _ in range(rounds))
    finally:
        col.close()


def _smoke(n: int = 20_000, d: int = 64, n_req: int = 128,
           rounds: int = 3, seed: int = 0) -> int:
    """CI gate: full observability (tracer + metrics) must cost <= 5%
    of obs-disabled serving throughput, best-of-3 rounds each side."""
    ds = synth.make_dataset("sift1m", n=n, n_queries=NQ, d=d, k_gt=K,
                            seed=seed)
    qps_off = _overhead_qps(ds, False, seed=seed, n_req=n_req,
                            rounds=rounds)
    qps_on = _overhead_qps(ds, True, seed=seed, n_req=n_req,
                           rounds=rounds)
    overhead = 1.0 - qps_on / qps_off
    print(row(f"profile-smoke/overhead/n={n}", 0.0,
              f"qps_off={qps_off:.1f} qps_on={qps_on:.1f} "
              f"overhead={100 * overhead:.2f}%"), flush=True)
    if overhead > OVERHEAD_GATE:
        print(f"# SMOKE FAIL: observability overhead "
              f"{100 * overhead:.2f}% > {100 * OVERHEAD_GATE:.0f}%")
        return 1
    print(f"# smoke OK: observability overhead {100 * overhead:.2f}% "
          f"<= {100 * OVERHEAD_GATE:.0f}% gate")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: obs-enabled serving within 5% of "
                         "obs-disabled throughput")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(_smoke())
    for r in run(sizes=(10_000, 100_000) if not args.full
                 else (10_000, 100_000, 200_000)):
        print(r)


if __name__ == "__main__":
    main()
