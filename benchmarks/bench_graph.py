"""Graph filter suite (EXPERIMENTS.md §Perf, DESIGN.md §15).

Grid: n x {host-hnsw, graph-f32, graph-int8, ivf-int8}.  Per cell it
reports the filter-phase latency/QPS (the backend `candidates` call —
the stage the batched CSR traversal accelerates), recall@10 of the full
filter-and-refine pipeline against plaintext ground truth, and the
edges/rows the filter actually scored (`n_dist_evals` — the work the
graph saves over a pooled scan, measured not estimated).

The host-hnsw cell is the per-query parity oracle exactly as PR 2
shipped it (a Python loop of host walks over the same owner-built
graph); the graph cells run the SAME graph through the batched
device-resident CSR traversal.  Every ratio is a ratio between served
paths over one identical index.

Writes `BENCH_graph.json` at the repo root (the graph-suite perf
trajectory record) in addition to the harness's results-dir copy.

  PYTHONPATH=src python -m benchmarks.bench_graph --smoke

exits non-zero if the batched f32 graph filter is slower than the
per-query host walk, or if its ids are not identical to the host
walk's at fixed ef — the `graph-smoke` CI gate.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import warnings

import numpy as np

from repro.core import dcpe, ppanns
from repro.core.hnsw import HNSW
from repro.data import synth
from repro.graph import GraphFilter
from repro.serving.search_engine import HNSWGraphFilter, SecureSearchEngine

from .common import row, timeit

K = 10
RATIO_K = 8.0
NQ = 16
EF = 96
# reduced build parameters: the owner-side host build is pure Python and
# the 100k cell has to stay CPU-feasible; recall is carried by ef at
# query time (fig-style M/efC trades are not this suite's subject)
HNSW_M = 8
HNSW_EFC = 48

_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _setup(n: int, d: int, nq: int, seed: int = 0):
    ds = synth.make_dataset("sift1m", n=n, n_queries=nq, d=d, k_gt=K,
                            seed=seed)
    beta = dcpe.suggest_beta(ds.base, fraction=0.01)
    owner = ppanns.DataOwner(d=d, sap_beta=beta, sap_s=1024.0, seed=seed)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    user = ppanns.User(owner.share_keys(), seed=seed + 1)
    enc = [user.encrypt_query(q) for q in ds.queries]
    Q = np.stack([c for c, _ in enc])
    T = np.stack([t for _, t in enc])
    t0 = time.perf_counter()
    index = HNSW(d, M=HNSW_M, ef_construction=HNSW_EFC, seed=seed)
    index.build(C_sap)
    build_s = time.perf_counter() - t0
    return ds, C_sap, C_dce, Q, T, index, build_s


def _backend(label: str, index: HNSW, seed: int):
    if label == "host-hnsw":
        return HNSWGraphFilter(index)
    if label == "graph-f32":
        return GraphFilter(index, seed=seed)
    if label == "graph-int8":
        return GraphFilter(index, quantization="int8", seed=seed)
    raise ValueError(label)


def _bench_cell(C_sap, C_dce, Q, T, gt, *, label: str, index: HNSW,
                seed: int, repeats: int):
    nq = Q.shape[0]
    if label == "ivf-int8":
        eng = SecureSearchEngine(
            C_sap, C_dce, backend="ivf", quantization="int8",
            n_partitions=min(256, max(8, C_sap.shape[0] // 256)),
            nprobe=16, seed=seed)
    else:
        eng = SecureSearchEngine(C_sap, C_dce,
                                 backend=_backend(label, index, seed))
    eng._ensure_attached()
    kp = int(RATIO_K * K)
    with warnings.catch_warnings():
        # the host-walk cell IS the deprecated path, measured on purpose
        warnings.simplefilter("ignore", DeprecationWarning)
        t_filter, out = timeit(lambda: eng.backend.candidates(Q, kp, EF),
                               repeats=repeats)
        ids, stats = eng.search_batch(Q, T, K, ratio_k=RATIO_K,
                                      ef_search=EF)
    n_evals = int(out[2])
    rec = synth.recall_at_k(np.asarray(ids), gt, K)
    return t_filter, rec, n_evals, np.asarray(ids)


def run(sizes=(10_000, 100_000), d: int = 128, nq: int = NQ,
        repeats: int = 3, seed: int = 0,
        write_root_json: bool = True) -> list[str]:
    rows = []
    for n in sizes:
        ds, C_sap, C_dce, Q, T, index, build_s = _setup(n, d, nq, seed)
        rows.append(row(f"graph/n={n}/owner-build", 1e6 * build_s / n,
                        f"build_s={build_s:.1f} M={HNSW_M} efC={HNSW_EFC}"))
        base_t = None
        for label in ("host-hnsw", "graph-f32", "graph-int8", "ivf-int8"):
            t, rec, n_evals, _ = _bench_cell(
                C_sap, C_dce, Q, T, ds.gt, label=label, index=index,
                seed=seed, repeats=repeats)
            if label == "host-hnsw":
                base_t = t
            speed = base_t / t if base_t else float("nan")
            rows.append(row(
                f"graph/n={n}/{label}", 1e6 * t / nq,
                f"qps={nq / t:.1f} recall@{K}={rec:.3f} "
                f"edges_scanned={n_evals} vs_host_x{speed:.2f}"))
    if write_root_json:
        _write_root_json(rows, sizes, d, nq)
    return rows


def _write_root_json(rows: list[str], sizes, d: int, nq: int):
    """The repo-root BENCH_graph.json: the graph-suite trajectory record
    sessions diff against (the harness also writes its own copy under
    results/bench)."""
    from .run import provenance
    payload = {
        "suite": "graph",
        "unix_time": time.time(),
        "config": {"sizes": list(sizes), "d": d, "nq": nq, "k": K,
                   "ratio_k": RATIO_K, "ef": EF, "hnsw_M": HNSW_M,
                   "hnsw_efC": HNSW_EFC},
        "provenance": provenance(),
        "rows": [{"name": r.split(",", 2)[0],
                  "us_per_call": float(r.split(",", 2)[1]),
                  "derived": r.split(",", 2)[2]} for r in rows],
    }
    (_ROOT / "BENCH_graph.json").write_text(
        json.dumps(payload, indent=2) + "\n")


def _smoke(n: int = 8192, d: int = 64, nq: int = 32, seed: int = 0) -> int:
    """CI gate: the batched CSR traversal must beat the per-query host
    walk's filter QPS on the same graph AND return identical ids at
    fixed ef (the parity-oracle contract of tests/test_graph.py, held
    at bench scale)."""
    ds, C_sap, C_dce, Q, T, index, build_s = _setup(n, d, nq, seed)
    print(row(f"graph-smoke/n={n}/owner-build", 1e6 * build_s / n,
              f"build_s={build_s:.1f}"), flush=True)
    results = {}
    for label in ("host-hnsw", "graph-f32"):
        t, rec, n_evals, ids = _bench_cell(
            C_sap, C_dce, Q, T, ds.gt, label=label, index=index,
            seed=seed, repeats=2)
        results[label] = (t, rec, ids)
        print(row(f"graph-smoke/n={n}/{label}", 1e6 * t / nq,
                  f"qps={nq / t:.1f} recall@{K}={rec:.3f}"), flush=True)
    ok = True
    if results["graph-f32"][0] > results["host-hnsw"][0]:
        print(f"# SMOKE FAIL: batched graph filter slower than host walk "
              f"({results['graph-f32'][0]:.3f}s vs "
              f"{results['host-hnsw'][0]:.3f}s)")
        ok = False
    if not np.array_equal(results["graph-f32"][2],
                          results["host-hnsw"][2]):
        print("# SMOKE FAIL: batched graph ids != host walk ids at "
              "fixed ef (parity oracle broken)")
        ok = False
    if ok:
        speed = results["host-hnsw"][0] / results["graph-f32"][0]
        print(f"# smoke OK: batched graph {speed:.2f}x the host walk, "
              f"ids identical at ef={EF}")
    return 0 if ok else 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: batched > host-walk QPS + id parity")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(_smoke())
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
