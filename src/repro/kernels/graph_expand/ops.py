"""Jitted public wrapper for the graph_expand kernel.

`graph_topk` is the single serving entry point for the batched CSR
traversal (GraphFilter and the runtime graph backend both call it):

  use_kernel=True, quant="f32", oblivious=False
      upper layers descend in XLA (`graph.traverse.upper_entry` — a
      handful of lockstep greedy hops, not worth a kernel), then the
      Pallas frontier-expansion kernel runs the layer-0 beam search
      with VMEM-resident beams/bitmaps and DMA row gathers;
  otherwise
      the pure-XLA `graph.traverse.traverse` — the fast path on CPU
      hosts, the only path for ADC (int8/pq8) edge scoring, and the
      only path for the oblivious (`hardened`) fixed-trip variant.

Both paths return the identical contract: (cand (nq, kp) int32 -1
fill, cand_d (nq, kp) f32 +inf fill, visited (nq, R) bool scan trace,
hops (nq,), edges (nq,)).  The beam merge in the kernel reproduces the
fallback's stable-sort tie order, so ids are bit-identical (pinned by
the interpret-mode parity test in tests/test_graph.py).

Like every serving wrapper, all shape-bearing arguments are static:
(kp, ef_cap, max_hops, quant, oblivious, use_kernel) select a cached
executable, `ef`/`entry` and every array are traced — varying ef,
bucket padding, and tombstones never recompile.
"""

from __future__ import annotations

import functools

import jax

from ...graph import traverse as _traverse
from . import graph_expand as _kernel

expand_layer0 = _kernel.expand_layer0


@functools.partial(
    jax.jit,
    static_argnames=("kp", "ef_cap", "max_hops", "quant", "oblivious",
                     "use_kernel", "block_q", "interpret"))
def graph_topk(
    neigh0,
    neigh_up,
    ok,
    db,
    qd,
    entry,
    ef,
    *,
    kp: int,
    ef_cap: int,
    max_hops: int,
    quant: str = "f32",
    oblivious: bool = False,
    use_kernel: bool = False,
    block_q: int = _kernel.DEFAULT_BLOCK_Q,
    interpret: bool | None = None,
):
    """Batched graph walk; see `graph.traverse.traverse` for the array
    contract.  With use_kernel=True (f32, non-oblivious only) the
    layer-0 beam runs in the Pallas kernel."""
    if not (use_kernel and quant == "f32" and not oblivious):
        return _traverse.traverse(
            neigh0, neigh_up, ok, db, qd, entry, ef, kp=kp,
            ef_cap=ef_cap, max_hops=max_hops, quant=quant,
            oblivious=oblivious)
    (C,) = db
    ep, ep_d, hops, edges = _traverse.upper_entry(
        neigh_up, ok, db, qd, entry, quant="f32", oblivious=False)
    beam_i, beam_d, visited, k_hops, k_edges = _kernel.expand_layer0(
        neigh0, ok, C, qd, ep, ep_d, ef, ef_cap=ef_cap,
        max_hops=max_hops, block_q=block_q, interpret=interpret)
    return (beam_i[:, :kp], beam_d[:, :kp], visited,
            hops + k_hops, edges + k_edges)


# Opt-in kernel profiling (repro.obs, DESIGN.md §13): strict
# passthrough unless a KernelProfiler is active; `_cache_size` is
# preserved for the recompile audit.
from ...obs.profiler import instrument as _instrument  # noqa: E402

graph_topk = _instrument("graph_expand.graph_topk", graph_topk)
