"""graph_expand — Pallas lockstep frontier expansion over the CSR
HNSW mirror (DESIGN.md §15).  ops.py holds the jitted `graph_topk`
dispatcher (kernel beam + XLA upper layers, or the pure-XLA
`graph.traverse` fallback); parity is tested in interpret mode."""
