"""Pallas TPU kernel: lockstep layer-0 beam search over the CSR graph.

This is the device form of `graph.traverse.beam_layer0` (DESIGN.md §15)
for the f32 edge-scoring mode: the beam heap, the visited bitmap, and
the per-hop neighbor/row staging buffers are all VMEM/SMEM-resident;
the big arrays — the (R, M0) layer-0 adjacency and the (R, d)
ciphertext matrix — stay in HBM and are gathered row-wise with explicit
async DMAs (the KV-cache gather pattern), so VMEM holds O(bq * ef_cap +
bq * R/32 + M0 * d) regardless of corpus size.

Grid: one step per query tile of `bq` queries; queries are independent,
so within a tile each runs its own bounded `while_loop` (a finished
query stops issuing hops — the XLA fallback can only stop when the
whole batch is done).  Per hop and per query:

  1. select the closest unexpanded beam entry (VPU argmin over the
     (1, EF) beam row) and test the host walk's break rule against the
     traced effective `ef` (an SMEM scalar);
  2. DMA its fixed-degree neighbor row (int32, SMEM) and then the M0
     neighbor vectors (HBM -> VMEM, per-slot semaphores so the copies
     overlap), always full rows — `-1` padding and tombstones are
     masked after the fact via the `ok` stream, never branched on;
  3. score edges (VPU sum((x-q)^2)), test+set visited bits in the
     per-query bitmap words, and insertion-sort the fresh neighbors
     into the beam row — `pos = sum(bd <= d)` places ties after equal
     keys, which is exactly where a stable argsort over
     [beam | neighbors] puts them, so the merge is bit-identical to
     the XLA fallback's;
  4. re-invalidate beam slots >= ef (effective-ef truncation), keeping
     results a pure function of `ef` across beam-capacity buckets.

The visited bitmap is emitted as packed uint32 words; `ops.graph_topk`
unpacks it to the (nq, R) bool scan trace so sec.leakage sees the same
view either path.  The oblivious (`hardened`) variant always takes the
XLA path — its value is constant trip counts, which the fallback's
`fori_loop` already provides, and keeping one oblivious implementation
keeps the cross-profile id-parity argument small.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import LANE, interpret_default, pad_to

DEFAULT_BLOCK_Q = 8
_INF = float("inf")     # python float: kernels must not capture arrays


def _beam_insert(bd, bi, bx, dm, im, fresh):
    """Insert one scored neighbor (dm, im) into the ascending beam row
    (1, EF).  Non-fresh slots insert an inert (+inf, -1, expanded)
    entry, which lands among the +inf tail — the same slots a stable
    sort of [beam | neighbors] would keep."""
    EF = bd.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, EF), 1)
    dm = jnp.where(fresh, dm, _INF)
    im = jnp.where(fresh, im, -1)
    pos = (bd <= dm).sum().astype(jnp.int32)       # after equal keys
    sh_d = jnp.concatenate([bd[:, :1], bd[:, :-1]], axis=1)
    sh_i = jnp.concatenate([bi[:, :1], bi[:, :-1]], axis=1)
    sh_x = jnp.concatenate([bx[:, :1], bx[:, :-1]], axis=1)
    at = iota == pos
    bd = jnp.where(iota < pos, bd, jnp.where(at, dm, sh_d))
    bi = jnp.where(iota < pos, bi, jnp.where(at, im, sh_i))
    bx = jnp.where(iota < pos, bx, jnp.where(at, ~fresh, sh_x))
    return bd, bi, bx


def _expand_kernel(
    ef_ref,            # (1, 1) int32 SMEM: traced effective ef
    q_ref,             # (bq, d_p) f32 VMEM: query tile
    ep_ref,            # (bq, 1) int32 VMEM: layer-0 entry per query
    epd_ref,           # (bq, 1) f32 VMEM: entry distance
    ok_ref,            # (1, R) int32 VMEM: row validity
    neigh0_hbm,        # (R, M0) int32 ANY: layer-0 adjacency
    c_hbm,             # (R, d_p) f32 ANY: ciphertext rows
    cand_ref,          # (bq, EF) int32 out
    cand_d_ref,        # (bq, EF) f32 out
    vis_ref,           # (bq, RW) uint32 out: packed visited bitmap
    hops_ref,          # (bq, 1) int32 out
    edges_ref,         # (bq, 1) int32 out
    nrow,              # (1, M0) int32 SMEM scratch: neighbor row
    crows,             # (M0, d_p) f32 VMEM scratch: gathered rows
    sems,              # (M0 + 1,) DMA semaphores
    *,
    max_hops: int,
):
    bq, EF = cand_ref.shape
    M0 = nrow.shape[1]
    RW = vis_ref.shape[1]
    ef = ef_ref[0, 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, EF), 1)
    one = jnp.uint32(1)

    for q in range(bq):                       # queries are independent
        qv = q_ref[pl.ds(q, 1), :]                         # (1, d_p)
        ep = ep_ref[q, 0]
        ep_ok = ep >= 0
        eps = jnp.maximum(ep, 0)
        bd = jnp.where((iota == 0) & ep_ok, epd_ref[q, 0], _INF)
        bi = jnp.where((iota == 0) & ep_ok, eps, -1)
        bx = ~((iota == 0) & ep_ok)
        vis = jnp.zeros((1, RW), jnp.uint32)
        bit0 = jnp.where(ep_ok, one << (eps & 31).astype(jnp.uint32),
                         jnp.uint32(0))
        vis = jax.lax.dynamic_update_slice(
            vis, bit0.reshape(1, 1),
            (0, jax.lax.shift_right_logical(eps, 5)))

        def hop(state):
            t, bd, bi, bx, vis, done, hops, edges = state
            du = jnp.where(bx, _INF, bd)
            j = jnp.argmin(du[0]).astype(jnp.int32)
            sel_d = jax.lax.dynamic_slice(du, (0, j), (1, 1))[0, 0]
            sel_i = jax.lax.dynamic_slice(bi, (0, j), (1, 1))[0, 0]
            worst = jax.lax.dynamic_slice(bd, (0, ef - 1), (1, 1))[0, 0]
            qdone = jnp.isinf(sel_d) | (sel_d > worst)
            active = ~qdone
            src = jnp.maximum(sel_i, 0)

            # stage the neighbor row, then its vectors (per-slot sems
            # so the M0 row copies are all in flight together)
            row_dma = pltpu.make_async_copy(
                neigh0_hbm.at[src], nrow.at[0], sems.at[M0])
            row_dma.start()
            row_dma.wait()
            row_dmas = [
                pltpu.make_async_copy(
                    c_hbm.at[jnp.maximum(nrow[0, m], 0)],
                    crows.at[m], sems.at[m])
                for m in range(M0)
            ]
            for dma in row_dmas:
                dma.start()
            for dma in row_dmas:
                dma.wait()

            diff = crows[...] - qv                        # (M0, d_p)
            d2 = (diff * diff).sum(axis=1)                # (M0,)

            bx = bx | (iota == j)                  # mark expanded slot
            fresh_cnt = jnp.int32(0)
            for m in range(M0):
                idx = nrow[0, m]
                safe = jnp.maximum(idx, 0)
                okv = pl.load(
                    ok_ref, (pl.ds(0, 1), pl.ds(safe, 1)))[0, 0] > 0
                w = jax.lax.shift_right_logical(safe, 5)
                b = (safe & 31).astype(jnp.uint32)
                word = jax.lax.dynamic_slice(vis, (0, w), (1, 1))
                seen = (jax.lax.shift_right_logical(word[0, 0], b)
                        & one) > 0
                fresh = (idx >= 0) & okv & ~seen & active
                vis = jax.lax.dynamic_update_slice(
                    vis,
                    (word | jnp.where(fresh, one << b, jnp.uint32(0))),
                    (0, w))
                bd, bi, bx = _beam_insert(bd, bi, bx, d2[m], safe, fresh)
                fresh_cnt = fresh_cnt + fresh.astype(jnp.int32)

            over = iota >= ef            # effective-ef truncation
            bd = jnp.where(over, _INF, bd)
            bi = jnp.where(over, -1, bi)
            bx = bx | over
            hops = hops + active.astype(jnp.int32)
            edges = edges + jnp.where(active, fresh_cnt, 0)
            return (t + 1, bd, bi, bx, vis, done | qdone, hops, edges)

        state = (jnp.int32(0), bd, bi, bx, vis, ~ep_ok,
                 jnp.int32(0), jnp.int32(0))
        state = jax.lax.while_loop(
            lambda s: (s[0] < max_hops) & ~s[5], hop, state)
        _, bd, bi, bx, vis, _, hops, edges = state

        cand_ref[pl.ds(q, 1), :] = bi
        cand_d_ref[pl.ds(q, 1), :] = bd
        vis_ref[pl.ds(q, 1), :] = vis
        hops_ref[q, 0] = hops
        edges_ref[q, 0] = edges


@functools.partial(
    jax.jit,
    static_argnames=("ef_cap", "max_hops", "block_q", "interpret"))
def expand_layer0(
    neigh0: jnp.ndarray,
    ok: jnp.ndarray,
    C: jnp.ndarray,
    Q: jnp.ndarray,
    ep: jnp.ndarray,
    ep_d: jnp.ndarray,
    ef,
    *,
    ef_cap: int,
    max_hops: int,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool | None = None,
):
    """Batched layer-0 beam search (f32 scoring).

    neigh0 (R, M0) int32; ok (R,) validity; C (R, d) f32; Q (nq, d)
    f32; ep/ep_d (nq,) the upper-layer descent endpoints; ef traced
    int32.  Returns (beam_i (nq, ef_cap) int32, beam_d (nq, ef_cap)
    f32, visited (nq, R) bool, hops (nq,), edges (nq,)) — the same
    contract as `graph.traverse.beam_layer0` before the kp slice.
    """
    if interpret is None:
        interpret = interpret_default()
    nq, d = Q.shape
    R, M0 = neigh0.shape
    if R % 32:
        raise ValueError(f"row capacity {R} not a multiple of 32")
    RW = R // 32

    Qp = pad_to(Q.astype(jnp.float32), 1, LANE)
    Cp = pad_to(C.astype(jnp.float32), 1, LANE)
    d_p = Qp.shape[1]
    bq = max(1, min(block_q, nq))
    nq_p = ((nq + bq - 1) // bq) * bq
    pq = nq_p - nq
    if pq:     # padded queries carry ep=-1 -> done before the first hop
        Qp = jnp.pad(Qp, ((0, pq), (0, 0)))
        ep = jnp.pad(ep, (0, pq), constant_values=-1)
        ep_d = jnp.pad(ep_d, (0, pq), constant_values=jnp.inf)

    grid = (nq_p // bq,)
    kernel = functools.partial(_expand_kernel, max_hops=max_hops)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bq, d_p), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, R), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((bq, ef_cap), lambda i: (i, 0)),
            pl.BlockSpec((bq, ef_cap), lambda i: (i, 0)),
            pl.BlockSpec((bq, RW), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq_p, ef_cap), jnp.int32),
            jax.ShapeDtypeStruct((nq_p, ef_cap), jnp.float32),
            jax.ShapeDtypeStruct((nq_p, RW), jnp.uint32),
            jax.ShapeDtypeStruct((nq_p, 1), jnp.int32),
            jax.ShapeDtypeStruct((nq_p, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, M0), jnp.int32),
            pltpu.VMEM((M0, d_p), jnp.float32),
            pltpu.SemaphoreType.DMA((M0 + 1,)),
        ],
        interpret=interpret,
    )(
        jnp.asarray(ef, jnp.int32).reshape(1, 1),
        Qp,
        ep.astype(jnp.int32).reshape(-1, 1),
        ep_d.astype(jnp.float32).reshape(-1, 1),
        ok.astype(jnp.int32)[None, :],
        neigh0,
        Cp,
    )
    beam_i, beam_d, vis_words, hops, edges = out
    bits = jax.lax.shift_right_logical(
        vis_words[:nq, :, None],
        jnp.arange(32, dtype=jnp.uint32)[None, None, :])
    visited = ((bits & jnp.uint32(1)) > 0).reshape(nq, R)
    return (beam_i[:nq], beam_d[:nq], visited,
            hops[:nq, 0], edges[:nq, 0])
