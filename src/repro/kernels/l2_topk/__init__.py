from .ops import knn, pairwise_sq_dists  # noqa: F401
from . import ref  # noqa: F401
