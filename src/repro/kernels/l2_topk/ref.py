"""Pure-jnp oracle for the l2_topk kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sq_dists(Q: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """||q - x||^2 for all pairs; Q: (nq, d), X: (n, d) -> (nq, n)."""
    Q = Q.astype(jnp.float32)
    X = X.astype(jnp.float32)
    qn = (Q * Q).sum(-1, keepdims=True)
    xn = (X * X).sum(-1)[None, :]
    return qn - 2.0 * Q @ X.T + xn


def knn(Q: jnp.ndarray, X: jnp.ndarray, k: int):
    """Exact k-NN: returns (dists (nq, k), idx (nq, k)) ascending."""
    d = pairwise_sq_dists(Q, X)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx
