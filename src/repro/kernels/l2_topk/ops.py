"""Jitted public wrappers around the l2_topk Pallas kernel.

`knn` streams the database through the distance kernel tile-by-tile and
keeps a running top-k (the HBM-resident database never materializes an
(nq, n) distance matrix) — the TPU analogue of the paper's linear scan with
a max-heap, restructured as a chunked merge so it is O(n/chunk) sequential
steps instead of O(n).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import l2_topk as _kernel
from . import ref as _ref

pairwise_sq_dists = _kernel.pairwise_sq_dists


@functools.partial(
    jax.jit, static_argnames=("k", "chunk", "interpret", "use_kernel"))
def knn(
    Q: jnp.ndarray,
    X: jnp.ndarray,
    k: int,
    *,
    chunk: int = 4096,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact k-NN of each query against X.

    Q: (nq, d), X: (n, d)  ->  (dists (nq, k) ascending, idx (nq, k)).
    Scans X in `chunk`-row tiles; per tile the Pallas kernel produces the
    distance block and a top-k merge folds it into the running state.
    """
    nq, _ = Q.shape
    n = X.shape[0]
    k = min(k, n)
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    n_pad = n_chunks * chunk
    Xp = jnp.pad(X, ((0, n_pad - n), (0, 0)))

    dist_fn = pairwise_sq_dists if use_kernel else _ref.pairwise_sq_dists

    def body(carry, ci):
        best_d, best_i = carry
        start = ci * chunk
        xs = jax.lax.dynamic_slice_in_dim(Xp, start, chunk, axis=0)
        if use_kernel:
            d_blk = dist_fn(Q, xs, interpret=interpret)
        else:
            d_blk = dist_fn(Q, xs)
        idx_blk = start + jnp.arange(chunk)[None, :]
        # mask padded rows
        valid = (idx_blk < n)
        d_blk = jnp.where(valid, d_blk, jnp.inf)
        cat_d = jnp.concatenate([best_d, d_blk], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(idx_blk, (nq, chunk))],
                                axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, pos, axis=1)), None

    init = (jnp.full((nq, k), jnp.inf, jnp.float32),
            jnp.full((nq, k), -1, jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return best_d, best_i.astype(jnp.int32)
