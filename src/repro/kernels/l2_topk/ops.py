"""Jitted public wrappers around the l2_topk Pallas kernel.

`knn` streams the database through the distance kernel tile-by-tile and
keeps a running top-k (the HBM-resident database never materializes an
(nq, n) distance matrix) — the TPU analogue of the paper's linear scan with
a max-heap, restructured as a chunked merge so it is O(n/chunk) sequential
steps instead of O(n).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import running_topk_scan
from . import l2_topk as _kernel
from . import ref as _ref

pairwise_sq_dists = _kernel.pairwise_sq_dists


@functools.partial(
    jax.jit, static_argnames=("k", "chunk", "interpret", "use_kernel"))
def knn(
    Q: jnp.ndarray,
    X: jnp.ndarray,
    k: int,
    *,
    chunk: int = 4096,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact k-NN of each query against X.

    Q: (nq, d), X: (n, d)  ->  (dists (nq, k) ascending, idx (nq, k)).
    Scans X in `chunk`-row tiles; per tile the Pallas kernel produces the
    distance block and a top-k merge folds it into the running state.
    """
    nq, _ = Q.shape
    n = X.shape[0]
    k = min(k, n)
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    n_pad = n_chunks * chunk
    Xp = jnp.pad(X, ((0, n_pad - n), (0, 0)))

    kernel_fn = pairwise_sq_dists if use_kernel else _ref.pairwise_sq_dists

    # Hoisted loop invariants: the in-chunk column offsets (the mask is
    # one add+compare against them per step, never a fresh arange).
    # The running-top-k merge itself — including the pos<k id mapping
    # that avoids materializing an (nq, chunk) id block — is the shared
    # `running_topk_scan` (kernels/common.py), one copy for this scan
    # and the adc_topk fallbacks.
    col = jnp.arange(chunk, dtype=jnp.int32)[None, :]

    def dist_fn(start):
        xs = jax.lax.dynamic_slice_in_dim(Xp, start, chunk, axis=0)
        if use_kernel:
            d_blk = kernel_fn(Q, xs, interpret=interpret)
        else:
            d_blk = kernel_fn(Q, xs)
        return jnp.where(start + col < n, d_blk, jnp.inf)

    best_d, best_i = running_topk_scan(dist_fn, n_pad, nq, k, chunk)
    return best_d, best_i.astype(jnp.int32)


# Opt-in kernel profiling (repro.obs, DESIGN.md §13): a strict
# passthrough unless a KernelProfiler is active, fencing each call with
# block_until_ready and recording device time + bytes touched.  The
# wrapper preserves `_cache_size` for the recompile audit
# (serving.runtime.telemetry.jit_cache_size).
from ...obs.profiler import instrument as _instrument  # noqa: E402

knn = _instrument("l2_topk.knn", knn)
