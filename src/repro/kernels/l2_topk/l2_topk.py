"""Pallas TPU kernel: fused batched squared-L2 distance tiles.

This is the *filter-phase* hot-spot of the paper's scheme (and the
brute-force / IVF scan): distances between encrypted queries and DCPE
ciphertexts.  TPU adaptation: the one-at-a-time C++ distance loop becomes
``||q||^2 - 2 q.x + ||x||^2`` where the cross term is an MXU matmul over
(block_q x d) x (d x block_n) VMEM tiles; norms are rank-1 broadcast adds
fused into the same kernel.

VMEM budget per grid step (block_q = block_n = 128, d <= 4096 padded to a
lane multiple): 2 * 128*4096*4B = 4 MiB of operand tiles + 64 KiB out —
comfortably inside the ~16 MiB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import LANE, interpret_default, pad_to, padded_size

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_N = 128


def _l2_tile_kernel(q_ref, x_ref, qn_ref, xn_ref, out_ref):
    """One (block_q, block_n) distance tile.

    q_ref: (bq, d) query tile;      x_ref: (bn, d) database tile
    qn_ref: (bq, 1) query norms;    xn_ref: (1, bn) database norms
    out_ref: (bq, bn) squared distances
    """
    cross = jax.lax.dot_general(
        q_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = qn_ref[...] - 2.0 * cross + xn_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def pairwise_sq_dists(
    Q: jnp.ndarray,
    X: jnp.ndarray,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """All-pairs ||q - x||^2 via the Pallas tile kernel.

    Q: (nq, d), X: (n, d)  ->  (nq, n) float32.
    """
    if interpret is None:
        interpret = interpret_default()
    nq, d = Q.shape
    n = X.shape[0]
    Qf = Q.astype(jnp.float32)
    Xf = X.astype(jnp.float32)
    qn = (Qf * Qf).sum(-1, keepdims=True)            # (nq, 1)
    xn = (Xf * Xf).sum(-1)[None, :]                  # (1, n)

    # Hardware-aligned padding: zero-padding rows adds zero-norm phantom
    # vectors whose distances land in sliced-away rows/cols.
    Qp = pad_to(pad_to(Qf, 0, block_q), 1, LANE)
    Xp = pad_to(pad_to(Xf, 0, block_n), 1, LANE)
    qnp_ = pad_to(qn, 0, block_q)
    xnp_ = pad_to(xn, 1, block_n)
    nq_p, d_p = Qp.shape
    n_p = Xp.shape[0]

    grid = (nq_p // block_q, n_p // block_n)
    out = pl.pallas_call(
        _l2_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d_p), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d_p), lambda i, j: (j, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq_p, n_p), jnp.float32),
        interpret=interpret,
    )(Qp, Xp, qnp_, xnp_)
    return out[:nq, :n]
