from .ops import top_k_by_wins, z_matrix  # noqa: F401
from . import ref  # noqa: F401
