from .ops import (batched_top_k_by_wins, batched_z_matrix,  # noqa: F401
                  top_k_by_wins, z_matrix)
from . import ref  # noqa: F401
