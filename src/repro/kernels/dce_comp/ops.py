"""Jitted public wrappers for the dce_comp kernel: the tournament refine."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dce_comp as _kernel
from . import ref as _ref

z_matrix = _kernel.z_matrix
batched_z_matrix = _kernel.batched_z_matrix


@functools.partial(
    jax.jit, static_argnames=("k", "block", "interpret", "use_kernel"))
def top_k_by_wins(
    C: jnp.ndarray,
    t: jnp.ndarray,
    k: int,
    *,
    block: int = _kernel.DEFAULT_BLOCK,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Exact top-k of a DCE-encrypted candidate set (refine phase).

    Ranks the n candidates by pairwise-comparison win counts computed from
    the Pallas Z-matrix kernel.  Exactness: DCE comparisons reflect true
    distances (Theorem 3), so win counts sort identically to distances
    (ties in wins <=> exact distance ties).
    """
    if use_kernel:
        Z = z_matrix(C, t, block=block, interpret=interpret)
    else:
        Z = _ref.z_matrix(C, t)
    # Exclude the diagonal: Z_ii is mathematically 0 but floats to +-eps.
    offdiag = ~jnp.eye(Z.shape[0], dtype=bool)
    wins = ((Z < 0) & offdiag).sum(axis=1)
    k = min(k, C.shape[0])
    _, idx = jax.lax.top_k(wins, k)
    return idx.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("k", "block", "interpret", "use_kernel"))
def batched_top_k_by_wins(
    C: jnp.ndarray,
    T: jnp.ndarray,
    k: int,
    *,
    valid: jnp.ndarray | None = None,
    block: int = _kernel.DEFAULT_BLOCK,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Batched refine: per-query exact top-k of DCE candidate sets.

    C: (B, n, 4, D) candidate ciphertexts, T: (B, D) trapdoors,
    valid: optional (B, n) bool mask for padded candidate slots (backends
    with ragged candidate lists pad to a rectangle) -> (B, k) int32 local
    indices, descending win count (== ascending true distance, Theorem 3).

    Per batch row this computes exactly what `top_k_by_wins` computes, so
    the per-query and batched engine paths return identical ids.  With
    use_kernel=False the Z tensor comes from the einsum oracle — the
    GSPMD-safe path for mesh-sharded serving.
    """
    if use_kernel:
        Z = batched_z_matrix(C, T, block=block, interpret=interpret)
    else:
        Z = _ref.batched_z_matrix(C, T)
    n = C.shape[1]
    # Exclude the diagonal: Z_ii is mathematically 0 but floats to +-eps.
    offdiag = ~jnp.eye(n, dtype=bool)[None]
    win_mask = (Z < 0) & offdiag
    if valid is not None:
        win_mask = win_mask & valid[:, None, :]   # wins vs real rivals only
    wins = win_mask.sum(axis=-1)
    if valid is not None:
        wins = jnp.where(valid, wins, -1)         # padded slots rank last
    k = min(k, n)
    _, idx = jax.lax.top_k(wins, k)
    return idx.astype(jnp.int32)


# Opt-in kernel profiling (repro.obs, DESIGN.md §13): strict
# passthrough unless a KernelProfiler is active.  batched_top_k_by_wins
# is also traced inside jitted engine code (refine_candidates, the
# sharded refine) — the wrapper detects tracer arguments and records
# only genuine host-initiated calls.  `_cache_size` is preserved for
# the recompile audit.
from ...obs.profiler import instrument as _instrument  # noqa: E402

top_k_by_wins = _instrument("dce_comp.top_k_by_wins", top_k_by_wins)
batched_top_k_by_wins = _instrument("dce_comp.batched_top_k_by_wins",
                                    batched_top_k_by_wins)
