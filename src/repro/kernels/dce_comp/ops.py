"""Jitted public wrappers for the dce_comp kernel: the tournament refine."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import dce_comp as _kernel
from . import ref as _ref

z_matrix = _kernel.z_matrix


@functools.partial(
    jax.jit, static_argnames=("k", "block", "interpret", "use_kernel"))
def top_k_by_wins(
    C: jnp.ndarray,
    t: jnp.ndarray,
    k: int,
    *,
    block: int = _kernel.DEFAULT_BLOCK,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Exact top-k of a DCE-encrypted candidate set (refine phase).

    Ranks the n candidates by pairwise-comparison win counts computed from
    the Pallas Z-matrix kernel.  Exactness: DCE comparisons reflect true
    distances (Theorem 3), so win counts sort identically to distances
    (ties in wins <=> exact distance ties).
    """
    if use_kernel:
        Z = z_matrix(C, t, block=block, interpret=interpret)
    else:
        Z = _ref.z_matrix(C, t)
    # Exclude the diagonal: Z_ii is mathematically 0 but floats to +-eps.
    offdiag = ~jnp.eye(Z.shape[0], dtype=bool)
    wins = ((Z < 0) & offdiag).sum(axis=1)
    k = min(k, C.shape[0])
    _, idx = jax.lax.top_k(wins, k)
    return idx.astype(jnp.int32)
