"""Pure-jnp oracle for the dce_comp kernel."""

from __future__ import annotations

import jax.numpy as jnp


def z_matrix(C: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """All-pairs DCE Z-scores.  C: (n, 4, D), t: (D,) -> (n, n).

    Z[i, j] = DistanceComp(C_i, C_j, t) = 2 r_i r_j r_q (d_i - d_j);
    Z[i, j] < 0  iff  dist(i, q) < dist(j, q).
    """
    C = C.astype(jnp.float32)
    t = t.astype(jnp.float32)
    term1 = (C[:, 0, :] * t) @ C[:, 2, :].T
    term2 = (C[:, 1, :] * t) @ C[:, 3, :].T
    return term1 - term2


def win_counts(C: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    """wins[i] = #{j != i : dist(i,q) < dist(j,q)} — ranking by wins is an
    exact total order because DCE comparisons are exact (Theorem 3).  The
    diagonal is excluded: Z_ii is mathematically 0 but floats to ±eps."""
    Z = z_matrix(C, t)
    n = Z.shape[0]
    offdiag = ~jnp.eye(n, dtype=bool)
    return ((Z < 0) & offdiag).sum(axis=1).astype(jnp.int32)


def top_k_by_wins(C: jnp.ndarray, t: jnp.ndarray, k: int) -> jnp.ndarray:
    """Indices of the k closest candidates (descending win count)."""
    wins = win_counts(C, t)
    return jnp.argsort(-wins)[:k]


def batched_z_matrix(C: jnp.ndarray, T: jnp.ndarray) -> jnp.ndarray:
    """Per-query all-pairs Z tensors.  C: (B, n, 4, D), T: (B, D) ->
    (B, n, n).  Pure-einsum formulation — also the GSPMD-friendly refine
    used under mesh sharding (DESIGN.md §3), where a Pallas call over
    gathered candidates would fight the partitioner."""
    C = C.astype(jnp.float32)
    T = T.astype(jnp.float32)
    left1 = C[:, :, 0, :] * T[:, None, :]
    left2 = C[:, :, 1, :] * T[:, None, :]
    z1 = jnp.einsum("bkd,bjd->bkj", left1, C[:, :, 2, :])
    z2 = jnp.einsum("bkd,bjd->bkj", left2, C[:, :, 3, :])
    return z1 - z2
