"""Pallas TPU kernel: batched DCE DistanceComp tiles (refine-phase hot-spot).

The paper's refine phase walks a max-heap doing one O(d) DistanceComp at a
time.  TPU adaptation (DESIGN.md §3): we compute the *pairwise Z matrix*
of a candidate set in MXU tiles,

    Z[i, j] = (C_i1 ∘ t) . C_j3  -  (C_i2 ∘ t) . C_j4 ,

then rank candidates by win counts — an exact total order because DCE
comparisons are exact (Theorem 3).  Two fused element-wise-scaled matmuls
per tile; the trapdoor scaling (C1 * t) is fused into the kernel rather
than materialized in HBM.

VMEM per grid step (block 128, D = 2d+16 padded to lane multiple; d=960 →
D=2048): 4 operand tiles * 128*2048*4B = 4 MiB + t (8 KiB) + out (64 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import LANE, interpret_default, pad_to, padded_size

DEFAULT_BLOCK = 128


def _z_tile_kernel(c1_ref, c2_ref, c3_ref, c4_ref, t_ref, out_ref):
    """One (block_i, block_j) tile of the Z matrix."""
    t = t_ref[...]                       # (1, D)
    left1 = c1_ref[...] * t              # fused trapdoor scaling
    left2 = c2_ref[...] * t
    term1 = jax.lax.dot_general(
        left1, c3_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    term2 = jax.lax.dot_general(
        left2, c4_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[...] = term1 - term2


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def z_matrix(
    C: jnp.ndarray,
    t: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """All-pairs DCE Z-scores via Pallas tiles.  C: (n, 4, D), t: (D,)."""
    if interpret is None:
        interpret = interpret_default()
    n, four, D = C.shape
    assert four == 4
    Cf = C.astype(jnp.float32)
    tf = t.astype(jnp.float32)[None, :]          # (1, D)

    Cp = pad_to(pad_to(Cf, 0, block), 2, LANE)
    tp = pad_to(tf, 1, LANE)
    n_p, _, D_p = Cp.shape
    comps = [Cp[:, i, :] for i in range(4)]      # (n_p, D_p) each

    grid = (n_p // block, n_p // block)
    out = pl.pallas_call(
        _z_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, D_p), lambda i, j: (i, 0)),   # C1 rows
            pl.BlockSpec((block, D_p), lambda i, j: (i, 0)),   # C2 rows
            pl.BlockSpec((block, D_p), lambda i, j: (j, 0)),   # C3 cols
            pl.BlockSpec((block, D_p), lambda i, j: (j, 0)),   # C4 cols
            pl.BlockSpec((1, D_p), lambda i, j: (0, 0)),       # trapdoor
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_p, n_p), jnp.float32),
        interpret=interpret,
    )(comps[0], comps[1], comps[2], comps[3], tp)
    return out[:n, :n]


def _z_tile_kernel_batched(c1_ref, c2_ref, c3_ref, c4_ref, t_ref, out_ref):
    """One (1, block_i, block_j) tile of the batched Z tensor.

    Identical math to `_z_tile_kernel`, with a leading batch grid dim
    selecting which query's candidate set and trapdoor are resident.
    """
    t = t_ref[...]                       # (1, D)
    left1 = c1_ref[0] * t                # fused trapdoor scaling
    left2 = c2_ref[0] * t
    term1 = jax.lax.dot_general(
        left1, c3_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    term2 = jax.lax.dot_general(
        left2, c4_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[0] = term1 - term2


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def batched_z_matrix(
    C: jnp.ndarray,
    T: jnp.ndarray,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-query all-pairs Z tensors for a batch of candidate sets.

    C: (B, n, 4, D) candidate ciphertexts, T: (B, D) trapdoors
    -> (B, n, n) float32.  One pallas_call with grid (B, n/block, n/block);
    each grid step touches one query's tiles, so VMEM per step matches the
    unbatched kernel (refine candidate sets are small: n = k' ~ O(100)).
    """
    if interpret is None:
        interpret = interpret_default()
    B, n, four, D = C.shape
    assert four == 4
    Cf = C.astype(jnp.float32)
    Tf = T.astype(jnp.float32)

    blk = min(block, max(LANE, padded_size(n, LANE)))
    Cp = pad_to(pad_to(Cf, 1, blk), 3, LANE)
    Tp = pad_to(Tf, 1, LANE)
    _, n_p, _, D_p = Cp.shape
    comps = [Cp[:, :, i, :] for i in range(4)]   # (B, n_p, D_p) each

    grid = (B, n_p // blk, n_p // blk)
    out = pl.pallas_call(
        _z_tile_kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, D_p), lambda b, i, j: (b, i, 0)),  # C1 rows
            pl.BlockSpec((1, blk, D_p), lambda b, i, j: (b, i, 0)),  # C2 rows
            pl.BlockSpec((1, blk, D_p), lambda b, i, j: (b, j, 0)),  # C3 cols
            pl.BlockSpec((1, blk, D_p), lambda b, i, j: (b, j, 0)),  # C4 cols
            pl.BlockSpec((1, D_p), lambda b, i, j: (b, 0)),          # trapdoor
        ],
        out_specs=pl.BlockSpec((1, blk, blk), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, n_p, n_p), jnp.float32),
        interpret=interpret,
    )(comps[0], comps[1], comps[2], comps[3], Tp)
    return out[:, :n, :n]
