"""Pallas TPU kernels for the paper's compute hot-spots.

l2_topk  — filter-phase batched squared-L2 distance tiles + streaming k-NN
adc_topk — quantized-ADC filter scan (int8 / PQ codes) + fused running top-k
dce_comp — refine-phase batched DCE DistanceComp (pairwise Z) tiles

Each kernel directory carries ops.py (jit wrapper) and ref.py (pure-jnp
oracle); tests sweep shapes/dtypes in interpret mode against the oracle.
"""
