"""Shared helpers for the Pallas TPU kernels.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True, per the repo conventions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# MXU/VPU-aligned tile sizes.
LANE = 128
SUBLANE = 8


def interpret_default() -> bool:
    """Run pallas in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def pad_to(x: jnp.ndarray, axis: int, multiple: int,
           value: float = 0.0) -> jnp.ndarray:
    """Right-pad `axis` of x up to a multiple (hardware-aligned shapes)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def padded_size(n: int, multiple: int) -> int:
    return n + ((-n) % multiple)
