"""Shared helpers for the Pallas TPU kernels.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True, per the repo conventions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# MXU/VPU-aligned tile sizes.
LANE = 128
SUBLANE = 8


def interpret_default() -> bool:
    """Run pallas in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"


def next_bucket(n: int, minimum: int = 1, maximum: int | None = None) -> int:
    """Smallest power-of-two bucket >= max(n, minimum), optionally capped.

    Jitted executables are cached per input shape, so callers that see
    ragged sizes (micro-batched query counts, ingestion delta buffers,
    owner-side encryption batches — DESIGN.md §8) pad to bucketed shapes
    and reuse a handful of executables instead of recompiling per size.
    """
    if n < 0:
        raise ValueError(f"negative size {n}")
    b = max(minimum, 1)
    while b < n:
        b <<= 1
    if maximum is not None and b > maximum:
        if n > maximum:
            raise ValueError(f"size {n} exceeds bucket cap {maximum}")
        b = maximum
    return b


def running_topk_scan(dist_fn, n: int, nq: int, k: int, chunk: int):
    """Streaming top-k merge shared by `l2_topk.ops.knn` and the
    adc_topk XLA fallbacks: fold `chunk`-row distance blocks into a
    running (nq, k) ascending state.

    `dist_fn(start)` returns the (nq, chunk) distance block for rows
    [start, start+chunk) of the (padded) database, with invalid rows
    already pushed to +inf/sentinel.  The id mapping avoids ever
    materializing an (nq, chunk) id block: merge positions < k select
    from the running ids, the rest are `start + (pos - k)`.  Returns
    (dists (nq, k) ascending, ids (nq, k) int32; unfilled slots -1).
    """
    n_chunks = -(-n // chunk)

    def body(carry, ci):
        best_d, best_i = carry
        start = ci * chunk
        d_blk = dist_fn(start)
        cat_d = jnp.concatenate([best_d, d_blk], axis=1)
        neg, pos = jax.lax.top_k(-cat_d, k)
        from_best = jnp.take_along_axis(best_i, jnp.minimum(pos, k - 1),
                                        axis=1)
        best_i = jnp.where(pos < k, from_best,
                           start + (pos - k).astype(jnp.int32))
        return (-neg, best_i), None

    init = (jnp.full((nq, k), jnp.inf, jnp.float32),
            jnp.full((nq, k), -1, jnp.int32))
    (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return best_d, best_i


def pad_to(x: jnp.ndarray, axis: int, multiple: int,
           value: float = 0.0) -> jnp.ndarray:
    """Right-pad `axis` of x up to a multiple (hardware-aligned shapes)."""
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def padded_size(n: int, multiple: int) -> int:
    return n + ((-n) % multiple)
