"""Jitted public wrappers for the adc_topk kernel family.

`sq_knn` / `pq_knn` are the quantized analogues of `l2_topk.ops.knn`:
one call scans the whole code array and returns the top-k by ADC
surrogate distance.  With use_kernel=True the fused Pallas scan runs
(codes stream HBM -> VMEM once, the running top-k never leaves VMEM);
with use_kernel=False an XLA formulation of the *same ranking* runs —
the fast path on CPU hosts, where Pallas executes in interpret mode.

Both accept an optional `ok` row-validity vector: invalid rows
(padded bucket slots, tombstones — serving/runtime hands sentinel-
padded power-of-two buffers here) rank last without recompiling as
the valid count changes.

The XLA fallbacks are *chunked* scans with the same running-top-k
merge shape as `l2_topk.ops.knn` (distance block of `chunk` rows,
fold into the (nq, k) state): on CPU hosts this is ~2x faster than a
single-shot matmul + full-width top_k — the top_k over an (nq, n)
row is the bottleneck, not the arithmetic — and it never materializes
the (nq, n) distance matrix either.

The f32 fallback of `sq_knn` is bit-exact w.r.t. the int32 kernel
while the whole surrogate |cn - 2*(q8.c8)| stays below 2^24 — worst
case d <= ~346 (the cross-product alone is exact up to d <= 1040).
Beyond that, near-ties within a few ulp may round together or swap —
absorbed by the ADC oversampling + exact-refine contract (core.adc);
do not write bit-exactness parity tests at larger d.

`sq_pool_scan` / `pq_pool_scan` are the quantized analogues of the
engine's `_masked_pruned_scan` for IVF-pruned candidate pools
(per-query gathers — a gather workload, so they are XLA-only by
design; the Pallas path covers the streaming flat scan).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_to, running_topk_scan
from . import adc_topk as _kernel
from . import ref as _ref  # noqa: F401  (parity tests import through ops)

sq_adc_topk = _kernel.sq_adc_topk
pq_adc_topk = _kernel.pq_adc_topk
INT_BIG = _kernel.INT_BIG

DEFAULT_CHUNK = 8192


def _chunked_scan(dist_fn, n: int, nq: int, k: int, chunk: int,
                  big: float):
    """Shared fallback merge: fold `chunk`-row distance blocks into a
    running (nq, k) top-k via `kernels.common.running_topk_scan`.
    Slots whose distance never dropped below `big` (masked rows, or
    fewer than k valid rows) come back as id -1 — the same empty-slot
    convention as the fused Pallas merge."""
    best_d, best_i = running_topk_scan(dist_fn, n, nq, k, chunk)
    return best_d, jnp.where(best_d >= big, -1, best_i)


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "interpret", "use_kernel"))
def sq_knn(
    q8: jnp.ndarray,
    c8: jnp.ndarray,
    cn: jnp.ndarray,
    k: int,
    *,
    ok: jnp.ndarray | None = None,
    block_n: int = _kernel.DEFAULT_BLOCK_N_SQ,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k by int8 ADC surrogate distance cn - 2*(q8 . c8).

    q8: (nq, d) int8; c8: (n, d) int8; cn: (n,) int32; ok: optional
    (n,) row validity -> (dists (nq, k) ascending, idx (nq, k) int32).
    Kernel path returns int32 distances, fallback f32 — identical
    ranking for small-d surrogates (exactness bound in the module
    docstring).
    """
    nq = q8.shape[0]
    n = c8.shape[0]
    k = min(k, n)
    if ok is None:
        ok = jnp.ones((n,), jnp.int32)
    if use_kernel:
        return _kernel.sq_adc_topk(q8, c8, cn, ok, k, block_n=block_n,
                                   interpret=interpret)
    chunk = min(DEFAULT_CHUNK, n)
    c8p = pad_to(c8, 0, chunk)
    cnp = pad_to(cn.astype(jnp.float32), 0, chunk)
    okp = pad_to(ok.astype(jnp.int32), 0, chunk, value=0)
    qf = q8.astype(jnp.float32)

    def dist_fn(start):
        xs = jax.lax.dynamic_slice_in_dim(c8p, start, chunk, axis=0)
        cs = jax.lax.dynamic_slice_in_dim(cnp, start, chunk, axis=0)
        os_ = jax.lax.dynamic_slice_in_dim(okp, start, chunk, axis=0)
        cross = jax.lax.dot_general(
            qf, xs.astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        return jnp.where(os_[None, :] > 0, cs[None, :] - 2.0 * cross,
                         jnp.float32(INT_BIG))

    return _chunked_scan(dist_fn, n, nq, k, chunk, float(INT_BIG))


@functools.partial(
    jax.jit, static_argnames=("k", "block_n", "interpret", "use_kernel"))
def pq_knn(
    lut: jnp.ndarray,
    codes_t: jnp.ndarray,
    k: int,
    *,
    ok: jnp.ndarray | None = None,
    block_n: int = _kernel.DEFAULT_BLOCK_N_PQ,
    interpret: bool | None = None,
    use_kernel: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k by PQ ADC distance sum_m LUT[m, codes_t[m, i]].

    lut: (nq, m, 256) f32; codes_t: (m, n) uint8; ok: optional (n,)
    row validity -> (dists (nq, k) f32 ascending, idx (nq, k) int32).
    """
    nq = lut.shape[0]
    n = codes_t.shape[1]
    k = min(k, n)
    if ok is None:
        ok = jnp.ones((n,), jnp.int32)
    if use_kernel:
        return _kernel.pq_adc_topk(lut, codes_t, ok, k, block_n=block_n,
                                   interpret=interpret)
    chunk = min(DEFAULT_CHUNK, n)
    ctp = pad_to(codes_t, 1, chunk)
    okp = pad_to(ok.astype(jnp.int32), 0, chunk, value=0)

    def dist_fn(start):
        cs = jax.lax.dynamic_slice_in_dim(ctp, start, chunk, axis=1)
        os_ = jax.lax.dynamic_slice_in_dim(okp, start, chunk, axis=0)
        cc = jnp.broadcast_to(cs.astype(jnp.int32)[None],
                              (nq,) + cs.shape)
        g = jnp.take_along_axis(lut, cc, axis=2)    # (nq, m, chunk)
        return jnp.where(os_[None, :] > 0, g.sum(axis=1), jnp.inf)

    return _chunked_scan(dist_fn, n, nq, k, chunk, float(jnp.inf))


@functools.partial(jax.jit, static_argnames=("kp",))
def sq_pool_scan(c8_dev, cn_dev, q8, cand, valid, kp: int):
    """IVF-pruned int8 ADC scan: per-query gather over probed rows.

    c8_dev: (n, d) int8 codes; cn_dev: (n,) int32; q8: (nq, d) int8;
    cand/valid: (nq, L) pool layout (search_engine.layout_pools)
    -> (ids (nq, kp), valid (nq, kp)) — same contract as the engine's
    `_masked_pruned_scan`.
    """
    rows = jnp.take(c8_dev, cand, axis=0).astype(jnp.float32)
    cn_c = jnp.take(cn_dev, cand).astype(jnp.float32)
    cross = jnp.einsum("qld,qd->ql", rows, q8.astype(jnp.float32))
    d = jnp.where(valid, cn_c - 2.0 * cross, jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (jnp.take_along_axis(cand, pos, axis=1),
            jnp.take_along_axis(valid, pos, axis=1))


@functools.partial(jax.jit, static_argnames=("kp",))
def pq_pool_scan(codes_t, lut, cand, valid, kp: int):
    """IVF-pruned PQ ADC scan (LUT gather over probed rows).

    codes_t: (m, n) uint8; lut: (nq, m, 256) f32; cand/valid: (nq, L)
    -> (ids (nq, kp), valid (nq, kp)).
    """
    cc = jnp.take(codes_t, cand, axis=1)            # (m, nq, L)
    cc = jnp.transpose(cc, (1, 0, 2)).astype(jnp.int32)
    g = jnp.take_along_axis(lut, cc, axis=2)        # (nq, m, L)
    d = jnp.where(valid, g.sum(axis=1), jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (jnp.take_along_axis(cand, pos, axis=1),
            jnp.take_along_axis(valid, pos, axis=1))


@functools.partial(jax.jit, static_argnames=("kp",))
def sq_oblivious_scan(c8_dev, cn_dev, q8, member, kp: int):
    """Scan-oblivious int8 ADC IVF scan (DESIGN.md §14): surrogate
    distances over EVERY code row, masked by per-query pool membership.

    c8_dev: (n, d) int8 codes; cn_dev: (n,) int32; q8: (nq, d) int8;
    member: (nq, n) bool (search_engine.pool_membership) -> (ids
    (nq, kp), valid (nq, kp)).  One constant-shape matmul over the full
    code bucket — no data-dependent gather, so the access pattern
    reveals nothing about the probes.  Member rows get bit-identical
    cn - 2*(q8.c8) values to `sq_pool_scan` (exact int accumulation in
    f32 below 2^24), so the candidate set matches the pruned scan.
    """
    cross = jax.lax.dot_general(
        q8.astype(jnp.float32), c8_dev.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    d = cn_dev.astype(jnp.float32)[None, :] - 2.0 * cross
    d = jnp.where(member, d, jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (pos.astype(jnp.int32),
            jnp.take_along_axis(member, pos, axis=1))


@functools.partial(jax.jit, static_argnames=("kp",))
def pq_oblivious_scan(codes_t, lut, member, kp: int):
    """Scan-oblivious PQ ADC IVF scan: full-bucket LUT accumulation
    masked by per-query pool membership.

    codes_t: (m, n) uint8; lut: (nq, m, 256) f32; member: (nq, n) bool
    -> (ids (nq, kp), valid (nq, kp)).  Same distance values as
    `pq_pool_scan` for member rows, constant access pattern.
    """
    nq = lut.shape[0]
    cc = jnp.broadcast_to(codes_t.astype(jnp.int32)[None],
                          (nq,) + codes_t.shape)
    g = jnp.take_along_axis(lut, cc, axis=2)        # (nq, m, n)
    d = jnp.where(member, g.sum(axis=1), jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (pos.astype(jnp.int32),
            jnp.take_along_axis(member, pos, axis=1))


# Opt-in kernel profiling (repro.obs, DESIGN.md §13): strict
# passthrough unless a KernelProfiler is active; `_cache_size` is
# preserved for the recompile audit.
from ...obs.profiler import instrument as _instrument  # noqa: E402

sq_knn = _instrument("adc_topk.sq_knn", sq_knn)
pq_knn = _instrument("adc_topk.pq_knn", pq_knn)
sq_pool_scan = _instrument("adc_topk.sq_pool_scan", sq_pool_scan)
pq_pool_scan = _instrument("adc_topk.pq_pool_scan", pq_pool_scan)
sq_oblivious_scan = _instrument("adc_topk.sq_oblivious_scan",
                                sq_oblivious_scan)
pq_oblivious_scan = _instrument("adc_topk.pq_oblivious_scan",
                                pq_oblivious_scan)
