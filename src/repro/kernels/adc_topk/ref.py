"""Pure numpy/jnp oracles for the adc_topk kernel family.

Distances here are the *ranking surrogates* the kernels compute, not
squared L2 itself:

  int8 (SQ):  d_i = cn_i - 2 * (q8 . c8_i)   — int32-exact; adding the
              per-query constant ||q8||^2 would give the true symmetric
              quantized distance, but constants do not change top-k.
  pq8  (PQ):  d_i = sum_m LUT[m, codes_t[m, i]] — the classic ADC LUT
              gather-accumulate (f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sq_dists(q8: np.ndarray, c8: np.ndarray, cn: np.ndarray) -> np.ndarray:
    """Symmetric int8 ADC surrogate distances, int32-exact.

    q8: (nq, d) int8 quantized queries; c8: (n, d) int8 codes;
    cn: (n,) int32 code norms  ->  (nq, n) int32.
    """
    cross = q8.astype(np.int32) @ c8.astype(np.int32).T
    return cn[None, :].astype(np.int32) - 2 * cross


def pq_dists(lut: np.ndarray, codes_t: np.ndarray) -> np.ndarray:
    """PQ ADC distances from per-query LUTs.

    lut: (nq, m, 256) f32; codes_t: (m, n) uint8  ->  (nq, n) f32.
    """
    m, n = codes_t.shape
    out = np.zeros((lut.shape[0], n), np.float32)
    for j in range(m):
        out += lut[:, j, codes_t[j].astype(np.int64)]
    return out


def _topk_ascending(d, k: int):
    neg, idx = jax.lax.top_k(-jnp.asarray(d), k)
    return -neg, idx.astype(jnp.int32)


def sq_knn(q8, c8, cn, k: int):
    """Exact top-k (ascending surrogate distance) of the SQ oracle."""
    return _topk_ascending(sq_dists(np.asarray(q8), np.asarray(c8),
                                    np.asarray(cn)), k)


def pq_knn(lut, codes_t, k: int):
    """Exact top-k (ascending surrogate distance) of the PQ oracle."""
    return _topk_ascending(pq_dists(np.asarray(lut), np.asarray(codes_t)),
                           k)
