"""adc_topk — fused quantized-ADC filter scan + running top-k
(DESIGN.md §11).  ops.py holds the jitted wrappers, ref.py the
numpy/jnp oracle; parity is tested in interpret mode."""
