"""Pallas TPU kernels: fused quantized-ADC scan + running top-k.

The filter-phase successor to `l2_topk` for quantized collections
(DESIGN.md §11): distances are computed *from codes* —

  int8 (SQ): cross = q8 . c8 on the MXU's native s8 x s8 -> s32 path,
             surrogate distance  cn - 2*cross  in pure int32;
  pq8  (PQ): per-query LUT (built host-side, resident in VMEM) gathered
             per code via a one-hot MXU matmul — the TPU formulation of
             Faiss-style ADC scanning: a (m*256, bn) one-hot of the code
             tile contracts against the (nq, m*256) flattened LUT, so
             the gather rides the systolic array instead of scatter/
             gather units;

and the per-tile distance block is folded into a *running partial
top-k* kept in the output refs (constant index_map -> the (nq, K)
state lives in VMEM across the whole sequential grid).  Neither the
decoded vectors nor the (nq, chunk) distance block ever round-trips
through HBM — HBM traffic is exactly: codes + the (1, n) row-validity
stream once, plus the final (nq, K) result.

Row validity is *data*, not shape: the `ok` input masks padded bucket
slots and tombstoned rows (serving/runtime mutable stores hand the
kernel sentinel-padded power-of-two buffers), so growing deltas reuse
executables instead of recompiling per row count.

The merge is K rounds of extract-min over the concatenated
[running-K | tile] buffer — pure VPU min/compare/select ops (no
lax.sort / lax.top_k inside the kernel), each round masking the
selected column, so the state stays ascending by construction.

VMEM per grid step (defaults): SQ — q8 (128 x d_p) + c8 tile
(512 x d_p) int8 + int32 state/scratch ~ d_p KiB-scale; PQ — LUT
(128 x m_p*256) f32 = 4 MiB + one-hot (m_p*256 x 128) f32 = 4 MiB.
Both comfortably inside ~16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..common import LANE, interpret_default, pad_to, padded_size

DEFAULT_BLOCK_N_SQ = 512
DEFAULT_BLOCK_N_PQ = 128
INT8_SUBLANE = 32            # min int8/uint8 tile is (32, 128)
PQ_K = 256                   # centroids per subspace (1-byte codes)

INT_BIG = np.int32(2 ** 30)  # sentinel surrogate distance (int32 path)


def _merge_topk(best_d_ref, best_i_ref, d_blk, i_blk, big):
    """Fold a (bq, bn) distance tile into the (bq, K) running top-k.

    K rounds of extract-min over [running | tile]: per round, the
    row-wise min and its first column are found with VPU reductions,
    written into output column t, and masked out of the buffer.  Ties
    resolve to the first column, i.e. the lowest global id (running
    entries precede the tile, and tile columns are ascending ids) —
    the same tie order as `jax.lax.top_k` over the full distance row.
    Exhausted rounds (min already `big`: fewer than K valid rows seen)
    emit id -1, never a duplicate of an already-extracted id — callers
    treat negative ids as empty slots.
    """
    prev_d = best_d_ref[...]
    prev_i = best_i_ref[...]
    bq, K = prev_d.shape
    cat_d = jnp.concatenate([prev_d, d_blk], axis=1)
    cat_i = jnp.concatenate([prev_i, i_blk], axis=1)
    W = cat_d.shape[1]
    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, W), 1)
    kcols = jax.lax.broadcasted_iota(jnp.int32, (bq, K), 1)

    def round_(t, carry):
        cat, out_d, out_i = carry
        m = jnp.min(cat, axis=1, keepdims=True)                 # (bq, 1)
        first = jnp.min(jnp.where(cat == m, cols, W), axis=1,
                        keepdims=True)
        sel = cols == first                                      # one-hot
        mi = jnp.max(jnp.where(sel, cat_i, -1), axis=1, keepdims=True)
        mi = jnp.where(m >= big, -1, mi)         # exhausted: empty slot
        out_d = jnp.where(kcols == t, m, out_d)
        out_i = jnp.where(kcols == t, mi, out_i)
        return jnp.where(sel, big, cat), out_d, out_i

    _, out_d, out_i = jax.lax.fori_loop(
        0, K, round_, (cat_d, jnp.full_like(prev_d, big),
                       jnp.full_like(prev_i, -1)))
    best_d_ref[...] = out_d
    best_i_ref[...] = out_i


def _sq_adc_kernel(q_ref, c_ref, cn_ref, ok_ref, best_d_ref, best_i_ref):
    """One code tile of the int8 scan: s8 MXU dot + top-k merge.

    q_ref: (nq_p, d_p) int8;  c_ref: (bn, d_p) int8;
    cn_ref/ok_ref: (1, bn) int32;  best_*_ref: (nq_p, K) int32 state.
    """
    pi = pl.program_id(0)

    @pl.when(pi == 0)
    def _init():
        best_d_ref[...] = jnp.full(best_d_ref.shape, INT_BIG, jnp.int32)
        best_i_ref[...] = jnp.full(best_i_ref.shape, -1, jnp.int32)

    cross = jax.lax.dot_general(
        q_ref[...], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    d_blk = jnp.where(ok_ref[...] > 0, cn_ref[...] - 2 * cross, INT_BIG)
    bn = d_blk.shape[1]
    gcol = pi * bn + jax.lax.broadcasted_iota(jnp.int32, d_blk.shape, 1)
    _merge_topk(best_d_ref, best_i_ref, d_blk, gcol, INT_BIG)


def _pq_adc_kernel(lut_ref, codes_ref, ok_ref, best_d_ref, best_i_ref):
    """One code tile of the PQ scan: one-hot MXU LUT gather + merge.

    lut_ref: (nq_p, m_p*256) f32 flattened per-query tables (padded
    subspaces hold zeros, so their gathered term vanishes);
    codes_ref: (m_p, bn) uint8 transposed code tile; ok_ref: (1, bn)
    int32 row validity.
    """
    pi = pl.program_id(0)

    @pl.when(pi == 0)
    def _init():
        best_d_ref[...] = jnp.full(best_d_ref.shape, jnp.inf, jnp.float32)
        best_i_ref[...] = jnp.full(best_i_ref.shape, -1, jnp.int32)

    codes = codes_ref[...].astype(jnp.int32)          # (m_p, bn)
    m_p, bn = codes.shape
    rem = jax.lax.broadcasted_iota(jnp.int32, (m_p, PQ_K, bn), 1)
    onehot = (codes[:, None, :] == rem).astype(jnp.float32)
    onehot = onehot.reshape(m_p * PQ_K, bn)
    d_blk = jax.lax.dot_general(
        lut_ref[...], onehot,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # (nq_p, bn)
    d_blk = jnp.where(ok_ref[...] > 0, d_blk, jnp.inf)
    gcol = pi * bn + jax.lax.broadcasted_iota(jnp.int32, d_blk.shape, 1)
    _merge_topk(best_d_ref, best_i_ref, d_blk, gcol, jnp.float32(jnp.inf))


def _pad_ok(ok: jnp.ndarray, n: int, block_n: int) -> jnp.ndarray:
    """(n,) validity -> (1, n_p) int32 with padded slots invalid."""
    row = ok.astype(jnp.int32)[None, :]
    return pad_to(row, 1, block_n, value=0)


@functools.partial(
    jax.jit, static_argnames=("kp", "block_n", "interpret"))
def sq_adc_topk(
    q8: jnp.ndarray,
    c8: jnp.ndarray,
    cn: jnp.ndarray,
    ok: jnp.ndarray,
    kp: int,
    *,
    block_n: int = DEFAULT_BLOCK_N_SQ,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused int8 ADC scan + top-kp.

    q8: (nq, d) int8; c8: (n, d) int8; cn: (n,) int32; ok: (n,) row
    validity -> (dists (nq, kp) int32 ascending, idx (nq, kp) int32).
    Slots beyond the valid-row count come back as id -1 / dist INT_BIG.
    """
    if interpret is None:
        interpret = interpret_default()
    nq, _ = q8.shape
    n = c8.shape[0]
    kp = min(kp, n)
    K = padded_size(max(kp, 1), LANE)

    block_n = max(LANE, min(block_n, padded_size(n, LANE)))
    Qp = pad_to(pad_to(q8, 0, INT8_SUBLANE), 1, LANE)
    Cp = pad_to(pad_to(c8, 0, block_n), 1, LANE)
    cnp = pad_to(cn[None, :].astype(jnp.int32), 1, block_n)
    okp = _pad_ok(ok, n, block_n)
    nq_p, d_p = Qp.shape
    n_p = Cp.shape[0]

    grid = (n_p // block_n,)
    best_d, best_i = pl.pallas_call(
        _sq_adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq_p, d_p), lambda i: (0, 0)),
            pl.BlockSpec((block_n, d_p), lambda i: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((nq_p, K), lambda i: (0, 0)),
            pl.BlockSpec((nq_p, K), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq_p, K), jnp.int32),
            jax.ShapeDtypeStruct((nq_p, K), jnp.int32),
        ],
        interpret=interpret,
    )(Qp, Cp, cnp, okp)
    return best_d[:nq, :kp], best_i[:nq, :kp]


@functools.partial(
    jax.jit, static_argnames=("kp", "block_n", "interpret"))
def pq_adc_topk(
    lut: jnp.ndarray,
    codes_t: jnp.ndarray,
    ok: jnp.ndarray,
    kp: int,
    *,
    block_n: int = DEFAULT_BLOCK_N_PQ,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused PQ ADC scan + top-kp.

    lut: (nq, m, 256) f32 per-query tables; codes_t: (m, n) uint8
    transposed codes; ok: (n,) row validity
    -> (dists (nq, kp) f32 ascending, idx (nq, kp) int32).
    """
    if interpret is None:
        interpret = interpret_default()
    nq, m, pqk = lut.shape
    assert pqk == PQ_K
    n = codes_t.shape[1]
    kp = min(kp, n)
    K = padded_size(max(kp, 1), LANE)

    block_n = max(LANE, min(block_n, padded_size(n, LANE)))
    # pad subspaces: zero LUT rows + code 0 -> padded term gathers 0.0
    lut_p = pad_to(pad_to(lut.astype(jnp.float32), 1, INT8_SUBLANE), 0, 8)
    nq_p, m_p, _ = lut_p.shape
    lut_flat = lut_p.reshape(nq_p, m_p * PQ_K)
    Cp = pad_to(pad_to(codes_t, 0, INT8_SUBLANE), 1, block_n)
    okp = _pad_ok(ok, n, block_n)
    n_p = Cp.shape[1]

    grid = (n_p // block_n,)
    best_d, best_i = pl.pallas_call(
        _pq_adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nq_p, m_p * PQ_K), lambda i: (0, 0)),
            pl.BlockSpec((m_p, block_n), lambda i: (0, i)),
            pl.BlockSpec((1, block_n), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((nq_p, K), lambda i: (0, 0)),
            pl.BlockSpec((nq_p, K), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq_p, K), jnp.float32),
            jax.ShapeDtypeStruct((nq_p, K), jnp.int32),
        ],
        interpret=interpret,
    )(lut_flat, Cp, okp)
    return best_d[:nq, :kp], best_i[:nq, :kp]
