"""repro.obs — observability for the secure serving stack (DESIGN.md §13).

Three independent pieces, composable or standalone:

  * `TraceRecorder` (trace.py): structured per-request span trees,
    deterministic under `VirtualClock`, exported as Chrome-trace JSON
    or a structured event log.
  * `MetricsRegistry` (metrics.py): counters/gauges/histograms with
    Prometheus text exposition.
  * `KernelProfiler` / `profile_kernels` (profiler.py): opt-in
    block-until-ready-fenced timing of the Pallas/XLA kernel entry
    points.

`Observability` bundles all three with one clock, which is what
`SecureAnnService(obs=...)` threads through the runtime.  Everything
is disabled-by-default at the call sites: a collection with no tracer
and no metrics attached records nothing and pays (nearly) nothing.
"""

from __future__ import annotations

import json
import threading

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      DEFAULT_LATENCY_BUCKETS)
from .profiler import (KernelProfiler, active_profiler, instrument,
                       profile_kernels)
from .trace import (NULL_RECORDER, NullRecorder, Span, TraceRecorder,
                    child_complete, child_span, current)

__all__ = [
    "Observability", "start_metrics_server",
    "TraceRecorder", "NullRecorder", "NULL_RECORDER", "Span",
    "child_span", "child_complete", "current",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_LATENCY_BUCKETS",
    "KernelProfiler", "profile_kernels", "instrument", "active_profiler",
]


class Observability:
    """One recorder + one registry + one profiler sharing one clock.

    clock: the runtime `Clock` the schedulers run on (None = wall
    time).  Using the same instance keeps span timestamps, telemetry
    windows, and test virtual time on a single timeline.
    """

    def __init__(self, clock=None, trace_capacity: int = 8192):
        self.clock = clock
        self.recorder = TraceRecorder(clock=clock,
                                      capacity=trace_capacity)
        self.metrics = MetricsRegistry()
        self.profiler = KernelProfiler()

    # convenience passthroughs -------------------------------------

    def metrics_text(self) -> str:
        return self.metrics.prometheus_text()

    def chrome_trace(self) -> dict:
        return self.recorder.to_chrome_trace()

    def export_chrome_trace(self, path) -> str:
        """Write Perfetto-loadable JSON; returns the path written."""
        payload = json.dumps(self.chrome_trace(), indent=1)
        with open(path, "w") as fh:
            fh.write(payload + "\n")
        return str(path)

    def events(self) -> list[dict]:
        return self.recorder.to_events()


def start_metrics_server(source, port: int, host: str = ""):
    """Serve `source.metrics_text()` (an `Observability`, a
    `MetricsRegistry`-like object, or anything with that method) at
    http://host:port/metrics on a daemon thread.  Returns the
    `HTTPServer`; call `.shutdown()` to stop.  Port 0 picks a free
    port (read it back from `server.server_address[1]`)."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):                           # noqa: N802
            if self.path.rstrip("/") not in ("", "/metrics"):
                self.send_error(404)
                return
            body = source.metrics_text().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                  # silence stderr
            pass

    server = ThreadingHTTPServer((host, int(port)), _Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="obs-metrics", daemon=True)
    thread.start()
    server._obs_thread = thread
    return server
