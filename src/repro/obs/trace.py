"""Structured per-request tracing for the serving stack (DESIGN.md §13).

One `TraceRecorder` per service (or per collection): a clock-injected,
ring-buffered, thread-safe span store.  Spans form per-trace trees —
one trace per request (`request` root with `queue`/`flush`|`slot`/
`emit` children), one trace per batched engine call (`flush`/`step`
root with `filter`/`refine` children, linked to the requests that rode
it by a `batch` attribute), one trace per ingest operation.

Three properties the rest of the repo depends on:

  * **Deterministic under `VirtualClock`** — the recorder never reads
    wall time itself; it asks the injected clock, the same instance the
    schedulers run on, so tests assert exact span trees (structure,
    attributes, and virtual timestamps) for scripted interleavings.
  * **Near-free when disabled** — nothing in the hot path allocates or
    locks when no recorder is attached: `child_span()` is a single
    contextvar read returning a shared no-op span, and the schedulers
    guard every recording call on `tracer is not None`.
  * **No plaintext leakage** — spans carry ids, counts, byte totals,
    and backend names.  They never carry query or database ciphertext
    material (let alone plaintexts); the trace of a search is exactly
    the accounting the paper's §V-C communication model already makes
    public to the server.

Exports: Chrome-trace/Perfetto JSON (`to_chrome_trace`) and a
structured event log (`to_events`).
"""

from __future__ import annotations

import contextvars
import itertools
import threading
import time
from collections import deque

__all__ = ["Span", "TraceRecorder", "NullRecorder", "NULL_RECORDER",
           "child_span", "child_complete", "current"]


class Span:
    """One timed, attributed node of a trace tree.  Usable as a context
    manager when produced by `TraceRecorder.span` (closes itself and
    pops the ambient-context stack on exit)."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t_start",
                 "t_end", "attrs", "_recorder", "_token")

    def __init__(self, name: str, trace_id: str, span_id: int,
                 parent_id: int | None, t_start: float,
                 t_end: float | None = None, attrs: dict | None = None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.t_end = t_end
        self.attrs = dict(attrs or {})
        self._recorder = None
        self._token = None

    def set(self, **attrs):
        """Attach attributes after the fact (e.g. counters only known
        once the spanned work completed)."""
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        return 0.0 if self.t_end is None else self.t_end - self.t_start

    def to_dict(self) -> dict:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "t_start": self.t_start, "t_end": self.t_end,
                "attrs": dict(self.attrs)}

    def __repr__(self):
        return (f"Span({self.name!r}, trace={self.trace_id!r}, "
                f"id={self.span_id}, parent={self.parent_id}, "
                f"[{self.t_start}, {self.t_end}], {self.attrs})")

    # -------------------------------------------------- context manager

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._recorder is not None:
            if exc is not None:
                self.attrs.setdefault("error", repr(exc))
            self._recorder._close_cm_span(self)
        return False


class _NullSpan:
    """Shared no-op span: what `child_span` hands out when no recorder
    context is active.  Stateless, so one instance serves every caller
    concurrently."""

    __slots__ = ()

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()

# Ambient (recorder, open span) for the current thread of execution —
# how the engine's filter/refine spans find the scheduler's batch span
# without threading a recorder through every signature.
_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_ctx", default=None)


def current():
    """The ambient (recorder, span) pair, or None."""
    return _CTX.get()


def child_span(name: str, **attrs):
    """Open a child span under the ambient context; a shared no-op span
    when there is none (one contextvar read — the disabled-mode cost)."""
    ctx = _CTX.get()
    if ctx is None:
        return _NULL_SPAN
    recorder, parent = ctx
    return recorder.span(name, trace_id=parent.trace_id, parent=parent,
                         **attrs)


def child_complete(name: str, t_start: float | None = None,
                   t_end: float | None = None, **attrs):
    """Record an already-finished child span under the ambient context
    (e.g. per-shard accounting emitted after a collective completes).
    Default interval: the ambient span's start -> now."""
    ctx = _CTX.get()
    if ctx is None:
        return None
    recorder, parent = ctx
    now = recorder._now()
    return recorder.add_span(
        name, parent.trace_id,
        parent.t_start if t_start is None else t_start,
        now if t_end is None else t_end,
        parent=parent, **attrs)


class TraceRecorder:
    """Thread-safe ring-buffered span/event recorder.

    clock: any object with `now() -> float` seconds (the runtime's
    `Clock` seam fits); None falls back to `time.monotonic`.  Pass the
    SAME clock instance the schedulers run on, so one timeline covers
    the whole request path.
    capacity: completed spans (and events) kept — oldest evicted first.
    """

    def __init__(self, clock=None, capacity: int = 8192):
        self._now = time.monotonic if clock is None else clock.now
        self._lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=int(capacity))
        self._events: deque[dict] = deque(maxlen=int(capacity))
        self._ids = itertools.count(1)
        self.enabled = True

    # ---------------------------------------------------------- writing

    def start_span(self, name: str, trace_id: str,
                   parent: Span | None = None, **attrs) -> Span:
        """Open a span; it is stored only once `end_span` closes it."""
        return Span(name, trace_id, next(self._ids),
                    None if parent is None else parent.span_id,
                    self._now(), attrs=attrs)

    def end_span(self, span: Span, **attrs) -> Span:
        if span.t_end is not None:      # idempotent: error paths may
            return span                 # race a regular close
        span.t_end = self._now()
        if attrs:
            span.attrs.update(attrs)
        with self._lock:
            self._spans.append(span)
        return span

    def add_span(self, name: str, trace_id: str, t_start: float,
                 t_end: float, parent: Span | None = None,
                 **attrs) -> Span:
        """Record a completed span retroactively (the schedulers stamp
        queue/emit intervals after the fact from clock readings they
        already took)."""
        span = Span(name, trace_id, next(self._ids),
                    None if parent is None else parent.span_id,
                    float(t_start), float(t_end), attrs)
        with self._lock:
            self._spans.append(span)
        return span

    def event(self, name: str, trace_id: str = "", **attrs) -> dict:
        ev = {"name": name, "trace_id": trace_id, "t": self._now(),
              "attrs": attrs}
        with self._lock:
            self._events.append(ev)
        return ev

    def span(self, name: str, trace_id: str, parent: Span | None = None,
             **attrs) -> Span:
        """Context-manager span: opens now, closes (and records) on
        exit, and publishes itself as the ambient context so nested
        `child_span` calls attach underneath."""
        sp = self.start_span(name, trace_id, parent=parent, **attrs)
        sp._recorder = self
        sp._token = _CTX.set((self, sp))
        return sp

    def _close_cm_span(self, span: Span):
        if span._token is not None:
            _CTX.reset(span._token)
            span._token = None
        span._recorder = None
        self.end_span(span)

    # ---------------------------------------------------------- reading

    def spans(self, trace_id: str | None = None) -> list[Span]:
        with self._lock:
            out = list(self._spans)
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def trace_ids(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self.spans():
            seen.setdefault(s.trace_id, None)
        return list(seen)

    def tree(self, trace_id: str) -> list[dict]:
        """The trace's span forest as nested dicts (children ordered by
        start time, then record order) — what tests assert exactly."""
        spans = sorted(self.spans(trace_id),
                       key=lambda s: (s.t_start, s.span_id))
        nodes = {s.span_id: {"name": s.name, "attrs": dict(s.attrs),
                             "t_start": s.t_start, "t_end": s.t_end,
                             "children": []} for s in spans}
        roots = []
        for s in spans:
            node = nodes[s.span_id]
            if s.parent_id in nodes:
                nodes[s.parent_id]["children"].append(node)
            else:
                roots.append(node)
        return roots

    def clear(self):
        with self._lock:
            self._spans.clear()
            self._events.clear()

    # ---------------------------------------------------------- exports

    def to_events(self) -> list[dict]:
        """Structured event log: every completed span (+ instant events)
        as plain dicts, in record order."""
        with self._lock:
            spans = list(self._spans)
            events = list(self._events)
        return ([dict(s.to_dict(), kind="span") for s in spans]
                + [dict(e, kind="event") for e in events])

    def to_chrome_trace(self) -> dict:
        """Chrome-trace / Perfetto JSON: one complete ("X") event per
        span, traces mapped to tids (named via "M" metadata events),
        instant ("i") events for point events.  `json.dump` the return
        value and load it in ui.perfetto.dev or chrome://tracing."""
        with self._lock:
            spans = list(self._spans)
            events = list(self._events)
        tids: dict[str, int] = {}

        def tid(trace_id: str) -> int:
            if trace_id not in tids:
                tids[trace_id] = len(tids) + 1
            return tids[trace_id]

        out = []
        for s in spans:
            out.append({
                "name": s.name, "ph": "X", "pid": 1,
                "tid": tid(s.trace_id),
                "ts": round(s.t_start * 1e6, 3),
                "dur": round(max(0.0, s.duration) * 1e6, 3),
                "args": {k: _jsonable(v) for k, v in s.attrs.items()},
            })
        for e in events:
            out.append({
                "name": e["name"], "ph": "i", "s": "t", "pid": 1,
                "tid": tid(e["trace_id"] or "events"),
                "ts": round(e["t"] * 1e6, 3),
                "args": {k: _jsonable(v) for k, v in e["attrs"].items()},
            })
        meta = [{"name": "thread_name", "ph": "M", "pid": 1, "tid": t,
                 "args": {"name": trace}} for trace, t in tids.items()]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}


def _jsonable(v):
    """Span attrs may carry numpy scalars; Chrome-trace args must be
    plain JSON values."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, float):
        return v
    try:
        return int(v)
    except (TypeError, ValueError):
        try:
            return float(v)
        except (TypeError, ValueError):
            return str(v)


class NullRecorder:
    """The disabled-mode recorder: the full `TraceRecorder` surface as
    no-ops.  Handy when a caller wants to thread one object through
    unconditionally; the schedulers instead skip recording entirely on
    `tracer is None`, which is cheaper still."""

    enabled = False

    def start_span(self, name, trace_id, parent=None, **attrs):
        return _NULL_SPAN

    def end_span(self, span, **attrs):
        return span

    def add_span(self, name, trace_id, t_start, t_end, parent=None,
                 **attrs):
        return _NULL_SPAN

    def event(self, name, trace_id="", **attrs):
        return None

    def span(self, name, trace_id, parent=None, **attrs):
        return _NULL_SPAN

    def spans(self, trace_id=None):
        return []

    def trace_ids(self):
        return []

    def tree(self, trace_id):
        return []

    def clear(self):
        pass

    def to_events(self):
        return []

    def to_chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms"}


NULL_RECORDER = NullRecorder()
