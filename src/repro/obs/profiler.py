"""Opt-in timed wrappers around the Pallas/XLA kernel entry points.

The three kernel families (`l2_topk`, `dce_comp`, `adc_topk`) expose
jitted module-level functions; each `ops.py` rebinds them through
`instrument(name, fn)` at import time.  The wrapper is a strict
passthrough — zero recording, one module-global read — unless a
`KernelProfiler` has been activated via `profile_kernels()`.

When active, each call is fenced with `jax.block_until_ready` and
timed on the host (on CPU/single-stream TPU this equals device time;
with async dispatch it is an upper bound that includes dispatch), and
the positional-argument `.nbytes` sum is recorded as bytes touched.
Two correctness subtleties the wrapper must preserve:

  * `batched_top_k_by_wins` is ALSO called inside jitted engine code
    (`refine_candidates`, `_sharded_refine`).  During tracing its args
    are `jax.core.Tracer`s and blocking would be meaningless — the
    wrapper detects tracer args and passes straight through, so only
    genuine op-level (host-initiated) calls are recorded.
  * `jit_cache_size()` introspects `fn._cache_size` on these entry
    points for the recompile audit — the wrapper copies it through.

`profile_kernels()` also opens a `jax.profiler.TraceAnnotation` around
each recorded call so the ops show up named in a `jax.profiler` deep
dive when one is being captured.
"""

from __future__ import annotations

import contextlib
import functools
import threading
import time

__all__ = ["KernelProfiler", "profile_kernels", "instrument",
           "active_profiler"]

# The single active profiler (None = disabled). One module-global read
# on the hot path; writes only via profile_kernels().
_ACTIVE: "KernelProfiler | None" = None
_ACTIVE_LOCK = threading.Lock()


class KernelProfiler:
    """Per-kernel call/time/bytes accumulator."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stats: dict[str, dict] = {}

    def record(self, name: str, seconds: float, nbytes: int):
        with self._lock:
            s = self._stats.setdefault(
                name, {"calls": 0, "total_s": 0.0, "total_bytes": 0})
            s["calls"] += 1
            s["total_s"] += seconds
            s["total_bytes"] += nbytes

    def summary(self) -> dict[str, dict]:
        """{kernel name: {calls, total_s, total_bytes}} snapshot."""
        with self._lock:
            return {k: dict(v) for k, v in self._stats.items()}

    def reset(self):
        with self._lock:
            self._stats.clear()

    def total_seconds(self, prefix: str = "") -> float:
        return sum(v["total_s"] for k, v in self.summary().items()
                   if k.startswith(prefix))

    def total_bytes(self, prefix: str = "") -> int:
        return sum(v["total_bytes"] for k, v in self.summary().items()
                   if k.startswith(prefix))


def active_profiler() -> KernelProfiler | None:
    return _ACTIVE


@contextlib.contextmanager
def profile_kernels(profiler: KernelProfiler | None = None):
    """Activate kernel profiling for the dynamic extent of the block.

        with profile_kernels() as prof:
            engine.search_batch(Q, T, k)
        prof.summary()  # {"adc_topk.sq_knn": {...}, "dce_comp...": ...}

    Not reentrant across threads by design: one global profiler keeps
    the disabled path to a single load; nested activations stack.
    """
    global _ACTIVE
    prof = profiler if profiler is not None else KernelProfiler()
    with _ACTIVE_LOCK:
        prev = _ACTIVE
        _ACTIVE = prof
    try:
        yield prof
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev


def _args_nbytes(args) -> int:
    total = 0
    for a in args:
        nb = getattr(a, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total


def instrument(name: str, fn):
    """Wrap a jitted kernel entry point with the opt-in timer."""
    import jax

    tracer_cls = jax.core.Tracer
    annotation = getattr(jax.profiler, "TraceAnnotation", None)

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        prof = _ACTIVE
        if prof is None or any(isinstance(a, tracer_cls) for a in args):
            return fn(*args, **kwargs)
        ctx = annotation(name) if annotation is not None \
            else contextlib.nullcontext()
        t0 = time.perf_counter()
        with ctx:
            out = jax.block_until_ready(fn(*args, **kwargs))
        prof.record(name, time.perf_counter() - t0, _args_nbytes(args))
        return out

    # jit_cache_size() (telemetry.py) audits recompiles through this
    # attribute — it must survive the wrap.
    if hasattr(fn, "_cache_size"):
        wrapper._cache_size = fn._cache_size
    wrapper.__wrapped__ = fn
    return wrapper
