"""Counters/gauges/histograms with Prometheus text exposition.

A `MetricsRegistry` aggregates across collections: each instrument is
registered once by name and fans out per label-set (tenant/collection/
backend/...).  Fixed-bucket histograms replace the reservoir-only
percentiles of `CollectionTelemetry` for cross-collection aggregation —
bucket counts sum across label-sets and scrape intervals, reservoirs do
not.

Everything is lock-protected and allocation-light: `inc`/`set`/
`observe` take one dict lookup + one lock.  When no registry is
attached the callers skip the calls entirely (see telemetry.py), so
disabled mode pays nothing here.

`prometheus_text()` renders the standard text exposition format
(HELP/TYPE headers, label escaping, cumulative `_bucket{le=...}` +
`_sum`/`_count` per histogram series) suitable for a Prometheus scrape
of `launch/serve.py --metrics-port` or `SecureAnnService.metrics_text()`.
"""

from __future__ import annotations

import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_LATENCY_BUCKETS"]

# Request latencies from sub-ms kernel calls to multi-second cold
# compiles; seconds, matching Prometheus convention.
DEFAULT_LATENCY_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(label_names: tuple, labels: dict) -> tuple:
    return tuple(str(labels.get(n, "")) for n in label_names)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt_labels(names: tuple, values: tuple, extra: str = "") -> str:
    parts = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


class _Instrument:
    def __init__(self, name: str, help_text: str, label_names: tuple):
        self.name = name
        self.help = help_text
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()


class Counter(_Instrument):
    kind = "counter"

    def __init__(self, name, help_text="", label_names=()):
        super().__init__(name, help_text, label_names)
        self._values: dict[tuple, float] = {}

    def inc(self, v: float = 1, **labels):
        key = _label_key(self.label_names, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + v

    def value(self, **labels) -> float:
        key = _label_key(self.label_names, labels)
        with self._lock:
            return self._values.get(key, 0)

    def expose(self) -> list[str]:
        with self._lock:
            items = sorted(self._values.items())
        return [f"{self.name}{_fmt_labels(self.label_names, k)} "
                f"{_fmt_num(v)}" for k, v in items]


class Gauge(_Instrument):
    kind = "gauge"

    def __init__(self, name, help_text="", label_names=()):
        super().__init__(name, help_text, label_names)
        self._values: dict[tuple, float] = {}

    def set(self, v: float, **labels):
        key = _label_key(self.label_names, labels)
        with self._lock:
            self._values[key] = v

    def inc(self, v: float = 1, **labels):
        key = _label_key(self.label_names, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + v

    def value(self, **labels) -> float:
        key = _label_key(self.label_names, labels)
        with self._lock:
            return self._values.get(key, 0)

    def expose(self) -> list[str]:
        with self._lock:
            items = sorted(self._values.items())
        return [f"{self.name}{_fmt_labels(self.label_names, k)} "
                f"{_fmt_num(v)}" for k, v in items]


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name, help_text="", label_names=(),
                 buckets=DEFAULT_LATENCY_BUCKETS):
        super().__init__(name, help_text, label_names)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        # per label-set: [bucket counts..., +Inf count], sum
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def observe(self, v: float, **labels):
        key = _label_key(self.label_names, labels)
        v = float(v)
        with self._lock:
            counts = self._counts.get(key)
            if counts is None:
                counts = self._counts[key] = [0] * (len(self.buckets) + 1)
                self._sums[key] = 0.0
            for i, ub in enumerate(self.buckets):
                if v <= ub:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            self._sums[key] += v

    def snapshot(self, **labels):
        """(cumulative bucket counts keyed by upper bound, sum, count)."""
        key = _label_key(self.label_names, labels)
        with self._lock:
            counts = list(self._counts.get(key, []))
            total_sum = self._sums.get(key, 0.0)
        if not counts:
            counts = [0] * (len(self.buckets) + 1)
        cum, acc = {}, 0
        for ub, c in zip(self.buckets, counts):
            acc += c
            cum[ub] = acc
        cum[math.inf] = acc + counts[-1]
        return cum, total_sum, cum[math.inf]

    def quantile(self, q: float, **labels) -> float:
        """Bucket-interpolated quantile (upper bound of the bucket the
        q-th observation falls in) — coarse but aggregation-safe."""
        cum, _, count = self.snapshot(**labels)
        if count == 0:
            return 0.0
        rank = q * count
        for ub, c in cum.items():
            if c >= rank:
                return self.buckets[-1] if ub == math.inf else ub
        return self.buckets[-1]

    def expose(self) -> list[str]:
        with self._lock:
            keys = sorted(self._counts)
        lines = []
        for key in keys:
            cum, total_sum, count = self.snapshot(
                **dict(zip(self.label_names, key)))
            for ub, c in cum.items():
                le = _fmt_labels(self.label_names, key,
                                 f'le="{_fmt_num(ub)}"')
                lines.append(f"{self.name}_bucket{le} {c}")
            base = _fmt_labels(self.label_names, key)
            lines.append(f"{self.name}_sum{base} {_fmt_num(total_sum)}")
            lines.append(f"{self.name}_count{base} {count}")
        return lines


class MetricsRegistry:
    """Get-or-create instrument registry with one text exposition."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    def _get(self, cls, name, help_text, label_names, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help_text, tuple(label_names), **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{inst.kind}, not {cls.kind}")
            return inst

    def counter(self, name, help_text="", labels=()) -> Counter:
        return self._get(Counter, name, help_text, labels)

    def gauge(self, name, help_text="", labels=()) -> Gauge:
        return self._get(Gauge, name, help_text, labels)

    def histogram(self, name, help_text="", labels=(),
                  buckets=DEFAULT_LATENCY_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help_text, labels,
                         buckets=buckets)

    def get(self, name) -> _Instrument | None:
        with self._lock:
            return self._instruments.get(name)

    def prometheus_text(self) -> str:
        with self._lock:
            instruments = sorted(self._instruments.items())
        lines = []
        for name, inst in instruments:
            if inst.help:
                lines.append(f"# HELP {name} {inst.help}")
            lines.append(f"# TYPE {name} {inst.kind}")
            lines.extend(inst.expose())
        return "\n".join(lines) + "\n"
