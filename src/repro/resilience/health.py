"""Shard-replica health registry (DESIGN.md §16).

One (n_shards, n_replicas) boolean up-matrix behind a lock, plus a
monotonic epoch that bumps on every transition — the epoch is the cache
key the sharded backend uses to rebuild its row-serve masks only when
health actually changed, keeping the healthy steady state allocation-
and recompile-free.

Semantics (simulated single-host mesh: replicas are logical copies of a
shard's row block, one physical array):

  * a shard *group* is servable while >= 1 of its replicas is up;
  * `serve_mask()[s]` is False only when every replica of shard s is
    down — exactly the shards whose rows degraded-mode answers omit;
  * `n_groups_down` / `degraded` feed `SearchStats.n_shards_down` /
    `SearchStats.degraded` on every answer served while unhealthy.
"""

from __future__ import annotations

import threading

import numpy as np

__all__ = ["ShardHealthRegistry"]


class ShardHealthRegistry:
    """Thread-safe up/down state for an (n_shards x n_replicas) group."""

    def __init__(self, n_shards: int, n_replicas: int = 1):
        if n_shards < 1 or n_replicas < 1:
            raise ValueError("n_shards and n_replicas must be >= 1")
        self.n_shards = int(n_shards)
        self.n_replicas = int(n_replicas)
        self._up = np.ones((self.n_shards, self.n_replicas), dtype=bool)
        self._lock = threading.Lock()
        self.epoch = 0

    def _check(self, shard: int, replica: int):
        if not (0 <= shard < self.n_shards):
            raise ValueError(f"shard {shard} out of range "
                             f"[0, {self.n_shards})")
        if not (0 <= replica < self.n_replicas):
            raise ValueError(f"replica {replica} out of range "
                             f"[0, {self.n_replicas})")

    def kill(self, shard: int, replica: int = 0) -> None:
        self._check(shard, replica)
        with self._lock:
            if self._up[shard, replica]:
                self._up[shard, replica] = False
                self.epoch += 1

    def revive(self, shard: int, replica: int = 0) -> None:
        self._check(shard, replica)
        with self._lock:
            if not self._up[shard, replica]:
                self._up[shard, replica] = True
                self.epoch += 1

    def is_up(self, shard: int, replica: int = 0) -> bool:
        self._check(shard, replica)
        with self._lock:
            return bool(self._up[shard, replica])

    def serve_mask(self) -> np.ndarray:
        """(n_shards,) bool: True where >= 1 replica is up."""
        with self._lock:
            return self._up.any(axis=1).copy()

    @property
    def n_groups_down(self) -> int:
        with self._lock:
            return int((~self._up.any(axis=1)).sum())

    @property
    def n_replicas_down(self) -> int:
        with self._lock:
            return int((~self._up).sum())

    @property
    def degraded(self) -> bool:
        """True when at least one shard group has no live replica —
        answers omit those rows and must say so."""
        return self.n_groups_down > 0

    @property
    def healthy(self) -> bool:
        """True when every replica of every shard is up."""
        with self._lock:
            return bool(self._up.all())

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "epoch": self.epoch,
                "up": self._up.copy(),
                "n_groups_down": int((~self._up.any(axis=1)).sum()),
                "n_replicas_down": int((~self._up).sum()),
            }
