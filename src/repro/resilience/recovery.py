"""Kill-restart recovery: checkpoint restore + WAL replay (DESIGN.md
§16).

The recovery contract: every mutation the old process *acknowledged* is
either inside the checkpoint (its seq <= the checkpoint's `wal_seq`) or
an fsync'd WAL record after it — so

    recover() = load checkpoint + replay records with seq > wal_seq

reproduces the acknowledged state bit-identically, including tombstone
layout, main/delta split, and generation counters.  Replay drives the
collection's *public* mutation methods with the WAL detached, so
derived state (auto-compaction thresholds, IVF delta assignment, graph
repair order) re-derives exactly as it did live; the WAL is re-attached
afterwards so post-recovery mutations keep logging with contiguous
sequence numbers.
"""

from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .checkpoint import restore_collection_state
from .wal import WriteAheadLog

__all__ = ["recover", "RecoveryReport", "attach_wal"]


@dataclass(frozen=True)
class RecoveryReport:
    """What `recover()` did — the numbers the recovery-time benchmark
    and the durability sweep assert on."""
    had_checkpoint: bool
    checkpoint_seq: int             # wal_seq the checkpoint covered (0 = none)
    n_replayed: int                 # WAL records applied
    n_rows_replayed: int            # rows inserted/deleted by replay
    last_seq: int                   # WAL position after recovery


def attach_wal(collection, wal: WriteAheadLog) -> None:
    """Attach a WAL to a live collection: from now on every acknowledged
    insert/delete/compact appends one durable record before the ack."""
    collection.attach_wal(wal)


def recover(make_collection, *, checkpoint_path=None, wal_dir=None,
            attach: bool = True):
    """Rebuild a collection after a kill.

    make_collection: zero-arg factory returning a fresh, empty
        collection with the same spec the dead process ran (backend,
        seed, placement, compact_every — recovery replays through the
        public mutation path, so derived state needs the same knobs).
    checkpoint_path: the `AsyncCheckpointer` target (may not exist yet
        — recovery then replays the WAL from the beginning).
    wal_dir: the `WriteAheadLog` directory (may be empty/missing).
    attach: re-attach the WAL to the recovered collection so new
        mutations keep logging (pass False for read-only forensics).

    Returns (collection, RecoveryReport).
    """
    col = make_collection()
    had_checkpoint = False
    after_seq = 0
    if checkpoint_path is not None and Path(checkpoint_path).exists():
        meta = restore_collection_state(
            col, Path(checkpoint_path).read_bytes())
        after_seq = int(meta.get("wal_seq", 0))
        had_checkpoint = True
    n_replayed = 0
    n_rows = 0
    wal = None
    if wal_dir is not None:
        wal = WriteAheadLog(wal_dir)
        for rec in wal.replay(after_seq=after_seq):
            if rec.op == "insert":
                col.insert_encrypted(rec.arrays["C_sap"],
                                     rec.arrays["C_dce"])
            elif rec.op == "delete":
                col.delete(np.asarray(rec.arrays["rows"], np.int64))
            elif rec.op == "compact":
                col.compact()
            else:
                raise ValueError(f"unknown WAL op {rec.op!r} "
                                 f"(seq {rec.seq})")
            n_replayed += 1
            n_rows += rec.n_rows
    report = RecoveryReport(
        had_checkpoint=had_checkpoint, checkpoint_seq=after_seq,
        n_replayed=n_replayed, n_rows_replayed=n_rows,
        last_seq=wal.last_seq if wal is not None else after_seq)
    telemetry = getattr(col, "telemetry", None)
    if telemetry is not None and n_replayed:
        telemetry.record_wal_replay(n_replayed)
    if wal is not None:
        if attach:
            col.attach_wal(wal)
        else:
            wal.close()
    return col, report
