"""Async background `.ppcol` checkpointing (DESIGN.md §16).

A checkpoint is `Collection.snapshot()` — which copies every array
*under the collection lock*, the copy-on-write step — serialized to one
versioned wireformat blob (kind "ppcol-checkpoint") and written
tmp + `os.replace`, so a crash mid-checkpoint leaves the previous
checkpoint intact.  The expensive parts (serialization, disk write,
fsync) run on a background thread: the serving path blocks only for the
in-memory array copies, never for I/O.

The snapshot's bookkeeping carries `wal_seq` — the WAL sequence number
of the last mutation the captured state includes, read under the same
lock hold — so recovery replays exactly the records after it, and a
durable checkpoint lets `WriteAheadLog.truncate_through(wal_seq)` drop
the log prefix it made redundant.
"""

from __future__ import annotations

import os
import threading
from pathlib import Path

import numpy as np

from ..core.wireformat import pack, unpack

__all__ = ["AsyncCheckpointer", "collection_state_bytes",
           "restore_collection_state", "CHECKPOINT_KIND",
           "CHECKPOINT_VERSION"]

CHECKPOINT_KIND = "ppcol-checkpoint"
CHECKPOINT_VERSION = 1


def collection_state_bytes(collection) -> bytes:
    """One self-contained checkpoint blob for a collection (arrays +
    bookkeeping, including `wal_seq` when a WAL is attached)."""
    arrays, bookkeeping = collection.snapshot()
    return pack(CHECKPOINT_KIND, CHECKPOINT_VERSION, arrays=arrays,
                meta=bookkeeping)


def restore_collection_state(collection, data: bytes) -> dict:
    """Load a checkpoint blob into an (empty, compatibly-specced)
    collection via `load_snapshot`; returns the bookkeeping meta (the
    caller reads `wal_seq` off it to know where replay starts).  The
    graph/ivf/adc sidecar decode mirrors `SecureAnnService.load` — the
    filter state that is not a pure function of the store rides the
    same prefixed arrays in both formats."""
    arrays, meta = unpack(data, CHECKPOINT_KIND, CHECKPOINT_VERSION)
    graph_arrays = {k[len("graph__"):]: v for k, v in arrays.items()
                    if k.startswith("graph__")} or None
    ivf_state = None
    if "ivf__centroids" in arrays:
        ivf_state = {
            "centroids": arrays["ivf__centroids"],
            "list_flat": arrays["ivf__list_flat"],
            "list_offsets": arrays["ivf__list_offsets"],
            "built_upto": meta["ivf_built_upto"],
            "attached_gen": meta["ivf_attached_gen"],
        }
    adc_arrays = {k[len("adc__"):]: v for k, v in arrays.items()
                  if k.startswith("adc__")}
    adc_state = ({"arrays": adc_arrays,
                  "trained_gen": meta["adc_trained_gen"]}
                 if adc_arrays else None)
    collection.load_snapshot(
        arrays["C_sap"], arrays["C_dce"],
        alive=np.asarray(arrays["alive"], bool),
        n_main=int(meta["n_main"]), main_gen=int(meta["main_gen"]),
        graph_arrays=graph_arrays, ivf_state=ivf_state,
        adc_state=adc_state)
    return dict(meta)


class AsyncCheckpointer:
    """Background checkpoint writer for one collection.

    `trigger()` captures the snapshot synchronously (array copies under
    the collection lock — the only part that can block a mutation) and
    hands serialization + tmp-write + `os.replace` + WAL truncation to
    a worker thread; it returns that thread so tests and shutdown paths
    can `join()`.  Checkpoints are serialized with respect to each
    other: a trigger while the previous write is in flight joins it
    first, so the newest state always wins the `os.replace`.

    `note_ops(n)` is the ops-count trigger seam: with `every_n_ops`
    set, the collection-side caller reports acknowledged mutations and
    a checkpoint fires automatically each time the counter crosses the
    interval — the knob the checkpoint-interval-vs-replay-cost curve in
    `benchmarks/bench_resilience.py` sweeps.
    """

    def __init__(self, collection, path, *, wal=None,
                 every_n_ops: int | None = None):
        self.collection = collection
        self.path = Path(path)
        self.wal = wal if wal is not None \
            else getattr(collection, "_wal", None)
        self.every_n_ops = every_n_ops
        self._ops_since = 0
        self._worker: threading.Thread | None = None
        self._trigger_lock = threading.Lock()
        self.n_checkpoints = 0
        self.n_segments_truncated = 0
        self.last_wal_seq = -1

    # ------------------------------------------------------------ trigger

    def trigger(self) -> threading.Thread:
        """Start one background checkpoint; returns the worker thread."""
        with self._trigger_lock:
            if self._worker is not None and self._worker.is_alive():
                self._worker.join()
            arrays, book = self.collection.snapshot()
            self._ops_since = 0
            worker = threading.Thread(
                target=self._write, args=(arrays, book),
                name=f"ckpt-{self.path.name}", daemon=True)
            self._worker = worker
            worker.start()
            return worker

    def checkpoint(self) -> dict:
        """Synchronous convenience: trigger and wait for durability."""
        self.trigger().join()
        return {"wal_seq": self.last_wal_seq,
                "n_checkpoints": self.n_checkpoints}

    def note_ops(self, n: int = 1):
        """Report n acknowledged mutations; fires `trigger()` when the
        configured interval is crossed."""
        if self.every_n_ops is None:
            return
        self._ops_since += int(n)
        if self._ops_since >= self.every_n_ops:
            self.trigger()

    def join(self):
        """Wait for the in-flight checkpoint write, if any."""
        with self._trigger_lock:
            worker = self._worker
        if worker is not None and worker.is_alive():
            worker.join()

    # ------------------------------------------------------------- worker

    def _write(self, arrays: dict, book: dict):
        data = pack(CHECKPOINT_KIND, CHECKPOINT_VERSION, arrays=arrays,
                    meta=book)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        seq = int(book.get("wal_seq", -1))
        if self.wal is not None and seq >= 0:
            self.n_segments_truncated += self.wal.truncate_through(seq)
        self.last_wal_seq = seq
        self.n_checkpoints += 1
        telemetry = getattr(self.collection, "telemetry", None)
        if telemetry is not None:
            telemetry.record_checkpoint()
