"""repro.resilience — fault-tolerant serving substrate (DESIGN.md §16).

Three layers, one contract ("acked means recoverable, unhealthy means
answered and labelled"):

  * durability — `WriteAheadLog` (ciphertext-only, fsync'd, segment-
    rotated), `AsyncCheckpointer` (background `.ppcol` checkpoints that
    never block serving), `recover` (checkpoint + replay -> bit-
    identical acknowledged state after a kill at any point);
  * availability — `ShardHealthRegistry` (replica up/down + epoch) the
    sharded backend routes around: one dead replica is invisible, a
    fully-dead shard group degrades the answer (`SearchResult.degraded`,
    `SearchStats.n_shards_down`) instead of failing it, and the
    schedulers retry transient engine faults per-request
    (`EngineRetryPolicy`) with poison-query quarantine;
  * determinism — `FaultPlan` injects kills, crashes around fsync,
    engine exceptions, and straggler delays at exact logical points on
    the `VirtualClock` seam, so every failure interleaving in the test
    suite replays exactly.

The seed-era `repro.ft` runner lives here now (`RetryPolicy`,
`ResilientRunner`, `StragglerWatchdog`), ported onto the injected
`Clock`; `repro.ft` remains as a deprecation shim.
"""

from ..serving.runtime.batcher import EngineRetryPolicy  # noqa: F401
from .checkpoint import (AsyncCheckpointer,              # noqa: F401
                         collection_state_bytes,
                         restore_collection_state)
from .faults import FaultPlan, InjectedFault, SimulatedCrash  # noqa: F401
from .health import ShardHealthRegistry                  # noqa: F401
from .recovery import RecoveryReport, attach_wal, recover  # noqa: F401
from .runner import (ResilientRunner, RetryPolicy,       # noqa: F401
                     StragglerWatchdog, sleep_on)
from .wal import WalCorruptionError, WalRecord, WriteAheadLog  # noqa: F401

__all__ = [
    "WriteAheadLog", "WalRecord", "WalCorruptionError",
    "AsyncCheckpointer", "collection_state_bytes",
    "restore_collection_state",
    "recover", "RecoveryReport", "attach_wal",
    "ShardHealthRegistry",
    "FaultPlan", "InjectedFault", "SimulatedCrash",
    "EngineRetryPolicy",
    "RetryPolicy", "ResilientRunner", "StragglerWatchdog", "sleep_on",
]
