"""Checkpoint-restart step runner + straggler watchdog on the Clock
seam (DESIGN.md §16).

This is the seed-era `repro.ft.runner` ported off raw `time.sleep` /
`time.perf_counter` onto the injected `Clock` (DESIGN.md §12) — the
same seam the schedulers, telemetry, and trace spans run on, so retry
backoff and straggler deadlines are now assertable on `VirtualClock`
without real sleeping.  `repro.ft` remains as a deprecation shim
re-exporting these names; behaviour under the default `SystemClock` is
unchanged.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable

from ..serving.runtime.clock import Clock, SystemClock

__all__ = ["RetryPolicy", "ResilientRunner", "StragglerWatchdog",
           "sleep_on"]


def sleep_on(clock: Clock, seconds: float) -> None:
    """Sleep `seconds` of *clock* time: a condition-wait loop that
    re-checks the deadline on every (possibly spurious) wakeup.  Under
    `SystemClock` this is a plain timed sleep; under `VirtualClock` it
    parks as a timed waiter until the test advances past the deadline —
    the clock-seam replacement for `time.sleep` everywhere in the
    resilience layer."""
    if seconds <= 0:
        return
    cv = threading.Condition()
    deadline = clock.now() + float(seconds)
    with cv:
        while True:
            remaining = deadline - clock.now()
            if remaining <= 0:
                return
            clock.wait(cv, timeout=remaining)


@dataclasses.dataclass
class RetryPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.0         # real deployments back off; tests don't


class ResilientRunner:
    """Wraps a step function with checkpoint-restart semantics:

        run step -> exception? -> restore latest checkpoint -> continue

    Failures are injected in tests via a hook; backoff between restarts
    runs on the injected clock."""

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, policy: RetryPolicy = RetryPolicy(),
                 checkpoint_every: int = 10, clock: Clock | None = None):
        self.step_fn = step_fn
        self.save_fn = save_fn          # (step, state) -> None
        self.restore_fn = restore_fn    # () -> (step, state)
        self.policy = policy
        self.checkpoint_every = checkpoint_every
        self.clock = clock if clock is not None else SystemClock()
        self.restarts = 0
        self.failures_seen = 0

    def run(self, state, start_step: int, n_steps: int, get_batch):
        """Run n_steps; on failure restore the latest checkpoint and replay.
        get_batch(step) must be deterministic in step (resumable loader)."""
        step = start_step
        end = start_step + n_steps
        metrics = None
        while step < end:
            try:
                state, metrics = self.step_fn(state, get_batch(step))
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(step, state)
            except Exception:
                self.failures_seen += 1
                self.restarts += 1
                if self.restarts > self.policy.max_restarts:
                    raise
                if self.policy.backoff_s:
                    sleep_on(self.clock, self.policy.backoff_s)
                step, state = self.restore_fn()
        return state, step, metrics


class StragglerWatchdog:
    """Deadline-based straggler mitigation for host-side work.

    Tracks a rolling median of durations on the injected clock;
    `run_sharded` dispatches a callable per shard and re-dispatches (to
    a fallback executor) any shard exceeding `factor` x median — the
    standard backup-task trick."""

    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_deadline_s: float = 1e-3,
                 clock: Clock | None = None):
        self.factor = factor
        self.durations: list[float] = []
        self.window = window
        self.min_deadline_s = min_deadline_s
        self.clock = clock if clock is not None else SystemClock()
        self.redispatches = 0

    @property
    def deadline_s(self) -> float:
        if not self.durations:
            return float("inf")
        tail = sorted(self.durations[-self.window:])
        med = tail[len(tail) // 2]
        return max(self.factor * med, self.min_deadline_s)

    def observe(self, duration_s: float):
        self.durations.append(duration_s)

    def run_sharded(self, shard_fns, fallback_fn=None):
        """Execute each shard fn; any shard slower than the deadline is
        re-run via fallback_fn (e.g., on a spare host).  Sequential here —
        the scheduling logic, not the parallel substrate, is under test."""
        results = []
        for i, fn in enumerate(shard_fns):
            t0 = self.clock.now()
            out = fn()
            dt = self.clock.now() - t0
            if dt > self.deadline_s and fallback_fn is not None:
                self.redispatches += 1
                out = fallback_fn(i)
            else:
                self.observe(dt)
            results.append(out)
        return results
