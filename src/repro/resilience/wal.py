"""Crash-safe ingestion write-ahead log (DESIGN.md §16).

Ciphertext-only durability for live mutations: every acknowledged
`insert_encrypted` / `delete` / explicit `compact` on a collection
appends one record here *after* the in-memory store applied it and
*before* the ack returns, so

    acked  =>  durable (fsync'd)  =>  replayed on recovery.

The converse direction is the torn-tail rule: a record the process died
writing was never acked, so recovery may (must) drop it.

On-disk format — append-only segment files `wal-<firstseq>.seg`, each a
sequence of frames:

    +--------+--------+---------+---------+-----------------+
    | b"PWAL"| seq u64| len u32 | crc u32 | payload (len B) |
    +--------+--------+---------+---------+-----------------+

The payload is a versioned `core.wireformat` blob (kind "wal-record"):
the op name + op metadata ride the JSON header, the ciphertext arrays
(C_sap / C_dce rows for inserts, row ids for deletes) ride the npz
body — the WAL stores exactly what the server already holds, never
plaintext, so its leakage surface is the store's own (DESIGN.md §14).

Sequence numbers are global and monotonic across segments and across
reopens; segment filenames carry their first seq so `truncate_through`
(called after a durable checkpoint) can drop whole prefix segments
without reading them.  CRC validation on replay: a bad frame in the
*last* segment is a torn tail (clean stop, file truncated at reopen); a
bad frame anywhere else is real corruption and raises.
"""

from __future__ import annotations

import os
import struct
import zlib
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from ..core import wireformat
from .faults import SimulatedCrash

__all__ = ["WriteAheadLog", "WalRecord", "WalCorruptionError"]

_MAGIC = b"PWAL"
_HEADER = struct.Struct("<QII")            # seq, payload_len, crc32
_FRAME_OVERHEAD = len(_MAGIC) + _HEADER.size
WAL_VERSION = 1


class WalCorruptionError(RuntimeError):
    """A CRC/framing failure somewhere other than the final segment's
    tail — data loss beyond what a torn write can explain."""


@dataclass(frozen=True)
class WalRecord:
    """One replayable acknowledged mutation."""
    seq: int
    op: str                         # insert | delete | compact
    arrays: dict
    meta: dict

    @property
    def n_rows(self) -> int:
        if self.op == "insert":
            return int(self.arrays["C_sap"].shape[0])
        if self.op == "delete":
            return int(self.arrays["rows"].shape[0])
        return 0


def _segment_name(first_seq: int) -> str:
    return f"wal-{first_seq:016d}.seg"


class WriteAheadLog:
    """Append / replay / truncate over a directory of segment files.

    Thread safety: appends are serialized by the caller (the collection
    appends under its own mutation lock, the same lock that orders the
    mutations themselves — a second lock here could only disagree).

    `fault_hook(seq, op) -> action | None` is the deterministic
    fault-injection seam: "crash_before_fsync" makes this append write
    a torn half-frame and die; "crash_after_fsync" makes it durable and
    then die before the caller can ack.
    """

    def __init__(self, root, *, segment_bytes: int = 4 << 20,
                 fsync: bool = True, fault_hook=None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.segment_bytes = int(segment_bytes)
        self.fsync_enabled = bool(fsync)
        self.fault_hook = fault_hook
        self._f = None
        self._f_path: Path | None = None
        self.n_appended = 0
        self.last_seq = 0
        self._recover_tail()

    # -------------------------------------------------------------- open

    def _segments(self) -> list[Path]:
        return sorted(self.root.glob("wal-*.seg"))

    def _recover_tail(self):
        """Find the last valid seq; physically truncate a torn tail of
        the final segment so later appends/replays see clean frames."""
        segs = self._segments()
        for i, path in enumerate(segs):
            last = i == len(segs) - 1
            good_end, seq = self._scan_segment(path, last=last)
            if seq is not None:
                self.last_seq = seq
            if last and good_end < path.stat().st_size:
                with open(path, "r+b") as f:
                    f.truncate(good_end)

    def _scan_segment(self, path: Path, *, last: bool):
        """Returns (byte offset after the last valid frame, last seq in
        the segment or None).  Raises on mid-log corruption."""
        seq = None
        good_end = 0
        with open(path, "rb") as f:
            data = f.read()
        off = 0
        while off < len(data):
            frame = self._parse_frame(data, off)
            if frame is None:
                if not last:
                    raise WalCorruptionError(
                        f"corrupt frame at {path.name}:{off} (not the "
                        f"final segment — cannot be a torn tail)")
                break
            off, seq = frame
            good_end = off
        return good_end, seq

    @staticmethod
    def _parse_frame(data: bytes, off: int):
        """(next_offset, seq) for a valid frame at off, else None."""
        end = off + _FRAME_OVERHEAD
        if end > len(data) or data[off: off + len(_MAGIC)] != _MAGIC:
            return None
        seq, length, crc = _HEADER.unpack_from(data, off + len(_MAGIC))
        payload_end = end + length
        if payload_end > len(data):
            return None
        if zlib.crc32(data[end:payload_end]) != crc:
            return None
        return payload_end, seq

    # ------------------------------------------------------------ append

    def _file_for(self, frame_len: int):
        """Current segment file, rotating when it would overflow."""
        if self._f is not None:
            if self._f.tell() + frame_len <= self.segment_bytes \
                    or self._f.tell() == 0:
                return self._f
            self._f.close()
            self._f = None
        path = self.root / _segment_name(self.last_seq + 1)
        self._f = open(path, "ab")
        self._f.seek(0, os.SEEK_END)   # 'ab' tell() is 0 on some libcs
        self._f_path = path
        return self._f

    def append(self, op: str, arrays: dict | None = None,
               meta: dict | None = None) -> int:
        """Durably log one acknowledged mutation; returns its seq."""
        seq = self.last_seq + 1
        payload = wireformat.pack(
            "wal-record", WAL_VERSION,
            {k: np.asarray(v) for k, v in (arrays or {}).items()},
            {"op": op, **(meta or {})})
        frame = (_MAGIC
                 + _HEADER.pack(seq, len(payload), zlib.crc32(payload))
                 + payload)
        f = self._file_for(len(frame))
        action = self.fault_hook(seq, op) if self.fault_hook else None
        if action == "crash_before_fsync":
            f.write(frame[: max(1, len(frame) // 2)])
            f.flush()
            raise SimulatedCrash(
                f"died mid-write of WAL record {seq} (torn tail)")
        f.write(frame)
        f.flush()
        if action == "crash_after_fsync":
            os.fsync(f.fileno())
            raise SimulatedCrash(
                f"died after fsync of WAL record {seq} (durable, unacked)")
        if self.fsync_enabled:
            os.fsync(f.fileno())
        self.last_seq = seq
        self.n_appended += 1
        return seq

    # ------------------------------------------------------------ replay

    def replay(self, after_seq: int = 0):
        """Yield `WalRecord`s with seq > after_seq, oldest first."""
        segs = self._segments()
        for i, path in enumerate(segs):
            with open(path, "rb") as f:
                data = f.read()
            off = 0
            while off < len(data):
                frame = self._parse_frame(data, off)
                if frame is None:
                    if i != len(segs) - 1:
                        raise WalCorruptionError(
                            f"corrupt frame at {path.name}:{off}")
                    return          # torn tail: clean stop
                payload_end, seq = frame
                if seq > after_seq:
                    arrays, m = wireformat.unpack(
                        data[off + _FRAME_OVERHEAD: payload_end],
                        "wal-record", WAL_VERSION)
                    meta = dict(m or {})
                    op = meta.pop("op")
                    yield WalRecord(seq=seq, op=op, arrays=dict(arrays),
                                    meta=meta)
                off = payload_end

    # ---------------------------------------------------------- truncate

    def truncate_through(self, seq: int) -> int:
        """Drop whole segments made redundant by a checkpoint that
        captured every mutation up to and including `seq`.  Returns the
        number of segment files deleted.  (Granularity is the segment:
        a segment straddling `seq` survives intact — replaying already-
        checkpointed inserts is prevented by the caller replaying only
        records with seq > checkpoint seq.)"""
        segs = self._segments()
        removed = 0
        for i, path in enumerate(segs):
            nxt_first = (int(segs[i + 1].stem.split("-")[1])
                         if i + 1 < len(segs) else self.last_seq + 1)
            if nxt_first - 1 <= seq and path != self._f_path:
                path.unlink()
                removed += 1
            else:
                break               # segments are ordered; stop early
        return removed

    # ------------------------------------------------------------- close

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
