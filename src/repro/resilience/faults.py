"""Deterministic fault injection for the serving runtime (DESIGN.md §16).

A `FaultPlan` is a declarative schedule of faults keyed on *logical*
event counts — the Nth batched engine call, the Nth WAL append — not on
wall time, so a seeded test replays the exact same failure interleaving
on every run.  It drives three seams the runtime already exposes:

  * the scheduler's `run_batch` callable (engine exceptions at step N,
    shard kill/revive through the backend's health registry, straggler
    delays via `VirtualClock.advance` — the injected-clock seam from
    DESIGN.md §12);
  * the WAL's `fault_hook` (crash-before-fsync = a torn half-written
    record that recovery must drop, crash-after-fsync = a record durable
    on disk whose ack never reached the client);
  * nothing else — faults enter through public seams only, so what the
    tests prove is the production code path, not a patched twin.

`SimulatedCrash` deliberately does NOT subclass `Exception`'s common
serving-error types: the schedulers treat it like any engine failure
(retry, then quarantine), while durability tests catch it to model a
process kill.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["FaultPlan", "InjectedFault", "SimulatedCrash"]


class InjectedFault(RuntimeError):
    """A fault-plan-injected engine failure (transient by construction:
    the same request retried on a later call succeeds unless the plan
    says otherwise)."""


class SimulatedCrash(RuntimeError):
    """The process 'died' at this exact point.  Durability tests catch
    this, drop every in-memory object, and recover from disk."""


@dataclass
class _EngineEvent:
    kind: str                       # error | kill | revive | straggle
    exc: BaseException | None = None
    shard: int = 0
    replica: int = 0
    delay_s: float = 0.0
    n: int = 1                      # how many consecutive calls it hits


@dataclass
class FaultPlan:
    """A deterministic schedule of runtime faults.

    Build one with the fluent helpers, then `install(collection)` —
    the plan wraps the collection's scheduler `run_batch` seam and (if a
    WAL is attached) the WAL's `fault_hook`.  Counters start at 1: the
    first engine call after install is call 1, the first WAL append
    after install is record 1.
    """

    clock: object | None = None     # VirtualClock for straggler delays
    _engine: dict = field(default_factory=dict)   # call_n -> [_EngineEvent]
    _wal: dict = field(default_factory=dict)      # record_n -> action str
    n_engine_calls: int = 0
    n_wal_records: int = 0

    # ------------------------------------------------------------ schedule

    def _add(self, call_n: int, ev: _EngineEvent) -> "FaultPlan":
        self._engine.setdefault(int(call_n), []).append(ev)
        return self

    def engine_error(self, at_call: int, exc: BaseException | None = None,
                     n: int = 1) -> "FaultPlan":
        """Raise from the engine on calls at_call .. at_call+n-1."""
        for i in range(n):
            self._add(at_call + i, _EngineEvent("error", exc=exc))
        return self

    def kill_shard(self, at_call: int, shard: int,
                   replica: int = 0) -> "FaultPlan":
        """Mark one shard replica down just before engine call N runs."""
        return self._add(at_call, _EngineEvent("kill", shard=shard,
                                               replica=replica))

    def revive_shard(self, at_call: int, shard: int,
                     replica: int = 0) -> "FaultPlan":
        return self._add(at_call, _EngineEvent("revive", shard=shard,
                                               replica=replica))

    def straggler(self, at_call: int, delay_s: float) -> "FaultPlan":
        """Advance the virtual clock by delay_s before call N — models a
        slow shard/step without real waiting."""
        return self._add(at_call, _EngineEvent("straggle", delay_s=delay_s))

    def crash_before_fsync(self, at_record: int) -> "FaultPlan":
        """WAL append N writes a torn half-record, then the process
        dies.  The op was never acked; recovery must drop the tail."""
        self._wal[int(at_record)] = "crash_before_fsync"
        return self

    def crash_after_fsync(self, at_record: int) -> "FaultPlan":
        """WAL append N is fully durable, then the process dies before
        the ack.  Recovery replays it (at-least-once on unacked ops)."""
        self._wal[int(at_record)] = "crash_after_fsync"
        return self

    # ------------------------------------------------------------- install

    def install(self, collection) -> None:
        """Wrap the collection's scheduler engine seam and WAL hook."""
        sched = collection.batcher
        inner = sched._run_batch
        health = getattr(collection, "health", None)
        if health is None:      # a bare backend instead of a Collection
            health = getattr(getattr(collection, "_backend", None),
                             "health", None)

        def run_batch(*args, **kw):
            self.n_engine_calls += 1
            for ev in self._engine.get(self.n_engine_calls, ()):
                if ev.kind == "kill" and health is not None:
                    health.kill(ev.shard, ev.replica)
                elif ev.kind == "revive" and health is not None:
                    health.revive(ev.shard, ev.replica)
                elif ev.kind == "straggle" and self.clock is not None:
                    self.clock.advance(ev.delay_s)
                elif ev.kind == "error":
                    raise ev.exc or InjectedFault(
                        f"injected engine fault at call "
                        f"{self.n_engine_calls}")
            return inner(*args, **kw)

        sched._run_batch = run_batch
        wal = getattr(collection, "_wal", None)
        if wal is not None:
            wal.fault_hook = self.wal_hook

    def wal_hook(self, seq: int, op: str) -> str | None:
        """The WAL-side seam: called once per append, returns the crash
        action for this record (or None).  Usable directly as the
        `fault_hook` of a hand-constructed `WriteAheadLog`."""
        self.n_wal_records += 1
        return self._wal.get(self.n_wal_records)
