"""Optimizers (no optax in this container — built from scratch).

* adamw     — AdamW with dtype-configurable moment storage (bf16 moments
              halve optimizer HBM for the 340B/1T configs; fp32 master
              update math regardless of storage dtype).
* adafactor — factored second moment (rank-1 row/col statistics) for the
              largest configs; m optional.
* sgdm      — momentum baseline.

All are pure pytree functions: init(params) -> state; update(grads, state,
params, step) -> (new_params, new_state).  Update math runs in fp32 and
casts back to storage dtypes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["OptConfig", "make_optimizer", "global_norm", "clip_by_global_norm",
           "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor | sgdm
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # bfloat16 halves optimizer HBM
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    """Scale in each gradient's own dtype: upcasting the tree to f32 would
    materialize a full-size f32 copy (16 GB/device at kimi-k2 scale)."""
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


_CHUNK_THRESHOLD = 1 << 28      # elements; ~0.5 GB bf16


def _chunked_leafwise(fn, p, *rest):
    """Apply a per-leaf update in slices along the leading (layer-stack)
    axis when the leaf is huge.  The fp32 upcast temporaries inside
    optimizer math otherwise materialize the WHOLE stacked tensor (a
    single ~1T-param leaf for kimi-k2: ~16 GB/device per temporary —
    see EXPERIMENTS.md §Perf)."""
    aligned = all(r.ndim >= 1 and r.shape[0] == p.shape[0]
                  for r in jax.tree.leaves(rest))
    if p.size >= _CHUNK_THRESHOLD and p.ndim >= 2 and p.shape[0] > 1 \
            and aligned:
        return jax.lax.map(lambda args: fn(*args), (p, *rest))
    return fn(p, *rest)


class _Opt:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params) -> Any:
        raise NotImplementedError

    def update(self, grads, state, params, step):
        raise NotImplementedError


class _AdamW(_Opt):
    def init(self, params):
        dt = np.dtype(self.cfg.state_dtype)
        z = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(self, grads, state, params, step):
        c = self.cfg
        lr = cosine_schedule(c, step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - c.b1 ** t
        bc2 = 1.0 - c.b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            mf = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g
            vf = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * g * g
            step_ = (mf / bc1) / (jnp.sqrt(vf / bc2) + c.eps)
            decay = c.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * (step_ + decay)
            dt = m.dtype
            return new_p.astype(p.dtype), mf.astype(dt), vf.astype(dt)

        out = jax.tree.map(
            lambda p, g, m, v: _chunked_leafwise(upd, p, g, m, v),
            params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda o: isinstance(o, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda o: isinstance(o, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda o: isinstance(o, tuple))
        return new_p, {"m": new_m, "v": new_v}


class _Adafactor(_Opt):
    """Factored second moment: for >=2D params store row/col mean-square
    statistics instead of the full tensor (O(n+m) vs O(nm))."""

    def init(self, params):
        dt = np.dtype(self.cfg.state_dtype)

        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], dt),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
            return {"v": jnp.zeros(p.shape, dt)}
        return {"f": jax.tree.map(one, params)}

    def update(self, grads, state, params, step):
        c = self.cfg
        lr = cosine_schedule(c, step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8                       # Adafactor decay

        def upd(p, g, f):
            g = g.astype(jnp.float32)
            g2 = g * g + 1e-30
            if p.ndim >= 2:
                vr = beta * f["vr"].astype(jnp.float32) + (1 - beta) * g2.mean(-1)
                vc = beta * f["vc"].astype(jnp.float32) + (1 - beta) * g2.mean(-2)
                denom = (vr[..., :, None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], 1e-30))
                step_ = g / (jnp.sqrt(denom) + c.eps)
                nf = {"vr": vr.astype(f["vr"].dtype),
                      "vc": vc.astype(f["vc"].dtype)}
            else:
                v = beta * f["v"].astype(jnp.float32) + (1 - beta) * g2
                step_ = g / (jnp.sqrt(v) + c.eps)
                nf = {"v": v.astype(f["v"].dtype)}
            # update clipping (Adafactor RMS-1 rule)
            rms = jnp.sqrt(jnp.mean(step_ * step_) + 1e-30)
            step_ = step_ / jnp.maximum(1.0, rms)
            new_p = (p.astype(jnp.float32)
                     - lr * (step_ + c.weight_decay * p.astype(jnp.float32)))
            return new_p.astype(p.dtype), nf

        flat, tdef = jax.tree_util.tree_flatten(params)
        gflat = jax.tree_util.tree_flatten(grads)[0]
        fflat = jax.tree_util.tree_flatten(
            state["f"], is_leaf=lambda x: isinstance(x, dict) and
            ("v" in x or "vr" in x))[0]
        outs = [_chunked_leafwise(upd, p, g, f)
                for p, g, f in zip(flat, gflat, fflat)]
        new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        new_f = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        return new_p, {"f": new_f}


class _SGDM(_Opt):
    def init(self, params):
        dt = np.dtype(self.cfg.state_dtype)
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)}

    def update(self, grads, state, params, step):
        c = self.cfg
        lr = cosine_schedule(c, step)

        def upd(p, g, m):
            mf = c.b1 * m.astype(jnp.float32) + g.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * mf
            return new_p.astype(p.dtype), mf.astype(m.dtype)

        out = jax.tree.map(upd, params, grads, state["m"])
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda o: isinstance(o, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda o: isinstance(o, tuple))
        return new_p, {"m": new_m}


def make_optimizer(cfg: OptConfig) -> _Opt:
    return {"adamw": _AdamW, "adafactor": _Adafactor,
            "sgdm": _SGDM}[cfg.kind](cfg)
