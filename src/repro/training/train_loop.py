"""Train-step builder: microbatch gradient accumulation, global-norm clip,
optimizer update, metrics.  The returned step is pjit-ready (callers pass
in_shardings from model.param_specs / batch_pspecs and donate state)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..models.model import Model
from . import optimizer as opt_mod

__all__ = ["TrainState", "build_train_step", "init_train_state"]


@dataclasses.dataclass
class TrainConfig:
    n_microbatches: int = 1
    opt: opt_mod.OptConfig = dataclasses.field(
        default_factory=opt_mod.OptConfig)


def init_train_state(model: Model, opt_cfg: opt_mod.OptConfig, key):
    params = model.init(key)
    opt = opt_mod.make_optimizer(opt_cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(model: Model, opt_cfg: opt_mod.OptConfig):
    params = model.abstract_params()
    opt = opt_mod.make_optimizer(opt_cfg)
    state = jax.eval_shape(lambda p: opt.init(p), params)
    return {"params": params, "opt": state,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def _zero1ify(spec, shape, mesh):
    """ZeRO-1: give an optimizer-state leaf one extra sharding over the
    'data' axis on its largest unsharded divisible dim.  GSPMD then
    reduce-scatters grads into the state sharding and all-gathers the
    updated params once per step — the standard ZeRO-1 schedule."""
    from jax.sharding import PartitionSpec
    if mesh is None or "data" not in mesh.shape:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for p in parts if p
            for a in ((p,) if isinstance(p, str) else p)}
    if "data" in used:
        return spec
    n = mesh.shape["data"]
    best = None
    for i, (d, p) in enumerate(zip(shape, parts)):
        if p is None and d >= n and d % n == 0:
            if best is None or d > shape[best]:
                best = i
    if best is None:
        return spec
    parts[best] = "data"
    return PartitionSpec(*parts)


def train_state_pspecs(model: Model, opt_cfg: opt_mod.OptConfig, mesh, rules,
                       zero1: bool = False):
    """Optimizer state inherits each parameter's PartitionSpec (moments are
    shaped like params; adafactor row/col stats drop the reduced axis).
    With zero1=True the state additionally shards over 'data'."""
    from jax.sharding import PartitionSpec
    pspecs = model.param_specs(mesh, rules)

    def opt_specs(ps):
        if opt_cfg.kind == "adamw":
            return {"m": ps, "v": ps}
        if opt_cfg.kind == "sgdm":
            return {"m": ps}
        # adafactor: vr drops the last axis, vc the second-to-last
        def one(spec):
            parts = tuple(spec)
            if len(parts) >= 2:
                return {"vr": PartitionSpec(*parts[:-1]),
                        "vc": PartitionSpec(*(parts[:-2] + parts[-1:]))}
            return {"v": PartitionSpec(*parts)}
        return {"f": jax.tree.map(one, pspecs,
                                  is_leaf=lambda s: isinstance(s, PartitionSpec))}

    opt = opt_specs(pspecs)
    if zero1:
        abstract = abstract_train_state(model, opt_cfg)["opt"]
        opt = jax.tree.map(
            lambda sp, ab: _zero1ify(sp, ab.shape, mesh),
            opt, abstract,
            is_leaf=lambda s: isinstance(s, PartitionSpec))
    return {"params": pspecs, "opt": opt,
            "step": PartitionSpec()}


def build_train_step(model: Model, opt_cfg: opt_mod.OptConfig,
                     mesh=None, rules=None,
                     n_microbatches: int = 1,
                     accum_dtype: str = "float32") -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Gradient accumulation: the global batch is split along axis 0 into
    n_microbatches chunks processed under lax.scan — bounding live
    activation memory (mandatory for the MoE dispatch buffers)."""
    opt = opt_mod.make_optimizer(opt_cfg)

    def loss_fn(params, mb):
        return model.loss(params, mb, mesh, rules)

    def train_step(state, batch):
        params = state["params"]

        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % n_microbatches == 0, (b, n_microbatches)
                return x.reshape((n_microbatches, b // n_microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)
            # accumulation dtype is configurable: bf16 halves the buffer
            # for the ~1T-param configs (precision trade-off documented)
            import numpy as _np
            acc_dt = _np.dtype(accum_dtype)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)

            def acc_body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zero), mbs)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)

        grads, grad_norm = opt_mod.clip_by_global_norm(
            grads, opt_cfg.grad_clip)
        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"])
        metrics = {"loss": loss, "grad_norm": grad_norm,
                   "lr": opt_mod.cosine_schedule(opt_cfg, state["step"])}
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, metrics)

    return train_step
