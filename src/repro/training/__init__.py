from .optimizer import OptConfig, make_optimizer, cosine_schedule  # noqa: F401
from .train_loop import (build_train_step, init_train_state,  # noqa: F401
                         abstract_train_state, train_state_pspecs)
