"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (jax locks the device count on first backend
init, and only launch/dryrun.py sets the 512-device XLA flag)."""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where this jax version
    supports them (jax.sharding.AxisType is newer than 0.4.x; Auto is the
    default behavior either way)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis.  Batch shards over ('pod','data'): only DP gradient all-reduce
    (and ZeRO all-gathers for fsdp archs) crosses the slow inter-pod links;
    TP stays intra-pod on 'model'."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
