"""Roofline analysis for the dry-run cells (TPU v5e targets).

Three terms per (arch x shape x mesh):
    compute    = exec_FLOPs / (chips * 197e12)         [bf16 MXU peak]
    memory     = exec_bytes / (chips * 819e9)          [HBM]
    collective = coll_bytes_per_chip / 50e9            [ICI link]

Why analytic models: XLA's HLO cost analysis counts while-loop bodies ONCE
(layer scans, microbatch accumulation, flash-attention KV chunks), so
`compiled.cost_analysis()` under-reports flops/bytes by the trip counts,
and collectives inside the layer scan are likewise counted once.  The
dry-run JSONs keep the raw parsed values (a lower bound / validation
anchor); the roofline uses the first-principles models below, which count
every loop iteration.  Both are reported side by side.
"""

from __future__ import annotations

import dataclasses
import glob
import json
import math
import os

import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.models.config import SHAPES, ModelConfig, ShapeConfig

# ---- hardware constants (TPU v5e)
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (conservative single-link)

BYTES = {"bfloat16": 2, "float32": 4, "float16": 2, "int32": 4}


def _train_settings(arch: str) -> dict:
    from repro.launch.dryrun import DEFAULT_TRAIN, TRAIN_SETTINGS
    return TRAIN_SETTINGS.get(arch, DEFAULT_TRAIN)


# ===================================================================
# Analytic FLOPs (counting every loop iteration)
# ===================================================================

def exec_flops(cfg: ModelConfig, sc: ShapeConfig) -> dict:
    B, S = sc.global_batch, sc.seq_len
    model = Model(cfg)
    n_act = model.n_active_params()
    H, dh, L = cfg.n_heads, cfg.head_dim, cfg.n_layers

    if sc.kind == "train":
        tokens, mult = B * S, (4.0 if cfg.remat else 3.0)   # fwd+bwd+refwd
    elif sc.kind == "prefill":
        tokens, mult = B * S, 1.0
    else:
        tokens, mult = B, 1.0

    matmul = 2.0 * n_act * tokens * mult

    attn = 0.0
    if cfg.family in ("dense", "moe", "vlm"):
        T = S if sc.kind != "decode" else S
        q_len = S if sc.kind != "decode" else 1
        # our flash path computes the full (S, T) rectangle (no causal skip)
        attn = L * 4.0 * B * q_len * T * H * dh * mult
    elif cfg.family == "encdec":
        Se = cfg.enc_seq_len
        q_len = S if sc.kind != "decode" else 1
        enc = (cfg.n_enc_layers * 4.0 * B * Se * Se * H * dh
               if sc.kind != "decode" else 0.0)
        self_a = L * 4.0 * B * q_len * S * H * dh
        cross = L * 4.0 * B * q_len * Se * H * dh
        attn = (enc + self_a + cross) * mult
    elif cfg.family in ("ssm", "hybrid"):
        Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        q = 128 if sc.kind != "decode" else 1
        tok = B * S if sc.kind != "decode" else B
        # SSD: within-chunk ~2*tok*q*(N+P) per head + states ~4*N*P
        ssd = L * 2.0 * tok * Hs * (q * (N + P) + 2.0 * N * P) * mult
        attn += ssd
        if cfg.family == "hybrid":
            every = max(cfg.attn_every, 1)
            n_slots = sum(1 for i in range(L) if i % every == 0)
            q_len = S if sc.kind != "decode" else 1
            attn += n_slots * 4.0 * B * q_len * S * H * dh * mult
    return {"matmul": matmul, "attn_ssm": attn, "total": matmul + attn}


# ===================================================================
# Analytic HBM bytes (per step, summed over chips)
# ===================================================================

def exec_bytes(cfg: ModelConfig, sc: ShapeConfig, arch: str) -> dict:
    model = Model(cfg)
    p_bytes = model.n_params() * BYTES[cfg.dtype]
    B, S = sc.global_batch, sc.seq_len
    d = cfg.d_model

    if sc.kind == "train":
        ts = _train_settings(arch)
        opt_b = {"adamw": 2, "sgdm": 1, "adafactor": 0.02}[ts["opt"]] \
            * model.n_params() * BYTES[ts["state_dtype"]]
        grad_b = model.n_params() * BYTES[ts["accum"]]
        tokens = B * S
        # weights: read fwd + bwd + remat refwd; grads: w+r; opt: r+w.
        # pure_dp replicates weights: every chip reads the full model, so
        # the global-equivalent traffic scales by the chip count.
        rep = 256 if ts.get("pure_dp") else 1
        weights = 3 * p_bytes * rep
        opt = 2 * opt_b + 2 * grad_b
        # layer-boundary activation checkpoints: write + read (bf16)
        acts = 2 * cfg.n_layers * tokens * d * 2
        logits = 2 * tokens * cfg.vocab_size * 2 / max(
            1, _train_settings(arch)["n_micro"]) * \
            _train_settings(arch)["n_micro"]     # streamed per microbatch
        total = weights + opt + acts + logits
        return {"weights": weights, "opt_grads": opt, "activations": acts,
                "logits": logits, "total": total}

    if sc.kind == "prefill":
        tokens = B * S
        cache = _cache_bytes(cfg, B, S)
        acts = 2 * cfg.n_layers * tokens * d * 2
        total = p_bytes + cache + acts
        return {"weights": p_bytes, "cache_write": cache,
                "activations": acts, "total": total}

    # decode: read active weights + read the whole cache, write 1 row
    n_act_b = model.n_active_params() * BYTES[cfg.dtype]
    cache = _cache_bytes(cfg, B, S)
    total = n_act_b + cache
    return {"weights": n_act_b, "cache_read": cache, "total": total}


def _cache_bytes(cfg: ModelConfig, B: int, T: int) -> float:
    dtb = BYTES[cfg.dtype]
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        c = 2 * cfg.n_layers * B * T * cfg.n_kv_heads * cfg.head_dim * dtb
        if cfg.family == "encdec":
            c += 2 * cfg.n_layers * B * cfg.enc_seq_len * \
                cfg.n_kv_heads * cfg.head_dim * dtb
        return c
    conv_d = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    c = cfg.n_layers * B * (cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                            * 4 + (cfg.ssm_conv - 1) * conv_d * dtb)
    if cfg.family == "hybrid":
        every = max(cfg.attn_every, 1)
        n_slots = sum(1 for i in range(cfg.n_layers) if i % every == 0)
        c += 2 * n_slots * B * T * cfg.n_kv_heads * cfg.head_dim * dtb
    return c


# ===================================================================
# Analytic collective bytes (per chip per step)
# ===================================================================

def exec_collectives(cfg: ModelConfig, sc: ShapeConfig, arch: str,
                     mesh_shape: dict) -> dict:
    model = Model(cfg)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    chips = dp * tp
    p_bytes = model.n_params() * BYTES[cfg.dtype]
    B, S = sc.global_batch, sc.seq_len
    d = cfg.d_model
    out: dict[str, float] = {}

    if sc.kind == "train":
        ts = _train_settings(arch)
        if ts.get("pure_dp"):
            # no TP: the only collective is the full-tree gradient
            # all-reduce over all chips (ring: ~2x bytes)
            out["dp_gradsync"] = 2 * model.n_params() * BYTES[ts["accum"]]
            out["total"] = sum(out.values())
            return out
        tokens_dev = B * S / dp
        n_ar = {"dense": 2, "moe": 3, "vlm": 2, "encdec": 4,
                "ssm": 2, "hybrid": 2}[cfg.family]
        # TP activation all-reduces: fwd + bwd + remat refwd (~3x), ring 2x
        out["tp_allreduce"] = (cfg.n_layers * n_ar * 3 * 2
                               * tokens_dev * d * 2)
        # DP gradient sync: ~2x local grad shard bytes
        out["dp_gradsync"] = 2 * (p_bytes / tp) * BYTES[ts["accum"]] / 2
        if cfg.fsdp:
            # ZeRO-3 weight all-gather per microbatch (fwd+bwd+refwd)
            out["fsdp_allgather"] = 3 * ts["n_micro"] * (p_bytes / tp)
        if cfg.family == "moe":
            # dispatch/combine cross-device token movement ~2x token bytes*k
            out["moe_alltoall"] = (2 * tokens_dev * d * 2
                                   * cfg.experts_per_token)
    elif sc.kind == "prefill":
        tokens_dev = B * S / max(dp, 1)
        n_ar = 2
        out["tp_allreduce"] = cfg.n_layers * n_ar * tokens_dev * d * 2
    else:  # decode
        b_dev = max(B / dp, 1)
        out["tp_allreduce"] = cfg.n_layers * 2 * b_dev * d * 2
        # flash-decode partial-softmax combine over the seq-sharded cache
        if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            out["softmax_combine"] = (cfg.n_layers * b_dev
                                      * cfg.n_heads * cfg.head_dim * 4 * 2)
    out["total"] = sum(out.values())
    return out


# ===================================================================
# Assembly
# ===================================================================

@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    exec_flops_total: float
    useful_ratio: float
    hlo_flops_raw: float
    note: str = ""

    def fraction_of_roofline(self) -> float:
        """useful model flops / (time-if-run-at-dominant-term * peak)."""
        t = max(self.compute_s, self.memory_s, self.collective_s)
        if t <= 0:
            return 0.0
        return self.model_flops / (t * self.chips * PEAK_FLOPS)


def analyze_record(rec: dict) -> RooflineRow | None:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    if not rec.get("ok"):
        return None
    chips = 512 if "512" in mesh else 256
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if chips == 512
                  else {"data": 16, "model": 16})

    if arch == "ppanns-scan":
        # filter matmul dominates: 2*n*d flops per query + norm adds
        from repro.launch.dryrun import PPANNS_CELLS
        cell = PPANNS_CELLS[shape]
        dtb = 2.0 if cell.get("dtype") == "bfloat16" else 4.0
        fl = 2.0 * cell["n"] * cell["d"] * cell["batch"]
        # filter reads C_sap once; refine reads only B*k' DCE rows
        by = (cell["n"] * cell["d"] * dtb
              + cell["batch"] * cell["k_prime"] * 4 * (2 * cell["d"] + 16)
              * dtb)
        if cell.get("gspmd"):
            # the (B, n) matrix is globally gathered for the top-k
            by += cell["batch"] * cell["n"] * 4.0
            coll = cell["batch"] * cell["n"] * 4.0 / chips
        else:
            coll = cell["batch"] * cell["k_prime"] * 8.0
        comp = fl / (chips * PEAK_FLOPS)
        mem = by / (chips * HBM_BW)
        cols = coll / ICI_BW
        dom = max((comp, "compute"), (mem, "memory"), (cols, "collective"))
        return RooflineRow(arch, shape, mesh, chips, comp, mem, cols,
                           dom[1], fl, fl, 1.0,
                           rec.get("cost", {}).get("flops", -1),
                           "filter scan matmul-bound")

    cfg = get_config(arch)
    sc = SHAPES[shape]
    ef = exec_flops(cfg, sc)
    eb = exec_bytes(cfg, sc, arch)
    ec = exec_collectives(cfg, sc, arch, mesh_shape)

    comp = ef["total"] / (chips * PEAK_FLOPS)
    mem = eb["total"] / (chips * HBM_BW)
    cols = ec["total"] / ICI_BW        # already per-chip
    dom = max((comp, "compute"), (mem, "memory"), (cols, "collective"))
    mf = rec.get("model_flops", 0.0)
    return RooflineRow(
        arch, shape, mesh, chips, comp, mem, cols, dom[1], mf,
        ef["total"], mf / ef["total"] if ef["total"] else 0.0,
        rec.get("cost", {}).get("flops", -1))


def load_records(results_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(results_dir: str = "results/dryrun",
          mesh_filter: str = "1pod_256") -> list[RooflineRow]:
    rows = []
    for rec in load_records(results_dir):
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        r = analyze_record(rec)
        if r is not None:
            rows.append(r)
    return rows


def format_table(rows: list[RooflineRow]) -> str:
    hdr = (f"{'arch':<18}{'shape':<13}{'compute_s':>11}{'memory_s':>11}"
           f"{'coll_s':>10}{'dominant':>11}{'MF/EF':>7}{'roofl%':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<18}{r.shape:<13}{r.compute_s:>11.4g}"
            f"{r.memory_s:>11.4g}{r.collective_s:>10.4g}{r.dominant:>11}"
            f"{r.useful_ratio:>7.2f}{100 * r.fraction_of_roofline():>7.1f}%")
    return "\n".join(lines)
