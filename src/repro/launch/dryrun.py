import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, single-pod (16x16=256) and multi-pod (2x16x16=512),
with ShapeDtypeStruct stand-ins (no allocation).

Per cell this records to JSON:
  * memory_analysis  — per-device argument/temp/output bytes (fits-check)
  * cost_analysis    — per-device HLO flops / bytes accessed
  * collective bytes — parsed from the partitioned HLO per collective kind
  * analytic MODEL_FLOPS (6·N·D train / 2·N·D inference, N_active for MoE)

CLI:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --all --multi-pod
  (add --out DIR to change the results directory; default results/dryrun)

The two XLA_FLAGS lines above MUST stay the first statements: jax locks
the device count on first init, and only the dry-run wants 512 host
devices (smoke tests and benchmarks see 1).
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.models.config import SHAPES
from repro.models.model import (abstract_batch, batch_pspecs, cache_pspecs)
from repro.sharding.rules import (LONG_DECODE_RULES, PURE_DP_TRAIN_RULES,
                                  SERVE_RULES, TRAIN_RULES)
from repro.training import OptConfig, abstract_train_state, build_train_step
from repro.training.train_loop import train_state_pspecs

RESULTS_DIR = "results/dryrun"

# Per-arch training knobs (optimizer family / state dtype / accumulation):
# chosen so optimizer state + gradient buffers fit the v5e HBM budget —
# rationale in EXPERIMENTS.md §Dry-run.
TRAIN_SETTINGS = {
    "nemotron-4-340b": dict(opt="adafactor", state_dtype="float32",
                            n_micro=8, accum="float32"),
    # n_micro trades ZeRO-3 gather volume (up) for activation memory
    # (down); 16 was tried in §Perf and reverted — see EXPERIMENTS.md
    "kimi-k2-1t-a32b": dict(opt="adafactor", state_dtype="float32",
                            n_micro=8, accum="bfloat16"),
    "grok-1-314b": dict(opt="adamw", state_dtype="bfloat16",
                        n_micro=8, accum="float32"),
    "qwen2.5-14b": dict(opt="adamw", state_dtype="float32",
                        n_micro=8, accum="float32"),
    # bf16 moments + 8 microbatches: f32 states/4-micro put the train
    # cell at 19-21 GB/dev (§Dry-run note)
    "chatglm3-6b": dict(opt="adamw", state_dtype="bfloat16",
                        n_micro=8, accum="float32"),
    # ZeRO-1 optimizer-state sharding for the 1-10B TP tier
    "qwen3-1.7b": dict(opt="adamw", state_dtype="float32",
                       n_micro=4, accum="float32", zero1=True),
    "zamba2-1.2b": dict(opt="adamw", state_dtype="float32",
                        n_micro=4, accum="float32", zero1=True),
    "paligemma-3b": dict(opt="adamw", state_dtype="float32",
                         n_micro=4, accum="float32", zero1=True),
    # pure-DP hillclimb (see sharding.rules.PURE_DP_TRAIN_RULES).
    # n_micro must be 1: global_batch 256 == chip count, so any microbatch
    # split would leave mesh axes without batch rows to shard.
    "mamba2-370m": dict(opt="adamw", state_dtype="float32",
                        n_micro=1, accum="float32", pure_dp=True),
    "whisper-small": dict(opt="adamw", state_dtype="float32",
                          n_micro=1, accum="float32", pure_dp=True),
}
# activation memory scales 1/n_micro (layer-scan stores one carry per
# layer per microbatch); 4 keeps small-model cells well under HBM.
DEFAULT_TRAIN = dict(opt="adamw", state_dtype="float32", n_micro=4,
                     accum="float32")

# The paper-technique cell: distributed secure scan (see
# repro/serving/secure_scan.py).  16M encrypted vectors, SIFT dims.
# Suffixed variants are the §Perf hillclimb iterations.
PPANNS_CELLS = {
    "scan_16m": dict(n=16_777_216, d=128, batch=1024, k=10, k_prime=128),
    # hillclimb: bf16 filter ciphertexts (DCPE is approximate by design;
    # refine stays f32 for exact DCE signs)
    "scan_16m_bf16": dict(n=16_777_216, d=128, batch=1024, k=10,
                          k_prime=128, dtype="bfloat16"),
    # hillclimb: amortize the DB read over a 4x query batch
    "scan_16m_bf16_b4096": dict(n=16_777_216, d=128, batch=4096, k=10,
                                k_prime=128, dtype="bfloat16"),
    # negative control: GSPMD-auto formulation (no shard_map)
    "scan_16m_gspmd": dict(n=16_777_216, d=128, batch=1024, k=10,
                           k_prime=128, gspmd=True),
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"= (\w+)\[([\d,]*)\][^ ]* (all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict — newer jax returns the
    dict directly, 0.4.x returns a one-element list of dicts."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device bytes moved per collective kind.

    Model: all-gather/all-to-all/collective-permute move ~result bytes per
    device; all-reduce moves ~2x (reduce-scatter + all-gather phases);
    reduce-scatter moves ~result x group_size (its operand)."""
    out: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        numel = int(np.prod([int(x) for x in dims.split(",") if x])) \
            if dims else 1
        size = numel * nbytes
        g = _GROUPS_RE.search(line)
        gsz = int(g.group(2)) if g else 1
        if kind == "all-reduce":
            moved = 2 * size * max(gsz - 1, 0) / max(gsz, 1)
        elif kind == "reduce-scatter":
            moved = size * max(gsz - 1, 0)
        elif kind == "all-gather":
            moved = size * max(gsz - 1, 0) / max(gsz, 1)
        else:   # all-to-all / collective-permute
            moved = size
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += float(moved)
    return out


def model_flops(cfg, sc) -> float:
    """Analytic 6·N·D (train) / 2·N·D (inference); N_active for MoE."""
    n = Model(cfg).n_active_params()
    if sc.kind == "train":
        tokens = sc.global_batch * sc.seq_len
        return 6.0 * n * tokens
    if sc.kind == "prefill":
        return 2.0 * n * sc.global_batch * sc.seq_len
    return 2.0 * n * sc.global_batch          # decode: 1 token / sequence


def rules_for(shape_name: str, arch: str = ""):
    if shape_name == "train_4k":
        ts = TRAIN_SETTINGS.get(arch, DEFAULT_TRAIN)
        return PURE_DP_TRAIN_RULES if ts.get("pure_dp") else TRAIN_RULES
    if shape_name == "long_500k":
        return LONG_DECODE_RULES
    return SERVE_RULES


def runnable(arch: str, shape_name: str) -> bool:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False          # full-attention archs skip (DESIGN.md §4)
    return True


def lower_cell(arch: str, shape_name: str, mesh):
    """Build and lower one cell; returns (lowered, aux)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if arch == "ppanns-scan":
        import jax.numpy as jnp
        from repro.api import (build_secure_scan_step,
                               build_secure_scan_step_gspmd,
                               secure_scan_input_specs,
                               secure_scan_pspecs)
        cell = PPANNS_CELLS[shape_name]
        builder = (build_secure_scan_step_gspmd if cell.get("gspmd")
                   else build_secure_scan_step)
        step = builder(mesh, k=cell["k"], k_prime=cell["k_prime"])
        specs = secure_scan_input_specs(
            cell["n"], cell["d"], cell["batch"],
            dtype=jnp.dtype(cell.get("dtype", "float32")))
        pspecs = secure_scan_pspecs(mesh)
        shardings = {k: NamedSharding(mesh, v) for k, v in pspecs.items()}
        jitted = jax.jit(
            step,
            in_shardings=(shardings["C_sap"], shardings["C_dce"],
                          shardings["Q_sap"], shardings["T_q"]))
        lowered = jitted.lower(specs["C_sap"], specs["C_dce"],
                               specs["Q_sap"], specs["T_q"])
        return lowered, {"model_flops": 2.0 * cell["n"] * cell["d"]
                         * cell["batch"], "n_params": 0}

    cfg = get_config(arch)
    sc = SHAPES[shape_name]
    model = Model(cfg)
    rules = rules_for(shape_name, arch)
    ns = lambda spec: NamedSharding(mesh, spec)
    tree_ns = lambda specs: jax.tree.map(
        ns, specs, is_leaf=lambda s: isinstance(s, P))
    aux = {"model_flops": model_flops(cfg, sc),
           "n_params": model.n_params(),
           "n_active_params": model.n_active_params()}

    if sc.kind == "train":
        ts = TRAIN_SETTINGS.get(arch, DEFAULT_TRAIN)
        opt_cfg = OptConfig(kind=ts["opt"], state_dtype=ts["state_dtype"])
        step = build_train_step(model, opt_cfg, mesh, rules,
                                n_microbatches=ts["n_micro"],
                                accum_dtype=ts["accum"])
        state_abs = abstract_train_state(model, opt_cfg)
        state_specs = train_state_pspecs(model, opt_cfg, mesh, rules,
                                         zero1=bool(ts.get("zero1")))
        batch_abs = abstract_batch(cfg, sc)
        b_specs = batch_pspecs(cfg, sc, mesh, rules)
        jitted = jax.jit(step,
                         in_shardings=(tree_ns(state_specs), tree_ns(b_specs)),
                         out_shardings=(tree_ns(state_specs), None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_abs, batch_abs)
        aux["train_settings"] = ts
        return lowered, aux

    params_abs = model.abstract_params()
    p_specs = model.param_specs(mesh, rules)
    B, T = sc.global_batch, sc.seq_len
    cache_abs = model.init_cache(B, T, abstract=True)
    c_specs = cache_pspecs(cfg, B, T, mesh, rules)

    if sc.kind == "prefill":
        batch_abs = abstract_batch(cfg, sc)
        b_specs = batch_pspecs(cfg, sc, mesh, rules)
        fn = lambda p, b, c: model.prefill(p, b, c, mesh, rules)
        jitted = jax.jit(fn,
                         in_shardings=(tree_ns(p_specs), tree_ns(b_specs),
                                       tree_ns(c_specs)),
                         out_shardings=(None, tree_ns(c_specs)),
                         donate_argnums=(2,))
        lowered = jitted.lower(params_abs, batch_abs, cache_abs)
        return lowered, aux

    # decode: one new token against a T-long cache
    token_abs = abstract_batch(cfg, sc)["tokens"]
    tok_spec = batch_pspecs(cfg, sc, mesh, rules)["tokens"]
    fn = lambda p, t, c: model.decode_step(p, t, c, mesh, rules)
    jitted = jax.jit(fn,
                     in_shardings=(tree_ns(p_specs), ns(tok_spec),
                                   tree_ns(c_specs)),
                     out_shardings=(None, tree_ns(c_specs)),
                     donate_argnums=(2,))
    lowered = jitted.lower(params_abs, token_abs, cache_abs)
    return lowered, aux


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, verbose: bool = True) -> dict:
    mesh_name = "2pod_512" if multi_pod else "1pod_256"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, aux = lower_cell(arch, shape_name, mesh)
        rec.update(aux)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        rec["lower_s"] = round(t1 - t0, 1)

        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0)),
            }
            print("memory_analysis:", ma)          # proves it fits
        ca = cost_analysis_dict(compiled)
        rec["cost"] = {"flops": float(ca.get("flops", -1)),
                       "bytes_accessed": float(ca.get("bytes accessed", -1))}
        print("cost_analysis:", {k: ca.get(k) for k in
                                 ("flops", "bytes accessed")})
        rec["collectives"] = parse_collectives(compiled.as_text())
        rec["ok"] = True
    except Exception as e:                          # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            import traceback
            traceback.print_exc()
    rec["total_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir,
                      f"{arch}__{shape_name}__{mesh_name}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {status} "
              f"({rec['total_s']}s)")
    return rec


def all_cells():
    cells = []
    for arch in ARCHS:
        for shape_name in SHAPES:
            if runnable(arch, shape_name):
                cells.append((arch, shape_name))
    for cell_name in PPANNS_CELLS:
        cells.append(("ppanns-scan", cell_name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        # subprocess per cell: isolates device-count env and XLA state
        for arch, shape_name in all_cells():
            for mp in ([False, True] if args.both_meshes
                       else [args.multi_pod]):
                mesh_name = "2pod_512" if mp else "1pod_256"
                fn = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fn):
                    print(f"[dryrun] skip existing {fn}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                subprocess.run(cmd, check=False)
        return

    assert args.arch and args.shape, "--arch and --shape (or --all)"
    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    sys.exit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
