"""Production train driver: config -> mesh -> sharded train loop with
checkpointing, auto-resume, failure recovery and straggler-aware data
loading.

On this CPU container it runs real (reduced-width) training; on a pod the
same code path runs the full config — the mesh and shardings are the same
objects the dry-run compiles.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --scale smoke --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.data.loader import TokenStream
from repro.ft import ResilientRunner, RetryPolicy
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model
from repro.sharding.rules import TRAIN_RULES
from repro.training import OptConfig, build_train_step, init_train_state
from repro.training.train_loop import train_state_pspecs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (requires 256 devices)")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="raise at this step once (FT drill)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scale == "smoke":
        cfg = cfg.smoke()
        # widen a bit so the run is a meaningful ~10-100M-param model
        cfg = dataclasses.replace(cfg, d_model=256, d_ff=1024,
                                  n_layers=min(cfg.n_layers + 2, 4))
    model = Model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    rules = TRAIN_RULES

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps)
    step_fn = build_train_step(model, opt_cfg, mesh, rules,
                               n_microbatches=args.n_micro)
    state_specs = train_state_pspecs(model, opt_cfg, mesh, rules)
    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         batch_size=args.batch, seed=0, markov_temp=0.3)

    # ---- init or resume
    start_step = 0
    state = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state, manifest = restore_checkpoint(args.ckpt_dir, state)
        start_step = manifest["step"]
        stream.step = start_step
        print(f"[train] resumed from step {start_step}")

    def save_fn(step, st):
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, step, st, mesh=mesh,
                            extra={"arch": args.arch})

    def restore_fn():
        st = init_train_state(model, opt_cfg, jax.random.PRNGKey(0))
        st, manifest = restore_checkpoint(args.ckpt_dir, st)
        print(f"[train] recovered from step {manifest['step']}")
        return manifest["step"], st

    fail_at = {args.inject_failure_at} if args.inject_failure_at >= 0 else set()
    t0 = time.time()
    losses = []

    def wrapped_step(st, batch):
        step_now = int(st["step"])
        if step_now in fail_at:
            fail_at.discard(step_now)
            raise RuntimeError(f"injected failure at step {step_now}")
        st, metrics = jit_step(st, batch)
        if step_now % args.log_every == 0:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"[train] step {step_now:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time() - t0):.1f}s)")
        return st, metrics

    def get_batch(step):
        stream.step = step           # deterministic in step (replayable)
        return {k: jnp.asarray(v) for k, v in stream.next().items()}

    runner = ResilientRunner(wrapped_step, save_fn, restore_fn,
                             RetryPolicy(max_restarts=3),
                             checkpoint_every=args.ckpt_every)
    if args.ckpt_dir:
        save_fn(start_step, state)
    state, step, metrics = runner.run(state, start_step,
                                      args.steps - start_step, get_batch)
    if args.ckpt_dir:
        save_fn(step, state)
    final_loss = float(metrics["loss"]) if metrics else float("nan")
    print(f"[train] done at step {step}; final loss {final_loss:.4f}; "
          f"restarts={runner.restarts}")
    return final_loss, losses


if __name__ == "__main__":
    main()
