"""Serve driver: loads (or inits) a model, runs batched prefill+decode,
and optionally attaches the PP-ANNS retrieval sidecar (the paper's secure
k-NN as a serving feature).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --batch 4 --prompt-len 32 --new-tokens 16 --secure-ann
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import dce, dcpe, ppanns
from repro.data import synth
from repro.models import Model
from repro.serving import DistributedSecureANN, LMServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--secure-ann", action="store_true",
                    help="attach the PP-ANNS retrieval sidecar")
    ap.add_argument("--ann-db-size", type=int, default=5000)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, params)

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            key, (args.batch, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq_len, cfg.d_model))

    t0 = time.time()
    out = server.generate(batch, args.new_tokens)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tok_s:.1f} tok/s)")

    if args.secure_ann:
        print("[serve] building PP-ANNS sidecar "
              f"({args.ann_db_size} encrypted vectors)...")
        d = min(cfg.d_model, 128)
        ds = synth.make_dataset("sift1m", n=args.ann_db_size, n_queries=4,
                                d=d, k_gt=10, seed=0)
        owner = ppanns.DataOwner(d=d, sap_beta=1.0, seed=0)
        C_sap = dcpe.encrypt(ds.base, owner.keys.sap_key, seed=1)
        C_dce = dce.encrypt(ds.base, owner.keys.dce_key, seed=2)
        user = ppanns.User(owner.share_keys())
        eng = DistributedSecureANN(C_sap, C_dce)
        t0 = time.time()
        qs, ts_ = zip(*(user.encrypt_query(q) for q in ds.queries))
        ids = eng.query_batch(np.stack(qs), np.stack(ts_), k=10)
        rec = synth.recall_at_k(ids, ds.gt, 10)
        print(f"[serve] secure 10-NN over {args.ann_db_size} vectors: "
              f"recall@10={rec:.3f} in {time.time() - t0:.2f}s")
    return out


if __name__ == "__main__":
    main()
