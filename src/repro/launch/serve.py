"""Serve driver: loads (or inits) a model, runs batched prefill+decode,
and optionally attaches the PP-ANNS retrieval sidecar (the paper's secure
k-NN as a serving feature) through the typed public API (DESIGN.md §9):
a keyless `SecureAnnService` hosts the collection, a `DataOwnerClient`
encrypts the corpus, and concurrent `QueryClient` requests coalesce in
the service's micro-batcher (DESIGN.md §8).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --batch 4 --prompt-len 32 --new-tokens 16 --secure-ann
"""

from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (DataOwnerClient, IndexSpec, PlacementSpec,
                       SearchParams, SecureAnnService, suggest_beta)
from repro.configs import get_config
from repro.data import synth
from repro.models import Model
from repro.serving import LMServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--secure-ann", action="store_true",
                    help="attach the PP-ANNS retrieval sidecar")
    ap.add_argument("--ann-db-size", type=int, default=5000)
    ap.add_argument("--ann-shards", type=int, default=0,
                    help="row-shard the ANN collection over this many "
                         "devices (0 = single-device placement; -1 = "
                         "every local device) — DESIGN.md §10")
    ap.add_argument("--metrics-port", type=int, default=0,
                    help="serve Prometheus metrics for the ANN sidecar on "
                         "this port (0 = disabled) — DESIGN.md §13")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome-trace JSON of the ANN sidecar's "
                         "request spans to this path on exit")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = LMServer(model, params)

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            key, (args.batch, cfg.n_vision_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(
            key, (args.batch, cfg.enc_seq_len, cfg.d_model))

    t0 = time.time()
    out = server.generate(batch, args.new_tokens)
    dt = time.time() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tok_s:.1f} tok/s)")

    if args.secure_ann:
        print("[serve] starting PP-ANNS service sidecar "
              f"({args.ann_db_size} encrypted vectors)...")
        d = min(cfg.d_model, 128)
        ds = synth.make_dataset("sift1m", n=args.ann_db_size, n_queries=16,
                                d=d, k_gt=10, seed=0)
        spec = IndexSpec(tenant="serve-demo", name="rag", d=d,
                         backend="flat",
                         sap_beta=suggest_beta(ds.base, fraction=0.03),
                         max_wait_ms=4.0, seed=0)
        placement = None
        if args.ann_shards:
            placement = PlacementSpec(
                kind="sharded",
                n_shards=None if args.ann_shards < 0 else args.ann_shards)
        want_obs = bool(args.metrics_port or args.trace_out)
        with SecureAnnService(obs=want_obs or None) as svc:
            metrics_server = None
            if args.metrics_port:
                from repro.obs import start_metrics_server
                metrics_server = start_metrics_server(
                    svc, args.metrics_port)
                print("[serve] metrics at http://localhost:"
                      f"{metrics_server.server_address[1]}/metrics")
            svc.create_collection(spec, placement=placement)
            owner = DataOwnerClient(spec)       # keys stay client-side
            t0 = time.time()
            C_sap, C_dce = owner.encrypt_vectors(ds.base)
            svc.insert(spec.tenant, spec.name, C_sap, C_dce)
            svc.compact(spec.tenant, spec.name)
            print(f"[serve] ingested {args.ann_db_size} vectors "
                  f"(jitted DCPE+DCE encrypt) in {time.time() - t0:.2f}s")
            svc.warmup(spec.tenant, spec.name, k=10)
            user = owner.query_client()
            reqs = [user.request(spec.tenant, spec.name, q,
                                 SearchParams(k=10)) for q in ds.queries]
            t0 = time.time()
            with ThreadPoolExecutor(len(reqs)) as pool:   # concurrent
                results = list(pool.map(svc.submit, reqs))
            ids = np.concatenate([r.ids for r in results])
            dt = time.time() - t0
            rec = synth.recall_at_k(ids, ds.gt, 10)
            snap = svc.stats(spec.tenant, spec.name)
            print(f"[serve] secure 10-NN over {args.ann_db_size} vectors: "
                  f"recall@10={rec:.3f} in {dt:.2f}s "
                  f"(occupancy={snap['batch_occupancy']:.1f}, "
                  f"p99={1e3 * snap['p99_latency_s']:.1f}ms)")
            if args.trace_out:
                svc.export_chrome_trace(args.trace_out)
                print(f"[serve] wrote Chrome trace to {args.trace_out}")
            if metrics_server is not None:
                metrics_server.shutdown()
    return out


if __name__ == "__main__":
    main()
