from .rules import (  # noqa: F401
    AxisRules, TRAIN_RULES, SERVE_RULES, LONG_DECODE_RULES,
    resolve_spec, constrain, param_pspecs, ParamMeta,
)
