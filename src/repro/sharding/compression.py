"""Gradient compression: int8 ring all-reduce with f32 accumulation.

A genuine wire-level 4x: the ring is written manually in shard_map with
jax.lax.ppermute, and every hop's payload is an int8-quantized partial
(per-chunk f32 scales ride along, amortized).  Accumulation happens in
f32 locally, so quantization error is one rounding per hop (error feed
-back is left as a knob).

Use for the DP gradient sync of the pure-DP / small-model tier, where the
grad all-reduce is the only collective (EXPERIMENTS.md §Perf): wraps as

    sync = make_int8_allreduce(mesh, axis="data")
    grads = jax.tree.map(sync, grads)        # inside shard_map context
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "int8_ring_allreduce",
           "make_int8_allreduce"]


def _axis_size(axis_name: str) -> int:
    """Static mapped-axis size; jax.lax.axis_size is newer than 0.4.x
    (older jax exposes it via core.axis_frame)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)   # an int on 0.4.x
    return frame if isinstance(frame, int) else frame.size


def quantize_int8(x):
    """Symmetric per-tensor int8; returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def int8_ring_allreduce(x, axis_name: str):
    """Ring all-reduce whose wire payloads are int8 (+1 f32 scale).

    reduce-scatter phase: n-1 hops, each sending an int8-quantized chunk
    to the next rank and accumulating in f32; all-gather phase: n-1 hops
    circulating the reduced int8 chunks.  Payload per hop = bytes/4 of the
    f32 equivalent.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    orig_shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)                       # chunk c per rank
    perm = [(i, (i + 1) % n) for i in range(n)]

    # ---- reduce-scatter: rank r starts with its copy of chunk (r+1) and
    # at hop s receives the partial for chunk (r-s+1), adding its own copy;
    # after n-1 hops it holds the full sum of chunk (r+2-n) mod n.
    acc = chunks[(idx + 1) % n]                        # start: own copy
    for step in range(1, n):
        q, s = quantize_int8(acc)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv = dequantize_int8(q, s)
        mine = jnp.take(chunks, (idx - step + 1) % n, axis=0)
        acc = recv + mine

    # ---- all-gather: circulate the reduced chunks n-1 hops (int8 wire)
    out = jnp.zeros_like(chunks)
    cur_id = (idx + 2 - n) % n                         # chunk we now own
    q, s = quantize_int8(acc)
    out = out.at[cur_id].set(dequantize_int8(q, s))
    for _ in range(n - 1):
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        cur_id = (cur_id - 1) % n
        out = out.at[cur_id].set(dequantize_int8(q, s))
    flat_out = out.reshape(-1)
    if pad:
        flat_out = flat_out[:-pad]
    return flat_out.reshape(orig_shape).astype(x.dtype)


def make_int8_allreduce(mesh: Mesh, axis: str = "data"):
    """shard_map-wrapped tree all-reduce over `axis` with int8 wire."""

    def sync_tree(tree):
        def one(x):
            fn = shard_map(
                functools.partial(int8_ring_allreduce, axis_name=axis),
                mesh=mesh, in_specs=P(), out_specs=P(), check_rep=False)
            return fn(x)
        return jax.tree.map(one, tree)

    return sync_tree
