"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Tensors carry *logical* axis names; a rule table maps each name to the mesh
axes it may shard over.  Resolution:

  * parameters / jit inputs — strict: an axis is used only if the dimension
    divides the mesh-axes product (jax rejects uneven input shardings);
    otherwise the dimension is replicated.
  * activations — permissive: uneven GSPMD sharding is allowed (XLA pads),
    but a mesh axis is never used twice within one tensor and tiny dims
    (dim < shards) fall back to replication.

Rule tables:
  TRAIN_RULES        — DP over (pod, data), TP over model, FSDP(ZeRO-3)
                       weight sharding over data for `fsdp=True` archs.
  SERVE_RULES        — decode: batch over (pod, data); KV-cache *sequence*
                       over model (flash-decode style: kv-head counts are
                       too small to shard, sequence is not).
  LONG_DECODE_RULES  — batch=1 long-context: sequence/state sharded over
                       both data and model.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "AxisRules", "ParamMeta", "TRAIN_RULES", "SERVE_RULES",
    "LONG_DECODE_RULES", "PURE_DP_TRAIN_RULES",
    "resolve_spec", "constrain", "param_pspecs",
]


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    """Shape + logical axes + dtype for one parameter tensor."""
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    table: dict[str, tuple[str, ...]]

    def get(self, logical: str | None) -> tuple[str, ...]:
        if logical is None:
            return ()
        return self.table.get(logical, ())


# --------------------------------------------------------------- tables

def _t(**kw) -> AxisRules:
    return AxisRules({k: (v,) if isinstance(v, str) else tuple(v)
                      for k, v in kw.items() if v is not None})


TRAIN_RULES = _t(
    # parameters
    vocab="model", heads="model", kv="model", ff="model", expert="model",
    ssm_inner="model", conv_dim="model",
    embed_fsdp=("pod", "data"),     # only emitted when cfg.fsdp
    # activations
    act_batch=("pod", "data"), act_heads="model", act_ff="model",
    act_vocab="model", act_expert="model", act_ssm="model",
)

SERVE_RULES = _t(
    vocab="model", heads="model", kv="model", ff="model", expert="model",
    ssm_inner="model", conv_dim="model",
    embed_fsdp=("pod", "data"),     # 2D weight sharding: 340B/1T archs do
                                    # not fit 16-way TP on 16 GB chips
    act_batch=("pod", "data"), act_heads="model", act_ff="model",
    act_vocab="model", act_expert="model", act_ssm="model",
    cache_batch=("pod", "data"),
    cache_seq="model",              # flash-decode: shard KV sequence
)

# Hillclimb variant (EXPERIMENTS.md §Perf, mamba2 cell): sub-1B models on
# a 256-chip pod waste the mesh on TP all-reduces (the weights fit
# per-chip).  Pure DP: every mesh axis becomes batch; weights and
# optimizer state replicate (ZeRO-1 sharding of the state is the logical
# next step for the 1-10B tier and is noted as future work).
PURE_DP_TRAIN_RULES = _t(
    act_batch=("pod", "data", "model"),
)

LONG_DECODE_RULES = _t(
    vocab="model", heads="model", kv="model", ff="model", expert="model",
    ssm_inner="model", conv_dim="model",
    embed_fsdp=("pod", "data"),
    act_heads="model", act_ff="model", act_vocab="model", act_ssm="model",
    cache_seq=("data", "model"),    # batch=1: all parallelism into sequence
    state_heads="model",            # SSM decode state heads
)


# ------------------------------------------------------------ resolution

def _mesh_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def resolve_spec(
    mesh: Mesh,
    rules: AxisRules,
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    *,
    strict: bool,
) -> PartitionSpec:
    """Map logical axes -> PartitionSpec under the rule table.

    If the full mesh-axis tuple does not fit a dimension, suffixes are
    tried (e.g. batch=256 on ('pod','data','model')=512 falls back to
    ('data','model')=256) — this is what lets one rule table serve both
    the single-pod and multi-pod meshes."""
    used: set[str] = set()
    out: list[Any] = []
    for dim, logical in zip(shape, axes):
        cand = [a for a in rules.get(logical)
                if a in mesh.shape and a not in used]
        placed = False
        while cand:
            size = _mesh_size(mesh, tuple(cand))
            ok = (dim % size == 0) if strict else (dim >= size)
            if ok:
                used.update(cand)
                out.append(tuple(cand) if len(cand) > 1 else cand[0])
                placed = True
                break
            cand = cand[1:]             # drop the leading (outermost) axis
        if not placed:
            out.append(None)
    return PartitionSpec(*out)


def constrain(x, mesh: Mesh, rules: AxisRules, *axes: str | None):
    """with_sharding_constraint by logical names (permissive resolution)."""
    if mesh is None:
        return x
    spec = resolve_spec(mesh, rules, tuple(axes), tuple(x.shape), strict=False)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def param_pspecs(metas, mesh: Mesh, rules: AxisRules):
    """Pytree of ParamMeta -> pytree of PartitionSpec (strict)."""
    return jax.tree.map(
        lambda m: resolve_spec(mesh, rules, m.axes, m.shape, strict=True),
        metas,
        is_leaf=lambda m: isinstance(m, ParamMeta),
    )


def abstract_params(metas):
    """Pytree of ParamMeta -> ShapeDtypeStruct (dry-run stand-ins)."""
    return jax.tree.map(
        lambda m: jax.ShapeDtypeStruct(m.shape, np.dtype(m.dtype)),
        metas,
        is_leaf=lambda m: isinstance(m, ParamMeta),
    )
