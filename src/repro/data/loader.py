"""Deterministic, resumable, shardable token pipeline.

Synthetic LM corpus with learnable structure (order-2 Markov chain over the
vocab): loss provably decreases under training, unlike iid tokens.  The
loader state is a plain (step, seed) tuple — checkpoint it and resume
bit-identically on any host; each data shard draws a disjoint substream
(host-sharded input pipeline)."""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    step: int = 0
    markov_temp: float = 0.5       # lower = more predictable corpus

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse row-stochastic transition matrix (8 successors per token)
        self._succ = rng.integers(0, v, size=(v, 8))
        logits = rng.standard_normal((v, 8)) / self.markov_temp
        p = np.exp(logits - logits.max(1, keepdims=True))
        self._p = p / p.sum(1, keepdims=True)

    # ------------------------------------------------------------ state

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed, "shard": self.shard,
                "n_shards": self.n_shards}

    @classmethod
    def from_state(cls, state: dict, **kw) -> "TokenStream":
        return cls(step=state["step"], seed=state["seed"],
                   shard=state["shard"], n_shards=state["n_shards"], **kw)

    # ------------------------------------------------------------ batches

    def _gen(self, rng, n_rows):
        v = self.vocab_size
        toks = np.empty((n_rows, self.seq_len + 1), np.int32)
        cur = rng.integers(0, v, size=n_rows)
        toks[:, 0] = cur
        for t in range(1, self.seq_len + 1):
            u = rng.random(n_rows)
            cum = np.cumsum(self._p[cur], axis=1)
            choice = (u[:, None] < cum).argmax(1)
            cur = self._succ[cur, choice]
            toks[:, t] = cur
        return toks

    def next(self) -> dict:
        """Returns {"tokens", "labels"} for this shard; advances state."""
        assert self.batch_size % self.n_shards == 0
        rows = self.batch_size // self.n_shards
        # disjoint deterministic substream per (step, shard)
        rng = np.random.default_rng(
            (self.seed, self.step, self.shard))
        toks = self._gen(rng, rows)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
