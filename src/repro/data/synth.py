"""Synthetic datasets with exact ground truth.

The container is offline, so the paper's SIFT/GIST/Glove/Deep datasets are
replaced by clustered Gaussians of the *same dimensionalities* (128 / 960 /
100 / 96).  Clustered (not iid) data is essential: iid Gaussians in high d
have near-constant pairwise distances, which makes ANN trivially hard and
un-representative; mixtures reproduce the local-neighborhood structure that
HNSW exploits.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["VectorDataset", "make_dataset", "PAPER_DIMS", "ground_truth",
           "recall_at_k"]

# dims matching the paper's datasets (Table I)
PAPER_DIMS = {"sift1m": 128, "gist": 960, "glove": 100, "deep1m": 96}


@dataclasses.dataclass
class VectorDataset:
    name: str
    base: np.ndarray      # (n, d) database vectors
    queries: np.ndarray   # (nq, d)
    gt: np.ndarray        # (nq, k_gt) exact NN ids (ascending distance)

    @property
    def n(self) -> int:
        return self.base.shape[0]

    @property
    def d(self) -> int:
        return self.base.shape[1]


def ground_truth(base: np.ndarray, queries: np.ndarray, k: int,
                 chunk: int = 2048) -> np.ndarray:
    """Exact brute-force k-NN ids, chunked over the base set."""
    base = np.asarray(base, np.float32)
    queries = np.asarray(queries, np.float32)
    nq = queries.shape[0]
    qn = (queries * queries).sum(1)[:, None]
    best_d = np.full((nq, k), np.inf, np.float32)
    best_i = np.full((nq, k), -1, np.int64)
    for start in range(0, base.shape[0], chunk):
        xs = base[start:start + chunk]
        d = qn - 2.0 * queries @ xs.T + (xs * xs).sum(1)[None, :]
        cat_d = np.concatenate([best_d, d], axis=1)
        cat_i = np.concatenate(
            [best_i, np.broadcast_to(start + np.arange(xs.shape[0])[None, :],
                                     (nq, xs.shape[0]))], axis=1)
        sel = np.argsort(cat_d, axis=1)[:, :k]
        best_d = np.take_along_axis(cat_d, sel, axis=1)
        best_i = np.take_along_axis(cat_i, sel, axis=1)
    return best_i


def make_dataset(
    name: str = "sift1m",
    n: int = 20_000,
    n_queries: int = 100,
    k_gt: int = 100,
    n_clusters: int = 64,
    cluster_std: float = 0.35,
    seed: int = 0,
    d: int | None = None,
) -> VectorDataset:
    """Clustered-Gaussian stand-in for the paper's datasets."""
    d = d or PAPER_DIMS[name]
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32) * 2.0
    assign = rng.integers(0, n_clusters, size=n)
    base = centers[assign] + cluster_std * rng.standard_normal(
        (n, d)).astype(np.float32)
    qassign = rng.integers(0, n_clusters, size=n_queries)
    queries = centers[qassign] + cluster_std * rng.standard_normal(
        (n_queries, d)).astype(np.float32)
    gt = ground_truth(base, queries, min(k_gt, n))
    return VectorDataset(name=name, base=base, queries=queries, gt=gt)


def recall_at_k(found_ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    """Recall@k = |found ∩ exact| / k, averaged over queries (paper §VII)."""
    found_ids = np.atleast_2d(found_ids)
    gt = np.atleast_2d(gt)[:, :k]
    hits = 0
    for f, g in zip(found_ids, gt):
        hits += len(set(f[:k].tolist()) & set(g.tolist()))
    return hits / (gt.shape[0] * k)
