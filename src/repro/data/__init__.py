from . import synth  # noqa: F401
