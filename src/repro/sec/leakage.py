"""Leakage measurement harness (DESIGN.md §14): replay the server's view
against every security profile and score what an honest-but-curious
server actually extracts from it.

The harness is the empirical half of `repro.sec`: `profiles.py` states
what each tier hides; this module *measures* it, by reconstructing
exactly the observables the serving runtime hands the server for a
profile/backend pair (`ServerView`) and running the strongest attacks
we know against them:

  * `aspe_kpa_attack`     — the paper's §III KPA against ASPE variants
                            (the strawman the scheme replaces).  Profile
                            -independent; included so the frontier shows
                            where "no DCE" lands: success ≈ 1, broken.
  * `dce_kpa_attack`      — the §III KPA machinery *revived against
                            DCE*: the refine protocol's defined output
                            is the comparison sign of Z = 2 r_o r_p r_q
                            (d_oq - d_pq), so the KPA attacker feeds
                            sign(Z) to the Theorem-1 linear solver
                            exactly as it broke ASPE on raw scores.  It
                            fails at every tier — one bit per comparison
                            cannot support the linear reconstruction —
                            which is the paper's Theorem 3/4 claim,
                            measured rather than asserted.  (Measured
                            caveat, DESIGN.md §14: the float Z
                            *magnitudes* are NOT covered by that claim —
                            the per-row multiplicative r_o averages out
                            over many leaked rows, so a magnitude-
                            reading server recovers approximate
                            distances at every scan tier.  That residual
                            is what the "oblivious-sketch" tier's
                            TEE/FHE refine cost model prices out.)
  * `access_pattern_attack` — query localization from WHICH filter rows
                            each query's scan touched: the attacker
                            averages the touched DCPE ciphertexts and
                            uses the result as a query estimate.  This
                            succeeds against pooled IVF scans ("perf" /
                            "balanced") and collapses to the zero-
                            leakage baseline under the scan-oblivious
                            tiers ("hardened" / "oblivious-sketch"),
                            where every query touches every row.  The
                            graph backend reports its beam traversal's
                            visited bitmap as the trace: data-dependent
                            at every tier (the bounded-hop oblivious
                            variant fixes counts, not addresses), so
                            its hardened row lands between pooled IVF
                            and the full-bucket scan (DESIGN.md §15).
  * `adc_code_attack`     — the same localization run on the *decoded
                            ADC codes* instead of the f32 ciphertexts:
                            the quantized codes are stored server-side
                            with a keyless codebook, so they are fair
                            game for the attacker.  Distinguishes the
                            quantized backends' leakage tiers.

Every attack reports `normalized_success` in [0, 1] against an explicit
random-guess baseline (`core.attacks`), so "broken" (≈ 1) and "at
chance" (≈ 0) mean the same thing across data scales and attacks —
that is what makes the BENCH_attacks.json frontier comparable across
profiles, backends, and attack families.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import attacks, dcpe, ppanns
from ..data import synth
from .profiles import SecurityProfile, get_profile

__all__ = [
    "AttackResult",
    "ServerView",
    "capture_server_view",
    "aspe_kpa_attack",
    "dce_kpa_attack",
    "adc_code_attack",
    "access_pattern_attack",
    "evaluate_profile",
]


@dataclasses.dataclass(frozen=True)
class AttackResult:
    """One attack against one server view, scored against chance."""

    attack: str           # attack family, e.g. "access-pattern"
    profile: str          # security profile the view was captured under
    backend: str          # filter backend ("ivf", "ivf+int8", ...)
    err: float            # raw recovery error (attack-specific metric)
    baseline: float       # the same metric for a zero-leakage guesser
    success: float        # normalized in [0,1]: 1 broken, 0 at chance

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServerView:
    """Exactly what the honest-but-curious server observes for one
    profile/backend run — plus the plaintexts, which only the *evaluator*
    reads (to score recovery error; the attacks' inputs are the
    ciphertext fields and `touched`).

    `touched[i, j]` is True iff query i's filter scan read corpus row j:
    the access pattern a server (or anyone watching its memory traffic)
    records for free.  Pooled IVF scans touch only the probed posting
    lists; the scan-oblivious variants touch every row by construction.
    `first_touched` refines it with scan *order*, which the trace also
    exposes: the rows of the first-probed (nearest-centroid) posting
    list.  An oblivious scan is one undifferentiated full-bucket pass,
    so there `first_touched == touched` — order carries nothing.
    """

    profile: str
    backend: str
    C_sap: np.ndarray                     # (n, d) DCPE filter ciphertexts
    C_dce: np.ndarray                     # (n, 4, cdim) DCE ciphertexts
    Q_sap: np.ndarray                     # (nq, d) query filter ciphertexts
    T_q: np.ndarray                       # (nq, cdim) trapdoors
    touched: np.ndarray                   # (nq, n) bool access pattern
    first_touched: np.ndarray             # (nq, n) bool first-scanned rows
    codes_decoded: np.ndarray | None      # (n, d) decoded ADC codes
    P: np.ndarray                         # evaluator-only ground truth
    Q: np.ndarray                         # evaluator-only ground truth


def capture_server_view(
    profile: SecurityProfile | str,
    backend: str = "ivf",
    quantization: str | None = None,
    *,
    n: int = 2048,
    d: int = 32,
    nq: int = 64,
    k: int = 10,
    seed: int = 0,
) -> ServerView:
    """Build a small encrypted collection under `profile`, serve `nq`
    queries through the real backend scan paths, and record the server's
    observables.  Clustered data (`repro.data.synth`) keeps the access
    pattern informative — iid Gaussians would understate the leak."""
    from ..serving.runtime.collections import Collection

    prof = get_profile(profile)
    ds = synth.make_dataset("sift1m", n=n, n_queries=nq, d=d,
                            k_gt=k, seed=seed)
    beta = dcpe.suggest_beta(ds.base, fraction=0.01)
    owner = ppanns.DataOwner(d=d, sap_beta=beta, sap_s=1024.0, seed=seed)
    user = ppanns.User(owner.share_keys(), seed=seed + 1)
    C_sap, C_dce = owner.encrypt_vectors(ds.base)
    pairs = [user.encrypt_query(q) for q in ds.queries]
    Q_sap = np.stack([c for c, _ in pairs])
    T_q = np.stack([t for _, t in pairs])

    kw = {"quantization": quantization} if quantization else {}
    col = Collection("leak", f"{prof.name}-{backend}", d, backend=backend,
                     seed=seed, keyless=True,
                     security_profile=prof.name, **kw)
    try:
        col.insert_encrypted(C_sap, C_dce)
        # run the real scan path once: attaches the IVF/ADC state the
        # access pattern derives from, and proves the profile serves
        col.search_batch(Q_sap, T_q, k)
        bk = col._backend
        touched = np.zeros((nq, n), bool)
        first_touched = np.zeros((nq, n), bool)
        trace = getattr(bk, "last_scan_trace", None)
        if trace is not None:
            # Graph backend: the traversal's visited bitmap IS the access
            # pattern — which rows each query's beam expansion gathered.
            # It stays data-dependent even under the oblivious profile
            # (fixed hop/fanout COUNTS, data-dependent gather ADDRESSES:
            # the bounded-hop tier, DESIGN.md §15), so the graph's
            # hardened row sits between pooled IVF and the oblivious
            # full-bucket scan rather than collapsing to baseline.  The
            # expansion is one undifferentiated frontier stream, so
            # order carries nothing beyond membership.
            touched = np.asarray(trace, bool)[:, :n]
            first_touched = touched.copy()
        elif prof.oblivious or bk.ivf is None:
            touched[:, :] = True          # full-bucket scan, every query
            first_touched[:, :] = True    # one pass: no order signal
        else:
            for i, q in enumerate(Q_sap):
                cells = bk.ivf.partition_of(q, bk.nprobe)
                for j, c in enumerate(cells):
                    rows = np.asarray(bk.ivf.lists[c], np.int64)
                    touched[i, rows] = True
                    if j == 0:
                        first_touched[i, rows] = True
        codes_decoded = None
        cb = getattr(bk, "adc_codebook", None)
        if cb is not None:
            enc = cb.encode(C_sap)
            codes = enc[0] if isinstance(enc, tuple) else enc
            codes_decoded = np.asarray(cb.decode(codes), np.float32)
    finally:
        col.close()

    name = backend if not quantization else f"{backend}+{quantization}"
    return ServerView(profile=prof.name, backend=name, C_sap=C_sap,
                      C_dce=C_dce, Q_sap=Q_sap, T_q=T_q, touched=touched,
                      first_touched=first_touched,
                      codes_decoded=codes_decoded, P=ds.base, Q=ds.queries)


# ---------------------------------------------------------------------------
# The attacks.
# ---------------------------------------------------------------------------

def aspe_kpa_attack(transform: str = "linear", *, d: int = 8, n: int = 64,
                    nq: int = 24, seed: int = 0) -> AttackResult:
    """The §III KPA against the ASPE strawman (profile-independent):
    recovery to numerical precision, success ≈ 1.  The frontier's
    'what the scheme replaced' row."""
    rep = attacks.attack_report(d=d, n=n, nq=nq, transform=transform,
                                seed=seed)
    return AttackResult(attack=f"aspe-kpa-{transform}", profile="(aspe)",
                        backend="(none)", err=rep["query_err"],
                        baseline=rep["query_baseline"],
                        success=rep["query_success"])


def dce_kpa_attack(view: ServerView, n_leak: int | None = None
                   ) -> AttackResult:
    """The §III Theorem-1 KPA revived against DCE's comparison output.

    The refine stage's defined output per candidate pair is the SIGN of
    Z(o, pivot; q) = 2 r_o r_piv r_q (d(o,q) - d(pivot,q)) — "is o
    closer than the pivot".  A KPA attacker who leaked `n_leak`
    plaintext rows replays Theorem 1 on that observable: feed sign(Z)
    as the leak matrix and solve for the queries, exactly the attack
    that broke ASPE's raw scores.  It fails at every tier — one bit per
    (row, query) pair cannot support the d+2-unknown linear
    reconstruction — so the query estimate lands at the zero-leakage
    baseline.  That is the paper's Theorem 3/4 claim as a measurement.

    (Caveat, deliberately not gated here: the float Z *magnitudes* do
    leak — the per-row multiplicative r_o averages out under least
    squares over many leaked rows, so a magnitude-reading server
    recovers approximate distance differences at every scan tier.  The
    sign-only restriction below is the scheme's claimed interface; the
    magnitude residual is the "oblivious-sketch" tier's motivation and
    is discussed in DESIGN.md §14.)"""
    from ..core import dce

    d = view.P.shape[1]
    if n_leak is None:
        n_leak = min(8 * (d + 2), view.P.shape[0] // 2)
    C = view.C_dce.astype(np.float64)
    piv = view.C_dce.shape[0] - 1             # pivot outside the leaked set
    # Z[i, q] for the leaked rows vs every trapdoor — what the server's
    # own refine computes (core.dce.distance_comp, batched over queries)
    T = view.T_q.astype(np.float64)
    Z = ((C[:n_leak, 0, :] * C[piv, 2, :][None]) @ T.T
         - (C[:n_leak, 1, :] * C[piv, 3, :][None]) @ T.T)   # (n_leak, nq)
    assert np.allclose(
        Z[:2], np.stack([dce.distance_comp(view.C_dce[i], view.C_dce[piv],
                                           view.T_q.astype(np.float64))
                         for i in range(2)]), rtol=1e-3, atol=1e-3)
    Q_hat, _ = attacks.recover_queries_linear(view.P[:n_leak], np.sign(Z),
                                              transform="linear")
    err = float(np.median(np.linalg.norm(Q_hat - view.Q, axis=1)))
    baseline = float(np.median(np.linalg.norm(
        view.P[:n_leak].mean(0, keepdims=True) - view.Q, axis=1)))
    return AttackResult(attack="dce-kpa-sign", profile=view.profile,
                        backend=view.backend, err=err, baseline=baseline,
                        success=attacks.normalized_success(err, baseline))


def _localize(view: ServerView, rows: np.ndarray) -> AttackResult:
    """Shared core of the access-pattern attacks: estimate each query's
    filter ciphertext as the mean of the rows its scan touched FIRST
    (the nearest-centroid posting list — scan order is part of the
    trace), and score against the uninformed guess (the global corpus
    centroid — exactly what the estimate degenerates to when every
    query's scan is one undifferentiated full-bucket pass)."""
    sel = view.first_touched
    nq = sel.shape[0]
    counts = sel.sum(1, keepdims=True).astype(np.float64)
    Q_hat = (sel.astype(np.float64) @ rows.astype(np.float64)
             ) / np.maximum(counts, 1)
    err = float(np.median(
        np.linalg.norm(Q_hat - view.Q_sap, axis=1)))
    centroid = rows.mean(0, keepdims=True).astype(np.float64)
    baseline = float(np.median(np.linalg.norm(
        np.broadcast_to(centroid, (nq, rows.shape[1])) - view.Q_sap,
        axis=1)))
    return AttackResult(attack="", profile=view.profile,
                        backend=view.backend, err=err, baseline=baseline,
                        success=attacks.normalized_success(err, baseline))


def access_pattern_attack(view: ServerView) -> AttackResult:
    """Query localization from the filter access pattern over the f32
    DCPE ciphertexts: which rows a pooled IVF scan touches pins the
    query to its probed cells; a scan-oblivious profile touches all
    rows, collapsing the estimate to the global centroid (= baseline)."""
    res = _localize(view, view.C_sap)
    return dataclasses.replace(res, attack="access-pattern")


def adc_code_attack(view: ServerView) -> AttackResult:
    """The access-pattern distinguisher run on the decoded ADC codes:
    the server holds the codebook (it is keyless by design, DESIGN.md
    §11), so decoded codes are part of its view.  Quantization does not
    hide the pooled access pattern — only the oblivious scan does."""
    if view.codes_decoded is None:
        raise ValueError(
            f"view for backend {view.backend!r} has no ADC codes: "
            "capture with quantization='int8' or 'pq'")
    res = _localize(view, view.codes_decoded)
    return dataclasses.replace(res, attack="adc-code-pattern")


def evaluate_profile(
    profile: SecurityProfile | str,
    backend: str = "ivf",
    quantization: str | None = None,
    *,
    n: int = 2048,
    d: int = 32,
    nq: int = 64,
    seed: int = 0,
) -> list[AttackResult]:
    """Capture one server view and run every applicable attack against
    it — one frontier point's leakage column."""
    view = capture_server_view(profile, backend, quantization,
                               n=n, d=d, nq=nq, seed=seed)
    results = [dce_kpa_attack(view), access_pattern_attack(view)]
    if view.codes_decoded is not None:
        results.append(adc_code_attack(view))
    return results
