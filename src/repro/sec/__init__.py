"""repro.sec — leakage-tiered security profiles + the leakage
measurement harness (DESIGN.md §14).

Two halves:

  `profiles`  the `SecurityProfile` tiers (`perf` / `balanced` /
              `hardened` / `oblivious-sketch`) wired through
              `IndexSpec.security_profile` — each names one point on
              the leakage-vs-QPS frontier (batch padding, dummy-query
              injection, fixed-shape results, scan-oblivious filters).
  `leakage`   the measurement side: replay the server's view
              (ciphertexts, ADC codes, access traces) and run the
              revived §III KPA attacks plus the new DCE/ADC/trace
              distinguishers against every profile, reporting
              normalized attack success (0 = random guessing, 1 =
              exact recovery).

`benchmarks/bench_attacks.py` joins the two into the repo-root
`BENCH_attacks.json` frontier; `scripts/check_api.py` gates this
export surface.
"""

import importlib

_EXPORTS = {
    # profiles
    "SecurityProfile": ".profiles",
    "PROFILES": ".profiles",
    "SECURITY_PROFILE_NAMES": ".profiles",
    "DEFAULT_PROFILE": ".profiles",
    "get_profile": ".profiles",
    # leakage harness
    "AttackResult": ".leakage",
    "ServerView": ".leakage",
    "capture_server_view": ".leakage",
    "aspe_kpa_attack": ".leakage",
    "dce_kpa_attack": ".leakage",
    "adc_code_attack": ".leakage",
    "access_pattern_attack": ".leakage",
    "evaluate_profile": ".leakage",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(_EXPORTS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
