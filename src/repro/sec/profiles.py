"""Leakage-tiered security profiles (DESIGN.md §14).

A `SecurityProfile` names one point on the leakage-vs-QPS frontier: how
much of the server's *observable behaviour* — batch shapes, result
sizes, which rows a scan touches — is flattened so it stops being a
function of the plaintext workload.  The ciphertext story (DCPE filter
+ DCE refine, and the keyless ADC codes derived from the DCPE
ciphertexts) is identical under every profile; profiles only change the
side channels around it:

  perf              the engine exactly as PR 1-7 ship it.  Batches pad
                    by replicating a real query, results carry exactly
                    the requested k columns, IVF scans touch only the
                    probed partitions.  Fastest; the trace/wire
                    observables correlate with the workload.
  balanced          wire observables flattened at ~zero compute cost:
                    batch padding rows are *dummy* (zero) queries
                    riding the existing row-validity stream, and result
                    ids are padded to a power-of-two column bucket so
                    result count / requested k never leak.  The scan
                    itself is unchanged.
  hardened          balanced + access-pattern flattening: every flush
                    pads to the full warmup-compiled `max_batch` bucket
                    (batch size never leaks, still zero recompiles) and
                    IVF/ADC filters run the scan-oblivious full-bucket
                    variant — every resident row is touched for every
                    query, no data-dependent early exit, so the access
                    trace and `filter_bytes_scanned` are constants.
  oblivious-sketch  hardened, plus a TEE/FHE-hybrid *refine* cost model
                    (`tee_refine_cost`, after Saeki et al., PAPERS.md):
                    the candidate-gather + tournament priced as if it
                    ran inside an enclave with FHE-assisted distance
                    comparison.  The sketch is a measured-constant cost
                    model, not an enclave runtime — the top rung of the
                    frontier is reported, not served.

Profiles never change *results*: dummy rows are dropped before emit,
padding columns are -1 (stripped by `SearchResult.ids_lists`), and the
oblivious scans compute the same distances over a superset of rows —
the cross-profile parity tests pin returned real ids bit-identical to
`perf` across schedulers and placements.
"""

from __future__ import annotations

import dataclasses

__all__ = ["SecurityProfile", "PROFILES", "SECURITY_PROFILE_NAMES",
           "DEFAULT_PROFILE", "get_profile"]

# scheduler batch-padding policies (runtime/batcher.py, slot_loop.py)
PAD_REPLICATE = "replicate"     # pad rows replicate a real query (perf)
PAD_DUMMY = "dummy"             # pad rows are zero dummy queries
PAD_FULL = "full"               # dummy-pad every flush to max_batch

_RESULT_COL_MIN = 16            # smallest padded result-column bucket


def _next_pow2(n: int, minimum: int) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class SecurityProfile:
    """One leakage tier: which observables are flattened, at what cost.

    `pad_policy` drives the schedulers' batch padding, `pad_results`
    the fixed-shape result columns, `oblivious` the full-bucket filter
    scans, `refine` the refine costing (`"dce"` = the served exact
    tournament; `"tee-sketch"` = the DCE tournament served + the
    TEE/FHE-hybrid cost model reported)."""

    name: str
    pad_policy: str = PAD_REPLICATE
    pad_results: bool = False
    oblivious: bool = False
    refine: str = "dce"                  # "dce" | "tee-sketch"
    description: str = ""

    def result_width(self, k: int) -> int:
        """Padded result-column count for a requested k: the next
        power-of-two bucket (>= 16) under padding profiles, exactly k
        under `perf` — so neither k nor per-query hit counts are
        readable off the result wire size."""
        if not self.pad_results:
            return int(k)
        return _next_pow2(int(k), _RESULT_COL_MIN)

    def tee_refine_cost(self, n_candidates: int, d: int) -> dict:
        """The `oblivious-sketch` refine cost model (Saeki et al.,
        PAPERS.md): a TEE-resident tournament whose DCE comparisons are
        FHE-assisted.  Constants: ~40x per-comparison slowdown for the
        in-enclave FHE comparison circuit and a fixed per-batch enclave
        transition (~0.1 ms-equivalent, expressed in comparisons).
        Returns the comparison budget and the multiplier vs the served
        plaintext-speed DCE tournament — the reported (not served) top
        rung of the frontier."""
        comparisons = int(n_candidates) * int(n_candidates)
        fhe_comp_x = 40.0
        enclave_transition_comps = 4096
        total = comparisons * fhe_comp_x + enclave_transition_comps
        return {
            "mode": "tee-sketch",
            "comparisons": comparisons,
            "fhe_comparison_slowdown_x": fhe_comp_x,
            "enclave_transition_comparisons": enclave_transition_comps,
            "est_cost_vs_dce_x": total / max(comparisons, 1),
        }


PROFILES: dict[str, SecurityProfile] = {
    p.name: p for p in (
        SecurityProfile(
            name="perf",
            description="no flattening — fastest; trace/wire observables"
                        " correlate with the workload"),
        SecurityProfile(
            name="balanced",
            pad_policy=PAD_DUMMY,
            pad_results=True,
            description="dummy-query batch padding + fixed-shape results;"
                        " scans unchanged"),
        SecurityProfile(
            name="hardened",
            pad_policy=PAD_FULL,
            pad_results=True,
            oblivious=True,
            description="full-bucket dummy padding + scan-oblivious"
                        " filters; access trace is constant"),
        SecurityProfile(
            name="oblivious-sketch",
            pad_policy=PAD_FULL,
            pad_results=True,
            oblivious=True,
            refine="tee-sketch",
            description="hardened + TEE/FHE-hybrid refine cost model"
                        " (reported, not served)"),
    )
}

SECURITY_PROFILE_NAMES = tuple(PROFILES)
DEFAULT_PROFILE = PROFILES["perf"]


def get_profile(name: str | SecurityProfile) -> SecurityProfile:
    """Resolve a profile by name (idempotent on profile objects)."""
    if isinstance(name, SecurityProfile):
        return name
    try:
        return PROFILES[name]
    except KeyError:
        raise ValueError(f"unknown security profile {name!r} "
                         f"(have {SECURITY_PROFILE_NAMES})") from None
