"""Deprecated: `repro.ft` moved into `repro.resilience` (DESIGN.md
§16).  This package remains as an import-compatible shim."""

from ..resilience.runner import (ResilientRunner, RetryPolicy,  # noqa: F401
                                 StragglerWatchdog)

__all__ = ["RetryPolicy", "ResilientRunner", "StragglerWatchdog"]
