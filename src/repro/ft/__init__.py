from .runner import RetryPolicy, ResilientRunner, StragglerWatchdog  # noqa: F401
