"""Deprecated location: the checkpoint-restart runner moved to
`repro.resilience.runner` (DESIGN.md §16), ported off raw
`time.sleep` / `time.perf_counter` onto the injected `Clock` seam.
This shim re-exports the new implementations; behaviour under the
default `SystemClock` is unchanged."""

from __future__ import annotations

import warnings

from ..resilience.runner import (ResilientRunner, RetryPolicy,  # noqa: F401
                                 StragglerWatchdog)

__all__ = ["RetryPolicy", "ResilientRunner", "StragglerWatchdog"]

warnings.warn(
    "repro.ft.runner is deprecated; import RetryPolicy/ResilientRunner/"
    "StragglerWatchdog from repro.resilience (clock-seam port, "
    "DESIGN.md §16)", DeprecationWarning, stacklevel=2)
