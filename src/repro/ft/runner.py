"""Fault tolerance: retrying step runner + straggler watchdog.

On a real multi-pod deployment the failure domain is a host/chip dropping
out of the collective; jax surfaces that as a raised exception on the
coordinator.  The recovery loop below is the production shape:

    run step -> exception? -> restore latest checkpoint -> rebuild mesh
    (possibly smaller: elastic) -> continue

`ResilientRunner` implements that loop; failures are injected in tests via
a hook.  `StragglerWatchdog` covers the other production failure mode —
a slow host — by timing steps against a rolling median and re-dispatching
work (host-level input shards) that exceeds the deadline factor.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["RetryPolicy", "ResilientRunner", "StragglerWatchdog"]


@dataclasses.dataclass
class RetryPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.0         # real deployments back off; tests don't


class ResilientRunner:
    """Wraps a step function with checkpoint-restart semantics."""

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, policy: RetryPolicy = RetryPolicy(),
                 checkpoint_every: int = 10):
        self.step_fn = step_fn
        self.save_fn = save_fn          # (step, state) -> None
        self.restore_fn = restore_fn    # () -> (step, state)
        self.policy = policy
        self.checkpoint_every = checkpoint_every
        self.restarts = 0
        self.failures_seen = 0

    def run(self, state, start_step: int, n_steps: int, get_batch):
        """Run n_steps; on failure restore the latest checkpoint and replay.
        get_batch(step) must be deterministic in step (resumable loader)."""
        step = start_step
        end = start_step + n_steps
        metrics = None
        while step < end:
            try:
                state, metrics = self.step_fn(state, get_batch(step))
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(step, state)
            except Exception:
                self.failures_seen += 1
                self.restarts += 1
                if self.restarts > self.policy.max_restarts:
                    raise
                if self.policy.backoff_s:
                    time.sleep(self.policy.backoff_s)
                step, state = self.restore_fn()
        return state, step, metrics


class StragglerWatchdog:
    """Deadline-based straggler mitigation for host-side work.

    Tracks a rolling median of durations; `run_sharded` dispatches a
    callable per shard and re-dispatches (to a fallback executor) any shard
    exceeding `factor` x median — the standard backup-task trick."""

    def __init__(self, factor: float = 3.0, window: int = 32,
                 min_deadline_s: float = 1e-3):
        self.factor = factor
        self.durations: list[float] = []
        self.window = window
        self.min_deadline_s = min_deadline_s
        self.redispatches = 0

    @property
    def deadline_s(self) -> float:
        if not self.durations:
            return float("inf")
        tail = sorted(self.durations[-self.window:])
        med = tail[len(tail) // 2]
        return max(self.factor * med, self.min_deadline_s)

    def observe(self, duration_s: float):
        self.durations.append(duration_s)

    def run_sharded(self, shard_fns, fallback_fn=None):
        """Execute each shard fn; any shard slower than the deadline is
        re-run via fallback_fn (e.g., on a spare host).  Sequential here —
        the scheduling logic, not the parallel substrate, is under test."""
        results = []
        for i, fn in enumerate(shard_fns):
            t0 = time.perf_counter()
            out = fn()
            dt = time.perf_counter() - t0
            if dt > self.deadline_s and fallback_fn is not None:
                self.redispatches += 1
                out = fallback_fn(i)
            else:
                self.observe(dt)
            results.append(out)
        return results
