"""repro — Privacy-Preserving ANN Search (Liu et al., 2025) as a
multi-pod JAX framework.  See README.md / DESIGN.md / EXPERIMENTS.md."""
