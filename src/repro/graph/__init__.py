"""repro.graph — device-resident batched encrypted graph index
(DESIGN.md §15).

`csr` holds the fixed-degree CSR mirror of the owner-built HNSW
(bit-identical `.ppcol` round-trip with `core.hnsw`); `traverse` the
jitted lockstep walk (upper-layer greedy descent + layer-0 beam
search, perf and oblivious variants); `filter` the
`SecureSearchEngine` backend.  The Pallas frontier-expansion kernel
lives in `kernels.graph_expand` and is dispatched through its ops
wrapper.
"""

from . import traverse  # noqa: F401  (before filter: import-cycle order)
from .csr import CSRGraph
from .filter import GraphFilter
from .traverse import beam_plan, graph_topk

__all__ = ["CSRGraph", "GraphFilter", "beam_plan", "graph_topk",
           "traverse"]
