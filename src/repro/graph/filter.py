"""GraphFilter — the batched device-resident HNSW filter backend
(DESIGN.md §15).

The drop-in successor of `HNSWGraphFilter`: same owner-built HNSW over
DCPE ciphertexts, but traversal runs as ONE jitted lockstep walk over
the CSR mirror for the whole query batch instead of a Python loop of
per-query host walks.  That buys the graph index everything the other
backends already had:

  * batching — beams expand for all queries per hop (`graph.traverse`,
    or the graph_expand Pallas kernel on TPU);
  * quantization — edges scored with the ADC int8/pq8 surrogates of
    `core.adc` (codebook trained keylessly at attach, exactly like
    `ADCFilter`), with the same oversample-then-exact-refine contract;
  * a `hardened` tier — `oblivious=True` runs the bounded-hop,
    fixed-fanout variant (constant hop/edge counts; sec.leakage
    measures the residual address pattern via `last_scan_trace`);
  * zero steady-state recompiles — every shape is a bucket (row
    capacity R, beam capacity ef_cap, padded layer count LU), `ef`
    and validity are data.

The host walk stays as the parity oracle: ids are recall-identical at
fixed ef (tests/test_graph.py pins it), per the equivalence argument
in `graph.traverse`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core import adc
from ..core.hnsw import HNSW
from .csr import CSRGraph
from .traverse import beam_plan

__all__ = ["GraphFilter"]


class GraphFilter:
    """Batched CSR traversal filter backend for `SecureSearchEngine`.

    index: the owner-built `core.hnsw.HNSW` (over DCPE ciphertexts).
    quantization: None (exact f32 ciphertext distances) | "int8" |
    "pq8" (ADC surrogate edge scoring + candidate oversampling).
    oblivious: bounded-hop fixed-fanout traversal (the `hardened`
    profile's tier); returned ids are bit-identical to the perf
    variant (the latched-freeze contract in `graph.traverse`).
    use_kernel=True engages the Pallas frontier kernel on actual TPU
    backends (f32 mode); elsewhere the XLA lockstep walk runs.
    """

    def __init__(self, index: HNSW, *, quantization: str | None = None,
                 refine_ratio: float | None = None, pq_m: int = 16,
                 use_kernel: bool = True, oblivious: bool = False,
                 seed: int = 0):
        if quantization not in (None, "int8", "pq8"):
            raise ValueError(f"GraphFilter quantization must be "
                             f"None|int8|pq8, got {quantization!r}")
        self.index = index
        self.quantization = quantization
        self.quant = quantization or "f32"
        self.name = ("graph" if quantization is None
                     else f"adc-graph-{quantization}")
        self.refine_ratio = (
            float(refine_ratio) if refine_ratio is not None
            else adc.default_refine_ratio(quantization)
            if quantization is not None else 1.0)
        self.pq_m = pq_m
        self.use_kernel = use_kernel
        self.oblivious = oblivious
        self.seed = seed
        self.codebook = None
        self.csr: CSRGraph | None = None
        self._neigh0 = self._neigh_up = self._ok = None
        self._db = None
        self._row_bytes = 0
        self.last_filter_bytes = 0
        self.last_n_hops = 0
        self.last_n_edges_scanned = 0
        self.last_scan_trace: np.ndarray | None = None

    # --------------------------------------------------------------- setup

    def _use_pallas(self) -> bool:
        return self.use_kernel and jax.default_backend() == "tpu"

    def oversampled(self, kp: int) -> int:
        return max(kp, int(np.ceil(kp * self.refine_ratio)))

    def attach(self, C_sap: np.ndarray, engine=None):
        self.csr = CSRGraph.from_hnsw(self.index)
        g = self.csr
        self._neigh0 = jnp.asarray(g.neigh0)
        self._neigh_up = jnp.asarray(g.neigh_up)
        self._ok = jnp.asarray(g.levels >= 0)
        d = g.d
        if self.quantization is None:
            # g.X carries +inf for deleted rows; `ok` masks them, and
            # scores are computed in diff form so padded zeros are inert
            X = np.where(np.isfinite(g.X), g.X, 0.0).astype(np.float32)
            self._db = (jnp.asarray(X),)
            self._row_bytes = d * 4
            return
        rows = np.where(np.isfinite(g.X[: g.n]), g.X[: g.n], 0.0)
        rows = rows.astype(np.float32)
        self.codebook = adc.train_codebook(
            rows, self.quantization, m=self.pq_m, seed=self.seed)
        if self.quantization == "int8":
            codes, cn = self.codebook.encode(rows)
            c8 = np.zeros((g.R, d), np.int8)
            c8[: g.n] = codes
            cnp = np.zeros(g.R, np.int32)
            cnp[: g.n] = cn
            self._db = (jnp.asarray(c8), jnp.asarray(cnp))
        else:
            codes = self.codebook.encode(rows)          # (n, m) uint8
            ct = np.zeros((codes.shape[1], g.R), np.uint8)
            ct[:, : g.n] = codes.T
            self._db = (jnp.asarray(ct),)
        self._row_bytes = self.codebook.code_bytes_per_vector()

    # ---------------------------------------------------------- candidates

    def _query_operand(self, Q: np.ndarray):
        if self.quantization is None:
            return jnp.asarray(Q)
        if self.quantization == "int8":
            return jnp.asarray(self.codebook.encode_query(Q))
        return jnp.asarray(self.codebook.lut(Q))

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        from ..kernels.graph_expand import ops as graph_ops
        Q = np.asarray(Q_sap, np.float32)
        nq = Q.shape[0]
        g = self.csr
        kp2 = max(1, min(self.oversampled(kp), max(g.n, 1)))
        ef_eff, ef_cap, max_hops = beam_plan(kp2, max(ef_search, kp2))
        cand, _, visited, hops, edges = graph_ops.graph_topk(
            self._neigh0, self._neigh_up, self._ok, self._db,
            self._query_operand(Q), jnp.int32(g.entry),
            jnp.int32(ef_eff), kp=kp2, ef_cap=ef_cap,
            max_hops=max_hops, quant=self.quant,
            oblivious=self.oblivious, use_kernel=self._use_pallas())
        cand = np.asarray(cand, np.int32)
        valid = cand >= 0
        cand = np.where(valid, cand, 0)
        n_edges = int(np.asarray(edges).sum())
        self.last_n_hops = int(np.asarray(hops).sum())
        self.last_n_edges_scanned = n_edges
        # every scored edge reads one row (f32) or one code row (ADC),
        # plus the entry-point read per query
        self.last_filter_bytes = (n_edges + nq) * self._row_bytes
        self.last_scan_trace = np.asarray(visited)
        return cand, valid, n_edges + nq
