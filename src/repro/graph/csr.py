"""Fixed-degree CSR mirror of the host HNSW graph (DESIGN.md §15).

`core.hnsw.HNSW` stays the single source of truth for graph *structure*
— construction, eager delta inserts, delete-with-repair all mutate the
host object.  `CSRGraph` is a derived, device-layout mirror of it:
padded fixed-degree neighbor rows (`-1` marks empty slots) that a
batched jitted traversal can gather from with constant shapes, plus
enough bookkeeping (`levels`, `meta`, an `X` copy) to reconstruct the
host graph's `to_arrays()` encoding bit-for-bit.

Layout
  neigh0   (R, M0)      int32   layer-0 neighbor rows, -1 padded
  neigh_up (LU, R, M)   int32   layers 1..n_layers-1 (LU is a padded
                                layer capacity so a new top layer does
                                not change array ranks)
  levels   (R,)         int32   host per-node level; -1 = deleted or
                                absent (rows >= n)
  X        (R, d)       f32     host vector copy (inf for deleted rows)

R is a power-of-two row capacity chosen by the caller (the runtime
backend passes its row bucket so traversal shapes track the store's),
so incremental inserts refresh rows in place and the device arrays
reupload without recompiling; R or LU overflow forces a rebuild at the
next bucket, exactly like every other bucketed array in the repo.

Invariant inherited from the host graph: `links[lev][node]` is non-None
iff `0 <= lev <= levels[node]`, which is what lets `to_arrays` rebuild
the exact offsets stream (including `-1` absent markers) from the
padded rows alone.
"""

from __future__ import annotations

import numpy as np

from ..core.hnsw import HNSW
from ..kernels.common import next_bucket

__all__ = ["CSRGraph"]


class CSRGraph:
    def __init__(self, d: int, M: int, efC: int, R: int, LU: int):
        self.d = d
        self.M = M
        self.M0 = 2 * M
        self.efC = efC
        self.R = int(R)
        self.LU = int(LU)
        self.n = 0
        self.n_layers = 0
        self.entry = -1
        self.max_level = -1
        self.neigh0 = np.full((self.R, self.M0), -1, np.int32)
        self.neigh_up = np.full((self.LU, self.R, self.M), -1, np.int32)
        self.levels = np.full(self.R, -1, np.int32)
        self.X = np.zeros((self.R, d), np.float32)

    # ------------------------------------------------------------ build

    @classmethod
    def from_hnsw(cls, h: HNSW, R: int | None = None,
                  LU: int | None = None) -> "CSRGraph":
        """Full mirror build.  R/LU default to power-of-two buckets with
        headroom so the eager insert path refreshes in place."""
        n = h.size
        if R is None:
            R = next_bucket(max(n, 1), minimum=64)
        if R < n:
            raise ValueError(f"row capacity {R} < graph size {n}")
        n_up = max(len(h.links) - 1, 0)
        if LU is None:
            LU = next_bucket(max(n_up, 1), minimum=4)
        if LU < n_up:
            raise ValueError(f"layer capacity {LU} < {n_up} upper layers")
        g = cls(h.dim, h.M, h.efC, R, LU)
        g.refresh_rows(h, range(n))
        g.refresh_meta(h)
        return g

    def fits(self, h: HNSW) -> bool:
        """Can this mirror absorb the host graph's current shape by
        row refreshes alone (no array reallocation)?"""
        return h.size <= self.R and max(len(h.links) - 1, 0) <= self.LU

    # -------------------------------------------------- incremental sync

    def refresh_rows(self, h: HNSW, rows) -> None:
        """Re-copy the given node ids' neighbor rows / level / vector
        from the host graph — the whole incremental-update surface:
        `on_insert` passes the new node plus its selected neighbors,
        `on_delete` passes the repaired in-neighbors."""
        for node in rows:
            node = int(node)
            lvl = h.levels[node] if node < h.size else -1
            self.levels[node] = lvl
            self.X[node] = h._X[node]
            row0 = h.links[0][node] if (h.links and lvl >= 0) else None
            self.neigh0[node] = -1
            if row0 is not None and row0.size:
                self.neigh0[node, : row0.size] = row0
            for li in range(self.LU):
                self.neigh_up[li, node] = -1
                lev = li + 1
                if lev < len(h.links) and 0 <= lev <= lvl:
                    up = h.links[lev][node]
                    if up is not None and up.size:
                        self.neigh_up[li, node, : up.size] = up

    def refresh_meta(self, h: HNSW) -> None:
        self.n = h.size
        self.n_layers = len(h.links)
        self.entry = int(h.entry)
        self.max_level = int(h.max_level)

    # ------------------------------------------------------- persistence

    def to_arrays(self) -> dict:
        """Rebuild the host graph's exact `to_arrays()` encoding from the
        padded rows (bit-identical: same flat/offsets stream, dtypes,
        and meta — the `.ppcol` round-trip contract)."""
        flat: list[int] = []
        offsets: list[int] = []
        for lev in range(self.n_layers):
            rows = self.neigh0 if lev == 0 else self.neigh_up[lev - 1]
            for node in range(self.n):
                if not 0 <= lev <= self.levels[node]:
                    offsets.append(-1)
                    continue
                row = rows[node]
                cnt = int((row >= 0).sum())
                offsets.append(len(flat))
                flat.append(cnt)
                flat.extend(int(v) for v in row[:cnt])
        return {
            "X": self.X[: self.n].copy(),
            "levels": np.asarray(self.levels[: self.n], np.int32).copy(),
            "flat": np.asarray(flat, np.int32),
            "offsets": np.asarray(offsets, np.int64),
            "meta": np.asarray(
                [self.M, self.efC, self.entry, self.max_level, self.n,
                 self.n_layers]),
        }

    @classmethod
    def from_arrays(cls, arrs: dict, R: int | None = None,
                    LU: int | None = None) -> "CSRGraph":
        """Inverse of `to_arrays` via the host decoder — one decoding
        path, so the mirror cannot drift from `HNSW.from_arrays`."""
        return cls.from_hnsw(HNSW.from_arrays(dict(arrs)), R=R, LU=LU)
