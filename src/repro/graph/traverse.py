"""Batched lockstep HNSW traversal over the CSR mirror (DESIGN.md §15).

One jitted call expands *all* queries' beams together: upper layers run
a lockstep greedy descent, layer 0 a lockstep best-first beam search —
each hop selects every query's closest unexpanded beam entry, gathers
its fixed-degree neighbor row, scores the edges, and merges into the
beam with one argsort.  Every shape is a function of static buckets
only (row capacity R, beam capacity ef_cap, padded layer count LU), so
bucket growth, tombstones, and varying `ef` never recompile:

  * invalid neighbor slots (`-1` padding) and tombstoned rows ride the
    `ok` validity stream as data — masked to +inf, never a shape;
  * the *effective* ef is a traced scalar: beam slots >= ef are
    re-invalidated after every merge, so results are a pure function of
    `ef` and identical across beam-capacity buckets (which is also what
    makes per-shard traversals mergeable bit-identically);
  * edge scoring is a static `quant` mode: "f32" exact ciphertext
    distances, "int8"/"pq8" the ADC surrogate distances of the existing
    `core.adc` codebooks (rank-equivalent integer forms, DESIGN.md §11).

Equivalence with the host walk (`core.hnsw.HNSW.search`): the host's
candidate heap can only ever expand a node that is within the current
best-ef results (a popped candidate worse than the ef-th best
terminates the layer), so discarding beam entries beyond slot ef loses
nothing; both sides expand the globally closest unexpanded node next,
giving identical expansion order and identical result sets up to
floating-point ties.  tests/test_graph.py pins this parity.

`oblivious=True` is the bounded-hop fixed-fanout variant behind the
`hardened` security profile (DESIGN.md §14/§15): the loop always runs
its static trip count and every step gathers and scores a full
fixed-degree row for every query (post-compute masking), so hop count,
edges scored, and wall-clock are constants of the bucket shapes.
Per-query termination still *latches* identically in both modes — a
finished query's state is frozen, never rewritten — so returned ids
are bit-identical between the perf and oblivious variants (the
cross-profile id-parity contract).  What remains data-dependent is
*which* rows the gathers touch; sec.leakage measures exactly that
residual (the documented intermediate tier — constant volume, not
constant addresses).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["graph_topk", "traverse", "upper_entry", "beam_layer0",
           "beam_plan", "GREEDY_BOUND"]

_INF = jnp.float32(jnp.inf)

# Static trip-count ceiling of each upper layer's greedy descent.  The
# climb strictly improves per step, so real path lengths are O(log n);
# the bound only exists so the oblivious variant has a constant trip
# count (and the while_loop a termination guarantee).
GREEDY_BOUND = 64


def _score(quant: str, db, qd, ids):
    """Edge scores of `ids` (any (nq, W) int32, pre-clamped safe) for
    each query.  f32 uses the host walk's exact formulation
    (sum((x-q)^2)) so the parity suite compares like to like; int8/pq8
    are the ADC surrogates (rank-equivalent, not metric)."""
    if quant == "f32":
        (C,) = db
        rows = jnp.take(C, ids, axis=0)                  # (nq, W, d)
        diff = rows - qd[:, None, :]
        return (diff * diff).sum(-1)
    if quant == "int8":
        c8, cn = db
        rows = jnp.take(c8, ids, axis=0).astype(jnp.float32)
        cross = jnp.einsum("qwd,qd->qw", rows, qd.astype(jnp.float32))
        return jnp.take(cn, ids).astype(jnp.float32) - 2.0 * cross
    if quant == "pq8":
        (codes_t,) = db                                  # (m, R) uint8
        cc = jnp.take(codes_t, ids, axis=1)              # (m, nq, W)
        cc = jnp.transpose(cc, (1, 0, 2)).astype(jnp.int32)
        g = jnp.take_along_axis(qd, cc, axis=2)          # (nq, m, W)
        return g.sum(axis=1)
    raise ValueError(f"unknown edge-scoring mode {quant!r}")


def _climb(rows, ok, db, qd, cur, cur_d, quant: str, oblivious: bool,
           hops, edges):
    """Lockstep greedy descent over one upper layer's (R, M) rows.
    Matches HNSW._greedy: move to the argmin neighbor while it strictly
    improves.  Updates latch per query (frozen once done), so the
    early-exit and fixed-trip variants reach the same state."""
    M = rows.shape[1]

    def step(state):
        t, cur, cur_d, done, hops, edges = state
        nbrs = jnp.take(rows, cur, axis=0)               # (nq, M)
        valid = nbrs >= 0
        safe = jnp.where(valid, nbrs, 0)
        valid = valid & jnp.take(ok, safe)
        d = jnp.where(valid, _score(quant, db, qd, safe), _INF)
        j = jnp.argmin(d, axis=1)
        best = jnp.take_along_axis(d, j[:, None], axis=1)[:, 0]
        sel = jnp.take_along_axis(safe, j[:, None], axis=1)[:, 0]
        better = (best < cur_d) & ~done
        cur = jnp.where(better, sel, cur)
        cur_d = jnp.where(better, best, cur_d)
        if oblivious:            # constant accounting: every query, full row
            hops = hops + 1
            edges = edges + M
        else:
            hops = hops + (~done).astype(jnp.int32)
            edges = edges + jnp.where(done, 0, valid.sum(axis=1))
        done = done | ~better
        return t + 1, cur, cur_d, done, hops, edges

    nq = cur.shape[0]
    done0 = cur < 0
    state = (jnp.int32(0), jnp.where(done0, 0, cur), cur_d, done0,
             hops, edges)
    if oblivious:
        state = jax.lax.fori_loop(0, GREEDY_BOUND,
                                  lambda _, s: step(s), state)
    else:
        state = jax.lax.while_loop(
            lambda s: (s[0] < GREEDY_BOUND) & jnp.any(~s[3]), step, state)
    _, cur, cur_d, _, hops, edges = state
    return jnp.where(done0, -1, cur), cur_d, hops, edges


def beam_plan(kp: int, ef: int, minimum: int = 32):
    """Static shape plan of one traversal call: (ef_eff, ef_cap,
    max_hops).  ef_cap is the power-of-two beam capacity (results stay
    a pure function of the traced effective ef, so bucket crossings
    change shapes, never ids); max_hops bounds the layer-0 expansion
    count — the host walk expands ~ef nodes, so 4x is generous slack
    (parity tests would catch a premature freeze)."""
    from ..kernels.common import next_bucket
    ef_eff = int(max(kp, ef))
    ef_cap = next_bucket(ef_eff, minimum=minimum)
    return ef_eff, ef_cap, 4 * ef_cap


def upper_entry(neigh_up, ok, db, qd, entry, *, quant: str = "f32",
                oblivious: bool = False):
    """Phase 1: greedy-descend the upper layers, top first, all queries
    in lockstep.  Layers above max_level hold only -1 rows
    (delete-with-repair empties them), so running every padded layer is
    inert, never wrong.  Returns (ep (nq,) int32 layer-0 entry per
    query (-1 if the graph is empty), ep_d (nq,) f32, hops, edges)."""
    nq = qd.shape[0]
    hops = jnp.zeros(nq, jnp.int32)
    edges = jnp.zeros(nq, jnp.int32)
    entry_ok = entry >= 0
    cur = jnp.where(entry_ok, entry, 0) * jnp.ones(nq, jnp.int32)
    cur = jnp.where(entry_ok, cur, -1)
    cur_d = jnp.where(
        entry_ok & jnp.take(ok, jnp.maximum(cur, 0)),
        _score(quant, db, qd, jnp.maximum(cur, 0)[:, None])[:, 0], _INF)
    cur = jnp.where(cur_d < _INF, cur, -1)
    for li in reversed(range(neigh_up.shape[0])):
        cur, cur_d, hops, edges = _climb(
            neigh_up[li], ok, db, qd, cur, cur_d, quant, oblivious,
            hops, edges)
    return cur, cur_d, hops, edges


def beam_layer0(neigh0, ok, db, qd, ep, ep_d, ef, *, kp: int,
                ef_cap: int, max_hops: int, quant: str = "f32",
                oblivious: bool = False, hops=None, edges=None):
    """Phase 2: lockstep best-first beam search over the layer-0 rows,
    starting each query at its descent endpoint ep/ep_d.  This is the
    phase the graph_expand Pallas kernel replaces on TPU (the XLA form
    here is the serving path everywhere else).

    Returns (cand (nq, kp) int32 with -1 fill, cand_d (nq, kp) f32
    (+inf fill), visited (nq, R) bool scan trace, hops, edges).
    """
    if not 1 <= kp <= ef_cap:
        raise ValueError(f"kp={kp} outside [1, ef_cap={ef_cap}]")
    nq = qd.shape[0]
    R = neigh0.shape[0]
    M0 = neigh0.shape[1]
    if hops is None:
        hops = jnp.zeros(nq, jnp.int32)
    if edges is None:
        edges = jnp.zeros(nq, jnp.int32)
    cur, cur_d = ep, ep_d
    ep_ok = cur >= 0
    ep = jnp.where(ep_ok, cur, 0)
    iota_ef = jax.lax.broadcasted_iota(jnp.int32, (nq, ef_cap), 1)
    bd = jnp.where((iota_ef == 0) & ep_ok[:, None], cur_d[:, None], _INF)
    bi = jnp.where((iota_ef == 0) & ep_ok[:, None], ep[:, None], -1)
    bx = ~((iota_ef == 0) & ep_ok[:, None])      # True = expanded/inert
    visited = jnp.zeros((nq, R), bool)
    visited = visited.at[jnp.arange(nq), ep].max(ep_ok)
    done = ~ep_ok
    rows_q = jnp.arange(nq)[:, None]

    def beam_step(state):
        t, bd, bi, bx, visited, done, hops, edges = state
        du = jnp.where(bx, _INF, bd)
        j = jnp.argmin(du, axis=1)
        sel_d = jnp.take_along_axis(du, j[:, None], axis=1)[:, 0]
        sel_i = jnp.take_along_axis(bi, j[:, None], axis=1)[:, 0]
        worst = jnp.take_along_axis(
            bd, jnp.broadcast_to(ef - 1, (nq, 1)), axis=1)[:, 0]
        # host break rule: min unexpanded worse than the ef-th best (or
        # nothing left to expand).  worst==inf while the beam is not
        # full, so the len(result)>=ef clause is implied.
        qdone = jnp.isinf(sel_d) | (sel_d > worst)
        active = ~done & ~qdone

        sel_safe = jnp.where(sel_i >= 0, sel_i, 0)
        nbrs = jnp.take(neigh0, sel_safe, axis=0)        # (nq, M0)
        valid = nbrs >= 0
        safe = jnp.where(valid, nbrs, 0)
        valid = valid & jnp.take(ok, safe)
        seen = jnp.take_along_axis(visited, safe, axis=1)
        fresh = valid & ~seen
        d = jnp.where(fresh, _score(quant, db, qd, safe), _INF)
        visited = visited.at[rows_q, safe].max(fresh & active[:, None])

        bx_sel = bx | (iota_ef == j[:, None])            # mark expanded
        cat_d = jnp.concatenate([bd, d], axis=1)
        cat_i = jnp.concatenate([bi, jnp.where(fresh, safe, -1)], axis=1)
        cat_x = jnp.concatenate([bx_sel, ~fresh], axis=1)
        # partial selection, not a full stable sort: lax.top_k breaks
        # equal keys toward the lower index, which on the negated
        # distances is exactly stable-ascending order — same permutation
        # the host heap induces, ~1.5x cheaper per hop on CPU
        perm = jax.lax.top_k(-cat_d, ef_cap)[1]
        nbd = jnp.take_along_axis(cat_d, perm, axis=1)
        nbi = jnp.take_along_axis(cat_i, perm, axis=1)
        nbx = jnp.take_along_axis(cat_x, perm, axis=1)
        over = iota_ef >= ef          # effective-ef truncation (traced)
        nbd = jnp.where(over, _INF, nbd)
        nbi = jnp.where(over, -1, nbi)
        nbx = nbx | over

        am = active[:, None]
        bd = jnp.where(am, nbd, bd)
        bi = jnp.where(am, nbi, bi)
        bx = jnp.where(am, nbx, bx)
        if oblivious:
            hops = hops + 1
            edges = edges + M0
        else:
            hops = hops + active.astype(jnp.int32)
            edges = edges + jnp.where(active, fresh.sum(axis=1), 0)
        done = done | qdone
        return t + 1, bd, bi, bx, visited, done, hops, edges

    state = (jnp.int32(0), bd, bi, bx, visited, done, hops, edges)
    if oblivious:
        state = jax.lax.fori_loop(0, max_hops,
                                  lambda _, s: beam_step(s), state)
    else:
        state = jax.lax.while_loop(
            lambda s: (s[0] < max_hops) & jnp.any(~s[5]), beam_step, state)
    _, bd, bi, bx, visited, done, hops, edges = state

    cand = bi[:, :kp]
    cand_d = bd[:, :kp]
    return cand, cand_d, visited, hops, edges


def traverse(neigh0, neigh_up, ok, db, qd, entry, ef, *, kp: int,
             ef_cap: int, max_hops: int, quant: str = "f32",
             oblivious: bool = False):
    """The full batched walk (pure function; `graph_topk` is its jitted
    module-level entry point, and the sharded backend calls this per
    shard under shard_map).

    neigh0 (R, M0) / neigh_up (LU, R, M) int32, `-1` padded; ok (R,)
    bool row validity; db the quant-mode scan arrays — ("f32": (C,),
    "int8": (c8, cn), "pq8": (codes_t,)); qd the matching per-query
    operand (Q | q8 | lut); entry/ef traced int32 scalars.

    Returns (cand (nq, kp) int32 with -1 fill, cand_d (nq, kp) f32
    (+inf fill), visited (nq, R) bool scan trace, hops (nq,) int32,
    edges (nq,) int32).
    """
    ep, ep_d, hops, edges = upper_entry(
        neigh_up, ok, db, qd, entry, quant=quant, oblivious=oblivious)
    return beam_layer0(
        neigh0, ok, db, qd, ep, ep_d, ef, kp=kp, ef_cap=ef_cap,
        max_hops=max_hops, quant=quant, oblivious=oblivious,
        hops=hops, edges=edges)


graph_topk = jax.jit(
    traverse,
    static_argnames=("kp", "ef_cap", "max_hops", "quant", "oblivious"))
