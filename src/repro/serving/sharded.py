"""Placement-aware sharded execution: the filter-and-refine pipeline
row-sharded across a device mesh (DESIGN.md §10).

`ShardedBackend` is a drop-in engine filter backend (the same
`attach`/`candidates` protocol as `runtime.ingest.DeltaAwareBackend`,
which it subclasses), so the micro-batcher, tenant routing, telemetry,
live encrypted ingestion, and `save`/`load` snapshots of the serving
runtime all work unchanged over a sharded collection.  What changes is
*where* the scan and the refine gather run:

  filter (flat):  the sentinel-padded ciphertext array is row-sharded
                  (`NamedSharding(P(axis, None))`); under `shard_map`
                  each shard scans its rows, takes a local top-k' with
                  *global* ids (`local_idx + shard * rows_per_shard` —
                  the stable-global-id offset), and an all-gather of
                  only k' rows per shard feeds the cross-shard top-k'
                  merge.
  filter (ivf):   coarse probing stays host-side (identical pools to
                  the single-device backend, so parity is exact); the
                  pool scan runs sharded — each shard computes the
                  distances for pool entries it owns, non-owned slots
                  are +inf, and a `pmin` over the axis reassembles the
                  full (nq, L) distance matrix bit-identically to the
                  single-device `_masked_pruned_scan`.
  filter (graph): per-shard subgraphs (DESIGN.md §15) — each shard owns
                  an independent HNSW over its contiguous row block,
                  mirrored into one shared (R, LU) CSR bucket; the
                  batched lockstep traversal runs per shard with one
                  reused executable and the k'-per-shard results merge
                  by surrogate distance (host-side; the traversal does
                  not run under the mesh).
  refine:         the DCE refine array is row-sharded too; each shard
                  extracts the candidate rows it owns (others zeroed)
                  and one `psum` of (nq, k', 4, D) — k' rows per query,
                  never the database — assembles the replicated
                  candidate tensor for the batched tournament (einsum
                  formulation: a Pallas call over mesh-sharded gathers
                  would fight the partitioner, DESIGN.md §3).

Row -> shard routing is the block partition of the padded capacity
bucket: global row id r lives on shard `r // rows_per_shard`.  Ids are
the stable store row ids, so live inserts append to the tail shard(s)
and deletes tombstone in place; `shard_manifest()` reports the current
partition for persistence (the per-shard manifest in a `.ppcol`
snapshot).

Every jitted entry point here is module-level and specialised only on
bucketed shapes + (mesh, axis, k') statics, so a warmed-up collection
serves steady-state traffic with zero recompiles
(`runtime.telemetry.jit_cache_size` audits these functions too).

Failover (repro.resilience, DESIGN.md §16): every shard group carries
`n_replicas` logical replicas in a `ShardHealthRegistry`; a group is
servable while >= 1 replica is up, so killing one replica changes
nothing.  When a whole group is down the backend *routes around it*
instead of failing: the group's rows are masked out of the scans (mask
is data — the healthy path stays byte-identical and executable-
identical), the graph walk skips the dead subgraphs, and every answer
is stamped `last_degraded` / `last_n_shards_down` for
`SearchStats.degraded` / `n_shards_down`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.hnsw import HNSW
from ..graph.csr import CSRGraph
from ..graph.traverse import beam_plan
from ..kernels.adc_topk.ops import INT_BIG
from ..kernels.common import next_bucket
from ..kernels.dce_comp import ops as dce_ops
from ..launch.mesh import make_mesh
from ..obs.trace import child_complete, current as obs_current
from ..resilience.health import ShardHealthRegistry
from .runtime.ingest import SENTINEL, DeltaAwareBackend
from .search_engine import layout_pools, pool_membership

__all__ = ["ShardedBackend", "sharded_mesh", "shard_bucket"]


def sharded_mesh(n_shards: int, data_axis: str = "data"):
    """A 1-D mesh over the first `n_shards` local devices."""
    n_dev = len(jax.devices())
    if n_shards > n_dev:
        raise ValueError(f"placement wants {n_shards} shards but only "
                         f"{n_dev} device(s) exist (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=N to "
                         f"simulate more on CPU)")
    return make_mesh((n_shards,), (data_axis,))


def shard_bucket(n: int, n_shards: int, minimum: int = 256) -> int:
    """Padded row capacity: the store's power-of-two bucket, rounded up
    to a multiple of n_shards so the block partition is even.  (For the
    usual power-of-two shard counts the rounding is a no-op.)"""
    b = next_bucket(max(n, 1), minimum=minimum)
    return -(-b // n_shards) * n_shards


# ---------------------------------------------------------------------------
# Jitted sharded entry points.  Module-level, specialised on (mesh, axis,
# k') statics + bucketed shapes only — the zero-recompile contract.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_flat_topk(C_sh, Q, *, mesh, axis, kp: int):
    """Row-sharded exhaustive filter: per-shard distances + local top-k'
    with global id offsets, then a cross-shard merge that all-gathers
    only k' rows per shard (never the (nq, n) matrix)."""

    def body(C_loc, Q_rep):
        n_loc = C_loc.shape[0]
        qn = (Q_rep * Q_rep).sum(-1, keepdims=True)
        xn = (C_loc * C_loc).sum(-1)[None, :]
        dist = qn - 2.0 * Q_rep @ C_loc.T + xn            # (nq, n_loc)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-dist, kp_loc)           # local top-k'
        gidx = idx + jax.lax.axis_index(axis) * n_loc     # global ids
        vals = jax.lax.all_gather(-neg, axis, axis=1, tiled=True)
        gids = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        neg2, pos = jax.lax.top_k(-vals, min(kp, vals.shape[1]))
        return jnp.take_along_axis(gids, pos, axis=1)     # (nq, kp_out)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(None, None)),
                     out_specs=P(None, None),
                     check_rep=False)(C_sh, Q)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_flat_topk_ok(C_sh, ok_sh, Q, *, mesh, axis, kp: int):
    """Degraded-mode twin of `_sharded_flat_topk` (DESIGN.md §16): the
    same scan with a row serve-mask as DATA, so rows of dead shard
    groups never reach the merge.  Compiled only on the first degraded
    call — the healthy path keeps its original executable untouched."""

    def body(C_loc, ok_loc, Q_rep):
        n_loc = C_loc.shape[0]
        qn = (Q_rep * Q_rep).sum(-1, keepdims=True)
        xn = (C_loc * C_loc).sum(-1)[None, :]
        dist = qn - 2.0 * Q_rep @ C_loc.T + xn            # (nq, n_loc)
        dist = jnp.where(ok_loc[None, :], dist, jnp.inf)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-dist, kp_loc)
        return _local_merge(axis, neg, idx, n_loc, kp)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(axis), P(None, None)),
                     out_specs=P(None, None),
                     check_rep=False)(C_sh, ok_sh, Q)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_pool_scan(C_sh, Q, cand, valid, *, mesh, axis, kp: int):
    """Row-sharded IVF pool scan.  Each shard computes the (nq, L)
    distance entries whose candidate row it owns (+inf elsewhere); a
    pmin over the axis reassembles the full matrix — element-for-element
    the same float32 values as the single-device masked scan, so the
    top-k' that follows is bit-identical."""

    def body(C_loc, Q_rep, cand_rep, valid_rep):
        n_loc = C_loc.shape[0]
        base = jax.lax.axis_index(axis) * n_loc
        loc = cand_rep - base
        mine = (loc >= 0) & (loc < n_loc) & valid_rep
        rows = jnp.take(C_loc, jnp.clip(loc, 0, n_loc - 1), axis=0)
        qn = (Q_rep * Q_rep).sum(-1)[:, None]
        xn = (rows * rows).sum(-1)
        cross = jnp.einsum("qld,qd->ql", rows, Q_rep)
        d = jnp.where(mine, qn - 2.0 * cross + xn, jnp.inf)
        d = jax.lax.pmin(d, axis)                         # (nq, L) full
        kp_out = min(kp, d.shape[1])
        _, pos = jax.lax.top_k(-d, kp_out)
        return (jnp.take_along_axis(cand_rep, pos, axis=1),
                jnp.take_along_axis(valid_rep, pos, axis=1))

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(None, None),
                               P(None, None), P(None, None)),
                     out_specs=(P(None, None), P(None, None)),
                     check_rep=False)(C_sh, Q, cand, valid)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_oblivious_scan(C_sh, Q, member, *, mesh, axis, kp: int):
    """Row-sharded scan-oblivious IVF filter (DESIGN.md §14): each shard
    scans ALL of its rows for every query — a constant-shape local
    matmul, no data-dependent gather — masks by its slice of the
    (nq, bucket) pool-membership matrix, and the usual local-top-k' /
    all-gather(k'/shard) merge follows.  Returns global ids only;
    validity is a host-side membership lookup (the mask is host data)."""

    def body(C_loc, Q_rep, m_loc):
        n_loc = C_loc.shape[0]
        qn = (Q_rep * Q_rep).sum(-1, keepdims=True)
        xn = (C_loc * C_loc).sum(-1)[None, :]
        d = qn - 2.0 * Q_rep @ C_loc.T + xn               # (nq, n_loc)
        d = jnp.where(m_loc, d, jnp.inf)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-d, kp_loc)
        return _local_merge(axis, neg, idx, n_loc, kp)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(None, None),
                               P(None, axis)),
                     out_specs=P(None, None),
                     check_rep=False)(C_sh, Q, member)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_sq_oblivious(C8_sh, cn_sh, Q8, member, *, mesh, axis,
                          kp: int):
    """Row-sharded scan-oblivious int8 ADC IVF filter: full local code
    scan masked by the shard's membership columns + all-gather merge."""

    def body(C_loc, cn_loc, Q_rep, m_loc):
        n_loc = C_loc.shape[0]
        cross = jax.lax.dot_general(
            Q_rep.astype(jnp.float32), C_loc.astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        d = cn_loc.astype(jnp.float32)[None, :] - 2.0 * cross
        d = jnp.where(m_loc, d, jnp.inf)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-d, kp_loc)
        return _local_merge(axis, neg, idx, n_loc, kp)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(axis), P(None, None),
                               P(None, axis)),
                     out_specs=P(None, None),
                     check_rep=False)(C8_sh, cn_sh, Q8, member)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_pq_oblivious(codes_t_sh, lut, member, *, mesh, axis,
                          kp: int):
    """Row-sharded scan-oblivious PQ ADC IVF filter: full local LUT
    accumulation masked by the shard's membership columns."""

    def body(ct_loc, lut_rep, m_loc):
        n_loc = ct_loc.shape[1]
        cc = jnp.broadcast_to(ct_loc.astype(jnp.int32)[None],
                              (lut_rep.shape[0],) + ct_loc.shape)
        g = jnp.take_along_axis(lut_rep, cc, axis=2)      # (nq, m, n_loc)
        d = jnp.where(m_loc, g.sum(axis=1), jnp.inf)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-d, kp_loc)
        return _local_merge(axis, neg, idx, n_loc, kp)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, axis), P(None, None, None),
                               P(None, axis)),
                     out_specs=P(None, None),
                     check_rep=False)(codes_t_sh, lut, member)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "k"))
def _sharded_refine(C_dce_sh, cand, T, valid, *, mesh, axis, k: int):
    """Sharded batched DCE tournament: per-shard candidate-row extraction
    + one psum of (nq, k', 4, D) assembles the replicated candidate
    tensor; the tournament itself (einsum Z-matrices, win-count ranking)
    runs replicated.  Same -1 semantics as `search_engine
    .refine_candidates` with a validity mask."""

    def gather(C_loc, cand_rep):
        n_loc = C_loc.shape[0]
        base = jax.lax.axis_index(axis) * n_loc
        loc = cand_rep - base
        mine = (loc >= 0) & (loc < n_loc)
        rows = jnp.take(C_loc, jnp.clip(loc, 0, n_loc - 1), axis=0)
        rows = jnp.where(mine[..., None, None], rows, 0.0)
        return jax.lax.psum(rows, axis)                   # (nq, kp, 4, D)

    Cc = shard_map(gather, mesh=mesh,
                   in_specs=(P(axis, None, None), P(None, None)),
                   out_specs=P(None, None, None, None),
                   check_rep=False)(C_dce_sh, cand)
    local = dce_ops.batched_top_k_by_wins(Cc, T, k, valid=valid,
                                          use_kernel=False)
    local = local.astype(cand.dtype)
    ids = jnp.take_along_axis(cand, local, axis=1)
    vsel = jnp.take_along_axis(valid, local, axis=1)
    return jnp.where(vsel, ids, -1)


# ---------------------------------------------------------------------------
# Quantized ADC variants (DESIGN.md §11): the same collective shapes as
# the f32 entry points above — per-shard local work + all-gather(k') or
# pmin merges — with distances computed from per-shard *codes* instead
# of f32 ciphertexts.  XLA/einsum formulation throughout (the Pallas
# adc_topk path stays single-device; a mesh-sharded pallas_call would
# fight the partitioner, same argument as the refine, DESIGN.md §3).
# ---------------------------------------------------------------------------

_BIG_F = jnp.float32(INT_BIG)


@jax.jit
def _and_ok(ok, sok):
    """Failover mask composition (DESIGN.md §16): ADC row validity AND
    the per-row shard-group serve mask.  Validity is data, so the
    composed mask reuses the already-compiled ADC executables — the
    degraded path costs one tiny jit, not a re-specialised scan."""
    return jnp.where(sok > 0, ok, jnp.zeros((), ok.dtype))


def _local_merge(axis, neg, idx, n_loc, kp):
    """Shared tail of the sharded flat scans: local top-k' -> global ids
    -> all-gather(k'/shard) -> cross-shard top-k'."""
    gidx = idx + jax.lax.axis_index(axis) * n_loc
    vals = jax.lax.all_gather(-neg, axis, axis=1, tiled=True)
    gids = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
    _, pos = jax.lax.top_k(-vals, min(kp, vals.shape[1]))
    return jnp.take_along_axis(gids, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_sq_topk(C8_sh, cn_sh, ok_sh, Q8, *, mesh, axis, kp: int):
    """Row-sharded int8 ADC filter: per-shard surrogate distances
    cn - 2*(q8 . c8) over the shard's codes, then the existing
    all-gather(k'/shard) merge."""

    def body(C_loc, cn_loc, ok_loc, Q_rep):
        n_loc = C_loc.shape[0]
        cross = jax.lax.dot_general(
            Q_rep.astype(jnp.float32), C_loc.astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        d = cn_loc.astype(jnp.float32)[None, :] - 2.0 * cross
        d = jnp.where(ok_loc[None, :] > 0, d, _BIG_F)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-d, kp_loc)
        return _local_merge(axis, neg, idx, n_loc, kp)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(axis), P(axis),
                               P(None, None)),
                     out_specs=P(None, None),
                     check_rep=False)(C8_sh, cn_sh, ok_sh, Q8)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_pq_topk(codes_t_sh, ok_sh, lut, *, mesh, axis, kp: int):
    """Row-sharded PQ ADC filter: per-shard LUT gather-accumulate over
    the shard's code columns, then the all-gather merge.  codes_t_sh is
    (m, n) sharded on its column axis."""

    def body(ct_loc, ok_loc, lut_rep):
        n_loc = ct_loc.shape[1]
        cc = jnp.broadcast_to(ct_loc.astype(jnp.int32)[None],
                              (lut_rep.shape[0],) + ct_loc.shape)
        g = jnp.take_along_axis(lut_rep, cc, axis=2)      # (nq, m, n_loc)
        d = jnp.where(ok_loc[None, :] > 0, g.sum(axis=1), jnp.inf)
        kp_loc = min(kp, n_loc)
        neg, idx = jax.lax.top_k(-d, kp_loc)
        return _local_merge(axis, neg, idx, n_loc, kp)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, axis), P(axis), P(None, None, None)),
                     out_specs=P(None, None),
                     check_rep=False)(codes_t_sh, ok_sh, lut)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_sq_pool_scan(C8_sh, cn_sh, Q8, cand, valid, *, mesh, axis,
                          kp: int):
    """Row-sharded int8 ADC pool scan: each shard fills the (nq, L)
    surrogate-distance entries it owns, pmin reassembles — the
    quantized twin of `_sharded_pool_scan`."""

    def body(C_loc, cn_loc, Q_rep, cand_rep, valid_rep):
        n_loc = C_loc.shape[0]
        base = jax.lax.axis_index(axis) * n_loc
        loc = cand_rep - base
        mine = (loc >= 0) & (loc < n_loc) & valid_rep
        safe = jnp.clip(loc, 0, n_loc - 1)
        rows = jnp.take(C_loc, safe, axis=0).astype(jnp.float32)
        cn_rows = jnp.take(cn_loc, safe).astype(jnp.float32)
        cross = jnp.einsum("qld,qd->ql", rows, Q_rep.astype(jnp.float32))
        d = jnp.where(mine, cn_rows - 2.0 * cross, jnp.inf)
        d = jax.lax.pmin(d, axis)                         # (nq, L) full
        kp_out = min(kp, d.shape[1])
        _, pos = jax.lax.top_k(-d, kp_out)
        return (jnp.take_along_axis(cand_rep, pos, axis=1),
                jnp.take_along_axis(valid_rep, pos, axis=1))

    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis, None), P(axis), P(None, None),
                               P(None, None), P(None, None)),
                     out_specs=(P(None, None), P(None, None)),
                     check_rep=False)(C8_sh, cn_sh, Q8, cand, valid)


@functools.partial(jax.jit, static_argnames=("mesh", "axis", "kp"))
def _sharded_pq_pool_scan(codes_t_sh, lut, cand, valid, *, mesh, axis,
                          kp: int):
    """Row-sharded PQ ADC pool scan (LUT gather over owned pool rows +
    pmin)."""

    def body(ct_loc, lut_rep, cand_rep, valid_rep):
        n_loc = ct_loc.shape[1]
        base = jax.lax.axis_index(axis) * n_loc
        loc = cand_rep - base
        mine = (loc >= 0) & (loc < n_loc) & valid_rep
        safe = jnp.clip(loc, 0, n_loc - 1)
        cc = jnp.take(ct_loc, safe, axis=1)               # (m, nq, L)
        cc = jnp.transpose(cc, (1, 0, 2)).astype(jnp.int32)
        g = jnp.take_along_axis(lut_rep, cc, axis=2)      # (nq, m, L)
        d = jnp.where(mine, g.sum(axis=1), jnp.inf)
        d = jax.lax.pmin(d, axis)
        kp_out = min(kp, d.shape[1])
        _, pos = jax.lax.top_k(-d, kp_out)
        return (jnp.take_along_axis(cand_rep, pos, axis=1),
                jnp.take_along_axis(valid_rep, pos, axis=1))

    return shard_map(body, mesh=mesh,
                     in_specs=(P(None, axis), P(None, None, None),
                               P(None, None), P(None, None)),
                     out_specs=(P(None, None), P(None, None)),
                     check_rep=False)(codes_t_sh, lut, cand, valid)


def cache_size() -> int:
    """Compiled-executable count of the sharded entry points (summed
    into `runtime.telemetry.jit_cache_size` for the recompile audit)."""
    return sum(f._cache_size() for f in
               (_sharded_flat_topk, _sharded_pool_scan, _sharded_refine,
                _sharded_sq_topk, _sharded_pq_topk,
                _sharded_sq_pool_scan, _sharded_pq_pool_scan,
                _sharded_oblivious_scan, _sharded_sq_oblivious,
                _sharded_pq_oblivious, _sharded_flat_topk_ok, _and_ok))


# ---------------------------------------------------------------------------
# The backend.
# ---------------------------------------------------------------------------

class ShardedBackend(DeltaAwareBackend):
    """Row-sharded flat / IVF / per-shard-graph filter + sharded refine
    over a mutable encrypted store.

    Reuses the delta-aware host-side machinery wholesale — mutation
    hooks, tombstone masking (`_mask_alive`), the IVF centroid build and
    incremental delta assignment — and replaces only the device layout
    (NamedSharding row partition) and the scan/refine executables
    (shard_map).  Engine parity therefore reduces to the collective
    formulation, which is tested id-exact against the single-device
    path (tests/test_placement.py).
    """

    def __init__(self, store, kind: str = "flat", *, n_shards: int,
                 n_replicas: int = 1, data_axis: str = "data", **kw):
        if kind not in ("flat", "ivf", "graph"):
            raise ValueError(
                f"sharded placement supports flat|ivf|graph filter "
                f"backends, not {kind!r} (the per-query host walk does "
                f"not shard; kind='graph' serves per-shard subgraphs, "
                f"DESIGN.md §3/§15)")
        self._hnsw_M = kw.get("hnsw_M", 16)
        self._hnsw_efc = kw.get("hnsw_ef_construction", 200)
        super().__init__(store, kind, **kw)
        self.n_shards = int(n_shards)
        self.axis = data_axis
        self.mesh = sharded_mesh(self.n_shards, data_axis)
        self.name = f"sharded-{self.name}"   # sharded-<kind | adc-...>
        self.use_kernel = False       # einsum refine under the mesh
        # failover state (DESIGN.md §16): the health registry is the one
        # mutable truth; masks derived from it are cached on its epoch
        self.n_replicas = int(n_replicas)
        self.health = ShardHealthRegistry(self.n_shards, self.n_replicas)
        self.last_degraded = False
        self.last_n_shards_down = 0
        self._ru_cache = (None, None)        # (epoch, bucket) -> row_up
        self._sok_cache: dict = {}           # device serve-mask rows
        self._sh_sap = NamedSharding(self.mesh, P(data_axis, None))
        self._sh_dce = NamedSharding(self.mesh, P(data_axis, None, None))
        self._sh_row = NamedSharding(self.mesh, P(data_axis))
        self._sh_codes_t = NamedSharding(self.mesh, P(None, data_axis))
        # per-shard subgraph state (kind="graph", DESIGN.md §15): each
        # shard owns an independent host HNSW over its contiguous row
        # block — graph edges never cross shards, so the batched
        # traversal runs per shard (one executable, reused across
        # shards: identical R/LU buckets) and the k'-per-shard results
        # merge by surrogate distance, the same collective shape as the
        # flat all-gather(k') merge.  The single global host graph of
        # the base class is disabled (its eager hooks assume node id ==
        # store row id, which a block partition breaks); mutations are
        # replayed shard-locally at the next attach instead.
        if kind == "graph":
            self.graph = None
        self._shard_graphs: list[HNSW] | None = None
        self._g_per = 0                    # rows per shard of the mirror
        self._g_built_n = 0                # store rows absorbed so far
        self._g_csrs: list[CSRGraph] | None = None
        self._g_dirty_sh: list[set] = []
        self._g_del_pending: list[int] = []
        self._g_neigh0_sh = self._g_neigh_up_sh = None

    # ------------------------------------------------------------ layout

    def _row_bucket(self, n: int) -> int:
        return shard_bucket(n, self.n_shards)

    @property
    def padded_rows(self) -> int:
        return self._row_bucket(self.store.n_total)

    def shard_manifest(self) -> list[dict]:
        """The current row -> shard block partition (persisted as the
        per-shard manifest of a sharded collection snapshot)."""
        st = self.store
        per = self.padded_rows // self.n_shards
        out = []
        for s in range(self.n_shards):
            start = min(s * per, st.n_total)
            stop = min((s + 1) * per, st.n_total)
            out.append({"shard": s, "row_start": int(start),
                        "row_stop": int(stop),
                        "n_alive": int(st.alive_view[start:stop].sum())})
        return out

    # ------------------------------------------------------------ attach

    def on_delete(self, row: int):
        if self.kind == "graph":
            # shard graphs sync lazily at attach (one replay per burst);
            # the store has already sentinelled the row, so a search
            # racing the replay still masks it via `_mask_alive`
            self._g_del_pending.append(int(row))
            return
        super().on_delete(row)
        if self.kind == "flat":
            # force a re-upload so the deleted row is sentinelled on
            # device too — keeps the sharded candidate sets identical to
            # the single-device backend's (which re-sentinels its main
            # array); ivf needs nothing: the row left its probe list
            self._scan_snapshot = (-1, -1)

    def _refresh_scan_array(self, C_sap: np.ndarray):
        """Sharded replacement for the parent's scan-array refresh: one
        sentinel-padded, row-sharded device array serving both the flat
        exhaustive scan and the ivf pool scan.  Same caching rule as the
        parent: insert bursts inside an unchanged bucket ship only the
        new rows (scatter preserves the NamedSharding), not the whole
        database; bucket growth, compaction, or a flat delete (which
        invalidates the snapshot) pay one full sharded re-upload."""
        st = self.store
        bucket = self._row_bucket(st.n_total)
        snapshot = (st.main_gen, st.n_total)
        if self._C_all is not None and self._scan_snapshot == snapshot:
            return
        old_gen, old_n = self._scan_snapshot
        if (self._C_all is not None and old_gen == st.main_gen
                and 0 <= old_n <= st.n_total
                and self._C_all.shape[0] == bucket):
            self._C_all = self._C_all.at[old_n: st.n_total].set(
                jnp.asarray(C_sap[old_n: st.n_total]))
        else:
            buf = np.full((bucket, st.d), SENTINEL, np.float32)
            buf[: st.n_total] = C_sap
            self._C_all = jax.device_put(buf, self._sh_sap)
        self._scan_snapshot = snapshot

    # sharded residency for the ADC code arrays (parent attach logic,
    # these placement hooks): codes row-sharded like the f32 scan
    # array, (m, n) PQ codes sharded on their column axis, per-row
    # norms/validity sharded 1-D — every shard streams only its codes
    def _put_codes(self, buf: np.ndarray):
        return jax.device_put(buf, self._sh_sap)

    def _put_codes_t(self, buf: np.ndarray):
        return jax.device_put(buf, self._sh_codes_t)

    def _put_rowvec(self, buf: np.ndarray):
        return jax.device_put(buf, self._sh_row)

    def attach(self, C_sap: np.ndarray, engine):
        if self.kind == "graph":
            self._attach_graph_sharded(C_sap)
            return
        if self.quantization is not None:
            if self.kind == "ivf":
                self._attach_ivf_index(C_sap)   # same pools as single
            self._attach_adc(C_sap)             # codes via our hooks
            return
        if self.kind == "ivf":
            self._attach_ivf(C_sap)       # parent logic; calls our
        else:                             # _refresh_scan_array override
            self._refresh_scan_array(C_sap)

    # ------------------------------------------- per-shard subgraphs

    def _ensure_shard_graphs(self, C_sap: np.ndarray):
        """Host-graph maintenance: one independent HNSW per shard over
        its contiguous row block (shard-local node id = row - shard
        base).  A bucket change or compaction rebuilds; otherwise the
        mutation burst replays shard-locally — appended rows insert
        into their owning tail shard(s), pending deletes repair in
        place — and only the changed rows are marked for CSR refresh."""
        st = self.store
        per = self._row_bucket(max(st.n_total, 1)) // self.n_shards
        rebuild = (self._shard_graphs is None or per != self._g_per
                   or self._attached_gen != st.main_gen)
        if rebuild:
            self._shard_graphs = [
                HNSW(dim=st.d, M=self._hnsw_M,
                     ef_construction=self._hnsw_efc, seed=self.seed + s)
                for s in range(self.n_shards)]
            self._g_per = per
            self._g_built_n = 0
            self._g_csrs = None
            self._g_dirty_sh = [set() for _ in range(self.n_shards)]
            self._g_del_pending.clear()   # tombstones replay from store
        built0 = self._g_built_n
        alive = st.alive_view
        for row in range(built0, st.n_total):
            # rows append in order, so each shard's inserts are its
            # contiguous local ids — node id == local offset by
            # construction (the sharded twin of the node==row invariant)
            s, local = divmod(row, per)
            g = self._shard_graphs[s]
            node = g.insert(C_sap[row])
            if node != local:
                raise RuntimeError(
                    f"shard {s} node id {node} != local row {local}: "
                    f"subgraph and store are desynchronized")
            dirty = self._g_dirty_sh[s]
            dirty.add(local)
            for lev in range(len(g.links)):
                nb = g.links[lev][local]
                if nb is not None:
                    dirty.update(int(v) for v in nb)
            if not alive[row]:      # tombstoned between attaches (or a
                dirty.update(g.delete(local))   # rebuild over dead rows)
        self._g_built_n = st.n_total
        for row in self._g_del_pending:
            if row < built0:        # rows >= built0 were handled above
                s, local = divmod(row, per)
                dirty = self._g_dirty_sh[s]
                dirty.add(local)
                dirty.update(self._shard_graphs[s].delete(local))
        self._g_del_pending.clear()
        self._attached_gen = st.main_gen

    def _attach_graph_sharded(self, C_sap: np.ndarray):
        """CSR mirrors + device arrays for the per-shard subgraphs.  All
        shards share one (R=per, LU) bucket so the jitted traversal
        compiles once and serves every shard."""
        st = self.store
        self._ensure_shard_graphs(C_sap)
        per = self._g_per
        graphs = self._shard_graphs
        if (self._g_csrs is None or self._g_csrs[0].R != per
                or any(not c.fits(g)
                       for c, g in zip(self._g_csrs, graphs))):
            LU = max(next_bucket(max(len(g.links) - 1, 1), minimum=4)
                     for g in graphs)
            if self._g_csrs is not None:
                LU = max(LU, self._g_csrs[0].LU)
            self._g_csrs = [CSRGraph.from_hnsw(g, R=per, LU=LU)
                            for g in graphs]
            for dirty in self._g_dirty_sh:
                dirty.clear()
        else:
            for s, (c, g) in enumerate(zip(self._g_csrs, graphs)):
                if self._g_dirty_sh[s]:
                    c.refresh_rows(g, sorted(self._g_dirty_sh[s]))
                    c.refresh_meta(g)
                    self._g_dirty_sh[s].clear()
        self._g_neigh0_sh = [jnp.asarray(c.neigh0) for c in self._g_csrs]
        self._g_neigh_up_sh = [jnp.asarray(c.neigh_up)
                               for c in self._g_csrs]
        if self.quantization is not None:
            self._attach_adc(C_sap)     # global codebook: surrogate
            self._g_ok = self._adc_ok > 0   # distances stay comparable
            self._g_db = ((self._adc_c8, self._adc_cn)   # across shards
                          if self.quantization == "int8"
                          else (self._adc_codes_t,))
        else:
            self._refresh_scan_array(C_sap)
            ok = np.zeros(per * self.n_shards, bool)
            ok[: st.n_total] = st.alive_view
            self._g_ok = jnp.asarray(ok)
            self._g_db = (self._C_all,)

    def dce_device(self, C_dce_padded: np.ndarray):
        """Row-sharded residency for the refine array, padded to the
        same bucket as the scan array so both partition identically.
        Same incremental rule as the parent: inside an unchanged bucket,
        ship only the rows appended since the last refresh (the scatter
        preserves the NamedSharding).  Tombstoned rows keep a stale
        device copy, exactly like the single-device backend — they are
        never valid candidates."""
        st = self.store
        bucket = self._row_bucket(st.n_total)
        old_bucket, old_n = self._dce_snapshot
        if self._C_dce_dev is not None and bucket == old_bucket:
            if st.n_total > old_n:
                self._C_dce_dev = self._C_dce_dev.at[old_n: st.n_total].set(
                    jnp.asarray(C_dce_padded[old_n: st.n_total]))
        else:
            buf = np.zeros((bucket,) + C_dce_padded.shape[1:], np.float32)
            buf[: st.n_total] = C_dce_padded[: st.n_total]
            self._C_dce_dev = jax.device_put(buf, self._sh_dce)
        self._dce_snapshot = (bucket, st.n_total)
        return self._C_dce_dev

    # ------------------------------------------- graph persistence

    def graph_arrays(self) -> dict:
        """Per-shard snapshot payload: each subgraph's `to_arrays`
        encoding under an `s<shard>__` prefix (restoring the exact
        host graphs keeps post-restore searches bit-identical — a
        rebuild would replay deletes in a different repair order)."""
        if self._shard_graphs is None:     # snapshot before first search
            self._ensure_shard_graphs(self.store.sap_view)
        out = {}
        for s, g in enumerate(self._shard_graphs):
            out.update({f"s{s}__{k}": v for k, v in
                        g.to_arrays().items()})
        return out

    def restore_graph(self, arrays: dict):
        st = self.store
        if not any(k.startswith("s0__") for k in arrays):
            # an owner-built *global* graph (EncryptedCorpus.index): a
            # single graph does not block-partition, so the service
            # builds its per-shard subgraphs over the uploaded DCPE
            # ciphertexts at the next attach (keyless-safe — the same
            # inputs the owner's build saw)
            self._shard_graphs = None
            self._attached_gen = -1
            return
        per = self._row_bucket(max(st.n_total, 1)) // self.n_shards
        graphs = []
        for s in range(self.n_shards):
            pre = f"s{s}__"
            sub = {k[len(pre):]: v for k, v in arrays.items()
                   if k.startswith(pre)}
            g = HNSW.from_arrays(sub)
            want = min(max(st.n_total - s * per, 0), per)
            if g.size != want:
                raise ValueError(
                    f"shard {s} graph has {g.size} nodes for {want} "
                    f"rows (snapshot from a different partition?)")
            graphs.append(g)
        self._shard_graphs = graphs
        self._g_per = per
        self._g_built_n = st.n_total
        self._g_csrs = None
        self._g_dirty_sh = [set() for _ in range(self.n_shards)]
        self._g_del_pending.clear()
        self._attached_gen = st.main_gen

    # ------------------------------------------------------- failover

    def _row_up(self, bucket: int) -> np.ndarray:
        """(bucket,) bool host mask: True where the row's shard group
        still has a live replica.  Cached on (health epoch, bucket) —
        the steady state never rebuilds it."""
        key = (self.health.epoch, bucket)
        if self._ru_cache[0] != key:
            per = bucket // self.n_shards
            self._ru_cache = (key,
                              np.repeat(self.health.serve_mask(), per))
        return self._ru_cache[1]

    def _sok_dev(self, bucket: int, dtype) -> jax.Array:
        """Device-resident, row-sharded copy of `_row_up` (dtype-matched
        so the composed ADC mask reuses the healthy executables)."""
        key = (self.health.epoch, bucket, np.dtype(dtype).str)
        hit = self._sok_cache.get(key)
        if hit is None:
            self._sok_cache = {k: v for k, v in self._sok_cache.items()
                               if k[0] == key[0]}   # drop stale epochs
            arr = self._row_up(bucket).astype(dtype)
            hit = self._sok_cache[key] = jax.device_put(arr, self._sh_row)
        return hit

    def _pool_alive(self):
        """Probe-pool validity for the IVF paths: alive, AND (degraded
        only) the row's shard group servable — host-side composition,
        so the pool-scan executables never change."""
        st = self.store
        if not self.last_degraded:
            return lambda p: st.alive_view[p]
        row_up = self._row_up(self._row_bucket(max(st.n_total, 1)))
        return lambda p: st.alive_view[p] & row_up[p]

    def _mask_alive(self, cand: np.ndarray, valid: np.ndarray):
        safe, v = super()._mask_alive(cand, valid)
        if self.last_degraded:
            # safety net: no id from a dead shard group survives, even
            # one a masked scan let through at +inf distance
            row_up = self._row_up(
                self._row_bucket(max(self.store.n_total, 1)))
            v = v & row_up[safe]
        return safe, v

    # ------------------------------------------------------- candidates

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        sm = self.health.serve_mask()
        self.last_n_shards_down = int(self.n_shards - int(sm.sum()))
        self.last_degraded = bool(self.last_n_shards_down)
        if self.kind == "graph":
            out = self._candidates_graph(Q_sap, kp, ef_search)
        elif self.quantization is not None:
            kp2 = self.oversampled(kp)
            if self.kind == "flat":
                out = self._candidates_adc_flat(Q_sap, kp2)
            else:
                out = self._candidates_adc_ivf(Q_sap, kp2)
        elif self.kind == "flat":
            out = self._candidates_flat(Q_sap, kp)
        else:
            out = self._candidates_ivf(Q_sap, kp)
        if obs_current() is not None:
            # obs (DESIGN.md §13): one completed child span per shard
            # under the ambient filter span.  The collective computed all
            # shards' work inside one host call, so the per-shard spans
            # share the filter interval and carry the row partition each
            # shard scanned — attribution, not independent timing.
            for m in self.shard_manifest():
                child_complete(f"shard{m['shard']}", shard=m["shard"],
                               row_start=m["row_start"],
                               row_stop=m["row_stop"],
                               n_alive=m["n_alive"])
        return out

    def _candidates_adc_flat(self, Q_sap: np.ndarray, kp2: int):
        st = self.store
        nq = Q_sap.shape[0]
        bucket = int(self._adc_ok.shape[0])
        kp_eff = min(kp2, bucket)
        Q = np.asarray(Q_sap, np.float32)
        ok = self._adc_ok
        if self.last_degraded:   # mask is data: same executables (§16)
            ok = _and_ok(ok, self._sok_dev(bucket, np.int32))
        if self.quantization == "int8":
            q8 = self.adc_codebook.encode_query(Q)
            cand = _sharded_sq_topk(
                self._adc_c8, self._adc_cn, ok,
                jnp.asarray(q8), mesh=self.mesh, axis=self.axis,
                kp=kp_eff)
        else:
            lut = self.adc_codebook.lut(Q)
            cand = _sharded_pq_topk(
                self._adc_codes_t, ok, jnp.asarray(lut),
                mesh=self.mesh, axis=self.axis, kp=kp_eff)
        cand = np.asarray(cand, np.int32)
        safe, valid = self._mask_alive(cand, np.ones(cand.shape, bool))
        self.last_filter_bytes = self._adc_code_bytes(bucket)
        return safe, valid, nq * st.n_total     # same accounting as the
        # f32 paths: rows present, incl. tombstones

    def _candidates_adc_ivf(self, Q_sap: np.ndarray, kp2: int):
        nq = Q_sap.shape[0]
        if self.ivf is None:                  # nothing alive to probe
            return (np.zeros((nq, kp2), np.int32),
                    np.zeros((nq, kp2), bool), 0)
        Q = np.asarray(Q_sap, np.float32)
        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        pm = self._pool_alive()
        if self.oblivious:
            bucket = int(self._adc_ok.shape[0])
            member = pool_membership(nq, pools, bucket, pool_mask=pm)
            kp_eff = min(kp2, bucket)
            if self.quantization == "int8":
                q8 = self.adc_codebook.encode_query(Q)
                ids = _sharded_sq_oblivious(
                    self._adc_c8, self._adc_cn, jnp.asarray(q8),
                    jnp.asarray(member), mesh=self.mesh, axis=self.axis,
                    kp=kp_eff)
            else:
                lut = self.adc_codebook.lut(Q)
                ids = _sharded_pq_oblivious(
                    self._adc_codes_t, jnp.asarray(lut),
                    jnp.asarray(member), mesh=self.mesh, axis=self.axis,
                    kp=kp_eff)
            ids = np.asarray(ids, np.int32)
            # validity = host-side membership lookup at the merged ids
            vout = member[np.arange(nq)[:, None], np.clip(ids, 0, bucket - 1)]
            ids, vout = self._mask_alive(ids, vout)
            evals = nq * bucket + nq * self.ivf.centroids.shape[0]
            self.last_filter_bytes = (self._adc_code_bytes(bucket)
                                      + self.ivf.centroids.nbytes)
            return ids, vout, evals
        cand, valid = layout_pools(nq, pools, kp2, pool_mask=pm)
        if self.quantization == "int8":
            q8 = self.adc_codebook.encode_query(Q)
            ids, vout = _sharded_sq_pool_scan(
                self._adc_c8, self._adc_cn, jnp.asarray(q8),
                jnp.asarray(cand), jnp.asarray(valid),
                mesh=self.mesh, axis=self.axis, kp=kp2)
        else:
            lut = self.adc_codebook.lut(Q)
            ids, vout = _sharded_pq_pool_scan(
                self._adc_codes_t, jnp.asarray(lut), jnp.asarray(cand),
                jnp.asarray(valid), mesh=self.mesh, axis=self.axis,
                kp=kp2)
        evals = sum(p.size for p in pools) \
            + nq * self.ivf.centroids.shape[0]
        self.last_filter_bytes = (
            self._adc_code_bytes(sum(p.size for p in pools))
            + self.ivf.centroids.nbytes)
        return np.asarray(ids), np.asarray(vout), evals

    def _candidates_flat(self, Q_sap: np.ndarray, kp: int):
        st = self.store
        nq = Q_sap.shape[0]
        bucket = int(self._C_all.shape[0])
        kp_eff = min(kp, bucket)
        Qd = jnp.asarray(np.asarray(Q_sap, np.float32))
        if self.last_degraded:
            cand = _sharded_flat_topk_ok(
                self._C_all, self._sok_dev(bucket, np.bool_), Qd,
                mesh=self.mesh, axis=self.axis, kp=kp_eff)
        else:
            cand = _sharded_flat_topk(self._C_all, Qd, mesh=self.mesh,
                                      axis=self.axis, kp=kp_eff)
        cand = np.asarray(cand, np.int32)
        safe, valid = self._mask_alive(cand, np.ones(cand.shape, bool))
        self.last_filter_bytes = int(self._C_all.size) * 4
        return safe, valid, nq * st.n_total

    def _candidates_ivf(self, Q_sap: np.ndarray, kp: int):
        st = self.store
        nq = Q_sap.shape[0]
        if self.ivf is None:                  # nothing alive to probe
            return (np.zeros((nq, kp), np.int32),
                    np.zeros((nq, kp), bool), 0)
        Q = np.asarray(Q_sap, np.float32)
        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        pm = self._pool_alive()
        if self.oblivious:
            bucket = int(self._C_all.shape[0])
            member = pool_membership(nq, pools, bucket, pool_mask=pm)
            ids = np.asarray(_sharded_oblivious_scan(
                self._C_all, jnp.asarray(Q), jnp.asarray(member),
                mesh=self.mesh, axis=self.axis,
                kp=min(kp, bucket)), np.int32)
            vout = member[np.arange(nq)[:, None], np.clip(ids, 0, bucket - 1)]
            ids, vout = self._mask_alive(ids, vout)
            evals = nq * bucket + nq * self.ivf.centroids.shape[0]
            self.last_filter_bytes = (bucket * st.d * 4
                                      + self.ivf.centroids.nbytes)
            return ids, vout, evals
        cand, valid = layout_pools(nq, pools, kp, pool_mask=pm)
        ids, vout = _sharded_pool_scan(
            self._C_all, jnp.asarray(Q), jnp.asarray(cand),
            jnp.asarray(valid), mesh=self.mesh, axis=self.axis, kp=kp)
        evals = sum(p.size for p in pools) \
            + nq * self.ivf.centroids.shape[0]
        self.last_filter_bytes = (sum(p.size for p in pools) * st.d * 4
                                  + self.ivf.centroids.nbytes)
        return np.asarray(ids), np.asarray(vout), evals

    def _candidates_graph(self, Q_sap: np.ndarray, kp: int,
                          ef_search: int):
        """Per-shard batched traversal + cross-shard k' merge.  Each
        shard's lockstep walk returns its local top-k' with surrogate
        distances (one global codebook, so the scores are comparable
        across shards); the merged candidate list is the top-k' of the
        (nq, S*k') concatenation — the same k'-per-shard collective
        shape as the flat all-gather merge, assembled host-side because
        the traversal itself does not run under the mesh."""
        from ..kernels.graph_expand import ops as graph_ops
        st = self.store
        Q = np.asarray(Q_sap, np.float32)
        nq = Q.shape[0]
        per = self._g_per
        kp2 = max(1, min(self.oversampled(kp), per))
        ef_eff, ef_cap, max_hops = beam_plan(kp2, max(ef_search, kp2))
        if self.quantization is None:
            qd = jnp.asarray(Q)
        elif self.quantization == "int8":
            qd = jnp.asarray(self.adc_codebook.encode_query(Q))
        else:
            qd = jnp.asarray(self.adc_codebook.lut(Q))
        sm = self.health.serve_mask()
        n_up = int(sm.sum())
        ids_p, d_p, vis_p = [], [], []
        hops_t = edges_t = 0
        for s in range(self.n_shards):
            if not sm[s]:
                continue       # dead group: no replica to walk (§16)
            lo, hi = s * per, (s + 1) * per
            if self.quantization is None:
                db = (self._C_all[lo:hi],)
            elif self.quantization == "int8":
                db = (self._adc_c8[lo:hi], self._adc_cn[lo:hi])
            else:
                db = (self._adc_codes_t[:, lo:hi],)
            cand, cand_d, visited, hops, edges = graph_ops.graph_topk(
                self._g_neigh0_sh[s], self._g_neigh_up_sh[s],
                self._g_ok[lo:hi], db, qd,
                jnp.int32(self._g_csrs[s].entry), jnp.int32(ef_eff),
                kp=kp2, ef_cap=ef_cap, max_hops=max_hops,
                quant=self.quantization or "f32",
                oblivious=self.oblivious, use_kernel=False)
            c = np.asarray(cand, np.int32)
            ids_p.append(np.where(c >= 0, c + np.int32(lo), -1))
            d_p.append(np.where(c >= 0, np.asarray(cand_d, np.float32),
                                np.inf))
            vis_p.append(np.asarray(visited))
            hops_t += int(np.asarray(hops).sum())
            edges_t += int(np.asarray(edges).sum())
        if not ids_p:                  # every shard group is down
            self.last_n_hops = self.last_n_edges_scanned = 0
            self.last_filter_bytes = 0
            self.last_scan_trace = np.zeros((nq, 0), np.int32)
            return (np.zeros((nq, kp2), np.int32),
                    np.zeros((nq, kp2), bool), 0)
        ids = np.concatenate(ids_p, axis=1)
        dists = np.concatenate(d_p, axis=1)
        order = np.argsort(dists, axis=1, kind="stable")[:, :kp2]
        cand = np.take_along_axis(ids, order, axis=1)
        safe, valid = self._mask_alive(cand, cand >= 0)
        self.last_n_hops = hops_t
        self.last_n_edges_scanned = edges_t
        row_bytes = (st.d * 4 if self.quantization is None
                     else self.adc_codebook.code_bytes_per_vector())
        self.last_filter_bytes = (edges_t + nq * n_up) * row_bytes
        self.last_scan_trace = np.concatenate(vis_p, axis=1)
        return safe, valid, edges_t + nq * n_up

    # ----------------------------------------------------------- refine

    def refine_batch(self, C_dce_dev, cand, T, valid, k: int):
        """Engine hook: the sharded tournament replaces the single-device
        `refine_candidates` call (same semantics, same -1 fill)."""
        return _sharded_refine(C_dce_dev, cand, T, valid,
                               mesh=self.mesh, axis=self.axis, k=k)
