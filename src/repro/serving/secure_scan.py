"""The unified engine's search, distribution-native: the sharded
secure-scan dry-run cell (DESIGN.md §3, §4).

This is the dry-run cell that represents the paper's technique at
production scale: the encrypted database (DCPE filter ciphertexts + DCE
refine ciphertexts) is sharded row-wise over EVERY mesh device; a batch of
encrypted queries runs

  filter:  per-shard L2 distance tiles (MXU) -> per-shard top-k'
           -> all-gather(k' candidates/shard) -> global top-k'   [shard_map]
  refine:  gather candidates' DCE ciphertexts -> the engine's shared
           batched tournament (kernels.dce_comp.batched_top_k_by_wins,
           einsum formulation) -> exact top-k                    [GSPMD]

The refine math is the same code path the live engine
(serving.search_engine) and the mesh server (serving.ann_server) run —
this module only adds the explicit-collective filter formulation:
per-device work is O(n/devices) and the only communication is k' rows
per shard, which is what makes the paper's single-server design scale
linearly in devices (EXPERIMENTS.md §Perf discusses the alternative
GSPMD-auto formulation, which all-gathers the (B, n) distance matrix).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..kernels.dce_comp import ops as dce_ops

__all__ = ["build_secure_scan_step", "secure_scan_input_specs"]


def secure_scan_input_specs(n: int, d: int, batch: int, *, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    Dd = 2 * d + 16
    return {
        "C_sap": jax.ShapeDtypeStruct((n, d), dtype),
        "C_dce": jax.ShapeDtypeStruct((n, 4, Dd), dtype),
        "Q_sap": jax.ShapeDtypeStruct((batch, d), dtype),
        "T_q": jax.ShapeDtypeStruct((batch, Dd), dtype),
    }


def secure_scan_pspecs(mesh: Mesh):
    axes = tuple(mesh.axis_names)
    return {
        "C_sap": P(axes, None),
        "C_dce": P(axes, None, None),
        "Q_sap": P(),            # queries replicated (tiny)
        "T_q": P(),
    }


def build_secure_scan_step_gspmd(mesh: Mesh, *, k: int, k_prime: int):
    """Negative control for §Perf: the GSPMD-auto formulation.  The global
    (B, n) distance matrix and its top-k are left to the partitioner,
    which must materialize/gather across the sharded n dimension — the
    collective/memory blowup the shard_map version avoids."""

    def step(C_sap, C_dce, Q_sap, T_q):
        qn = (Q_sap * Q_sap).sum(-1, keepdims=True)
        xn = (C_sap * C_sap).sum(-1)[None, :]
        dist = qn - 2.0 * Q_sap @ C_sap.T + xn            # (B, n) global
        _, cand = jax.lax.top_k(-dist, k_prime)
        Cc = jnp.take(C_dce, cand, axis=0)
        top = dce_ops.batched_top_k_by_wins(Cc, T_q, k, use_kernel=False)
        return jnp.take_along_axis(cand, top, axis=1)

    return step


def build_secure_scan_step(mesh: Mesh, *, k: int, k_prime: int):
    axes = tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))

    def _shard_index():
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axes, None), P(None, None)),
        out_specs=(P(None, None), P(None, None)),
        check_rep=False)
    def filter_local(C_sap_loc, Q):
        """Per-shard filter + global candidate merge."""
        n_loc = C_sap_loc.shape[0]
        qn = (Q * Q).sum(-1, keepdims=True)
        xn = (C_sap_loc * C_sap_loc).sum(-1)[None, :]
        dist = qn - 2.0 * Q @ C_sap_loc.T + xn            # (B, n_loc)
        kp = min(k_prime, n_loc)
        neg, idx = jax.lax.top_k(-dist, kp)               # local top-k'
        gidx = idx + _shard_index() * n_loc
        # every shard contributes k' candidates -> (B, shards * k')
        vals = jax.lax.all_gather(-neg, axes, axis=1, tiled=True)
        gids = jax.lax.all_gather(gidx, axes, axis=1, tiled=True)
        neg2, pos = jax.lax.top_k(-vals, min(k_prime, vals.shape[1]))
        cand = jnp.take_along_axis(gids, pos, axis=1)
        return -neg2, cand                                # (B, k')

    def step(C_sap, C_dce, Q_sap, T_q):
        _, cand = filter_local(C_sap, Q_sap)              # (B, k')
        # refine: the engine's shared batched tournament (GSPMD gather)
        Cc = jnp.take(C_dce, cand, axis=0)                # (B, k', 4, Dd)
        top = dce_ops.batched_top_k_by_wins(Cc, T_q, k, use_kernel=False)
        return jnp.take_along_axis(cand, top, axis=1)     # (B, k)

    return step
