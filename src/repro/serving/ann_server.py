"""DEPRECATED — the legacy mesh server, now a shim over the unified
sharded execution layer (DESIGN.md §10).

`DistributedSecureANN` predates placement-aware collections: it was a
parallel implementation of the sharded scan (its own filter jit, its own
pad/sentinel logic).  The real thing now lives in `serving/sharded.py`
(`ShardedBackend` behind `SecureSearchEngine`), which is what
`repro.api`'s `placement=PlacementSpec(kind="sharded")` collections run.
This class remains only so old callers keep working — it warns, builds
the same sharded backend, and returns bit-identical ids (parity-tested
in tests/test_search_engine.py).
"""

from __future__ import annotations

import warnings

import numpy as np

from ..core import dce
from .runtime.ingest import MutableEncryptedStore
from .search_engine import SecureSearchEngine
from .sharded import ShardedBackend

__all__ = ["DistributedSecureANN"]


class DistributedSecureANN:
    """DEPRECATED shim: sharded filter + batched refine via the unified
    engine.  Use `repro.api` with a sharded `PlacementSpec` instead."""

    def __init__(self, C_sap: np.ndarray, C_dce: np.ndarray,
                 mesh=None, axis: str | None = None):
        warnings.warn(
            "serving.ann_server.DistributedSecureANN is deprecated; use "
            "repro.api: SecureAnnService.create_collection(spec, "
            "placement=PlacementSpec(kind='sharded', ...)) runs the same "
            "sharded pipeline behind submit()", DeprecationWarning,
            stacklevel=2)
        C_sap = np.asarray(C_sap, np.float32)
        C_dce = np.asarray(C_dce, np.float32)
        self.n = C_sap.shape[0]
        self.mesh = mesh
        if mesh is not None:
            axes = tuple(mesh.axis_names) if axis is None else (axis,)
            n_shards = int(np.prod([mesh.shape[a] for a in axes]))
            axis_name = axes[0]
        else:
            n_shards, axis_name = 1, "data"
        store = MutableEncryptedStore(C_sap.shape[1],
                                      dce.ciphertext_dim(C_sap.shape[1]))
        store.append(C_sap, C_dce)
        self._backend = ShardedBackend(store, "flat", n_shards=n_shards,
                                       data_axis=axis_name)
        self._engine = SecureSearchEngine(
            store.sap_view, store.dce_padded_view, backend=self._backend,
            use_kernel=False)

    @property
    def n_padded(self) -> int:
        return self._backend.padded_rows

    def query_batch(self, Q_sap: np.ndarray, T_q: np.ndarray, k: int,
                    ratio_k: float = 8.0):
        """Q_sap: (nq, d) DCPE-encrypted queries; T_q: (nq, 2d+16) DCE
        trapdoors.  Returns ids (nq, k); -1 fills slots where fewer than
        k real rows exist — the engine's uniform contract."""
        ids, _ = self._engine.search_batch(Q_sap, T_q, k, ratio_k=ratio_k)
        return ids
