"""Distributed privacy-preserving ANN serving — the paper's server role
mapped onto a TPU mesh (DESIGN.md §3).

Graph traversal doesn't shard; partition-pruned scans do.  Layout:
  * the DCPE ciphertexts and DCE ciphertexts are sharded row-wise across
    every mesh device (jax.device_put with a NamedSharding);
  * an IVF coarse quantizer (built over DCPE ciphertexts — same privacy
    envelope as the HNSW index) prunes partitions;
  * `query_batch` runs under jit on the mesh: each device computes local
    filter distances (l2_topk kernel math), local top-k', then a global
    merge; the refine phase runs the exact DCE tournament on the merged
    candidate set.

This gives the single-server PP-ANNS of the paper a data-parallel scan
path whose distance evaluations ride the MXU — the 1000x-at-scale story.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core import dce
from ..core.ivf import IVFIndex

__all__ = ["DistributedSecureANN"]


class DistributedSecureANN:
    """Sharded filter (DCPE distances) + exact refine (DCE tournament)."""

    def __init__(self, C_sap: np.ndarray, C_dce: np.ndarray,
                 mesh: Mesh | None = None, n_partitions: int = 0,
                 axis: str | None = None):
        self.mesh = mesh
        self.n = C_sap.shape[0]
        if mesh is not None:
            axes = tuple(mesh.axis_names) if axis is None else (axis,)
            shards = int(np.prod([mesh.shape[a] for a in axes]))
            pad = (-self.n) % shards
        else:
            axes, pad = (), 0
        # zero-padding adds far-away phantoms only if vectors can be near 0;
        # pad with +inf-ish sentinel rows instead so they never enter top-k.
        if pad:
            big = np.full((pad, C_sap.shape[1]), 1e9, C_sap.dtype)
            C_sap = np.concatenate([C_sap, big], 0)
            C_dce = np.concatenate(
                [C_dce, np.zeros((pad,) + C_dce.shape[1:], C_dce.dtype)], 0)
        self.n_padded = C_sap.shape[0]
        if mesh is not None:
            sh_sap = NamedSharding(mesh, P(axes, None))
            sh_dce = NamedSharding(mesh, P(axes, None, None))
            self.C_sap = jax.device_put(jnp.asarray(C_sap), sh_sap)
            self.C_dce = jax.device_put(jnp.asarray(C_dce), sh_dce)
        else:
            self.C_sap = jnp.asarray(C_sap)
            self.C_dce = jnp.asarray(C_dce)

        self.ivf = None
        if n_partitions:
            self.ivf = IVFIndex(n_clusters=n_partitions).build(
                np.asarray(C_sap[: self.n]))

        self._filter = jax.jit(self._filter_impl, static_argnames=("kp",))
        self._refine = jax.jit(self._refine_impl, static_argnames=("k",))

    # ---- filter phase: sharded DCPE distance scan + global top-k'
    def _filter_impl(self, Q_sap, kp: int):
        qn = (Q_sap * Q_sap).sum(-1, keepdims=True)
        xn = (self.C_sap * self.C_sap).sum(-1)[None, :]
        d = qn - 2.0 * Q_sap @ self.C_sap.T + xn        # (nq, n_padded)
        neg, idx = jax.lax.top_k(-d, kp)
        return -neg, idx

    # ---- refine phase: exact DCE tournament on the candidate set
    def _refine_impl(self, cand_C, T_q, k: int):
        term1 = (cand_C[:, 0, :] * T_q) @ cand_C[:, 2, :].T
        term2 = (cand_C[:, 1, :] * T_q) @ cand_C[:, 3, :].T
        Z = term1 - term2
        offdiag = ~jnp.eye(Z.shape[0], dtype=bool)
        wins = ((Z < 0) & offdiag).sum(axis=1)
        _, top = jax.lax.top_k(wins, k)
        return top

    def query_batch(self, Q_sap: np.ndarray, T_q: np.ndarray, k: int,
                    ratio_k: float = 8.0):
        """Q_sap: (nq, d) DCPE-encrypted queries; T_q: (nq, 2d+16) DCE
        trapdoors.  Returns ids (nq, k)."""
        kp = int(max(k, round(ratio_k * k)))
        _, cand = self._filter(jnp.asarray(Q_sap), kp)   # (nq, kp)
        cand = np.asarray(cand)
        out = np.empty((cand.shape[0], k), np.int64)
        for qi in range(cand.shape[0]):
            ids = cand[qi]
            local = self._refine(self.C_dce[ids], jnp.asarray(T_q[qi]), k)
            out[qi] = ids[np.asarray(local)]
        return out
