"""Distributed privacy-preserving ANN serving — the unified search
engine's filter-and-refine pipeline mapped onto a TPU mesh (DESIGN.md §3).

Graph traversal doesn't shard; scans do.  Layout:
  * the DCPE ciphertexts and DCE ciphertexts are sharded row-wise across
    every mesh device (jax.device_put with a NamedSharding);
  * `query_batch` runs under jit on the mesh: each device computes local
    filter distances (the l2_topk kernel's ||q||^2 - 2 q.x + ||x||^2
    restructuring), a global top-k' merge prunes to the candidate sets;
  * the refine phase is the engine's shared batched DCE tournament
    (`serving.search_engine.refine_candidates`) — the einsum formulation
    under a mesh (GSPMD partitions the gather + matmuls), the dce_comp
    Pallas kernel on a single device.  There is no per-query Python loop
    anywhere in the batched path.

Single-host partition pruning (IVF) lives in the engine's IVFScanFilter
backend; this module is the mesh-sharded deployment of the same pipeline
— the 1000x-at-scale story of the single-server PP-ANNS design.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .search_engine import refine_candidates

__all__ = ["DistributedSecureANN"]


class DistributedSecureANN:
    """Sharded filter (DCPE distances) + exact batched refine (DCE
    tournament) — the mesh deployment of the unified search engine."""

    def __init__(self, C_sap: np.ndarray, C_dce: np.ndarray,
                 mesh: Mesh | None = None, axis: str | None = None):
        self.mesh = mesh
        self.n = C_sap.shape[0]
        if mesh is not None:
            axes = tuple(mesh.axis_names) if axis is None else (axis,)
            shards = int(np.prod([mesh.shape[a] for a in axes]))
            pad = (-self.n) % shards
        else:
            axes, pad = (), 0
        # zero-padding adds far-away phantoms only if vectors can be near 0;
        # pad with +inf-ish sentinel rows instead so they never enter top-k.
        if pad:
            big = np.full((pad, C_sap.shape[1]), 1e9, C_sap.dtype)
            C_sap = np.concatenate([C_sap, big], 0)
            C_dce = np.concatenate(
                [C_dce, np.zeros((pad,) + C_dce.shape[1:], C_dce.dtype)], 0)
        self.n_padded = C_sap.shape[0]
        if mesh is not None:
            sh_sap = NamedSharding(mesh, P(axes, None))
            sh_dce = NamedSharding(mesh, P(axes, None, None))
            self.C_sap = jax.device_put(jnp.asarray(C_sap), sh_sap)
            self.C_dce = jax.device_put(jnp.asarray(C_dce), sh_dce)
        else:
            self.C_sap = jnp.asarray(C_sap)
            self.C_dce = jnp.asarray(C_dce)

        # Pallas refine on a single device; einsum refine under GSPMD
        # (a pallas_call over mesh-sharded gathers fights the partitioner).
        self._use_kernel = mesh is None
        self._filter = jax.jit(self._filter_impl, static_argnames=("kp",))

    # ---- filter phase: sharded DCPE distance scan + global top-k'
    def _filter_impl(self, Q_sap, kp: int):
        qn = (Q_sap * Q_sap).sum(-1, keepdims=True)
        xn = (self.C_sap * self.C_sap).sum(-1)[None, :]
        d = qn - 2.0 * Q_sap @ self.C_sap.T + xn        # (nq, n_padded)
        neg, idx = jax.lax.top_k(-d, kp)
        return -neg, idx

    def query_batch(self, Q_sap: np.ndarray, T_q: np.ndarray, k: int,
                    ratio_k: float = 8.0):
        """Q_sap: (nq, d) DCPE-encrypted queries; T_q: (nq, 2d+16) DCE
        trapdoors.  Returns ids (nq, k); -1 fills slots where fewer than
        k real rows exist.  Filter and refine both run batched under jit
        — no per-query host loop."""
        kp = min(int(max(k, round(ratio_k * k))), self.n_padded)
        _, cand = self._filter(jnp.asarray(Q_sap), kp)   # (nq, kp)
        valid = cand < self.n          # mask the +inf sentinel pad rows
        ids = refine_candidates(self.C_dce, cand, jnp.asarray(T_q), valid,
                                min(k, kp), self._use_kernel)
        ids = np.asarray(ids, np.int64)
        if ids.shape[1] < k:           # uniform (nq, k) contract: -1 fill
            ids = np.pad(ids, ((0, 0), (0, k - ids.shape[1])),
                         constant_values=-1)
        return ids
