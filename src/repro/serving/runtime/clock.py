"""Deterministic time seam for the serving schedulers (DESIGN.md §12).

Scheduler code is exactly where wall-clock coupling turns tests into
sleep festivals: a deadline flush is "wait 2 ms", a drain is "join and
hope".  Both schedulers (`MicroBatcher`, `SlotLoop`) therefore never
call `time.monotonic()` or `Condition.wait(timeout)` directly — they go
through an injected `Clock`:

  * `SystemClock` (production default) — `time.monotonic()` + real
    `Condition.wait` timeouts; zero behavioural change.
  * `VirtualClock` (tests) — time advances only when the test calls
    `advance(dt)`; a timed wait parks on the condition until a notify
    arrives or virtual time passes its deadline.  Tests drive the
    scheduler through its deadline logic deterministically, with no
    real sleeping and no timing races.

The contract mirrors `threading.Condition.wait`: `wait(cv, timeout)`
may return spuriously (callers re-check their predicate), must be
called with `cv`'s lock held, and a `timeout=None` wait returns only on
notify.  `VirtualClock` keeps a small *real* safety timeout underneath
so a test that forgets to `advance()` fails loudly instead of hanging
the suite.
"""

from __future__ import annotations

import threading
import time

__all__ = ["Clock", "SystemClock", "VirtualClock"]


class Clock:
    """Scheduler time source: `now()` seconds + condition-wait seam."""

    def now(self) -> float:
        raise NotImplementedError

    def wait(self, cv: threading.Condition, timeout: float | None):
        """Park on `cv` (lock held by caller) until notified or until
        `timeout` seconds of *this clock's* time have passed.  May
        return spuriously, like `Condition.wait`."""
        raise NotImplementedError


class SystemClock(Clock):
    """Wall-clock time — the production default."""

    def now(self) -> float:
        return time.monotonic()

    def wait(self, cv: threading.Condition, timeout: float | None):
        cv.wait(timeout=timeout)


class VirtualClock(Clock):
    """Manually advanced time for deterministic scheduler tests.

    `advance(dt)` moves time forward and wakes every timed waiter whose
    deadline has passed; untimed waiters wake only on their condition's
    own notify (exactly the semantics the schedulers assume).  A
    `safety_s` *real* timeout underneath every park keeps a buggy test
    from deadlocking the whole suite — spurious returns are legal, so
    this never changes scheduler behaviour.
    """

    def __init__(self, start: float = 0.0, safety_s: float = 10.0):
        self._t = float(start)
        self.safety_s = float(safety_s)
        self._lock = threading.Lock()
        self._changed = threading.Condition(self._lock)
        self._waiters: list[tuple[threading.Condition, float]] = []

    def now(self) -> float:
        with self._lock:
            return self._t

    def wait(self, cv: threading.Condition, timeout: float | None):
        if timeout is None:
            cv.wait(timeout=self.safety_s)
            return
        with self._lock:
            # registered before cv.wait releases cv's lock: an
            # advance() racing this wait either sees the entry and
            # notifies, or has already moved time — the scheduler
            # re-checks `now()` against its deadline on return anyway
            entry = (cv, self._t + float(timeout))
            self._waiters.append(entry)
            self._changed.notify_all()
        try:
            cv.wait(timeout=self.safety_s)
        finally:
            with self._lock:
                if entry in self._waiters:
                    self._waiters.remove(entry)
                self._changed.notify_all()

    def advance(self, dt: float):
        """Move virtual time forward and wake expired timed waiters."""
        if dt < 0:
            raise ValueError(f"cannot advance time backwards ({dt})")
        with self._lock:
            self._t += float(dt)
            due = [cv for cv, deadline in self._waiters
                   if deadline <= self._t]
        for cv in due:
            with cv:
                cv.notify_all()

    def wait_for_waiters(self, n: int = 1, timeout: float = 10.0) -> int:
        """Block (real time) until >= n timed waiters are parked — the
        deterministic sync point for "the scheduler is now waiting on
        its deadline" before a test advances the clock."""
        with self._changed:
            ok = self._changed.wait_for(
                lambda: len(self._waiters) >= n, timeout=timeout)
            if not ok:
                raise TimeoutError(
                    f"{len(self._waiters)} timed waiter(s) after "
                    f"{timeout}s (wanted {n})")
            return len(self._waiters)
