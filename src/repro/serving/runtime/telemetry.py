"""Per-collection serving telemetry (DESIGN.md §8, §13).

Every number the runtime reports is derived from the engine's uniform
`SearchStats` plus batcher-side timestamps — there is no second
accounting path to drift from the engine's.

Counters and gauges per collection:
  * request / reject / batch counts, insert / delete / compaction counts;
  * the accumulated `SearchStats` cost counters (paper §V-C: ciphertext
    distance evaluations, DCE comparisons, filter bytes scanned, bytes
    up/down) — the engine's communication/work model, operator-visible;
  * QPS over a sliding window;
  * batch occupancy (real requests per flushed batch — the coalescing
    win; > 1 means the micro-batcher is actually batching);
  * slot occupancy (continuous scheduler, DESIGN.md §12: active slots /
    table capacity per step, rolling mean — ≈ 1 at high arrival rate
    means the slot table refills as fast as it emits) and step counts;
  * p50 / p99 request sojourn latency (enqueue -> result) from a bounded
    reservoir of recent requests, plus insert -> emit sojourn for the
    slot loop (time a request actually occupied a slot row);
  * queue depth gauge (set by the scheduler on every transition);
  * jit recompile tracking: `jit_cache_size()` sums the executable-cache
    sizes of the jitted search/encrypt entry points, so a bench or test
    can assert "zero recompiles after warmup across bucketed shapes"
    (flush) or "zero recompiles after one warmup step" (continuous).

Time comes from the injected `Clock` (DESIGN.md §12) — telemetry never
reads wall time directly, so QPS windows, pruning, and sojourn math are
assertable on `VirtualClock` like everything else in the runtime.

When a `repro.obs.MetricsRegistry` is attached (DESIGN.md §13), every
record_* call additionally feeds the cross-collection Prometheus
instruments (fixed-bucket latency histograms, labelled counters/gauges,
first-class recompile events with the triggering batch shape).  With no
registry attached — the default — none of that code runs.
"""

from __future__ import annotations

import collections
import threading
import time

__all__ = ["CollectionTelemetry", "jit_cache_size"]


def jit_cache_size() -> int:
    """Total cached-executable count across the runtime's jitted entry
    points.  A steady value across a traffic phase == zero recompiles."""
    from ...core import dce, dcpe
    from ...kernels.adc_topk import ops as adc_ops
    from ...kernels.dce_comp import ops as dce_ops
    from ...kernels.graph_expand import ops as graph_ops
    from ...kernels.l2_topk import ops as l2_ops
    from .. import search_engine as se
    from .. import sharded

    fns = (
        graph_ops.graph_topk,
        se.refine_candidates,
        se._masked_pruned_scan,
        se._masked_full_scan,
        l2_ops.knn,
        dce_ops.batched_top_k_by_wins,
        dce._encrypt_jax_core,
        dcpe._encrypt_jax,
        adc_ops.sq_knn,
        adc_ops.pq_knn,
        adc_ops.sq_pool_scan,
        adc_ops.pq_pool_scan,
        adc_ops.sq_oblivious_scan,
        adc_ops.pq_oblivious_scan,
    )
    return sum(f._cache_size() for f in fns) + sharded.cache_size()


class _ClockShim:
    """Wrap a bare clock-less default so the class body reads uniformly."""
    now = staticmethod(time.monotonic)


class CollectionTelemetry:
    """Thread-safe rolling metrics for one collection.

    clock: the runtime `Clock` the collection's scheduler runs on (the
    seam PR 6 added); None = wall time.  metrics/labels: an optional
    `repro.obs.MetricsRegistry` plus the label values ({"tenant": ...,
    "collection": ...}) this collection exports under.
    """

    def __init__(self, window_s: float = 60.0, reservoir: int = 1024,
                 clock=None, metrics=None, labels=None):
        self.window_s = float(window_s)
        self.clock = clock if clock is not None else _ClockShim()
        self._t0 = self.clock.now()
        self._lock = threading.Lock()
        self._latencies = collections.deque(maxlen=reservoir)
        self._flushes = collections.deque()        # (t, n_real_requests)
        self._insert_to_emit = collections.deque(maxlen=reservoir)
        self._slot_occ = collections.deque(maxlen=reservoir)
        self.n_requests = 0
        self.n_rejected = 0
        self.n_batches = 0
        self.n_steps = 0
        self.n_batched_requests = 0
        self.n_inserts = 0
        self.n_deletes = 0
        self.n_compactions = 0
        self.queue_depth = 0
        self.last_backend = ""
        # accumulated SearchStats counters (paper §V-C): summed over
        # every batched engine call this collection served
        self.filter_dist_evals = 0
        self.refine_comparisons = 0
        self.filter_bytes_scanned = 0
        self.bytes_up = 0
        self.bytes_down = 0
        # security-profile overhead accounting (repro.sec, DESIGN.md
        # §14): dummy padding rows the schedulers injected, and result
        # bytes added by fixed-shape id padding.  Dummies never count
        # toward QPS/occupancy — those track n_real/n_active only.
        self.n_dummy_queries = 0
        self.padded_result_bytes = 0
        # graph-backend traversal accounting (repro.graph, DESIGN.md
        # §15): beam/greedy hops and edges scored, summed from the
        # engine's SearchStats — 0 for scan backends
        self.n_hops = 0
        self.n_edges_scanned = 0
        # resilience accounting (repro.resilience, DESIGN.md §16):
        # durability (WAL records logged / replayed, checkpoints
        # written), per-request retry/quarantine at the schedulers, and
        # degraded answers served while shard groups were down
        self.n_wal_records = 0
        self.n_wal_replayed = 0
        self.n_checkpoints = 0
        self.n_retries = 0
        self.n_quarantined = 0
        self.n_degraded_answers = 0
        self._wire_metrics(metrics, labels or {})

    # ------------------------------------------------- metrics exposition

    def _wire_metrics(self, metrics, labels: dict):
        """Register this collection's label-set on the shared registry.
        All _m_* handles stay None when no registry is attached, and the
        record_* paths skip exposition entirely."""
        self._labels = dict(labels)
        if metrics is None:
            self._m_requests = None
            return
        names = tuple(self._labels)
        c = lambda n, h: metrics.counter(n, h, names)        # noqa: E731
        self._m_requests = c("ann_requests_total",
                             "Requests admitted to the queue")
        self._m_rejected = c("ann_rejected_total",
                             "Requests shed by admission control")
        self._m_batches = c("ann_batches_total", "Flushed micro-batches")
        self._m_steps = c("ann_steps_total", "Slot-table steps")
        self._m_batched = c("ann_batched_requests_total",
                            "Requests served through batched engine calls")
        self._m_inserts = c("ann_inserts_total", "Rows inserted")
        self._m_deletes = c("ann_deletes_total", "Rows tombstoned")
        self._m_compactions = c("ann_compactions_total",
                                "Store compactions")
        self._m_dist = c("ann_filter_dist_evals_total",
                         "Ciphertext distance evaluations (filter stage)")
        self._m_cmp = c("ann_refine_comparisons_total",
                        "DCE comparison sign evaluations (refine stage)")
        self._m_scanned = c("ann_filter_bytes_scanned_total",
                            "Bytes the filter stage touched")
        self._m_up = c("ann_bytes_up_total",
                       "Serialized request bytes, client to server")
        self._m_down = c("ann_bytes_down_total",
                         "Serialized result bytes, server to client")
        self._m_hops = c("ann_graph_hops_total",
                         "Graph-backend traversal hops (filter stage)")
        self._m_edges = c("ann_graph_edges_scanned_total",
                          "Graph-backend edges scored (filter stage)")
        self._m_dummies = c("ann_dummy_queries_total",
                            "Dummy padding rows injected by the "
                            "scheduler (security profiles)")
        self._m_padded = c("ann_padded_bytes_total",
                           "Result bytes added by fixed-shape id "
                           "padding (security profiles)")
        self._m_wal = c("ann_wal_records_total",
                        "Acknowledged mutations appended to the WAL")
        self._m_wal_replayed = c("ann_wal_replayed_total",
                                 "WAL records replayed during recovery")
        self._m_checkpoints = c("ann_checkpoints_total",
                                "Background collection checkpoints "
                                "written")
        self._m_retries = c("ann_request_retries_total",
                            "Per-request engine-call retries after a "
                            "failed batch")
        self._m_quarantined = c("ann_quarantined_total",
                                "Requests quarantined after exhausting "
                                "retries (poison queries)")
        self._m_degraded = c("ann_degraded_answers_total",
                             "Engine calls answered with >= 1 shard "
                             "group down")
        self._m_queue = metrics.gauge(
            "ann_queue_depth", "Requests waiting in the scheduler queue",
            names)
        self._m_slot_occ = metrics.gauge(
            "ann_slot_occupancy",
            "Active slots / table capacity, last step", names)
        self._m_latency = metrics.histogram(
            "ann_request_latency_seconds",
            "Request sojourn latency, enqueue to result", names)
        self._m_sojourn = metrics.histogram(
            "ann_insert_to_emit_seconds",
            "Slot occupancy time, insert to emit", names)
        # recompiles as first-class events with the triggering shape:
        # the jit caches are global, so deltas are attributed to the
        # collection (and batch shape) whose engine call grew them
        self._m_recompiles = metrics.counter(
            "ann_recompiles_total",
            "Jitted-executable cache growth events", names + ("shape",))
        self._cache_size_seen = jit_cache_size()

    def _record_compiles(self, shape):
        """Counter increment per newly compiled executable, labelled with
        the batch shape of the engine call that triggered it."""
        size = jit_cache_size()
        grew = size - self._cache_size_seen
        self._cache_size_seen = size
        if grew > 0:
            self._m_recompiles.inc(
                grew, shape=str(tuple(shape or ())), **self._labels)

    # ------------------------------------------------------------ recording

    def record_submit(self, queue_depth: int):
        with self._lock:
            self.n_requests += 1
            self.queue_depth = queue_depth
        if self._m_requests is not None:
            self._m_requests.inc(**self._labels)
            self._m_queue.set(queue_depth, **self._labels)

    def record_reject(self):
        with self._lock:
            self.n_rejected += 1
        if self._m_requests is not None:
            self._m_rejected.inc(**self._labels)

    def _accumulate_stats_locked(self, stats):
        self.last_backend = stats.backend
        self.filter_dist_evals += stats.filter_dist_evals
        self.refine_comparisons += stats.refine_comparisons
        self.filter_bytes_scanned += stats.filter_bytes_scanned
        self.bytes_up += stats.bytes_up
        self.bytes_down += stats.bytes_down
        self.n_dummy_queries += stats.n_dummy_queries
        self.n_hops += stats.n_hops
        self.n_edges_scanned += stats.n_edges_scanned
        self.n_degraded_answers += int(stats.degraded)

    def _export_stats(self, stats, latencies_s):
        self._m_dist.inc(stats.filter_dist_evals, **self._labels)
        self._m_cmp.inc(stats.refine_comparisons, **self._labels)
        self._m_scanned.inc(stats.filter_bytes_scanned, **self._labels)
        self._m_up.inc(stats.bytes_up, **self._labels)
        self._m_down.inc(stats.bytes_down, **self._labels)
        if stats.n_hops:
            self._m_hops.inc(stats.n_hops, **self._labels)
        if stats.n_edges_scanned:
            self._m_edges.inc(stats.n_edges_scanned, **self._labels)
        if stats.degraded:
            self._m_degraded.inc(**self._labels)
        for x in latencies_s:
            self._m_latency.observe(float(x), **self._labels)

    def record_flush(self, n_real: int, latencies_s, stats,
                     queue_depth: int, shape=None, n_dummies: int = 0):
        """One micro-batch flush: n_real real requests rode one engine
        call whose uniform accounting is `stats` (a SearchStats).
        `n_dummies` padding rows (security profiles) rode alongside —
        they feed `ann_dummy_queries_total` but never the QPS window,
        which counts n_real only."""
        now = self.clock.now()
        with self._lock:
            self.n_batches += 1
            self.n_batched_requests += n_real
            self.queue_depth = queue_depth
            self._accumulate_stats_locked(stats)
            self._flushes.append((now, n_real))
            self._latencies.extend(float(x) for x in latencies_s)
            horizon = now - self.window_s
            while self._flushes and self._flushes[0][0] < horizon:
                self._flushes.popleft()
        if self._m_requests is not None:
            self._m_batches.inc(**self._labels)
            self._m_batched.inc(n_real, **self._labels)
            self._m_queue.set(queue_depth, **self._labels)
            if n_dummies:
                self._m_dummies.inc(n_dummies, **self._labels)
            self._export_stats(stats, latencies_s)
            self._record_compiles(shape)

    def record_step(self, n_active: int, capacity: int, sojourn_s,
                    insert_to_emit_s, stats, queue_depth: int,
                    shape=None, n_dummies: int = 0):
        """One slot-table step (DESIGN.md §12): n_active of capacity
        slots held requests; both sojourn streams feed the reservoirs."""
        now = self.clock.now()
        occ = n_active / capacity if capacity else 0.0
        with self._lock:
            self.n_steps += 1
            self.n_batched_requests += n_active
            self.queue_depth = queue_depth
            self._accumulate_stats_locked(stats)
            self._slot_occ.append(occ)
            self._flushes.append((now, n_active))
            self._latencies.extend(float(x) for x in sojourn_s)
            self._insert_to_emit.extend(float(x) for x in insert_to_emit_s)
            horizon = now - self.window_s
            while self._flushes and self._flushes[0][0] < horizon:
                self._flushes.popleft()
        if self._m_requests is not None:
            self._m_steps.inc(**self._labels)
            self._m_batched.inc(n_active, **self._labels)
            self._m_queue.set(queue_depth, **self._labels)
            if n_dummies:
                self._m_dummies.inc(n_dummies, **self._labels)
            self._m_slot_occ.set(occ, **self._labels)
            self._export_stats(stats, sojourn_s)
            for x in insert_to_emit_s:
                self._m_sojourn.observe(float(x), **self._labels)
            self._record_compiles(shape)

    def record_padded_bytes(self, n_bytes: int):
        """Result bytes added by fixed-shape id padding (security
        profiles) — fed by the API layer at result-padding time, since
        the engine's `bytes_down` counts the unpadded payload."""
        if n_bytes <= 0:
            return
        with self._lock:
            self.padded_result_bytes += n_bytes
        if self._m_requests is not None:
            self._m_padded.inc(n_bytes, **self._labels)

    # resilience events (repro.resilience, DESIGN.md §16) --------------

    def record_wal(self, n: int = 1):
        """n acknowledged mutations appended (and fsync'd) to the WAL."""
        with self._lock:
            self.n_wal_records += n
        if self._m_requests is not None:
            self._m_wal.inc(n, **self._labels)

    def record_wal_replay(self, n: int):
        """n WAL records replayed into this collection at recovery."""
        with self._lock:
            self.n_wal_replayed += n
        if self._m_requests is not None and n:
            self._m_wal_replayed.inc(n, **self._labels)

    def record_checkpoint(self):
        """One background `.ppcol` checkpoint durably replaced."""
        with self._lock:
            self.n_checkpoints += 1
        if self._m_requests is not None:
            self._m_checkpoints.inc(**self._labels)

    def record_retry(self):
        """One per-request retry of a request whose batch call failed."""
        with self._lock:
            self.n_retries += 1
        if self._m_requests is not None:
            self._m_retries.inc(**self._labels)

    def record_quarantine(self):
        """One request quarantined after exhausting its retry budget."""
        with self._lock:
            self.n_quarantined += 1
        if self._m_requests is not None:
            self._m_quarantined.inc(**self._labels)

    def record_ingest(self, n_inserted: int = 0, n_deleted: int = 0,
                      compacted: bool = False):
        with self._lock:
            self.n_inserts += n_inserted
            self.n_deletes += n_deleted
            self.n_compactions += int(compacted)
        if self._m_requests is not None:
            if n_inserted:
                self._m_inserts.inc(n_inserted, **self._labels)
            if n_deleted:
                self._m_deletes.inc(n_deleted, **self._labels)
            if compacted:
                self._m_compactions.inc(**self._labels)

    # ------------------------------------------------------------- reading

    @staticmethod
    def _percentile(sorted_xs: list[float], p: float) -> float:
        if not sorted_xs:
            return 0.0
        i = min(len(sorted_xs) - 1, int(round(p * (len(sorted_xs) - 1))))
        return sorted_xs[i]

    def snapshot(self) -> dict:
        now = self.clock.now()
        with self._lock:
            horizon = now - self.window_s
            # prune here too: record_flush-only pruning would leave span
            # stretching past the window after a quiet gap, deflating qps
            while self._flushes and self._flushes[0][0] < horizon:
                self._flushes.popleft()
            served = sum(n for _, n in self._flushes)
            # rate over the observed lifetime, capped at the window — a
            # single fresh flush must not read as thousands of QPS
            span = min(self.window_s, now - self._t0)
            lat = sorted(self._latencies)
            ins = sorted(self._insert_to_emit)
            occupancy = (self.n_batched_requests / self.n_batches
                         if self.n_batches else 0.0)
            slot_occ = (sum(self._slot_occ) / len(self._slot_occ)
                        if self._slot_occ else 0.0)
            return {
                "backend": self.last_backend,
                "n_requests": self.n_requests,
                "n_rejected": self.n_rejected,
                "n_batches": self.n_batches,
                "n_steps": self.n_steps,
                "n_inserts": self.n_inserts,
                "n_deletes": self.n_deletes,
                "n_compactions": self.n_compactions,
                "queue_depth": self.queue_depth,
                "filter_dist_evals": self.filter_dist_evals,
                "refine_comparisons": self.refine_comparisons,
                "filter_bytes_scanned": self.filter_bytes_scanned,
                "bytes_up": self.bytes_up,
                "bytes_down": self.bytes_down,
                "n_dummy_queries": self.n_dummy_queries,
                "padded_result_bytes": self.padded_result_bytes,
                "n_hops": self.n_hops,
                "n_edges_scanned": self.n_edges_scanned,
                "n_wal_records": self.n_wal_records,
                "n_wal_replayed": self.n_wal_replayed,
                "n_checkpoints": self.n_checkpoints,
                "n_retries": self.n_retries,
                "n_quarantined": self.n_quarantined,
                "n_degraded_answers": self.n_degraded_answers,
                "qps": served / span if span > 0 else 0.0,
                "batch_occupancy": occupancy,
                "slot_occupancy": slot_occ,
                "p50_latency_s": self._percentile(lat, 0.50),
                "p99_latency_s": self._percentile(lat, 0.99),
                "p50_insert_to_emit_s": self._percentile(ins, 0.50),
                "p99_insert_to_emit_s": self._percentile(ins, 0.99),
            }
