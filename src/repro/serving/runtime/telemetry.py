"""Per-collection serving telemetry (DESIGN.md §8).

Every number the runtime reports is derived from the engine's uniform
`SearchStats` plus batcher-side timestamps — there is no second
accounting path to drift from the engine's.

Counters and gauges per collection:
  * request / reject / batch counts, insert / delete / compaction counts;
  * QPS over a sliding window;
  * batch occupancy (real requests per flushed batch — the coalescing
    win; > 1 means the micro-batcher is actually batching);
  * slot occupancy (continuous scheduler, DESIGN.md §12: active slots /
    table capacity per step, rolling mean — ≈ 1 at high arrival rate
    means the slot table refills as fast as it emits) and step counts;
  * p50 / p99 request sojourn latency (enqueue -> result) from a bounded
    reservoir of recent requests, plus insert -> emit sojourn for the
    slot loop (time a request actually occupied a slot row);
  * queue depth gauge (set by the scheduler on every transition);
  * jit recompile tracking: `jit_cache_size()` sums the executable-cache
    sizes of the jitted search/encrypt entry points, so a bench or test
    can assert "zero recompiles after warmup across bucketed shapes"
    (flush) or "zero recompiles after one warmup step" (continuous).
"""

from __future__ import annotations

import collections
import threading
import time

__all__ = ["CollectionTelemetry", "jit_cache_size"]


def jit_cache_size() -> int:
    """Total cached-executable count across the runtime's jitted entry
    points.  A steady value across a traffic phase == zero recompiles."""
    from ...core import dce, dcpe
    from ...kernels.adc_topk import ops as adc_ops
    from ...kernels.dce_comp import ops as dce_ops
    from ...kernels.l2_topk import ops as l2_ops
    from .. import search_engine as se
    from .. import sharded

    fns = (
        se.refine_candidates,
        se._masked_pruned_scan,
        l2_ops.knn,
        dce_ops.batched_top_k_by_wins,
        dce._encrypt_jax_core,
        dcpe._encrypt_jax,
        adc_ops.sq_knn,
        adc_ops.pq_knn,
        adc_ops.sq_pool_scan,
        adc_ops.pq_pool_scan,
    )
    return sum(f._cache_size() for f in fns) + sharded.cache_size()


class CollectionTelemetry:
    """Thread-safe rolling metrics for one collection."""

    def __init__(self, window_s: float = 60.0, reservoir: int = 1024):
        self.window_s = float(window_s)
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._latencies = collections.deque(maxlen=reservoir)
        self._flushes = collections.deque()        # (t, n_real_requests)
        self._insert_to_emit = collections.deque(maxlen=reservoir)
        self._slot_occ = collections.deque(maxlen=reservoir)
        self.n_requests = 0
        self.n_rejected = 0
        self.n_batches = 0
        self.n_steps = 0
        self.n_batched_requests = 0
        self.n_inserts = 0
        self.n_deletes = 0
        self.n_compactions = 0
        self.queue_depth = 0
        self.last_backend = ""

    # ------------------------------------------------------------ recording

    def record_submit(self, queue_depth: int):
        with self._lock:
            self.n_requests += 1
            self.queue_depth = queue_depth

    def record_reject(self):
        with self._lock:
            self.n_rejected += 1

    def record_flush(self, n_real: int, latencies_s, backend: str,
                     queue_depth: int):
        now = time.monotonic()
        with self._lock:
            self.n_batches += 1
            self.n_batched_requests += n_real
            self.queue_depth = queue_depth
            self.last_backend = backend
            self._flushes.append((now, n_real))
            self._latencies.extend(float(x) for x in latencies_s)
            horizon = now - self.window_s
            while self._flushes and self._flushes[0][0] < horizon:
                self._flushes.popleft()

    def record_step(self, n_active: int, capacity: int, sojourn_s,
                    insert_to_emit_s, backend: str, queue_depth: int):
        """One slot-table step (DESIGN.md §12): n_active of capacity
        slots held requests; both sojourn streams feed the reservoirs."""
        now = time.monotonic()
        with self._lock:
            self.n_steps += 1
            self.n_batched_requests += n_active
            self.queue_depth = queue_depth
            self.last_backend = backend
            self._slot_occ.append(n_active / capacity if capacity else 0.0)
            self._flushes.append((now, n_active))
            self._latencies.extend(float(x) for x in sojourn_s)
            self._insert_to_emit.extend(float(x) for x in insert_to_emit_s)
            horizon = now - self.window_s
            while self._flushes and self._flushes[0][0] < horizon:
                self._flushes.popleft()

    def record_ingest(self, n_inserted: int = 0, n_deleted: int = 0,
                      compacted: bool = False):
        with self._lock:
            self.n_inserts += n_inserted
            self.n_deletes += n_deleted
            self.n_compactions += int(compacted)

    # ------------------------------------------------------------- reading

    @staticmethod
    def _percentile(sorted_xs: list[float], p: float) -> float:
        if not sorted_xs:
            return 0.0
        i = min(len(sorted_xs) - 1, int(round(p * (len(sorted_xs) - 1))))
        return sorted_xs[i]

    def snapshot(self) -> dict:
        now = time.monotonic()
        with self._lock:
            horizon = now - self.window_s
            # prune here too: record_flush-only pruning would leave span
            # stretching past the window after a quiet gap, deflating qps
            while self._flushes and self._flushes[0][0] < horizon:
                self._flushes.popleft()
            served = sum(n for _, n in self._flushes)
            # rate over the observed lifetime, capped at the window — a
            # single fresh flush must not read as thousands of QPS
            span = min(self.window_s, now - self._t0)
            lat = sorted(self._latencies)
            ins = sorted(self._insert_to_emit)
            occupancy = (self.n_batched_requests / self.n_batches
                         if self.n_batches else 0.0)
            slot_occ = (sum(self._slot_occ) / len(self._slot_occ)
                        if self._slot_occ else 0.0)
            return {
                "backend": self.last_backend,
                "n_requests": self.n_requests,
                "n_rejected": self.n_rejected,
                "n_batches": self.n_batches,
                "n_steps": self.n_steps,
                "n_inserts": self.n_inserts,
                "n_deletes": self.n_deletes,
                "n_compactions": self.n_compactions,
                "queue_depth": self.queue_depth,
                "qps": served / span if span > 0 else 0.0,
                "batch_occupancy": occupancy,
                "slot_occupancy": slot_occ,
                "p50_latency_s": self._percentile(lat, 0.50),
                "p99_latency_s": self._percentile(lat, 0.99),
                "p50_insert_to_emit_s": self._percentile(ins, 0.50),
                "p99_insert_to_emit_s": self._percentile(ins, 0.99),
            }
