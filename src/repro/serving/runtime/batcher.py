"""Request scheduling: the `Scheduler` interface and the flush-based
dynamic micro-batcher (DESIGN.md §8, §12).

`Scheduler` owns everything both serving schedulers share — the bounded
request queue with admission control, per-request futures, parameter-
group extraction, the worker thread, close/drain semantics, and the
injected `Clock` (DESIGN.md §12: schedulers never read wall time
directly, so tests drive them on virtual time).  Two implementations:

  * `MicroBatcher` (this module) — the classic deadline/size flush:
    a flush fires when `max_batch` compatible requests wait or the
    oldest has waited `max_wait_ms`; the real batch pads up to the next
    power-of-two bucket, so arrivals map onto a handful of compiled
    executables.
  * `SlotLoop` (`slot_loop.py`) — continuous batching over one fixed
    slot table: no deadline, no buckets, one compiled shape.

Requests batch together only when their search parameters
`(k, ratio_k, ef_search)` agree (the jitted executables are specialized
on them); mixed traffic is served FIFO by the head request's parameter
group.

Admission control: when `max_queue` requests are already waiting the
submit raises `QueueFullError` instead of growing an unbounded backlog
(callers shed load or retry; the reject is counted in telemetry).
"""

from __future__ import annotations

import abc
import collections
import contextlib
import dataclasses
import threading
from concurrent.futures import Future, InvalidStateError
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np

from ...kernels.common import next_bucket
from .clock import Clock, SystemClock

__all__ = ["Scheduler", "MicroBatcher", "QueueFullError", "batch_buckets",
           "EngineRetryPolicy"]


class QueueFullError(RuntimeError):
    """Raised by submit() when the scheduler's queue is at max_queue."""


@dataclasses.dataclass(frozen=True)
class EngineRetryPolicy:
    """Per-request retry contract for engine failures (DESIGN.md §16).

    When a batched engine call raises, the batch's requests are NOT all
    failed with the batch: each is re-run individually up to
    `max_attempts` total attempts (the failed batch call counts as each
    rider's first), with `backoff_s` of scheduler-clock time between
    attempts.  A request that exhausts its attempts is quarantined —
    its future gets the last exception and it is never retried again —
    so one poison query costs its own attempts, not its batchmates'
    results, and a persistent fault cannot retry forever.

    `max_attempts=1` restores the pre-resilience behaviour (batch
    failure fails every rider, no retry).  `AssertionError` is never
    retried: parity-verification failures are deterministic bugs, not
    transient faults.
    """

    max_attempts: int = 2
    backoff_s: float = 0.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")


def batch_buckets(max_batch: int) -> list[int]:
    """The bucketed batch shapes: powers of two up to max_batch (plus
    max_batch itself when it is not a power of two)."""
    sizes, b = [], 1
    while b < max_batch:
        sizes.append(b)
        b <<= 1
    sizes.append(max_batch)
    return sizes


@dataclasses.dataclass(eq=False)      # identity compare: numpy fields
class _Request:                        # make generated __eq__ ambiguous
    Q: np.ndarray                 # (d,) DCPE query ciphertext
    T: np.ndarray                 # (2d+16,) DCE trapdoor
    group: tuple                  # (k, ratio_k, ef_search)
    future: Future
    t_enq: float
    want_stats: bool = False      # future resolves to (ids, flush stats)
    t_insert: float = 0.0         # slot loop: when the row entered a slot
    span: object = None           # open obs "request" span (tracing on)
    trace_id: str = ""
    n_attempts: int = 0           # engine calls this request rode (retry)


def _stats_attrs(stats) -> dict:
    """SearchStats -> span attributes (paper §V-C cost counters)."""
    return {"backend": stats.backend, "n_queries": stats.n_queries,
            "filter_dist_evals": stats.filter_dist_evals,
            "refine_comparisons": stats.refine_comparisons,
            "filter_bytes_scanned": stats.filter_bytes_scanned,
            "bytes_up": stats.bytes_up, "bytes_down": stats.bytes_down}


class Scheduler(abc.ABC):
    """Request queue + worker thread around one `run_batch` callable.

    run_batch(Q (B, d), T (B, D), k, ratio_k=..., ef_search=...) must
    return (ids (B, k), stats) — in the runtime this is the collection's
    locked `SecureSearchEngine.search_batch`.  Subclasses implement
    `_loop` (the scheduling policy) and `warmup` (which shapes to
    compile); everything client-facing lives here so both schedulers
    present one contract to the collection and the API.
    """

    kind = "abstract"

    def __init__(self, run_batch, *, max_batch: int = 32,
                 max_queue: int = 256, telemetry=None,
                 clock: Clock | None = None, name: str = "collection",
                 tracer=None, retry_policy: EngineRetryPolicy | None = None):
        if max_batch < 1 or max_queue < 1:
            raise ValueError("max_batch and max_queue must be >= 1")
        self._run_batch = run_batch
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.telemetry = telemetry
        self.clock = clock if clock is not None else SystemClock()
        self.name = name
        self.retry_policy = (retry_policy if retry_policy is not None
                             else EngineRetryPolicy())
        self.n_retries = 0            # individual re-run engine calls
        self.n_quarantined = 0        # requests rejected after retries
        # obs (DESIGN.md §13): a repro.obs.TraceRecorder, or None = off.
        # Every recording call below is guarded on `is not None`, so the
        # disabled path costs one attribute read per flush.
        self.tracer = tracer
        self._req_seq = 0             # request trace ids  {name}:rN
        self._batch_seq = 0           # batch  trace ids  {name}:bN / :sN
        self._pending: collections.deque[_Request] = collections.deque()
        self._cv = threading.Condition()
        self._closed = False
        self._worker = threading.Thread(
            target=self._loop, daemon=True, name=f"{self.kind}-{name}")
        self._worker.start()

    # ------------------------------------------------------------- client

    def submit(self, C_sap_q: np.ndarray, T_q: np.ndarray, k: int, *,
               ratio_k: float = 8.0, ef_search: int = 96,
               want_stats: bool = False,
               trace_id: str | None = None) -> Future:
        """Enqueue one query; resolves to its (k,) id vector — or, with
        want_stats, to (ids, SearchStats of the enclosing batched call),
        so a protocol-level caller can report the engine's uniform
        accounting (stats.n_queries tells it how many requests rode the
        same engine call).

        trace_id names the request's trace when tracing is on (a client-
        propagated id, DESIGN.md §13); None autogenerates `{name}:rN`.
        """
        req = _Request(
            Q=np.asarray(C_sap_q), T=np.asarray(T_q),
            group=(int(k), float(ratio_k), int(ef_search)),
            future=Future(), t_enq=self.clock.now(),
            want_stats=want_stats)
        with self._cv:
            if self._closed:
                raise RuntimeError(f"{self.kind} is closed")
            if len(self._pending) >= self.max_queue:
                if self.telemetry is not None:
                    self.telemetry.record_reject()
                raise QueueFullError(
                    f"queue at max_queue={self.max_queue}; shed load")
            if self.tracer is not None:
                # the root span opens at admission and closes at emit;
                # queue/flush/slot/emit children are stamped by the
                # scheduler from clock readings it takes anyway
                req.trace_id = trace_id or f"{self.name}:r{self._req_seq}"
                self._req_seq += 1
                req.span = self.tracer.start_span(
                    "request", req.trace_id, collection=self.name,
                    scheduler=self.kind, k=int(k))
            self._pending.append(req)
            if self.telemetry is not None:
                self.telemetry.record_submit(len(self._pending))
            self._cv.notify()
        return req.future

    def search(self, C_sap_q, T_q, k, *, ratio_k: float = 8.0,
               ef_search: int = 96, timeout: float | None = 30.0):
        """Synchronous single query through the scheduling path.

        A timeout *discards* the request: if it is still queued it is
        removed (freeing its admission-control slot) and its future is
        cancelled, so the scheduler never burns a batched engine call
        computing into a future nobody will read."""
        fut = self.submit(C_sap_q, T_q, k, ratio_k=ratio_k,
                          ef_search=ef_search)
        try:
            return fut.result(timeout=timeout)
        except FutureTimeoutError:
            self.discard(fut)
            raise

    def discard(self, future: Future) -> bool:
        """Withdraw a submitted request: drop it from the queue if still
        pending and cancel its future.  Returns True when the future was
        cancelled (False = it already completed; the result stands)."""
        removed = None
        with self._cv:
            for r in self._pending:
                if r.future is future:
                    removed = r
                    self._pending.remove(r)
                    break
        cancelled = future.cancel()
        if removed is not None and removed.span is not None:
            self.tracer.end_span(removed.span, cancelled=True)
        return cancelled

    @abc.abstractmethod
    def warmup(self, example_q: np.ndarray, example_t: np.ndarray,
               k: int = 10, *, ratio_k: float = 8.0, ef_search: int = 96):
        """Compile every batch shape this policy will run, bypassing the
        queue.  Call after (re)ingesting, before steady-state traffic."""

    def close(self, wait: bool = True):
        """Stop accepting requests; drain what is queued, then exit.  If
        the drain outlives the join timeout, still-queued requests get a
        RuntimeError instead of leaving their clients hung forever."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if wait:
            self._worker.join(timeout=60.0)
            if self._worker.is_alive():
                with self._cv:
                    stranded = list(self._pending)
                    self._pending = collections.deque()
                for r in stranded:
                    self._resolve(r.future, exc=RuntimeError(
                        f"{self.kind} closed before this request was "
                        f"served"))
                    if r.span is not None:
                        self.tracer.end_span(r.span, error="stranded")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------- scheduler

    @abc.abstractmethod
    def _loop(self):
        """Worker thread body: drain `_pending` into batched engine
        calls until closed-and-drained."""

    def _n_matching_locked(self, group: tuple) -> int:
        return sum(r.group == group for r in self._pending)

    def _take_group_locked(self, group: tuple,
                           limit: int | None = None) -> list[_Request]:
        limit = self.max_batch if limit is None else limit
        took, rest = [], collections.deque()
        for r in self._pending:
            if r.group == group and len(took) < limit:
                took.append(r)
            else:
                rest.append(r)
        self._pending = rest
        return took

    @staticmethod
    def _resolve(future: Future, result=None, exc=None):
        """Deliver a result/exception, tolerating a client cancel() that
        lands between our check and the set_* call — an InvalidStateError
        here must never escape into (and kill) the scheduler thread."""
        try:
            if future.cancelled():
                return
            if exc is not None:
                future.set_exception(exc)
            else:
                future.set_result(result)
        except InvalidStateError:
            pass

    # ----------------------------------------------- retry / quarantine

    def _backoff(self, seconds: float):
        """Sleep `seconds` of scheduler-clock time (DESIGN.md §12: no
        raw time.sleep) — a timed condition wait re-checked against the
        deadline, so VirtualClock tests drive retry backoff with
        `advance()` exactly like flush deadlines."""
        if seconds <= 0:
            return
        cv = threading.Condition()
        deadline = self.clock.now() + float(seconds)
        with cv:
            while True:
                remaining = deadline - self.clock.now()
                if remaining <= 0:
                    return
                self.clock.wait(cv, remaining)

    def _run_single(self, r: _Request, k, ratio_k, ef_search):
        """One individual engine call for a retried request, at a shape
        the scheduler has already compiled.  Returns (row, stats)."""
        ids, stats = self._run_batch(r.Q[None], r.T[None], k,
                                     ratio_k=ratio_k, ef_search=ef_search)
        return np.asarray(ids[0]), stats

    def _retry_failed_batch(self, batch: list[_Request], exc, group):
        """Per-request recovery after a failed batched engine call
        (DESIGN.md §16): every rider re-runs individually under the
        retry policy, so a poison query fails alone — its batchmates'
        retries succeed — and is quarantined (rejected with the last
        exception, never retried again) once its attempts are spent.
        AssertionError (parity verification) is deterministic and fails
        the whole batch immediately, pre-resilience style."""
        k, ratio_k, ef_search = group
        tracer = self.tracer
        policy = self.retry_policy
        retryable = not isinstance(exc, AssertionError)
        for r in batch:
            r.n_attempts += 1              # the failed batched call
            last_exc = exc
            row = stats = None
            while retryable and r.n_attempts < policy.max_attempts:
                self._backoff(policy.backoff_s)
                r.n_attempts += 1
                self.n_retries += 1
                if self.telemetry is not None:
                    self.telemetry.record_retry()
                try:
                    row, stats = self._run_single(r, k, ratio_k, ef_search)
                    last_exc = None
                    break
                except Exception as e:     # noqa: BLE001 — to the policy
                    last_exc = e
            if last_exc is not None:
                self.n_quarantined += 1
                if self.telemetry is not None:
                    self.telemetry.record_quarantine()
                self._resolve(r.future, exc=last_exc)
                if r.span is not None:
                    tracer.end_span(r.span, error=repr(last_exc),
                                    attempts=r.n_attempts,
                                    quarantined=True)
            else:
                self._resolve(r.future,
                              result=(row, stats) if r.want_stats else row)
                if r.span is not None:
                    tracer.end_span(r.span, attempts=r.n_attempts,
                                    retried=True)


class MicroBatcher(Scheduler):
    """Flush-based dynamic micro-batcher (DESIGN.md §8).

    Concurrently submitted single-query requests land in the bounded
    queue; the worker drains them into one `search_batch` call per
    flush.  A flush fires when `max_batch` compatible requests are
    waiting or when the oldest request has waited `max_wait_ms` — the
    classic throughput/latency dial.

    Shape bucketing: the real batch is padded (by replicating the first
    request's query) up to the next power of two, capped at `max_batch`,
    so every arrival pattern maps onto a handful of compiled executables
    — zero recompiles after `warmup()` has touched each bucket.
    Padded-row results are discarded; real results scatter back to
    per-request futures.
    """

    kind = "microbatcher"

    def __init__(self, run_batch, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 256,
                 telemetry=None, verify_parity: bool = False,
                 verify_lock=None, clock: Clock | None = None,
                 name: str = "collection", tracer=None,
                 pad_policy: str = "replicate",
                 retry_policy: EngineRetryPolicy | None = None):
        # batch-padding policy (repro.sec, DESIGN.md §14):
        #   "replicate"  pad rows replicate a real query (perf)
        #   "dummy"      pad rows are zero dummy queries, counted in
        #                SearchStats.n_dummy_queries and telemetry
        #   "full"       dummy-pad every flush to max_batch, so batch
        #                size never leaks — still one warmup-compiled
        #                bucket per group, zero recompiles
        # Padded rows never reach a future under any policy, so results
        # are identical across policies.
        if pad_policy not in ("replicate", "dummy", "full"):
            raise ValueError(f"unknown pad_policy {pad_policy!r}")
        self.pad_policy = pad_policy
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.verify_parity = verify_parity
        # held across the batched call AND the parity re-runs, so a
        # concurrent mutation cannot change the database between the two
        # and fail the assert spuriously (pass the collection's RLock)
        self.verify_lock = verify_lock
        super().__init__(run_batch, max_batch=max_batch,
                         max_queue=max_queue, telemetry=telemetry,
                         clock=clock, name=name, tracer=tracer,
                         retry_policy=retry_policy)

    def warmup(self, example_q: np.ndarray, example_t: np.ndarray,
               k: int = 10, *, ratio_k: float = 8.0, ef_search: int = 96):
        """Compile every bucketed batch shape once, bypassing the queue."""
        for b in batch_buckets(self.max_batch):
            Q = np.broadcast_to(np.asarray(example_q), (b,) +
                                np.asarray(example_q).shape).copy()
            T = np.broadcast_to(np.asarray(example_t), (b,) +
                                np.asarray(example_t).shape).copy()
            self._run_batch(Q, T, k, ratio_k=ratio_k, ef_search=ef_search)

    # ---------------------------------------------------------- scheduler

    def _loop(self):
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self.clock.wait(self._cv, None)
                if not self._pending:
                    return                       # closed and drained
                head = self._pending[0]
                deadline = head.t_enq + self.max_wait_s
                while (not self._closed
                       and self._n_matching_locked(head.group)
                       < self.max_batch):
                    remaining = deadline - self.clock.now()
                    if remaining <= 0:
                        break
                    self.clock.wait(self._cv, remaining)
                batch = self._take_group_locked(head.group)
                depth = len(self._pending)
            if batch:                            # all discarded mid-wait?
                self._flush(batch, depth)

    def _flush(self, batch: list[_Request], queue_depth: int):
        """Any failure lands on the batch's futures, never on the
        scheduler thread — one bad request must not wedge the queue."""
        k, ratio_k, ef_search = batch[0].group
        B = len(batch)
        tracer = self.tracer
        t_take = self.clock.now()      # queue wait ends, assembly begins
        batch_tid = ""
        try:
            bucket = (self.max_batch if self.pad_policy == "full"
                      else next_bucket(B, minimum=1,
                                       maximum=self.max_batch))
            if self.pad_policy == "replicate":
                pad_q, pad_t = batch[0].Q, batch[0].T
                n_dummies = 0
            else:           # dummy rows: zero-content queries that ride
                pad_q = np.zeros_like(batch[0].Q)     # the batched call
                pad_t = np.zeros_like(batch[0].T)     # but no future
                n_dummies = bucket - B
            Q = np.stack([r.Q for r in batch] + [pad_q] * (bucket - B))
            T = np.stack([r.T for r in batch] + [pad_t] * (bucket - B))
            lock = (self.verify_lock if self.verify_parity
                    and self.verify_lock is not None
                    else contextlib.nullcontext())
            with lock:
                if tracer is not None:
                    # the batch trace: one "flush" root over the engine
                    # call; the engine's filter/refine child spans attach
                    # under it through the ambient context
                    batch_tid = f"{self.name}:b{self._batch_seq}"
                    self._batch_seq += 1
                    bspan = tracer.span(
                        "flush", batch_tid, collection=self.name,
                        n_real=B, bucket=int(bucket), k=k)
                else:
                    bspan = contextlib.nullcontext()
                with bspan:
                    ids, stats = self._run_batch(Q, T, k, ratio_k=ratio_k,
                                                 ef_search=ef_search)
                    stats.n_dummy_queries = n_dummies
                    # sojourn latency ends when results are computed —
                    # before the (debug-only) parity sweep below, which
                    # would inflate p99
                    now = self.clock.now()
                    if tracer is not None:
                        bspan.set(**_stats_attrs(stats))
                if self.verify_parity:           # engine parity, per request
                    for i, r in enumerate(batch):
                        single, _ = self._run_batch(
                            r.Q[None], r.T[None], k, ratio_k=ratio_k,
                            ef_search=ef_search)
                        np.testing.assert_array_equal(ids[i], single[0])
        except Exception as exc:                 # noqa: BLE001 — to policy
            # never onto the scheduler thread: each rider retries
            # individually (at the warmup-compiled bucket-1 shape) and
            # is quarantined when its attempts run out (DESIGN.md §16)
            self._retry_failed_batch(batch, exc, batch[0].group)
            return
        for i, r in enumerate(batch):
            row = np.asarray(ids[i])
            self._resolve(r.future,
                          result=(row, stats) if r.want_stats else row)
        if tracer is not None:
            t_emit = self.clock.now()
            stats_attrs = _stats_attrs(stats)
            for r in batch:
                if r.span is None:
                    continue
                tracer.add_span("queue", r.trace_id, r.t_enq, t_take,
                                parent=r.span)
                tracer.add_span("flush", r.trace_id, t_take, now,
                                parent=r.span, batch=batch_tid,
                                n_real=B, backend=stats.backend)
                tracer.add_span("emit", r.trace_id, now, t_emit,
                                parent=r.span)
                tracer.end_span(r.span, **stats_attrs)
        if self.telemetry is not None:
            self.telemetry.record_flush(
                B, [now - r.t_enq for r in batch], stats,
                queue_depth, shape=Q.shape, n_dummies=n_dummies)
