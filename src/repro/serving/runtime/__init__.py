"""Online serving runtime over the unified secure-search engine
(DESIGN.md §8).

  batcher      dynamic micro-batching: request queue -> bucketed padded
               batches -> per-request futures; deadline/size flush,
               bounded-queue admission control
  collections  multi-tenant `CollectionManager`: per-tenant keys,
               ciphertext stores, index, engine; strict routing
  ingest       live encrypted ingestion: mutable tombstoned store,
               delta buffer + compaction, delta-aware filter backend
  telemetry    per-collection QPS / occupancy / p50-p99 / queue depth,
               jit-recompile tracking
"""

from .batcher import MicroBatcher, QueueFullError, batch_buckets
from .collections import Collection, CollectionManager, TenantIsolationError
from .ingest import DeltaAwareBackend, MutableEncryptedStore
from .telemetry import CollectionTelemetry, jit_cache_size

__all__ = [
    "MicroBatcher", "QueueFullError", "batch_buckets",
    "Collection", "CollectionManager", "TenantIsolationError",
    "DeltaAwareBackend", "MutableEncryptedStore",
    "CollectionTelemetry", "jit_cache_size",
]
