"""Online serving runtime over the unified secure-search engine
(DESIGN.md §8, §12).

  batcher      `Scheduler` interface (queue, admission control, futures,
               injected clock) + the flush-based `MicroBatcher`:
               bucketed padded batches, deadline/size flush
  slot_loop    `SlotLoop`: continuous batching over one fixed slot
               table — insert into free slots, emit on completion, no
               deadline, one compiled shape (DESIGN.md §12)
  clock        deterministic time seam: `SystemClock` (production) /
               `VirtualClock` (tests drive scheduler time manually)
  collections  multi-tenant `CollectionManager`: per-tenant keys,
               ciphertext stores, index, engine; strict routing;
               per-collection scheduler selection
  ingest       live encrypted ingestion: mutable tombstoned store,
               delta buffer + compaction, delta-aware filter backend
  telemetry    per-collection QPS / batch + slot occupancy / p50-p99
               sojourn / queue depth, jit-recompile tracking
"""

from .batcher import MicroBatcher, QueueFullError, Scheduler, batch_buckets
from .clock import Clock, SystemClock, VirtualClock
from .collections import (SCHEDULERS, Collection, CollectionManager,
                          TenantIsolationError)
from .ingest import DeltaAwareBackend, MutableEncryptedStore
from .slot_loop import SlotLoop
from .telemetry import CollectionTelemetry, jit_cache_size

__all__ = [
    "Scheduler", "MicroBatcher", "SlotLoop", "QueueFullError",
    "batch_buckets", "SCHEDULERS",
    "Clock", "SystemClock", "VirtualClock",
    "Collection", "CollectionManager", "TenantIsolationError",
    "DeltaAwareBackend", "MutableEncryptedStore",
    "CollectionTelemetry", "jit_cache_size",
]
