"""Multi-tenant collections: per-tenant keys, ciphertext stores, index,
engine, and batcher — with strict routing (DESIGN.md §8).

Tenancy model: one key pair per tenant collection (the paper's
single-owner scheme, applied per collection).  The server routes a
request to exactly the collection named by `(tenant, collection)`; a
tenant id that does not own the named collection raises
`TenantIsolationError` before any ciphertext is touched, so one tenant's
trapdoors never meet another tenant's ciphertexts.  (Even if routing
were bypassed, cross-tenant results are cryptographic garbage — keys
differ — but the runtime's guarantee is structural, not accidental.)

Role colocation note: `Collection.insert(P)` runs the *owner-side*
batched encryption in-process — this runtime plays both the data-owner
ingestion endpoint and the honest-but-curious search server, as in the
paper's evaluation harness.  The search/storage path only ever sees
ciphertexts; `insert_encrypted` is the wire-format entry point for a
remote owner.
"""

from __future__ import annotations

import threading

import numpy as np

from ...core import dce, ppanns
from ...core.ivf import IVFIndex
from ...obs.trace import NULL_RECORDER
from ..search_engine import SearchStats, SecureSearchEngine
from .batcher import MicroBatcher
from .ingest import DeltaAwareBackend, MutableEncryptedStore
from .slot_loop import SlotLoop
from .telemetry import CollectionTelemetry

__all__ = ["Collection", "CollectionManager", "TenantIsolationError",
           "SCHEDULERS"]

# The serving schedulers a collection can run its request queue on
# (DESIGN.md §12): "flush" = deadline/size micro-batching over bucketed
# shapes; "continuous" = the slot-table loop (no deadline, one shape).
SCHEDULERS = ("flush", "continuous")


class TenantIsolationError(KeyError):
    """A tenant addressed a collection it does not own (or that does not
    exist — the two cases are deliberately indistinguishable, so a
    tenant cannot enumerate other tenants' collection names)."""


class Collection:
    """One tenant's encrypted corpus: keys + store + index + engine +
    request scheduler (flush micro-batcher or continuous slot loop) +
    telemetry."""

    def __init__(self, tenant: str, name: str, d: int, *,
                 backend: str = "flat", sap_beta: float = 1.0,
                 sap_s: float = 1024.0, seed: int | None = None,
                 use_kernel: bool = True, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 256,
                 compact_every: int = 4096, verify_parity: bool = False,
                 keyless: bool = False, placement=None,
                 scheduler: str = "flush", clock=None, tracer=None,
                 metrics=None, security_profile: str = "perf",
                 retry_policy=None, **backend_kw):
        self.tenant = tenant
        self.name = name
        self.d = d
        # leakage tier (repro.sec, DESIGN.md §14): resolves the profile
        # once and threads its knobs into the layers that implement it —
        # oblivious scan variants into the backend, the dummy-padding
        # policy into the scheduler.  Result-width padding happens in
        # the API layer (repro.api.roles), which reads the same profile
        # off its IndexSpec.
        from ...sec import get_profile
        self.security_profile = get_profile(security_profile)
        if self.security_profile.oblivious:
            backend_kw["oblivious"] = True
        # obs (DESIGN.md §13): tracer = repro.obs.TraceRecorder (request/
        # batch/ingest span trees), metrics = repro.obs.MetricsRegistry
        # (cross-collection Prometheus instruments).  Both default off.
        self.tracer = tracer
        self._ingest_seq = 0
        if seed is None:
            # fresh entropy per collection: two tenants must never derive
            # the same key pair just because neither passed a seed
            seed = int(np.random.SeedSequence().entropy % (2 ** 31))
        self.seed = seed          # effective seed — recorded by save()
        # keyless = the honest-but-curious server's view (repro.api): the
        # collection holds ciphertexts only; keys live with the remote
        # DataOwnerClient and plaintext ingestion is structurally absent
        self.owner = None if keyless else ppanns.DataOwner(
            d=d, sap_beta=sap_beta, sap_s=sap_s, seed=seed)
        self.store = MutableEncryptedStore(d, dce.ciphertext_dim(d))
        # placement chooses WHERE the engine executes (DESIGN.md §10):
        # None/"single" -> the delta-aware single-device backend,
        # "sharded"     -> row-sharded shard_map scans + sharded refine.
        # Everything above the backend (batcher, ingestion, telemetry,
        # snapshots) is placement-agnostic.
        self.placement = placement
        if placement is not None and placement.kind == "sharded":
            from ..sharded import ShardedBackend
            if placement.n_shards is None:
                raise ValueError("sharded placement must be resolved "
                                 "(n_shards pinned) before it reaches "
                                 "the runtime")
            self._backend = ShardedBackend(
                self.store, backend, n_shards=placement.n_shards,
                n_replicas=getattr(placement, "n_replicas", 1),
                data_axis=placement.data_axis, use_kernel=use_kernel,
                seed=seed, **backend_kw)
        else:
            self._backend = DeltaAwareBackend(self.store, backend,
                                              use_kernel=use_kernel,
                                              seed=seed, **backend_kw)
        self._engine: SecureSearchEngine | None = None
        self._lock = threading.RLock()
        self.compact_every = int(compact_every)
        # crash-safe ingestion (repro.resilience, DESIGN.md §16): when a
        # WAL is attached every acknowledged mutation is fsync'd before
        # the call returns.  Duck-typed (any object with .append/
        # .last_seq) so the runtime never imports repro.resilience.
        self._wal = None
        # telemetry runs on the same injected clock as the scheduler, so
        # its QPS windows / sojourns live on one (virtual) timeline
        self.telemetry = CollectionTelemetry(
            clock=clock, metrics=metrics,
            labels={"tenant": tenant, "collection": name})
        # scheduler chooses HOW concurrent requests share engine calls
        # (DESIGN.md §12) — orthogonal to placement, which chooses WHERE
        # the engine executes; `self.batcher` keeps its name as the
        # client-facing Scheduler handle either way.
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r} "
                             f"(have {SCHEDULERS})")
        self.scheduler = scheduler
        pad_policy = self.security_profile.pad_policy
        if scheduler == "continuous":
            self.batcher = SlotLoop(
                self._run_batch, max_batch=max_batch, max_queue=max_queue,
                d=d, cdim=dce.ciphertext_dim(d), telemetry=self.telemetry,
                verify_parity=verify_parity, verify_lock=self._lock,
                clock=clock, name=f"{tenant}/{name}", tracer=tracer,
                pad_policy=pad_policy, retry_policy=retry_policy)
        else:
            self.batcher = MicroBatcher(
                self._run_batch, max_batch=max_batch,
                max_wait_ms=max_wait_ms, max_queue=max_queue,
                telemetry=self.telemetry, verify_parity=verify_parity,
                verify_lock=self._lock, clock=clock,
                name=f"{tenant}/{name}", tracer=tracer,
                pad_policy=pad_policy, retry_policy=retry_policy)

    # ------------------------------------------------------------ keys

    def new_user(self) -> ppanns.User:
        """Owner -> trusted user key handoff for this collection."""
        if self.owner is None:
            raise RuntimeError(
                f"collection {self.tenant}/{self.name} is keyless "
                "(server-side): keys live with the DataOwnerClient")
        return ppanns.User(self.owner.share_keys())

    # ------------------------------------------------------ durability

    def attach_wal(self, wal):
        """Attach a write-ahead log (repro.resilience.WriteAheadLog or
        anything shaped like it).  From here on, every acknowledged
        insert/delete/explicit-compact appends a ciphertext-only record
        under the collection lock — applied first, logged second, acked
        third — so `repro.resilience.recover` replays exactly the
        mutations callers saw succeed.  Auto-compaction is NOT logged:
        replay re-triggers it deterministically at the same
        `compact_every` threshold."""
        self._wal = wal

    @property
    def health(self):
        """The sharded backend's ShardHealthRegistry (None for single
        placement — there is no replica to fail over to)."""
        return getattr(self._backend, "health", None)

    def _wal_append(self, op: str, arrays=None):
        """Log one applied mutation (caller holds `_lock`)."""
        if self._wal is not None:
            self._wal.append(op, arrays)
            self.telemetry.record_wal()

    # ------------------------------------------------------- ingestion

    def _ingest_span(self, op: str):
        """One trace per ingest operation (DESIGN.md §13): a root span
        the store's compaction hook attaches under via the ambient
        context.  A shared no-op span when tracing is off."""
        if self.tracer is None:
            return NULL_RECORDER.span(op, "")
        tid = f"{self.tenant}/{self.name}:i{self._ingest_seq}"
        self._ingest_seq += 1
        return self.tracer.span(
            op, tid, collection=f"{self.tenant}/{self.name}")

    def insert(self, P: np.ndarray) -> np.ndarray:
        """Owner-side API: batch-encrypt plaintext vectors (jitted DCPE +
        DCE paths) and append.  Returns the stable row ids."""
        if self.owner is None:
            raise RuntimeError(
                f"collection {self.tenant}/{self.name} is keyless "
                "(server-side): ingest ciphertexts via insert_encrypted")
        C_sap, C_dce = self.owner.encrypt_vectors(P)
        return self.insert_encrypted(C_sap, C_dce)

    def insert_encrypted(self, C_sap: np.ndarray,
                         C_dce: np.ndarray) -> np.ndarray:
        """Server-side API: append pre-encrypted rows (wire format)."""
        with self._ingest_span("insert") as sp, self._lock:
            rows = self.store.append(C_sap, C_dce)
            self._backend.on_insert(rows, C_sap)
            compacted = False
            if self.store.delta_size >= self.compact_every:
                self.store.compact()
                compacted = True
            self._refresh_engine()
            # durability point (DESIGN.md §16): log the STORE's copy of
            # the rows (normalized dtypes/layout), so replay through
            # this same method reconstructs bit-identical state; fsync
            # happens inside append, before the ack below
            self._wal_append("insert", {
                "C_sap": self.store.sap_view[rows].copy(),
                "C_dce": self.store.dce_view[rows].copy()})
            sp.set(n_rows=len(rows), compacted=compacted)
        self.telemetry.record_ingest(n_inserted=len(rows),
                                     compacted=compacted)
        return rows

    def delete(self, ids) -> int:
        """Tombstone rows; searches issued after this never return them.
        All-or-nothing: every id is validated before the first mutation,
        so a bad id cannot leave the batch half-applied (and the engine
        is re-marked dirty even if a backend hook fails mid-way)."""
        rows = [int(r) for r in np.atleast_1d(np.asarray(ids, np.int64))]
        with self._ingest_span("delete") as sp, self._lock:
            sp.set(n_rows=len(rows))
            seen: set[int] = set()
            for row in rows:
                if row in seen or not (0 <= row < self.store.n_total) \
                        or not self.store.alive_view[row]:
                    raise KeyError(
                        f"unknown, duplicate, or already-deleted id {row}")
                seen.add(row)
            try:
                for row in rows:
                    self.store.delete(row)
                    self._backend.on_delete(row)
            finally:
                self._refresh_engine()
            # reached only when every row applied — a mid-batch hook
            # failure raises above, and an unacked mutation must never
            # be replayed as if the caller saw it succeed
            self._wal_append("delete",
                             {"rows": np.asarray(rows, np.int64)})
        self.telemetry.record_ingest(n_deleted=len(rows))
        return len(rows)

    def compact(self):
        with self._ingest_span("compact"), self._lock:
            self.store.compact()
            self._refresh_engine()
            # an EXPLICIT compact is an acknowledged state transition
            # (main_gen bump) a replay cannot re-derive from thresholds
            self._wal_append("compact")
        self.telemetry.record_ingest(compacted=True)

    def load_snapshot(self, C_sap: np.ndarray, C_dce: np.ndarray, *,
                      alive: np.ndarray | None = None, n_main: int = -1,
                      main_gen: int = 1, graph_arrays: dict | None = None,
                      ivf_state: dict | None = None,
                      adc_state: dict | None = None):
        """Load pre-encrypted rows — an owner-uploaded corpus or a
        persisted collection snapshot — into this (empty) collection
        without re-running per-row ingestion (DESIGN.md §9).

        For an hnsw-backed collection the filter graph comes in as
        `graph_arrays` (`HNSW.to_arrays` payload — built by the data
        owner over DCPE ciphertexts, or saved by a previous service
        incarnation); node ids must equal row ids.  flat/ivf backends
        rebuild their (deterministic, seed-keyed) acceleration state
        lazily on the next search.  Returns the row ids."""
        C_sap = np.atleast_2d(np.asarray(C_sap, np.float32))
        n = C_sap.shape[0]
        if alive is None:
            alive = np.ones(n, bool)
        if n_main < 0:
            n_main = n            # an uploaded corpus is all main region
        with self._ingest_span("load_snapshot") as sp, self._lock:
            sp.set(n_rows=n)
            self.store.restore(C_sap, C_dce, alive, n_main, main_gen)
            if self._backend.kind in ("hnsw", "graph"):
                if graph_arrays is None:
                    raise ValueError(
                        "hnsw/graph-backed collection needs the filter "
                        "graph (HNSW.to_arrays payload) alongside the "
                        "ciphertexts")
                self._backend.restore_graph(dict(graph_arrays))
            elif self._backend.kind == "ivf" and ivf_state is not None:
                # restore the IVF index exactly as snapshotted: its
                # centroids depend on which rows were alive at build
                # time, which a fresh kmeans over today's survivors
                # would not reproduce
                cent = np.asarray(ivf_state["centroids"], np.float32)
                offs = np.asarray(ivf_state["list_offsets"], np.int64)
                flat = np.asarray(ivf_state["list_flat"], np.int64)
                ivf = IVFIndex(n_clusters=cent.shape[0], seed=self.seed)
                ivf.centroids = cent
                ivf.lists = [flat[offs[i]: offs[i + 1]].copy()
                             for i in range(offs.size - 1)]
                b = self._backend
                b.ivf = ivf
                b._assign = {int(r): c
                             for c, l in enumerate(ivf.lists) for r in l}
                b._ivf_built_upto = int(ivf_state["built_upto"])
                b._attached_gen = int(ivf_state["attached_gen"])
            if adc_state is not None:
                # restore the exact codebook the snapshot was trained
                # with (its grid/centroids depend on the rows alive at
                # training time); the codes re-encode bit-identically
                # from the restored ciphertexts (DESIGN.md §11)
                from ...core import adc as adc_mod
                codebook = adc_mod.codebook_from_arrays(
                    self._backend.quantization, adc_state["arrays"])
                self._backend.restore_adc(
                    codebook, int(adc_state["trained_gen"]))
            self._refresh_engine()
        self.telemetry.record_ingest(n_inserted=n)
        return np.arange(n)

    def _refresh_engine(self):
        """Mark engine state dirty; the rebuild happens lazily on the next
        search, so a burst of mutations pays one refresh (DESIGN.md §8)."""
        if self._engine is None:
            if self.store.n_total:
                self._engine = SecureSearchEngine(
                    self.store.sap_view, self.store.dce_padded_view,
                    backend=self._backend,
                    use_kernel=self._backend.use_kernel)
        else:
            self._engine.update_database(self.store.sap_view,
                                         self.store.dce_padded_view)

    def snapshot(self) -> tuple[dict, dict]:
        """Persistable state: (arrays, bookkeeping) — the ciphertext
        store with its tombstone encoding plus the filter state that is
        NOT a pure function of the store: the hnsw graph (prefixed
        `graph__`) and the live IVF index (prefixed `ivf__` — its
        centroids were fit over the rows alive *at build time*, so a
        rebuild after later deletes would not reproduce it).  Key
        material is never part of a snapshot (a keyless collection has
        none to begin with); feed the output back through
        `load_snapshot` to restore bit-identical search behaviour
        (DESIGN.md §9).  Every array is copied under the lock — a
        concurrent mutation cannot tear the payload."""
        with self._lock:
            st = self.store
            arrays = {"C_sap": st.sap_view.copy(),
                      "C_dce": st.dce_view.copy(),
                      "alive": st.alive_view.copy()}
            bookkeeping = {"n_main": st.n_main, "main_gen": st.main_gen}
            if self._backend.kind in ("hnsw", "graph"):
                arrays.update({f"graph__{k}": np.array(v) for k, v in
                               self._backend.graph_arrays().items()})
            elif self._backend.kind == "ivf" \
                    and self._backend.ivf is not None:
                ivf = self._backend.ivf
                lists = [np.asarray(l, np.int64) for l in ivf.lists]
                offsets = np.zeros(len(lists) + 1, np.int64)
                np.cumsum([l.size for l in lists], out=offsets[1:])
                arrays.update({
                    "ivf__centroids": np.array(ivf.centroids, np.float32),
                    "ivf__list_flat": (np.concatenate(lists) if lists
                                       else np.zeros(0, np.int64)),
                    "ivf__list_offsets": offsets,
                })
                bookkeeping["ivf_built_upto"] = \
                    int(self._backend._ivf_built_upto)
                bookkeeping["ivf_attached_gen"] = \
                    int(self._backend._attached_gen)
            if getattr(self._backend, "adc_codebook", None) is not None:
                # quantized collections persist the codebook (codes are
                # a deterministic function of ciphertexts + codebook,
                # so they re-derive bit-identically on load)
                arrays.update({f"adc__{k}": np.asarray(v) for k, v in
                               self._backend.adc_codebook.to_arrays()
                               .items()})
                bookkeeping["adc_trained_gen"] = \
                    int(self._backend.adc_trained_gen)
            if self._wal is not None:
                # captured under the SAME lock hold as the array copies:
                # this snapshot contains exactly the mutations logged
                # through wal seq <= wal_seq, so recovery replays only
                # records after it and the WAL prefix can be truncated
                bookkeeping["wal_seq"] = int(self._wal.last_seq)
            manifest_fn = getattr(self._backend, "shard_manifest", None)
            if manifest_fn is not None:
                # computed under the SAME lock hold as the array copies,
                # so the persisted manifest describes exactly the store
                # state the snapshot captured — a concurrent insert
                # cannot wedge between them
                bookkeeping["shard_manifest"] = manifest_fn()
        return arrays, bookkeeping

    def shard_manifest(self) -> list[dict] | None:
        """Per-shard row partition of a sharded collection (None for
        single placement) — observability; `snapshot()` embeds its own
        lock-consistent copy for persistence."""
        fn = getattr(self._backend, "shard_manifest", None)
        if fn is None:
            return None
        with self._lock:
            return fn()

    # ---------------------------------------------------------- search

    def _run_batch(self, Q, T, k, ratio_k=8.0, ef_search=96,
                   refine="tournament"):
        """The batcher's flush target: one locked engine call."""
        with self._lock:
            if self._engine is None:            # empty collection
                nq = np.atleast_2d(Q).shape[0]
                health = getattr(self._backend, "health", None)
                down = (health.n_groups_down if health is not None
                        else 0)
                return (np.full((nq, k), -1, np.int64),
                        SearchStats(latency_s=0.0, filter_dist_evals=0,
                                    refine_comparisons=0, bytes_up=0,
                                    bytes_down=0, n_queries=nq,
                                    backend=self._backend.name,
                                    n_shards_down=down,
                                    degraded=bool(down)))
            return self._engine.search_batch(Q, T, k, ratio_k=ratio_k,
                                             ef_search=ef_search,
                                             refine=refine)

    def submit(self, C_sap_q, T_q, k, *, ratio_k: float = 8.0,
               ef_search: int = 96, want_stats: bool = False,
               trace_id: str | None = None):
        """Async single query through the micro-batcher -> Future[(k,) ids]
        (or Future[(ids, flush SearchStats)] with want_stats)."""
        C_sap_q = np.asarray(C_sap_q)
        T_q = np.asarray(T_q)
        if C_sap_q.shape != (self.d,) or \
                T_q.shape != (dce.ciphertext_dim(self.d),):
            raise ValueError(
                f"query shapes {C_sap_q.shape}/{T_q.shape} do not match "
                f"collection (d={self.d}, cdim={dce.ciphertext_dim(self.d)})")
        return self.batcher.submit(C_sap_q, T_q, k, ratio_k=ratio_k,
                                   ef_search=ef_search,
                                   want_stats=want_stats,
                                   trace_id=trace_id)

    def search(self, C_sap_q, T_q, k, *, ratio_k: float = 8.0,
               ef_search: int = 96, timeout: float | None = 30.0):
        """Sync single query through the micro-batcher."""
        return self.submit(C_sap_q, T_q, k, ratio_k=ratio_k,
                           ef_search=ef_search).result(timeout=timeout)

    def search_batch(self, Q, T, k, **kw):
        """Bulk client path: straight to the engine (still locked)."""
        return self._run_batch(Q, T, k, **kw)

    def warmup(self, k: int = 10, *, ratio_k: float = 8.0,
               ef_search: int = 96):
        """Compile every bucketed batch shape against the current store."""
        zq = np.zeros(self.d, np.float32)
        zt = np.zeros(dce.ciphertext_dim(self.d), np.float32)
        self.batcher.warmup(zq, zt, k, ratio_k=ratio_k, ef_search=ef_search)

    # ------------------------------------------------------------- misc

    def stats(self) -> dict:
        snap = self.telemetry.snapshot()
        snap.update(tenant=self.tenant, collection=self.name,
                    scheduler=self.scheduler,
                    security_profile=self.security_profile.name,
                    n_total=self.store.n_total, n_alive=self.store.n_alive,
                    n_delta=self.store.delta_size)
        return snap

    def close(self):
        self.batcher.close()


class CollectionManager:
    """Routing front door: (tenant, collection) -> Collection, strictly."""

    def __init__(self, **default_kw):
        self._default_kw = default_kw
        self._collections: dict[tuple[str, str], Collection] = {}
        self._creating: set[tuple[str, str]] = set()
        self._lock = threading.Lock()

    def create_collection(self, tenant: str, name: str, d: int,
                          **kw) -> Collection:
        """Construction (keygen QR at O((2d+16)^2), index state, batcher
        thread) runs *outside* the routing lock — one tenant creating a
        big collection must not stall every other tenant's requests."""
        merged = {**self._default_kw, **kw}
        key = (tenant, name)
        with self._lock:
            if key in self._collections or key in self._creating:
                raise ValueError(f"collection {tenant}/{name} exists")
            self._creating.add(key)
        try:
            col = Collection(tenant, name, d, **merged)
            with self._lock:
                self._collections[key] = col
            return col
        finally:
            with self._lock:
                self._creating.discard(key)

    def collection(self, tenant: str, name: str) -> Collection:
        with self._lock:
            col = self._collections.get((tenant, name))
            if col is None:
                # one error for "owned by someone else" and "nonexistent":
                # anything else is a name-enumeration oracle across tenants
                raise TenantIsolationError(
                    f"no collection {name!r} for tenant {tenant!r}")
            return col

    # thin routed delegates -------------------------------------------------

    def insert(self, tenant, name, P):
        return self.collection(tenant, name).insert(P)

    def delete(self, tenant, name, ids):
        return self.collection(tenant, name).delete(ids)

    def submit(self, tenant, name, C_sap_q, T_q, k, **kw):
        return self.collection(tenant, name).submit(C_sap_q, T_q, k, **kw)

    def search(self, tenant, name, C_sap_q, T_q, k, **kw):
        return self.collection(tenant, name).search(C_sap_q, T_q, k, **kw)

    def stats(self, tenant, name):
        return self.collection(tenant, name).stats()

    def drop_collection(self, tenant, name):
        with self._lock:
            col = self._collections.pop((tenant, name), None)
        if col is None:
            raise KeyError(f"no collection {tenant}/{name}")
        col.close()

    def close(self):
        with self._lock:
            cols = list(self._collections.values())
            self._collections.clear()
        for col in cols:
            col.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
