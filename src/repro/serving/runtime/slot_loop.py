"""Continuous-batching slot-table serving loop (DESIGN.md §12).

The flush batcher's deadline is the p99 floor under open-loop traffic:
a lone request waits `max_wait_ms` hoping for company, and mixed
parameter groups head-of-line block behind the head group's deadline.
`SlotLoop` removes the flush entirely, the way an LLM decode engine
treats prefill/insert/generate: one persistent step over a fixed
`(max_batch,)` **slot table** whose rows hold query/trapdoor data plus
an active-slot validity mask.

  insert   new requests are written into free slot rows the moment the
           loop observes them — no deadline, no waiting for company;
  step     one batched engine call over the WHOLE table, every step,
           at the one compiled `(max_batch, d)` shape (inactive rows
           carry stale/zero queries whose results are simply never
           read — validity is data, not shape, exactly the `ok`
           row-validity convention of the adc_topk kernels);
  emit     completed rows scatter to their futures and the slots free.

Because an ANN search completes in a single engine call (unlike
iterative LLM decode), every active slot completes every step; the
continuous structure still pays off exactly where the flush batcher
hurts: a lone arrival is served immediately at the already-compiled
full-table shape, and under load the table refills to occupancy ≈ 1
with **zero** steady-state recompiles after a single `warmup()` — one
executable per parameter group, not one per bucket.

Requests sharing a step must agree on `(k, ratio_k, ef_search)` (the
executables specialize on them); the loop admits the head group each
step, FIFO, same as the flush batcher — so both schedulers serve any
request stream with bit-identical per-request ids (engine parity:
batched ids == per-query ids, independent of batch composition).
"""

from __future__ import annotations

import contextlib

import numpy as np

from .batcher import EngineRetryPolicy, Scheduler, _stats_attrs
from .clock import Clock

__all__ = ["SlotLoop"]


class SlotLoop(Scheduler):
    """Continuous-batching scheduler over one fixed slot table.

    Same client contract as `MicroBatcher` (submit/search/warmup/close,
    bounded-queue admission, futures, injected clock); the scheduling
    policy is the difference: no deadline, no buckets, one shape.

    `d`/`cdim` pre-allocate the table at construction (the runtime
    knows its collection's dims); left None, the table is allocated
    lazily from the first request's shapes — convenient for benches and
    tests driving the loop standalone.
    """

    kind = "slotloop"

    def __init__(self, run_batch, *, max_batch: int = 32,
                 max_queue: int = 256, d: int | None = None,
                 cdim: int | None = None, telemetry=None,
                 verify_parity: bool = False, verify_lock=None,
                 clock: Clock | None = None, name: str = "collection",
                 tracer=None, pad_policy: str = "replicate",
                 retry_policy: EngineRetryPolicy | None = None):
        # Padding policy (repro.sec, DESIGN.md §14).  The slot table is
        # always full-shape, so "full" adds nothing over "dummy" here;
        # under either, freed rows are scrubbed to zeros (a fixed dummy
        # query instead of a stale real one) and the inactive rows are
        # counted as dummies in SearchStats/telemetry.  "replicate"
        # (perf) keeps the PR-6 behaviour: stale rows ride unscrubbed.
        if pad_policy not in ("replicate", "dummy", "full"):
            raise ValueError(f"unknown pad_policy {pad_policy!r}")
        self.pad_policy = pad_policy
        self._Q = self._T = None
        self._ok = np.zeros(int(max_batch), bool)
        self._slots = [None] * int(max_batch)        # _Request per row
        if d is not None and cdim is not None:
            self._alloc(int(d), int(cdim))
        self.verify_parity = verify_parity
        self.verify_lock = verify_lock
        super().__init__(run_batch, max_batch=max_batch,
                         max_queue=max_queue, telemetry=telemetry,
                         clock=clock, name=name, tracer=tracer,
                         retry_policy=retry_policy)

    # ---------------------------------------------------------- the table

    def _alloc(self, d: int, cdim: int):
        self._Q = np.zeros((self._ok.size, d), np.float32)
        self._T = np.zeros((self._ok.size, cdim), np.float32)

    @property
    def capacity(self) -> int:
        return self._ok.size

    @property
    def n_active(self) -> int:
        return int(self._ok.sum())

    def _insert(self, batch):
        """Write requests into free slot rows; validity flips to True.
        Rows of freed slots keep their stale queries — already-compiled
        data the step computes and the emit never reads."""
        if self._Q is None:
            self._alloc(np.asarray(batch[0].Q).shape[-1],
                        np.asarray(batch[0].T).shape[-1])
        free = np.flatnonzero(~self._ok)
        now = self.clock.now()
        for slot, req in zip(free, batch):
            self._Q[slot] = req.Q
            self._T[slot] = req.T
            self._ok[slot] = True
            self._slots[slot] = req
            req.t_insert = now
            if req.span is not None:
                # queue wait ends the moment the row enters a slot; the
                # "slot" occupancy span is stamped at emit (_step)
                self.tracer.add_span("queue", req.trace_id, req.t_enq,
                                     now, parent=req.span)

    # ---------------------------------------------------------- scheduler

    def warmup(self, example_q: np.ndarray, example_t: np.ndarray,
               k: int = 10, *, ratio_k: float = 8.0, ef_search: int = 96):
        """One full-table step per parameter group is the ENTIRE warmup:
        the slot loop only ever runs the `(max_batch, d)` shape."""
        eq = np.asarray(example_q)
        et = np.asarray(example_t)
        if self._Q is None:
            self._alloc(eq.shape[-1], et.shape[-1])
        Q = np.broadcast_to(eq, self._Q.shape).copy()
        T = np.broadcast_to(et, self._T.shape).copy()
        self._run_batch(Q, T, k, ratio_k=ratio_k, ef_search=ef_search)

    def _loop(self):
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self.clock.wait(self._cv, None)
                if not self._pending:
                    return                       # closed and drained
                # no deadline: launch the step with whatever is waiting.
                # Head parameter group only — the executables specialize
                # on (k, ratio_k, ef_search); other groups keep their
                # queue position for the next step (steps are the unit
                # of progress, so head-of-line blocking is one step, not
                # one deadline).
                group = self._pending[0].group
                n_free = int((~self._ok).sum())
                batch = self._take_group_locked(group, limit=n_free)
                depth = len(self._pending)
            if batch:                            # all discarded mid-wait?
                self._insert(batch)
                self._step(group, depth)

    def _step(self, group: tuple, queue_depth: int):
        """One batched engine call over the whole table; emit every
        active row.  Any failure lands on the active slots' futures —
        never on the loop thread — and the slots free either way."""
        k, ratio_k, ef_search = group
        active = np.flatnonzero(self._ok)
        tracer = self.tracer
        step_tid = ""
        try:
            lock = (self.verify_lock if self.verify_parity
                    and self.verify_lock is not None
                    else contextlib.nullcontext())
            with lock:
                if tracer is not None:
                    # the step trace: one "step" root over the full-table
                    # engine call; filter/refine children attach under it
                    step_tid = f"{self.name}:s{self._batch_seq}"
                    self._batch_seq += 1
                    sspan = tracer.span(
                        "step", step_tid, collection=self.name,
                        n_active=int(active.size),
                        capacity=int(self.capacity), k=k)
                else:
                    sspan = contextlib.nullcontext()
                with sspan:
                    ids, stats = self._run_batch(self._Q, self._T, k,
                                                 ratio_k=ratio_k,
                                                 ef_search=ef_search)
                    n_dummies = (self.capacity - int(active.size)
                                 if self.pad_policy != "replicate" else 0)
                    stats.n_dummy_queries = n_dummies
                    now = self.clock.now()
                    if tracer is not None:
                        sspan.set(**_stats_attrs(stats))
                if self.verify_parity:           # engine parity, per slot
                    for slot in active:
                        r = self._slots[slot]
                        single, _ = self._run_batch(
                            r.Q[None], r.T[None], k, ratio_k=ratio_k,
                            ef_search=ef_search)
                        np.testing.assert_array_equal(ids[slot], single[0])
        except Exception as exc:                 # noqa: BLE001 — to policy
            # free the slots first (the table must keep serving), then
            # recover per request: each rider retries individually at
            # the one compiled full-table shape (DESIGN.md §16)
            riders = [self._slots[slot] for slot in active]
            for slot in active:
                self._free(slot)
            self._retry_failed_batch(riders, exc, group)
            return
        sojourn, insert_to_emit = [], []
        t_emit = self.clock.now() if tracer is not None else now
        stats_attrs = _stats_attrs(stats) if tracer is not None else None
        for slot in active:
            r = self._slots[slot]
            row = np.asarray(ids[slot])
            self._resolve(r.future,
                          result=(row, stats) if r.want_stats else row)
            sojourn.append(now - r.t_enq)
            insert_to_emit.append(now - r.t_insert)
            if r.span is not None:
                tracer.add_span("slot", r.trace_id, r.t_insert, now,
                                parent=r.span, slot=int(slot),
                                batch=step_tid, backend=stats.backend)
                tracer.add_span("emit", r.trace_id, now, t_emit,
                                parent=r.span)
                tracer.end_span(r.span, **stats_attrs)
            self._free(slot)
        if self.telemetry is not None:
            self.telemetry.record_step(
                len(active), self.capacity, sojourn, insert_to_emit,
                stats, queue_depth, shape=self._Q.shape,
                n_dummies=n_dummies)

    def _free(self, slot: int):
        self._ok[slot] = False
        self._slots[slot] = None
        if self.pad_policy != "replicate" and self._Q is not None:
            self._Q[slot] = 0.0          # scrub: freed row becomes the
            self._T[slot] = 0.0          # fixed zero dummy query

    def _run_single(self, r, k, ratio_k, ef_search):
        """Retry at the ONE compiled shape: the request's query
        broadcast across the full table (a (1, d) call would compile a
        second executable and break the zero-recompile contract)."""
        Q = np.broadcast_to(np.asarray(r.Q), self._Q.shape).copy()
        T = np.broadcast_to(np.asarray(r.T), self._T.shape).copy()
        ids, stats = self._run_batch(Q, T, k, ratio_k=ratio_k,
                                     ef_search=ef_search)
        return np.asarray(ids[0]), stats
