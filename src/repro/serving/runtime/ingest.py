"""Live encrypted ingestion: mutable ciphertext store + delta-aware
filter backend (DESIGN.md §8).

Storage model — append-only rows with tombstones:

  rows:   [0 ............ n_main) [n_main ........ n_total)
           "main" region           "delta" region
           served by the base      served by a bucketed flat
           filter backend          scan (flat/IVF kinds)

  * ids are stable: a row id handed out by `append` never moves or gets
    reused.  `delete` tombstones the row (alive=False), scrubs its DCE
    ciphertext and sentinels its DCPE ciphertext; the filter masks dead
    rows out of every candidate set before refine, so a deleted id is
    never returned.
  * `compact` promotes the delta into the main region (n_main := n_total
    and a generation bump) — the expensive per-backend state (flat device
    array, IVF centroids) is rebuilt once per compaction, not per insert.
  * searches see inserts immediately: every mutation marks the engine
    dirty, and the next search's attach refreshes the (cheap) delta
    state.  A burst of mutations pays one refresh, not one per op.

`DeltaAwareBackend` implements the engine's filter-backend protocol
(`attach` / `candidates`), so `SecureSearchEngine.search_batch` — and
with it the batch-of-one parity guarantee — works unchanged over a
mutating database.
"""

from __future__ import annotations

import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from ...core import adc
from ...core.hnsw import HNSW
from ...core.ivf import IVFIndex
from ...graph.csr import CSRGraph
from ...graph.traverse import beam_plan
from ...kernels.adc_topk import ops as adc_ops
from ...kernels.common import next_bucket
from ...kernels.l2_topk import ops as l2_ops
from ...obs.trace import child_complete
from .. import search_engine as se

__all__ = ["MutableEncryptedStore", "DeltaAwareBackend", "SENTINEL"]

# Far-away sentinel for dead / padded DCPE rows (same convention as the
# mesh server's pad rows): never enters a top-k' unless nothing else can.
SENTINEL = 1e9


class MutableEncryptedStore:
    """Growable per-collection ciphertext arrays with tombstones."""

    def __init__(self, d: int, cdim: int):
        self.d = d
        self.cdim = cdim
        self._C_sap = np.zeros((0, d), np.float32)
        self._C_dce = np.zeros((0, 4, cdim), np.float32)
        self._alive = np.zeros(0, bool)
        self.n_main = 0
        self.n_total = 0
        self.main_gen = 0          # bumped by compact()

    # ------------------------------------------------------------- storage

    def _grow(self, extra: int):
        need = self.n_total + extra
        if need <= self._C_sap.shape[0]:
            return
        cap = next_bucket(need, minimum=256)   # power-of-two capacity
        for name in ("_C_sap", "_C_dce", "_alive"):
            old = getattr(self, name)
            grown = np.zeros((cap,) + old.shape[1:], old.dtype)
            grown[: self.n_total] = old[: self.n_total]
            setattr(self, name, grown)

    @property
    def sap_view(self) -> np.ndarray:
        return self._C_sap[: self.n_total]

    @property
    def dce_view(self) -> np.ndarray:
        return self._C_dce[: self.n_total]

    @property
    def dce_padded_view(self) -> np.ndarray:
        """DCE rows padded (with scrubbed zeros) to the power-of-two
        capacity bucket.  The engine's refine executable is specialized
        on this array's row count, so handing it bucketed shapes means a
        growing delta recompiles once per capacity doubling, not once
        per insert burst.  Rows >= n_total are never valid candidates."""
        if self.n_total == 0:
            return self._C_dce[:0]
        return self._C_dce[: next_bucket(self.n_total, minimum=256)]

    @property
    def alive_view(self) -> np.ndarray:
        return self._alive[: self.n_total]

    @property
    def delta_size(self) -> int:
        return self.n_total - self.n_main

    @property
    def n_alive(self) -> int:
        return int(self.alive_view.sum())

    def state_digest(self) -> str:
        """SHA-256 over the logical store state — ciphertexts,
        tombstones, and region bookkeeping, excluding growth slack.  Two
        stores with equal digests answer every search identically, so
        the recovery tests assert bit-identical post-replay state with
        one string compare (repro.resilience, DESIGN.md §16)."""
        h = hashlib.sha256()
        for a in (self.sap_view, self.dce_view, self.alive_view):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(np.int64([self.n_main, self.n_total,
                           self.main_gen]).tobytes())
        return h.hexdigest()

    # ----------------------------------------------------------- mutation

    def append(self, C_sap: np.ndarray, C_dce: np.ndarray) -> np.ndarray:
        C_sap = np.atleast_2d(np.asarray(C_sap, np.float32))
        C_dce = np.asarray(C_dce, np.float32)
        m = C_sap.shape[0]
        if C_sap.shape[1] != self.d or C_dce.shape != (m, 4, self.cdim):
            raise ValueError(
                f"ciphertext shapes {C_sap.shape}/{C_dce.shape} do not "
                f"match collection dims (n={m}, d={self.d}, "
                f"cdim={self.cdim})")
        self._grow(m)
        rows = np.arange(self.n_total, self.n_total + m)
        self._C_sap[rows] = C_sap
        self._C_dce[rows] = C_dce
        self._alive[rows] = True
        self.n_total += m
        return rows

    def delete(self, row: int):
        row = int(row)
        if not (0 <= row < self.n_total) or not self._alive[row]:
            raise KeyError(f"unknown or already-deleted id {row}")
        self._alive[row] = False
        self._C_dce[row] = 0.0          # scrub refine ciphertext
        self._C_sap[row] = SENTINEL     # fall out of future filter top-k'

    def compact(self):
        """Promote delta -> main.  Ids are stable (tombstones persist);
        only per-backend acceleration state is rebuilt, on next attach."""
        n_delta = self.delta_size
        self.n_main = self.n_total
        self.main_gen += 1
        # obs (DESIGN.md §13): attaches under the collection's ambient
        # ingest span when tracing is on; no-op otherwise
        child_complete("compact", n_promoted=n_delta,
                       main_gen=self.main_gen, n_total=self.n_total)

    def restore(self, C_sap: np.ndarray, C_dce: np.ndarray,
                alive: np.ndarray, n_main: int, main_gen: int):
        """Reload a persisted snapshot into an empty store (DESIGN.md §9).

        The saved arrays already carry the tombstone encoding (SENTINEL
        DCPE rows, scrubbed DCE rows), so restoring is append + alive
        overlay + bookkeeping — row ids and the main/delta split come
        back exactly as saved, which is what makes restored searches
        bit-identical."""
        if self.n_total:
            raise RuntimeError("restore requires an empty store "
                               f"(store already holds {self.n_total} rows)")
        rows = self.append(C_sap, C_dce)
        alive = np.asarray(alive, bool)
        if alive.shape != (rows.size,):
            raise ValueError(f"alive mask shape {alive.shape} does not "
                             f"match {rows.size} restored rows")
        self._alive[: rows.size] = alive
        if not 0 <= int(n_main) <= self.n_total:
            raise ValueError(f"n_main={n_main} out of range for "
                             f"{self.n_total} rows")
        self.n_main = int(n_main)
        self.main_gen = int(main_gen)


class DeltaAwareBackend:
    """Engine filter backend over a `MutableEncryptedStore`.

    kind="flat":  main region scanned via a cached device array + the
                  l2_topk kernel; delta region scanned via a
                  power-of-two-bucketed device buffer (sentinel-padded),
                  so jitted executables are reused as the delta grows.
    kind="ivf":   coarse centroids built over the main region at
                  compaction; delta rows are incrementally *assigned* to
                  their nearest centroid at the next attach (no kmeans
                  rerun), so probes see inserts immediately.
    kind="hnsw":  one graph over all rows, updated eagerly by
                  `on_insert` / `on_delete` (graph node id == row id),
                  walked per query on the host (the legacy shim — the
                  batched path below supersedes it, DESIGN.md §15).
    kind="graph": the same eager host graph, but served through its
                  CSR mirror by the batched lockstep traversal
                  (`repro.graph`): inserts refresh exactly the changed
                  neighbor rows into the bucketed mirror (reserved
                  slack slots — `_row_bucket` headroom — absorb them
                  without reallocation), deletes flip `ok` validity
                  bits (plus the repaired in-neighbor rows), and a
                  compaction or bucket overflow rebuilds the mirror.
                  Accepts quantization (ADC surrogate edge scoring)
                  and `oblivious` (the bounded-hop fixed-fanout
                  `hardened` tier) — the two things the host walk
                  never could.

    All kinds mask tombstoned rows out of the candidate validity mask, so
    the refine never returns a deleted id.

    quantization="int8"|"pq8" (flat/ivf/graph kinds) swaps the f32
    scans for the quantized ADC path (DESIGN.md §11): the backend keeps one
    capacity-bucketed code array over *all* rows plus an int32
    row-validity stream, so delta appends re-encode only the new rows
    at the next attach (codes are 4-32x smaller than the ciphertexts —
    a delta re-encode burst is cheap) and deletes only flip validity.
    The codebook is trained keylessly over the alive ciphertexts at
    first attach; a compaction *retrains* it when the collection has
    at least doubled since training (stale codebooks lose recall as
    the distribution drifts) and *reuses* it otherwise — and a
    codebook restored from a snapshot re-encodes bit-identical codes.
    The filter oversamples k' by `refine_ratio` into the unchanged
    exact refine (core.adc).
    """

    def __init__(self, store: MutableEncryptedStore, kind: str = "flat", *,
                 use_kernel: bool = True, n_partitions: int = 64,
                 nprobe: int = 8, hnsw_M: int = 16,
                 hnsw_ef_construction: int = 200,
                 delta_bucket_min: int = 128, seed: int = 0,
                 quantization: str | None = None,
                 refine_ratio: float | None = None, pq_m: int = 16,
                 oblivious: bool = False):
        if kind not in ("flat", "ivf", "hnsw", "graph"):
            raise ValueError(f"unknown backend kind {kind!r}")
        if oblivious and kind == "hnsw":
            raise ValueError("scan-oblivious filtering needs flat|ivf|"
                             "graph backends (the per-query host walk "
                             "is data-dependent by construction; "
                             "kind='graph' has the bounded-hop fixed-"
                             "fanout tier, DESIGN.md §14/§15)")
        if quantization not in adc.QUANTIZATIONS:
            raise ValueError(f"unknown quantization {quantization!r} "
                             f"(have {adc.QUANTIZATIONS})")
        if quantization is not None and kind == "hnsw":
            raise ValueError("quantization applies to flat|ivf|graph "
                             "backends (the host graph walk reads "
                             "full-precision rows)")
        self.store = store
        self.kind = kind
        # scan-oblivious access-pattern flattening (repro.sec,
        # DESIGN.md §14).  The flat scans are full-bucket already —
        # the flag only reroutes the IVF paths from the pooled gather
        # scans to the membership-masked full-bucket scans.
        self.oblivious = bool(oblivious)
        self.quantization = quantization
        self.name = (kind if quantization is None
                     else f"adc-{kind}-{quantization}")
        self.refine_ratio = (adc.default_refine_ratio(quantization)
                             if refine_ratio is None else
                             float(refine_ratio))
        self.pq_m = pq_m
        self.use_kernel = use_kernel
        self.n_partitions = n_partitions
        self.nprobe = nprobe
        self.delta_bucket_min = delta_bucket_min
        self.seed = seed
        self.graph = (HNSW(dim=store.d, M=hnsw_M,
                           ef_construction=hnsw_ef_construction, seed=seed)
                      if kind in ("hnsw", "graph") else None)
        self.ivf: IVFIndex | None = None
        self._assign: dict[int, int] = {}       # row -> ivf cluster
        self._ivf_built_upto = 0
        self._attached_gen = -1
        self._C_main = None       # flat: device array of the main region
        self._C_all = None        # ivf: bucketed device array of all rows
        self._scan_snapshot = (-1, -1)          # (main_gen, n_total) of it
        self._C_delta = None      # flat: bucketed delta device buffer
        self._delta_base = 0
        self._delta_n = 0
        self._C_dce_dev = None    # refine array device residency (all
        self._dce_snapshot = (-1, -1)    # kinds); (padded_len, n_total)
        # quantized-ADC state: codebook + one bucketed code array over
        # all rows + row-validity stream (see class docstring)
        self.adc_codebook = None
        self.adc_trained_gen = -1        # main_gen the codebook is for
        self._adc_c8 = self._adc_cn = self._adc_codes_t = None
        self._adc_ok = None
        self._adc_snapshot = (-1, -1, -1)  # (codebook id, gen, n_total)
        # batched-graph state (kind="graph", DESIGN.md §15): the CSR
        # mirror of self.graph, its device arrays, and the dirty-row
        # set accumulated by the eager mutation hooks
        self._csr: CSRGraph | None = None
        self._g_dirty: set[int] = set()
        self._g_neigh0 = self._g_neigh_up = self._g_ok = None
        self._g_db = None
        self.last_filter_bytes = 0
        self.last_n_hops = 0
        self.last_n_edges_scanned = 0
        self.last_scan_trace: np.ndarray | None = None

    # ------------------------------------------------- mutation hooks
    # Called by the Collection under its lock, *before* the engine is
    # marked dirty — eager for graph structure, lazy for device arrays.

    def on_insert(self, rows: np.ndarray, C_sap: np.ndarray):
        if self.graph is not None:
            for row, vec in zip(rows, np.atleast_2d(C_sap)):
                node = self.graph.insert(vec)
                if node != row:     # every downstream lookup (candidates,
                    # alive mask, refine gather) depends on this equality
                    raise RuntimeError(
                        f"graph node id {node} != store row id {row}: "
                        f"graph and store are desynchronized")
                if self.kind == "graph":
                    # changed-row set of an insert: the new node plus
                    # the neighbors it linked back to (HNSW.insert only
                    # touches links[lev][node] and _add_link targets)
                    self._g_dirty.add(int(node))
                    for lev in range(len(self.graph.links)):
                        nb = self.graph.links[lev][node]
                        if nb is not None:
                            self._g_dirty.update(int(v) for v in nb)

    def on_delete(self, row: int):
        if self.graph is not None:
            repaired = self.graph.delete(row)
            if self.kind == "graph":
                self._g_dirty.add(int(row))
                self._g_dirty.update(repaired)
        if self.kind == "ivf":
            c = self._assign.pop(row, None)
            if c is not None and self.ivf is not None:
                lst = self.ivf.lists[c]
                self.ivf.lists[c] = lst[lst != row]
        if self.kind == "flat" and row < self.store.n_main:
            # re-sentinel the main device array; delta-region deletes need
            # no rebuild (the delta buffer is refreshed every attach)
            self._C_main = None

    # ----------------------------------------------------------- attach

    def dce_device(self, C_dce_padded: np.ndarray):
        """Device residency for the refine array (engine hook): inside an
        unchanged capacity bucket, ship only the rows appended since the
        last refresh instead of the whole database.  Tombstoned rows keep
        their stale device copy — they are never valid candidates, so
        the refine cannot observe them (the host copy stays scrubbed)."""
        n_total = self.store.n_total
        plen = C_dce_padded.shape[0]
        old_plen, old_n = self._dce_snapshot
        if self._C_dce_dev is not None and plen == old_plen:
            if n_total > old_n:
                self._C_dce_dev = self._C_dce_dev.at[old_n: n_total].set(
                    jnp.asarray(C_dce_padded[old_n: n_total]))
        else:
            self._C_dce_dev = jnp.asarray(C_dce_padded)
        self._dce_snapshot = (plen, n_total)
        return self._C_dce_dev

    def _row_bucket(self, n: int) -> int:
        """Padded row capacity of the bucketed scan/code arrays (the
        sharded backend overrides this with its shard-even bucket)."""
        return next_bucket(n, minimum=256)

    def _use_pallas(self) -> bool:
        """ADC Pallas path on actual TPU only; elsewhere the
        rank-identical XLA formulation is the serving path
        (kernels/adc_topk/ops.py)."""
        return self.use_kernel and jax.default_backend() == "tpu"

    # ------------------------------------------- graph persistence

    def graph_arrays(self) -> dict:
        """Persistable filter-graph payload (`Collection.snapshot`):
        the host graph's `to_arrays` encoding — which `CSRGraph
        .to_arrays` reproduces bit-for-bit, the `.ppcol` contract."""
        return self.graph.to_arrays()

    def restore_graph(self, arrays: dict):
        """Install a snapshotted filter graph (`Collection
        .load_snapshot`); the CSR mirror rebuilds on the next attach."""
        g = HNSW.from_arrays(dict(arrays))
        if g.size != self.store.n_total:
            raise ValueError(f"graph has {g.size} nodes for "
                             f"{self.store.n_total} rows")
        self.graph = g
        self._csr = None
        self._g_dirty.clear()

    # ----------------------------------------------- ADC code arrays

    def restore_adc(self, codebook, trained_gen: int):
        """Install a snapshotted codebook (Collection.load_snapshot):
        codes re-encode from the restored ciphertexts bit-identically,
        so only the codebook itself persists (DESIGN.md §11)."""
        self.adc_codebook = codebook
        self.adc_trained_gen = int(trained_gen)
        self._adc_snapshot = (-1, -1, -1)

    # device-placement hooks (the sharded backend re-targets these)
    def _put_codes(self, buf: np.ndarray):
        return jnp.asarray(buf)             # (bucket, d) int8

    def _put_codes_t(self, buf: np.ndarray):
        return jnp.asarray(buf)             # (m, bucket) uint8

    def _put_rowvec(self, buf: np.ndarray):
        return jnp.asarray(buf)             # (bucket,) int32

    def _attach_adc(self, C_sap: np.ndarray):
        """Refresh codebook + code arrays (one refresh per mutation
        burst).  Retrain-or-reuse: a compaction retrains only once the
        alive set has at least doubled since training; anything else
        reuses the codebook and encodes just the appended rows."""
        st = self.store
        alive = st.alive_view
        cb = self.adc_codebook
        # retrain-or-reuse: at a compaction once the alive set doubled,
        # or at the first attach with real rows after a placeholder
        # training pass (trained_n == 0: a fully-tombstoned store has
        # no geometry to fit — its degenerate grid must never encode
        # real rows, cf. code review)
        stale = cb is not None and (
            (st.main_gen != self.adc_trained_gen
             and st.n_alive >= 2 * cb.trained_n)
            or (cb.trained_n == 0 and st.n_alive > 0))
        if cb is None or stale:
            rows = C_sap[alive]
            placeholder = rows.shape[0] == 0
            if placeholder:                 # fully tombstoned: keep a
                rows = np.zeros((1, st.d), np.float32)   # usable grid
            self.adc_codebook = adc.train_codebook(
                rows, self.quantization, m=self.pq_m, seed=self.seed)
            if placeholder:
                self.adc_codebook.trained_n = 0
            self._adc_snapshot = (-1, -1, -1)   # force full re-encode
        self.adc_trained_gen = st.main_gen

        bucket = self._row_bucket(st.n_total)
        cb_id = id(self.adc_codebook)
        old_cb, old_bucket, old_n = self._adc_snapshot
        fresh = not (old_cb == cb_id and old_bucket == bucket)
        if self.quantization == "int8":
            if fresh:
                buf = np.zeros((bucket, st.d), np.int8)
                cnb = np.zeros(bucket, np.int32)
                codes, cn = self.adc_codebook.encode(C_sap)
                buf[: st.n_total], cnb[: st.n_total] = codes, cn
                self._adc_c8 = self._put_codes(buf)
                self._adc_cn = self._put_rowvec(cnb)
            elif st.n_total > old_n:        # encode appended rows only
                codes, cn = self.adc_codebook.encode(
                    C_sap[old_n: st.n_total])
                self._adc_c8 = self._adc_c8.at[old_n: st.n_total].set(
                    jnp.asarray(codes))
                self._adc_cn = self._adc_cn.at[old_n: st.n_total].set(
                    jnp.asarray(cn))
        else:                               # pq8
            if fresh:
                buf = np.zeros((self.adc_codebook.m, bucket), np.uint8)
                codes = self.adc_codebook.encode(C_sap)
                buf[:, : st.n_total] = codes.T
                self._adc_codes_t = self._put_codes_t(buf)
            elif st.n_total > old_n:
                codes = self.adc_codebook.encode(C_sap[old_n: st.n_total])
                self._adc_codes_t = \
                    self._adc_codes_t.at[:, old_n: st.n_total].set(
                        jnp.asarray(np.ascontiguousarray(codes.T)))
        # validity is data, not shape: refreshed every burst, so
        # deletes flip bits without touching the code arrays
        ok = np.zeros(bucket, np.int32)
        ok[: st.n_total] = alive
        self._adc_ok = self._put_rowvec(ok)
        self._adc_snapshot = (cb_id, bucket, st.n_total)

    def attach(self, C_sap: np.ndarray, engine):
        """One refresh per mutation burst (the engine attaches lazily)."""
        st = self.store
        if self.kind == "graph":
            self._attach_graph(C_sap)
            return
        if self.quantization is not None:
            if self.kind == "ivf":
                self._attach_ivf_index(C_sap)
            self._attach_adc(C_sap)
            return
        if self.kind == "flat":
            if self._attached_gen != st.main_gen or self._C_main is None:
                self._C_main = (jnp.asarray(C_sap[: st.n_main])
                                if st.n_main else None)
                self._attached_gen = st.main_gen
            dn = st.delta_size
            self._delta_base, self._delta_n = st.n_main, dn
            if dn:
                bucket = next_bucket(dn, minimum=self.delta_bucket_min)
                buf = np.full((bucket, st.d), SENTINEL, np.float32)
                buf[:dn] = C_sap[st.n_main: st.n_total]
                self._C_delta = jnp.asarray(buf)
            else:
                self._C_delta = None
        elif self.kind == "ivf":
            self._attach_ivf(C_sap)
        # hnsw: the graph already holds its ciphertexts, nothing to refresh

    def _attach_graph(self, C_sap: np.ndarray):
        """CSR mirror + device-array refresh (DESIGN.md §15).

        Eager delta inserts only touched their changed host rows (the
        `_g_dirty` set), so inside an unchanged row bucket the refresh
        is row-local — the reserved slack slots of the power-of-two
        bucket absorb appends without reallocation and the jitted
        traversal never recompiles.  A compaction, a bucket overflow,
        or a new top layer rebuilds the mirror at the next bucket,
        exactly like every other bucketed array in the runtime."""
        st = self.store
        g = self.graph
        R = self._row_bucket(max(st.n_total, 1))
        rebuild = (self._csr is None or self._csr.R != R
                   or not self._csr.fits(g)
                   or self._attached_gen != st.main_gen)
        if rebuild:
            LU = next_bucket(max(len(g.links) - 1, 1), minimum=4)
            self._csr = CSRGraph.from_hnsw(g, R=R, LU=LU)
            self._attached_gen = st.main_gen
        elif self._g_dirty:
            self._csr.refresh_rows(g, sorted(self._g_dirty))
            self._csr.refresh_meta(g)
        self._g_dirty.clear()
        self._g_neigh0 = jnp.asarray(self._csr.neigh0)
        self._g_neigh_up = jnp.asarray(self._csr.neigh_up)
        if self.quantization is not None:
            self._attach_adc(C_sap)    # code bucket == R (_row_bucket)
            self._g_ok = self._adc_ok > 0
            self._g_db = ((self._adc_c8, self._adc_cn)
                          if self.quantization == "int8"
                          else (self._adc_codes_t,))
        else:
            self._refresh_scan_array(C_sap)
            ok = np.zeros(R, bool)
            ok[: st.n_total] = st.alive_view
            self._g_ok = jnp.asarray(ok)
            self._g_db = (self._C_all,)

    def _attach_ivf(self, C_sap: np.ndarray):
        self._attach_ivf_index(C_sap)
        self._refresh_scan_array(C_sap)

    def _attach_ivf_index(self, C_sap: np.ndarray):
        """Coarse-quantizer maintenance only (centroid build at
        compaction + incremental delta assignment) — shared by the f32
        scan and the quantized ADC pool scan, so probe pools are
        identical across quantization settings."""
        st = self.store
        if self.ivf is None or self._attached_gen != st.main_gen:
            base_n = st.n_main if st.n_main else st.n_total
            rows = np.flatnonzero(st.alive_view[:base_n])
            if rows.size == 0:          # base region fully tombstoned:
                base_n = st.n_total     # recover by building over the delta
                rows = np.flatnonzero(st.alive_view[:base_n])
            if rows.size:
                ivf = IVFIndex(n_clusters=min(self.n_partitions, rows.size),
                               seed=self.seed).build(C_sap[rows])
                ivf.lists = [rows[l] for l in ivf.lists]   # local -> row ids
                self._assign = {int(r): c
                                for c, l in enumerate(ivf.lists) for r in l}
                self.ivf = ivf
                self._ivf_built_upto = base_n
                self._attached_gen = st.main_gen
            else:                       # nothing alive anywhere; ivf stays
                self.ivf = None         # None, so the next attach retries
                self._assign = {}
                self._ivf_built_upto = 0
        # incremental assignment: new rows join their nearest centroid —
        # no kmeans rerun, probes see inserts immediately
        if self.ivf is not None and self._ivf_built_upto < st.n_total:
            new = np.arange(self._ivf_built_upto, st.n_total)
            new = new[st.alive_view[new]]
            if new.size:
                X = C_sap[new]
                d2 = (((X[:, None, :] - self.ivf.centroids[None]) ** 2)
                      .sum(-1))
                cl = d2.argmin(1)
                for c in np.unique(cl):       # one concat per cluster
                    sel = new[cl == c]
                    self.ivf.lists[c] = np.concatenate(
                        [self.ivf.lists[c], sel])
                    for row in sel:
                        self._assign[int(row)] = int(c)
            self._ivf_built_upto = st.n_total

    def _refresh_scan_array(self, C_sap: np.ndarray):
        """Sentinel-padded capacity-bucketed device copy of all rows for
        the jitted masked scan.  Cached on (main_gen, n_total): pure
        delete bursts skip the rebuild entirely (tombstoned rows leave
        the probe lists eagerly, so the stale scan row is unreachable),
        and insert bursts inside an unchanged bucket ship only the new
        rows instead of the whole database."""
        st = self.store
        snapshot = (st.main_gen, st.n_total)
        if self._C_all is not None and self._scan_snapshot == snapshot:
            return
        bucket = next_bucket(st.n_total, minimum=256)
        old_gen, old_n = self._scan_snapshot
        if (self._C_all is not None and old_gen == st.main_gen
                and self._C_all.shape[0] == bucket):
            self._C_all = self._C_all.at[old_n: st.n_total].set(
                jnp.asarray(C_sap[old_n: st.n_total]))
        else:
            buf = np.full((bucket, st.d), SENTINEL, np.float32)
            buf[: st.n_total] = C_sap
            self._C_all = jnp.asarray(buf)
        self._scan_snapshot = snapshot

    # ------------------------------------------------------- candidates

    def _mask_alive(self, cand: np.ndarray, valid: np.ndarray):
        """valid &= alive, with out-of-range ids (sentinel pad slots,
        and the ADC kernels' -1 empty-slot marker) invalidated and
        clamped so the host-side alive lookup is safe."""
        st = self.store
        in_range = (cand >= 0) & (cand < st.n_total)
        safe = np.where(in_range, cand, 0)
        return safe, valid & in_range & st.alive_view[safe]

    def oversampled(self, kp: int) -> int:
        """ADC recall model: quantized filters hand k'*refine_ratio
        candidates to the exact refine (core.adc)."""
        return max(kp, int(np.ceil(kp * self.refine_ratio))) \
            if self.quantization is not None else kp

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        if self.kind == "graph":
            return self._candidates_graph(Q_sap, kp, ef_search)
        if self.quantization is not None:
            kp2 = self.oversampled(kp)
            if self.kind == "flat":
                return self._candidates_adc_flat(Q_sap, kp2)
            return self._candidates_adc_ivf(Q_sap, kp2)
        if self.kind == "flat":
            return self._candidates_flat(Q_sap, kp)
        if self.kind == "ivf":
            return self._candidates_ivf(Q_sap, kp)
        return self._candidates_hnsw(Q_sap, kp, ef_search)

    def _adc_code_bytes(self, rows: int) -> int:
        # codes (+ SQ norms) plus the int32 validity stream — what the
        # quantized scan actually touches per bucketed row
        return rows * (self.adc_codebook.code_bytes_per_vector() + 4)

    def _candidates_adc_flat(self, Q_sap: np.ndarray, kp2: int):
        st = self.store
        nq = Q_sap.shape[0]
        bucket = int(self._adc_ok.shape[0])
        kp2 = min(kp2, bucket)
        if self.quantization == "int8":
            q8 = self.adc_codebook.encode_query(np.asarray(Q_sap,
                                                           np.float32))
            _, idx = adc_ops.sq_knn(jnp.asarray(q8), self._adc_c8,
                                    self._adc_cn, kp2, ok=self._adc_ok,
                                    use_kernel=self._use_pallas())
        else:
            lut = self.adc_codebook.lut(np.asarray(Q_sap, np.float32))
            _, idx = adc_ops.pq_knn(jnp.asarray(lut), self._adc_codes_t,
                                    kp2, ok=self._adc_ok,
                                    use_kernel=self._use_pallas())
        cand = np.asarray(idx, np.int32)
        safe, valid = self._mask_alive(cand, np.ones(cand.shape, bool))
        self.last_filter_bytes = self._adc_code_bytes(bucket)
        # rows present (incl. tombstones), matching the f32 flat path's
        # main+delta accounting — evals stay comparable across
        # quantization settings
        return safe, valid, nq * st.n_total

    def _candidates_adc_ivf(self, Q_sap: np.ndarray, kp2: int):
        st = self.store
        nq = Q_sap.shape[0]
        if self.ivf is None:                  # nothing alive to probe
            return (np.zeros((nq, kp2), np.int32),
                    np.zeros((nq, kp2), bool), 0)
        Q = np.asarray(Q_sap, np.float32)
        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        if self.oblivious:
            # membership-masked full-code scan (DESIGN.md §14): the
            # bucketed code arrays already span every row, so the
            # oblivious variant reuses them with a (nq, bucket) mask
            bucket = int(self._adc_ok.shape[0])
            member = se.pool_membership(
                nq, pools, bucket, pool_mask=lambda p: st.alive_view[p])
            if self.quantization == "int8":
                q8 = self.adc_codebook.encode_query(Q)
                ids, vout = adc_ops.sq_oblivious_scan(
                    self._adc_c8, self._adc_cn, jnp.asarray(q8),
                    jnp.asarray(member), min(kp2, bucket))
            else:
                lut = self.adc_codebook.lut(Q)
                ids, vout = adc_ops.pq_oblivious_scan(
                    self._adc_codes_t, jnp.asarray(lut),
                    jnp.asarray(member), min(kp2, bucket))
            ids, vout = self._mask_alive(np.asarray(ids, np.int32),
                                         np.asarray(vout))
            evals = nq * bucket + nq * self.ivf.centroids.shape[0]
            self.last_filter_bytes = (self._adc_code_bytes(bucket)
                                      + self.ivf.centroids.nbytes)
            return ids, vout, evals
        cand, valid = se.layout_pools(nq, pools, kp2,
                                      pool_mask=lambda p: st.alive_view[p])
        if self.quantization == "int8":
            q8 = self.adc_codebook.encode_query(Q)
            ids, vout = adc_ops.sq_pool_scan(
                self._adc_c8, self._adc_cn, jnp.asarray(q8),
                jnp.asarray(cand), jnp.asarray(valid), kp2)
        else:
            lut = self.adc_codebook.lut(Q)
            ids, vout = adc_ops.pq_pool_scan(
                self._adc_codes_t, jnp.asarray(lut), jnp.asarray(cand),
                jnp.asarray(valid), kp2)
        evals = sum(p.size for p in pools) \
            + nq * self.ivf.centroids.shape[0]
        self.last_filter_bytes = (
            self._adc_code_bytes(sum(p.size for p in pools))
            + self.ivf.centroids.nbytes)
        return np.asarray(ids), np.asarray(vout), evals

    def _candidates_flat(self, Q_sap: np.ndarray, kp: int):
        st = self.store
        nq = Q_sap.shape[0]
        Qd = jnp.asarray(Q_sap, jnp.float32)
        parts, evals = [], 0
        if self._C_main is not None:
            n_main = int(self._C_main.shape[0])
            dist, idx = l2_ops.knn(Qd, self._C_main, min(kp, n_main),
                                   chunk=min(4096, n_main),
                                   use_kernel=self.use_kernel)
            cand = np.asarray(idx, np.int32)
            safe, valid = self._mask_alive(cand,
                                           np.ones(cand.shape, bool))
            parts.append((np.asarray(dist), safe, valid))
            evals += nq * n_main
        if self._C_delta is not None:
            bucket = int(self._C_delta.shape[0])
            dist, idx = l2_ops.knn(Qd, self._C_delta, min(kp, bucket),
                                   chunk=bucket, use_kernel=self.use_kernel)
            raw = np.asarray(idx, np.int32)
            in_delta = raw < self._delta_n
            cand = raw + np.int32(self._delta_base)
            safe, valid = self._mask_alive(cand, in_delta)
            parts.append((np.asarray(dist), safe, valid))
            evals += nq * self._delta_n
        self.last_filter_bytes = st.d * 4 * (
            (int(self._C_main.shape[0]) if self._C_main is not None else 0)
            + (int(self._C_delta.shape[0]) if self._C_delta is not None
               else 0))
        dists = np.concatenate([d for d, _, _ in parts], axis=1)
        cand = np.concatenate([c for _, c, _ in parts], axis=1)
        valid = np.concatenate([v for _, _, v in parts], axis=1)
        # merge main and delta blocks into one globally distance-sorted
        # list — the engine contract (refine="none" takes cand[:, :k])
        order = np.argsort(np.where(valid, dists, np.inf), axis=1,
                           kind="stable")
        return (np.take_along_axis(cand, order, axis=1),
                np.take_along_axis(valid, order, axis=1), evals)

    def _candidates_ivf(self, Q_sap: np.ndarray, kp: int):
        st = self.store
        nq = Q_sap.shape[0]
        if self.ivf is None:                      # nothing alive to probe
            return (np.zeros((nq, kp), np.int32),
                    np.zeros((nq, kp), bool), 0)
        Q = np.asarray(Q_sap, np.float32)
        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        if self.oblivious:
            # full-bucket membership-masked scan: every resident row is
            # touched for every query, so evals/bytes are constants of
            # the bucket — the access-pattern observable the hardened
            # profiles flatten (DESIGN.md §14)
            bucket = int(self._C_all.shape[0])
            ids, vout = se.scan_ivf_oblivious(
                self._C_all, Q, pools, kp,
                pool_mask=lambda p: st.alive_view[p])
            ids, vout = self._mask_alive(ids, vout)
            evals = nq * bucket + nq * self.ivf.centroids.shape[0]
            self.last_filter_bytes = (bucket * st.d * 4
                                      + self.ivf.centroids.nbytes)
            return ids, vout, evals
        ids, vout = se.scan_ivf_pools(
            self._C_all, Q, pools, kp,
            pool_mask=lambda p: st.alive_view[p])
        evals = sum(p.size for p in pools) + nq * self.ivf.centroids.shape[0]
        self.last_filter_bytes = (sum(p.size for p in pools) * st.d * 4
                                  + self.ivf.centroids.nbytes)
        return ids, vout, evals

    def _candidates_graph(self, Q_sap: np.ndarray, kp: int,
                          ef_search: int):
        """Batched lockstep traversal over the CSR mirror (the whole
        query batch in one jitted call — `kernels.graph_expand.ops`).
        Static args are buckets only; ef/entry/validity are data, so
        steady-state serving reuses one executable."""
        from ...kernels.graph_expand import ops as graph_ops
        st = self.store
        Q = np.asarray(Q_sap, np.float32)
        nq = Q.shape[0]
        R = int(self._g_neigh0.shape[0])
        kp2 = max(1, min(self.oversampled(kp), R))
        ef_eff, ef_cap, max_hops = beam_plan(kp2, max(ef_search, kp2))
        if self.quantization is None:
            qd = jnp.asarray(Q)
        elif self.quantization == "int8":
            qd = jnp.asarray(self.adc_codebook.encode_query(Q))
        else:
            qd = jnp.asarray(self.adc_codebook.lut(Q))
        cand, _, visited, hops, edges = graph_ops.graph_topk(
            self._g_neigh0, self._g_neigh_up, self._g_ok, self._g_db,
            qd, jnp.int32(self._csr.entry), jnp.int32(ef_eff),
            kp=kp2, ef_cap=ef_cap, max_hops=max_hops,
            quant=self.quantization or "f32",
            oblivious=self.oblivious, use_kernel=self._use_pallas())
        safe, valid = self._mask_alive(np.asarray(cand, np.int32),
                                       np.asarray(cand) >= 0)
        n_edges = int(np.asarray(edges).sum())
        self.last_n_hops = int(np.asarray(hops).sum())
        self.last_n_edges_scanned = n_edges
        row_bytes = (st.d * 4 if self.quantization is None
                     else self.adc_codebook.code_bytes_per_vector())
        self.last_filter_bytes = (n_edges + nq) * row_bytes
        self.last_scan_trace = np.asarray(visited)
        return safe, valid, n_edges + nq

    def _candidates_hnsw(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        cand, valid, evals = se.traverse_graph_candidates(
            self.graph, Q_sap, kp, ef_search)
        safe, valid = self._mask_alive(cand, valid)
        self.last_filter_bytes = int(evals) * self.store.d * 4
        return safe, valid, evals
