"""Unified batched secure filter-and-refine engine (DESIGN.md §2).

This is the single search path behind every entry point in the repo:

  filter:  a pluggable backend produces k' candidate ids per query —
             * FlatScanFilter  — exhaustive scan via the l2_topk Pallas
               kernel (chunked MXU tiles, no (nq, n) matrix in HBM);
             * IVFScanFilter   — partition-pruned scan: host-side coarse
               probe over DCPE ciphertext centroids, then one jitted
               masked gather+scan over the probed rows;
             * HNSWGraphFilter — host-side graph traversal (pointer
               chasing stays on CPU, DESIGN.md §3).
  refine:  one jitted batched DCE tournament over the candidate sets,
           routed through the dce_comp Pallas kernel
           (`batched_top_k_by_wins`) — no per-query Python loop.

`SecureSearchEngine.search` is a thin batch-of-one wrapper over
`search_batch`, so the per-query path (`core.ppanns.Server.search`) and
the batched path provably return identical ids for every backend.  All
backends report the same `SearchStats` (latency, distance evaluations,
DCE comparisons, bytes up/down).

Privacy envelope: every backend sees only DCPE filter ciphertexts and
DCE refine ciphertexts / trapdoors — the engine never touches plaintexts
or true distances, only ciphertext distances and comparison signs (the
leakage proven in the paper, §VI).
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from ..core import adc, secure_knn
from ..core.hnsw import HNSW
from ..core.ivf import IVFIndex
from ..kernels.adc_topk import ops as adc_ops
from ..kernels.common import next_bucket
from ..kernels.dce_comp import ops as dce_ops
from ..kernels.l2_topk import ops as l2_ops
from ..obs.trace import child_span

__all__ = ["SearchStats", "SecureSearchEngine", "FlatScanFilter",
           "IVFScanFilter", "HNSWGraphFilter", "ADCFilter",
           "refine_candidates", "layout_pools", "scan_ivf_pools",
           "pool_membership", "scan_ivf_oblivious",
           "traverse_graph_candidates"]


@dataclasses.dataclass
class SearchStats:
    """Uniform per-call search accounting (single query or batch).

    Communication model (paper §V-C): user -> server is the DCPE query
    ciphertext + DCE trapdoor + k (4 bytes); server -> user is the
    serialized id matrix — int64 ids, so 8 bytes per returned slot.
    """
    latency_s: float
    filter_dist_evals: int      # ciphertext distance evaluations (filter)
    refine_comparisons: int     # DCE DistanceComp sign evaluations (refine)
    bytes_up: int
    bytes_down: int
    n_queries: int = 1
    backend: str = ""
    # true bytes the filter touched this call: full-precision rows for
    # the f32 backends, codes (+ norms / LUT centroids) for quantized
    # ADC backends — the direct observable of the bandwidth win
    # (DESIGN.md §11).  0 for an empty collection.
    filter_bytes_scanned: int = 0
    # dummy padding rows injected by the scheduler under padding
    # security profiles (repro.sec, DESIGN.md §14).  Dummies ride the
    # engine call but never a user-visible future, and the telemetry
    # QPS/occupancy accounting excludes them.  Additive wire field:
    # results serialized before it decode with 0.
    n_dummy_queries: int = 0
    # graph-backend traversal accounting (repro.graph, DESIGN.md §15):
    # total beam/greedy hops and edges scored across the batch.  0 for
    # scan backends; additive wire fields — old payloads decode with 0.
    n_hops: int = 0
    n_edges_scanned: int = 0
    # failover accounting (repro.resilience, DESIGN.md §16): how many
    # shard GROUPS had no live replica when this call was served, and
    # whether the answer is therefore partial (`degraded=True` ⇒ ids
    # cover only alive shards' rows).  Additive wire fields — payloads
    # from before replication decode as healthy.
    n_shards_down: int = 0
    degraded: bool = False


# ---------------------------------------------------------------------------
# Batched refine — the one refine path every entry point routes through.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def refine_candidates(C_dce, cand, T, valid, k: int, use_kernel: bool = True):
    """Exact DCE tournament refine of per-query candidate sets, batched.

    C_dce: (n, 4, D) refine ciphertexts; cand: (nq, kp) candidate ids;
    T: (nq, D) trapdoors; valid: (nq, kp) bool or None (padded-slot mask)
    -> (nq, k) ids, ascending true distance; -1 marks slots where a query
    had fewer than k real candidates (never a fabricated id).
    use_kernel=False swaps the Pallas Z-matrix for the einsum oracle (the
    GSPMD-safe formulation for mesh-sharded C_dce, see serving.ann_server).
    """
    Cc = jnp.take(C_dce, cand, axis=0)                  # (nq, kp, 4, D)
    local = dce_ops.batched_top_k_by_wins(
        Cc, T, k, valid=valid, use_kernel=use_kernel)   # (nq, k)
    local = local.astype(cand.dtype)
    ids = jnp.take_along_axis(cand, local, axis=1)
    if valid is None:
        return ids
    vsel = jnp.take_along_axis(valid, local, axis=1)
    return jnp.where(vsel, ids, -1)


@functools.partial(jax.jit, static_argnames=("kp",))
def _masked_pruned_scan(C_sap, Q, cand, valid, kp: int):
    """IVF filter inner loop: ciphertext distances over probed rows only.

    Same ||q||^2 - 2 q.x + ||x||^2 restructuring as the l2_topk kernel,
    with a per-query gather (each query probes different partitions) and
    an invalid-slot mask.  Returns (ids, valid) of the per-query top-kp.
    """
    rows = jnp.take(C_sap, cand, axis=0)                # (nq, L, d)
    qn = (Q * Q).sum(-1)[:, None]
    xn = (rows * rows).sum(-1)
    cross = jnp.einsum("qld,qd->ql", rows, Q)
    d = jnp.where(valid, qn - 2.0 * cross + xn, jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (jnp.take_along_axis(cand, pos, axis=1),
            jnp.take_along_axis(valid, pos, axis=1))


# ---------------------------------------------------------------------------
# Filter backends.  Each returns (cand (nq, kp') int32, valid (nq, kp') bool,
# n_dist_evals) given a batch of DCPE-encrypted queries.
#
# The two shared scan/traversal bodies below are used both by the static
# backends here and by the runtime's mutable DeltaAwareBackend
# (serving/runtime/ingest.py) — one copy, so bucketing rules and eval
# accounting cannot diverge between the frozen and the mutating paths.
# ---------------------------------------------------------------------------


def layout_pools(nq: int, pools, kp: int, pool_mask=None):
    """Pad ragged probe pools to a 128-bucketed (nq, L) rectangle.

    Shared by the single-device masked scan and the sharded pool scan
    (serving/sharded.py) — one layout, so candidate order (and with it
    exact id parity across placements) cannot drift.  The power-of-two
    bucket on L matters: probe-pool sizes vary per batch and grow with
    ingestion, so a finer rounding would recompile the jitted scans at
    every boundary crossing — pow2 bounds the distinct widths to
    O(log n).  pool_mask(p) -> bool mask lets a caller pre-invalidate
    pool entries (e.g. tombstoned rows)."""
    L = next_bucket(max(kp, max((p.size for p in pools), default=1), 1),
                    minimum=128)
    cand = np.zeros((nq, L), np.int32)
    valid = np.zeros((nq, L), bool)
    for qi, p in enumerate(pools):                      # id layout only
        cand[qi, : p.size] = p
        valid[qi, : p.size] = True if pool_mask is None else pool_mask(p)
    return cand, valid


def scan_ivf_pools(C_dev, Q_sap: np.ndarray, pools, kp: int,
                   pool_mask=None):
    """Lay out the probe pools and run the jitted masked scan over
    C_dev.  Returns (ids (nq, kp), valid (nq, kp))."""
    nq = Q_sap.shape[0]
    cand, valid = layout_pools(nq, pools, kp, pool_mask)
    ids, vout = _masked_pruned_scan(
        C_dev, jnp.asarray(np.asarray(Q_sap, np.float32)),
        jnp.asarray(cand), jnp.asarray(valid), kp)
    return np.asarray(ids), np.asarray(vout)


@functools.partial(jax.jit, static_argnames=("kp",))
def _masked_full_scan(C_all, Q, member, kp: int):
    """Scan-oblivious IVF filter inner loop (DESIGN.md §14): ciphertext
    distances over EVERY resident row, masked afterwards by per-query
    pool membership.

    The access pattern is a constant — one (nq, bucket) matmul whose
    shape depends only on the row bucket, no data-dependent gather — so
    which rows a query's probes selected is not observable from the
    scan.  The distances themselves are the same ||q||^2 - 2 q.x +
    ||x||^2 values the pruned scan computes for member rows, so the
    surviving candidate set matches `_masked_pruned_scan` and the exact
    DCE refine returns identical ids (the cross-profile parity tests).
    Returns (ids, valid) of the per-query top-kp over member rows.
    """
    qn = (Q * Q).sum(-1)[:, None]
    xn = (C_all * C_all).sum(-1)[None, :]
    d = qn - 2.0 * Q @ C_all.T + xn                     # (nq, bucket)
    d = jnp.where(member, d, jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (pos.astype(jnp.int32),
            jnp.take_along_axis(member, pos, axis=1))


def pool_membership(nq: int, pools, bucket: int, pool_mask=None):
    """(nq, bucket) bool membership mask for the oblivious scans:
    member[qi, r] iff row r is in query qi's probe pool (and passes
    pool_mask, e.g. tombstone filtering).  Host-side layout only — the
    device never sees the ragged pools."""
    member = np.zeros((nq, bucket), bool)
    for qi, p in enumerate(pools):
        member[qi, p] = True if pool_mask is None else pool_mask(p)
    return member


def scan_ivf_oblivious(C_dev, Q_sap: np.ndarray, pools, kp: int,
                       pool_mask=None):
    """Oblivious twin of `scan_ivf_pools`: full-bucket masked scan over
    the resident scan array.  Returns (ids (nq, kp), valid (nq, kp))."""
    nq = Q_sap.shape[0]
    member = pool_membership(nq, pools, int(C_dev.shape[0]), pool_mask)
    ids, vout = _masked_full_scan(
        C_dev, jnp.asarray(np.asarray(Q_sap, np.float32)),
        jnp.asarray(member), kp)
    return np.asarray(ids), np.asarray(vout)


def traverse_graph_candidates(index: HNSW, Q_sap: np.ndarray, kp: int,
                              ef_search: int):
    """Per-query host-side HNSW traversal (pointer chasing stays on CPU,
    DESIGN.md §3), padded to an (nq, kp) rectangle.
    Returns (cand, valid, n_dist_evals).

    Deprecated as a serving path: `repro.graph.GraphFilter` runs the
    same walk batched over the whole query set (recall-identical at
    fixed ef — the parity suite in tests/test_graph.py).  This loop is
    kept as the parity oracle."""
    warnings.warn(
        "the per-query host HNSW walk is deprecated as a serving path; "
        "use repro.graph.GraphFilter (batched, recall-identical at "
        "fixed ef) — the host walk remains as the parity oracle",
        DeprecationWarning, stacklevel=2)
    nq = Q_sap.shape[0]
    evals0 = index.n_dist_evals
    cand = np.zeros((nq, kp), np.int32)
    valid = np.zeros((nq, kp), bool)
    for qi in range(nq):
        ids, _ = index.search(np.asarray(Q_sap[qi]), kp,
                              ef=max(ef_search, kp))
        cand[qi, : ids.size] = ids
        valid[qi, : ids.size] = True
    return cand, valid, index.n_dist_evals - evals0

class FlatScanFilter:
    """Exhaustive Pallas l2_topk scan over all DCPE ciphertexts."""

    name = "flat"

    def __init__(self, use_kernel: bool = True, chunk: int = 4096):
        self.use_kernel = use_kernel
        self.chunk = chunk
        self._C = None
        self.last_filter_bytes = 0

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        self._C = jnp.asarray(C_sap)

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        n = self._C.shape[0]
        _, idx = l2_ops.knn(jnp.asarray(Q_sap, jnp.float32), self._C,
                            min(kp, n), chunk=min(self.chunk, n),
                            use_kernel=self.use_kernel)
        cand = np.asarray(idx, np.int32)
        valid = np.ones(cand.shape, bool)
        self.last_filter_bytes = int(self._C.size) * 4
        return cand, valid, Q_sap.shape[0] * n


class IVFScanFilter:
    """Partition-pruned scan: coarse k-means probe + jitted masked scan.

    The coarse quantizer is built over DCPE ciphertexts — the same privacy
    envelope as the HNSW graph (centroids are functions of ciphertexts
    only).  Probing is host-side (`IVFIndex.probe`, tiny: nq x
    n_clusters); the per-row distance work rides the MXU path in
    `_masked_pruned_scan`.
    """

    name = "ivf"

    def __init__(self, n_partitions: int = 64, nprobe: int = 8,
                 seed: int = 0):
        self.n_partitions = n_partitions
        self.nprobe = nprobe
        self.seed = seed
        self.ivf: IVFIndex | None = None
        self._C = None
        self.last_filter_bytes = 0

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        self._C = jnp.asarray(C_sap)
        self.ivf = IVFIndex(n_clusters=min(self.n_partitions,
                                           C_sap.shape[0]),
                            seed=self.seed).build(C_sap)

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        Q = np.asarray(Q_sap, np.float32)
        nq = Q.shape[0]
        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        ids, vout = scan_ivf_pools(self._C, Q, pools, kp)
        evals = sum(p.size for p in pools) \
            + nq * self.ivf.centroids.shape[0]
        d = Q.shape[1]
        self.last_filter_bytes = (sum(p.size for p in pools) * d * 4
                                  + self.ivf.centroids.nbytes)
        return ids, vout, evals


class HNSWGraphFilter:
    """Host-side HNSW traversal over DCPE ciphertexts (DESIGN.md §3).

    Graph walks are sequential pointer chasing and stay on CPU even in
    the TPU deployment; only the filter phase loops over queries — the
    refine phase is batched regardless of backend.
    """

    name = "hnsw"

    def __init__(self, index: HNSW):
        self.index = index
        self.last_filter_bytes = 0

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        pass                      # the graph already stores its ciphertexts

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        cand, valid, evals = traverse_graph_candidates(
            self.index, Q_sap, kp, ef_search)
        # pointer chasing re-reads per query: one full row per eval
        self.last_filter_bytes = int(evals) * Q_sap.shape[1] * 4
        return cand, valid, evals


class ADCFilter:
    """Quantized approximate-distance filter over ciphertext codes
    (DESIGN.md §11): the flat/IVF scan at 1 byte/dim (int8) or m
    bytes/vector (pq8) instead of 4 bytes/dim.

    The backend trains its codebook *keylessly* over the DCPE filter
    ciphertexts at attach (the server quantizes data it already holds —
    no new leakage), scans codes through the fused adc_topk kernel
    family, and **oversamples**: asked for k' candidates it returns
    k' * refine_ratio of them, so the unchanged exact DCE refine
    recovers the order that quantization blurred (`core.adc` holds the
    recall model and the per-kind defaults).

    kind="flat" streams all codes (Pallas `sq_adc_topk`/`pq_adc_topk`
    with the in-kernel running top-k); kind="ivf" probes the same
    coarse quantizer as `IVFScanFilter` (identical pools) and runs the
    ADC pool scan over the probed rows.

    use_kernel=True engages the Pallas path on actual TPU backends; on
    other backends the rank-identical XLA formulation runs instead —
    interpret-mode execution is a correctness harness, not a serving
    path (kernels/adc_topk/ops.py).  use_kernel=False forces XLA
    everywhere (the GSPMD-safe form the sharded backend uses).
    """

    def __init__(self, quantization: str = "int8", kind: str = "flat", *,
                 refine_ratio: float | None = None, use_kernel: bool = True,
                 n_partitions: int = 64, nprobe: int = 8, pq_m: int = 16,
                 seed: int = 0):
        if quantization not in ("int8", "pq8"):
            raise ValueError(f"ADCFilter needs quantization int8|pq8, "
                             f"got {quantization!r}")
        if kind not in ("flat", "ivf"):
            raise ValueError(f"ADCFilter kind must be flat|ivf, "
                             f"got {kind!r}")
        self.quantization = quantization
        self.kind = kind
        self.name = f"adc-{kind}-{quantization}"
        self.refine_ratio = (adc.default_refine_ratio(quantization)
                             if refine_ratio is None else
                             float(refine_ratio))
        self.use_kernel = use_kernel
        self.n_partitions = n_partitions
        self.nprobe = nprobe
        self.pq_m = pq_m
        self.seed = seed
        self.codebook = None
        self.ivf: IVFIndex | None = None
        self._c8 = self._cn = self._codes_t = None
        self._n = 0
        self.last_filter_bytes = 0

    # --------------------------------------------------------- encoding

    def _use_pallas(self) -> bool:
        return self.use_kernel and jax.default_backend() == "tpu"

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        self._n = C_sap.shape[0]
        self.codebook = adc.train_codebook(
            C_sap, self.quantization, m=self.pq_m, seed=self.seed)
        if self.quantization == "int8":
            codes, cn = self.codebook.encode(C_sap)
            self._c8 = jnp.asarray(codes)
            self._cn = jnp.asarray(cn)
        else:
            codes = self.codebook.encode(C_sap)
            self._codes_t = jnp.asarray(np.ascontiguousarray(codes.T))
        if self.kind == "ivf":
            # the SAME coarse quantizer as IVFScanFilter — probe pools
            # are identical, only the per-row distance math changes
            self.ivf = IVFIndex(n_clusters=min(self.n_partitions,
                                               C_sap.shape[0]),
                                seed=self.seed).build(C_sap)

    def _code_bytes(self) -> int:
        return self.codebook.code_bytes_per_vector()

    def oversampled(self, kp: int) -> int:
        return max(kp, int(np.ceil(kp * self.refine_ratio)))

    # ------------------------------------------------------- candidates

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        Q = np.asarray(Q_sap, np.float32)
        nq = Q.shape[0]
        kp2 = min(self.oversampled(kp), self._n)
        if self.kind == "flat":
            if self.quantization == "int8":
                q8 = self.codebook.encode_query(Q)
                _, idx = adc_ops.sq_knn(jnp.asarray(q8), self._c8,
                                        self._cn, kp2,
                                        use_kernel=self._use_pallas())
            else:
                lut = self.codebook.lut(Q)
                _, idx = adc_ops.pq_knn(jnp.asarray(lut), self._codes_t,
                                        kp2,
                                        use_kernel=self._use_pallas())
            cand = np.asarray(idx, np.int32)
            # -1 marks slots beyond the valid-row count (kp' > n); the
            # refine sees them masked, never a wrapped gather index
            valid = cand >= 0
            cand = np.where(valid, cand, 0)
            self.last_filter_bytes = self._n * self._code_bytes()
            return cand, valid, nq * self._n

        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        cand, valid = layout_pools(nq, pools, kp2)
        if self.quantization == "int8":
            q8 = self.codebook.encode_query(Q)
            ids, vout = adc_ops.sq_pool_scan(
                self._c8, self._cn, jnp.asarray(q8), jnp.asarray(cand),
                jnp.asarray(valid), kp2)
        else:
            lut = self.codebook.lut(Q)
            ids, vout = adc_ops.pq_pool_scan(
                self._codes_t, jnp.asarray(lut), jnp.asarray(cand),
                jnp.asarray(valid), kp2)
        evals = sum(p.size for p in pools) \
            + nq * self.ivf.centroids.shape[0]
        self.last_filter_bytes = (sum(p.size for p in pools)
                                  * self._code_bytes()
                                  + self.ivf.centroids.nbytes)
        return np.asarray(ids), np.asarray(vout), evals


_BACKENDS = {"flat": FlatScanFilter, "ivf": IVFScanFilter}


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

class SecureSearchEngine:
    """Batched filter-and-refine over an encrypted database.

    backend: "flat" | "ivf" | a filter-backend instance (e.g.
    `HNSWGraphFilter(index)` — pass the HNSW built by the data owner).
    quantization: None | "int8" | "pq8" — a non-None value swaps the
    string-selected flat/ivf backend for the quantized `ADCFilter`
    variant of the same kind (DESIGN.md §11); the refine is unchanged.
    use_kernel=False drops to the einsum refine (GSPMD-safe / debugging).
    """

    def __init__(self, C_sap: np.ndarray, C_dce: np.ndarray, *,
                 backend="flat", use_kernel: bool = True,
                 quantization: str | None = None, **backend_kw):
        if isinstance(backend, str):
            if backend == "hnsw":
                raise ValueError(
                    "pass HNSWGraphFilter(index) explicitly: the graph is "
                    "built by the data owner, not the engine")
            if backend == "graph":
                raise ValueError(
                    "pass repro.graph.GraphFilter(index) explicitly: the "
                    "graph is built by the data owner, not the engine")
            if quantization is not None:
                if backend not in ("flat", "ivf"):
                    raise ValueError(
                        f"quantization applies to flat|ivf backends, "
                        f"not {backend!r}")
                backend = ADCFilter(quantization, kind=backend,
                                    use_kernel=use_kernel, **backend_kw)
            else:
                backend = _BACKENDS[backend](**backend_kw)
        elif quantization is not None:
            raise ValueError("pass quantization to the backend instance, "
                             "not the engine, when supplying one")
        self.backend = backend
        self.use_kernel = use_kernel
        self.update_database(C_sap, C_dce)

    # -------------------------------------------------------------- state

    @property
    def n(self) -> int:
        return self._C_sap.shape[0]

    def update_database(self, C_sap: np.ndarray, C_dce: np.ndarray):
        """(Re)load ciphertexts, e.g. after owner-side insert (§V-D).

        Cheap: only marks backend acceleration state (device copies, IVF
        centroids) dirty; the rebuild happens lazily on the next search,
        so a burst of maintenance ops pays one refresh, not one per op."""
        self._C_sap = np.asarray(C_sap)
        self._C_dce = np.asarray(C_dce)
        self._dirty = True

    def _ensure_attached(self):
        if self._dirty:
            # a backend may manage the refine array's device residency
            # itself (the runtime's mutable store ships only appended
            # rows, DESIGN.md §8); default is a full upload
            provider = getattr(self.backend, "dce_device", None)
            self._C_dce_dev = (jnp.asarray(self._C_dce) if provider is None
                               else provider(self._C_dce))
            self.backend.attach(self._C_sap, self)
            self._dirty = False

    # ------------------------------------------------------------- search

    def search_batch(self, Q_sap: np.ndarray, T_q: np.ndarray, k: int,
                     ratio_k: float = 8.0, ef_search: int = 96,
                     refine: str = "tournament"):
        """Algorithm 2, batched: k'-ANN filter then exact DCE refine.

        Q_sap: (nq, d) DCPE query ciphertexts; T_q: (nq, 2d+16) trapdoors.
        Returns (ids (nq, k) int64, SearchStats); id -1 fills slots where
        a query had fewer than k real candidates (tiny database, sparse
        IVF probe).  refine: "tournament" (batched MXU tournament,
        default) | "none" (filter-only baseline, Fig. 6).  The paper's
        sequential heap refine is per-query only — use
        `search(..., refine="heap")`.
        """
        t0 = time.perf_counter()
        self._ensure_attached()
        Q_sap = np.atleast_2d(np.asarray(Q_sap))
        T_q = np.atleast_2d(np.asarray(T_q))
        nq = Q_sap.shape[0]
        kp = int(max(k, round(ratio_k * k)))
        # obs (DESIGN.md §13): when a scheduler's batch span is ambient,
        # filter/refine become its children; no-op spans otherwise
        with child_span("filter", backend=self.backend.name,
                        kp=kp, nq=nq) as fsp:
            cand, valid, dist_evals = self.backend.candidates(
                Q_sap, kp, ef_search)
            fsp.set(dist_evals=int(dist_evals),
                    bytes_scanned=int(
                        getattr(self.backend, "last_filter_bytes", 0)),
                    hops=int(getattr(self.backend, "last_n_hops", 0)),
                    edges_scanned=int(
                        getattr(self.backend, "last_n_edges_scanned", 0)))
        if cand.shape[1] < k:       # uniform (nq, k) contract: -1 fill
            pad = ((0, 0), (0, k - cand.shape[1]))
            cand = np.pad(cand, pad)
            valid = np.pad(valid, pad)

        with child_span("refine", mode=refine) as rsp:
            if refine == "tournament":
                # a backend may supply its own batched refine (the sharded
                # backend's tournament runs the candidate gather under the
                # mesh, serving/sharded.py); semantics are identical
                refine_fn = getattr(self.backend, "refine_batch", None)
                if refine_fn is not None:
                    out = refine_fn(self._C_dce_dev, jnp.asarray(cand),
                                    jnp.asarray(T_q), jnp.asarray(valid), k)
                else:
                    out = refine_candidates(
                        self._C_dce_dev, jnp.asarray(cand), jnp.asarray(T_q),
                        jnp.asarray(valid), k, self.use_kernel)
                ids = np.asarray(out, np.int64)
                nv = valid.sum(axis=1)
                ncmp = int((nv * (nv - 1)).sum())
            elif refine == "none":          # filter-only baseline
                ids = np.where(valid[:, :k], cand[:, :k], -1)\
                    .astype(np.int64)
                ncmp = 0
            else:
                raise ValueError(f"batched refine must be 'tournament' or "
                                 f"'none', got {refine!r}")
            rsp.set(comparisons=ncmp)

        stats = SearchStats(
            latency_s=time.perf_counter() - t0,
            filter_dist_evals=int(dist_evals),
            refine_comparisons=ncmp,
            bytes_up=Q_sap.nbytes + T_q.nbytes + 4 * nq,
            bytes_down=ids.nbytes,          # int64 ids: 8 bytes per slot
            n_queries=nq,
            backend=self.backend.name,
            filter_bytes_scanned=int(
                getattr(self.backend, "last_filter_bytes", 0)),
            n_hops=int(getattr(self.backend, "last_n_hops", 0)),
            n_edges_scanned=int(
                getattr(self.backend, "last_n_edges_scanned", 0)),
            n_shards_down=int(
                getattr(self.backend, "last_n_shards_down", 0)),
            degraded=bool(getattr(self.backend, "last_degraded", False)),
        )
        return ids, stats

    def search(self, C_sap_q: np.ndarray, T_q: np.ndarray, k: int,
               ratio_k: float = 8.0, ef_search: int = 96,
               refine: str = "tournament"):
        """Single-query search: a batch-of-one view of `search_batch`
        (identical ids by construction), plus the paper-faithful
        sequential refine modes ("heap")."""
        if refine in ("tournament", "none"):
            ids, stats = self.search_batch(
                C_sap_q[None], np.asarray(T_q)[None], k, ratio_k=ratio_k,
                ef_search=ef_search, refine=refine)
            return ids[0], stats

        if refine != "heap":
            raise ValueError(refine)
        # paper Algorithm 2: max-heap keyed by DCE comparison signs
        t0 = time.perf_counter()
        self._ensure_attached()
        kp = int(max(k, round(ratio_k * k)))
        cand, valid, dist_evals = self.backend.candidates(
            np.asarray(C_sap_q)[None], kp, ef_search)
        cids = cand[0][valid[0]].astype(np.int64)
        ids, ncmp = secure_knn.refine_heap(
            self._C_dce[cids], cids, np.asarray(T_q), k)
        stats = SearchStats(
            latency_s=time.perf_counter() - t0,
            filter_dist_evals=int(dist_evals),
            refine_comparisons=int(ncmp),
            bytes_up=np.asarray(C_sap_q).nbytes + np.asarray(T_q).nbytes + 4,
            bytes_down=np.asarray(ids, np.int64).nbytes,
            n_queries=1,
            backend=self.backend.name,
            filter_bytes_scanned=int(
                getattr(self.backend, "last_filter_bytes", 0)),
            n_hops=int(getattr(self.backend, "last_n_hops", 0)),
            n_edges_scanned=int(
                getattr(self.backend, "last_n_edges_scanned", 0)),
            n_shards_down=int(
                getattr(self.backend, "last_n_shards_down", 0)),
            degraded=bool(getattr(self.backend, "last_degraded", False)),
        )
        return ids, stats
