"""Unified batched secure filter-and-refine engine (DESIGN.md §2).

This is the single search path behind every entry point in the repo:

  filter:  a pluggable backend produces k' candidate ids per query —
             * FlatScanFilter  — exhaustive scan via the l2_topk Pallas
               kernel (chunked MXU tiles, no (nq, n) matrix in HBM);
             * IVFScanFilter   — partition-pruned scan: host-side coarse
               probe over DCPE ciphertext centroids, then one jitted
               masked gather+scan over the probed rows;
             * HNSWGraphFilter — host-side graph traversal (pointer
               chasing stays on CPU, DESIGN.md §3).
  refine:  one jitted batched DCE tournament over the candidate sets,
           routed through the dce_comp Pallas kernel
           (`batched_top_k_by_wins`) — no per-query Python loop.

`SecureSearchEngine.search` is a thin batch-of-one wrapper over
`search_batch`, so the per-query path (`core.ppanns.Server.search`) and
the batched path provably return identical ids for every backend.  All
backends report the same `SearchStats` (latency, distance evaluations,
DCE comparisons, bytes up/down).

Privacy envelope: every backend sees only DCPE filter ciphertexts and
DCE refine ciphertexts / trapdoors — the engine never touches plaintexts
or true distances, only ciphertext distances and comparison signs (the
leakage proven in the paper, §VI).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import secure_knn
from ..core.hnsw import HNSW
from ..core.ivf import IVFIndex
from ..kernels.common import next_bucket
from ..kernels.dce_comp import ops as dce_ops
from ..kernels.l2_topk import ops as l2_ops

__all__ = ["SearchStats", "SecureSearchEngine", "FlatScanFilter",
           "IVFScanFilter", "HNSWGraphFilter", "refine_candidates",
           "layout_pools", "scan_ivf_pools", "traverse_graph_candidates"]


@dataclasses.dataclass
class SearchStats:
    """Uniform per-call search accounting (single query or batch).

    Communication model (paper §V-C): user -> server is the DCPE query
    ciphertext + DCE trapdoor + k (4 bytes); server -> user is the
    serialized id matrix — int64 ids, so 8 bytes per returned slot.
    """
    latency_s: float
    filter_dist_evals: int      # ciphertext distance evaluations (filter)
    refine_comparisons: int     # DCE DistanceComp sign evaluations (refine)
    bytes_up: int
    bytes_down: int
    n_queries: int = 1
    backend: str = ""


# ---------------------------------------------------------------------------
# Batched refine — the one refine path every entry point routes through.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def refine_candidates(C_dce, cand, T, valid, k: int, use_kernel: bool = True):
    """Exact DCE tournament refine of per-query candidate sets, batched.

    C_dce: (n, 4, D) refine ciphertexts; cand: (nq, kp) candidate ids;
    T: (nq, D) trapdoors; valid: (nq, kp) bool or None (padded-slot mask)
    -> (nq, k) ids, ascending true distance; -1 marks slots where a query
    had fewer than k real candidates (never a fabricated id).
    use_kernel=False swaps the Pallas Z-matrix for the einsum oracle (the
    GSPMD-safe formulation for mesh-sharded C_dce, see serving.ann_server).
    """
    Cc = jnp.take(C_dce, cand, axis=0)                  # (nq, kp, 4, D)
    local = dce_ops.batched_top_k_by_wins(
        Cc, T, k, valid=valid, use_kernel=use_kernel)   # (nq, k)
    local = local.astype(cand.dtype)
    ids = jnp.take_along_axis(cand, local, axis=1)
    if valid is None:
        return ids
    vsel = jnp.take_along_axis(valid, local, axis=1)
    return jnp.where(vsel, ids, -1)


@functools.partial(jax.jit, static_argnames=("kp",))
def _masked_pruned_scan(C_sap, Q, cand, valid, kp: int):
    """IVF filter inner loop: ciphertext distances over probed rows only.

    Same ||q||^2 - 2 q.x + ||x||^2 restructuring as the l2_topk kernel,
    with a per-query gather (each query probes different partitions) and
    an invalid-slot mask.  Returns (ids, valid) of the per-query top-kp.
    """
    rows = jnp.take(C_sap, cand, axis=0)                # (nq, L, d)
    qn = (Q * Q).sum(-1)[:, None]
    xn = (rows * rows).sum(-1)
    cross = jnp.einsum("qld,qd->ql", rows, Q)
    d = jnp.where(valid, qn - 2.0 * cross + xn, jnp.inf)
    kp = min(kp, d.shape[1])
    _, pos = jax.lax.top_k(-d, kp)
    return (jnp.take_along_axis(cand, pos, axis=1),
            jnp.take_along_axis(valid, pos, axis=1))


# ---------------------------------------------------------------------------
# Filter backends.  Each returns (cand (nq, kp') int32, valid (nq, kp') bool,
# n_dist_evals) given a batch of DCPE-encrypted queries.
#
# The two shared scan/traversal bodies below are used both by the static
# backends here and by the runtime's mutable DeltaAwareBackend
# (serving/runtime/ingest.py) — one copy, so bucketing rules and eval
# accounting cannot diverge between the frozen and the mutating paths.
# ---------------------------------------------------------------------------


def layout_pools(nq: int, pools, kp: int, pool_mask=None):
    """Pad ragged probe pools to a 128-bucketed (nq, L) rectangle.

    Shared by the single-device masked scan and the sharded pool scan
    (serving/sharded.py) — one layout, so candidate order (and with it
    exact id parity across placements) cannot drift.  The power-of-two
    bucket on L matters: probe-pool sizes vary per batch and grow with
    ingestion, so a finer rounding would recompile the jitted scans at
    every boundary crossing — pow2 bounds the distinct widths to
    O(log n).  pool_mask(p) -> bool mask lets a caller pre-invalidate
    pool entries (e.g. tombstoned rows)."""
    L = next_bucket(max(kp, max((p.size for p in pools), default=1), 1),
                    minimum=128)
    cand = np.zeros((nq, L), np.int32)
    valid = np.zeros((nq, L), bool)
    for qi, p in enumerate(pools):                      # id layout only
        cand[qi, : p.size] = p
        valid[qi, : p.size] = True if pool_mask is None else pool_mask(p)
    return cand, valid


def scan_ivf_pools(C_dev, Q_sap: np.ndarray, pools, kp: int,
                   pool_mask=None):
    """Lay out the probe pools and run the jitted masked scan over
    C_dev.  Returns (ids (nq, kp), valid (nq, kp))."""
    nq = Q_sap.shape[0]
    cand, valid = layout_pools(nq, pools, kp, pool_mask)
    ids, vout = _masked_pruned_scan(
        C_dev, jnp.asarray(np.asarray(Q_sap, np.float32)),
        jnp.asarray(cand), jnp.asarray(valid), kp)
    return np.asarray(ids), np.asarray(vout)


def traverse_graph_candidates(index: HNSW, Q_sap: np.ndarray, kp: int,
                              ef_search: int):
    """Per-query host-side HNSW traversal (pointer chasing stays on CPU,
    DESIGN.md §3), padded to an (nq, kp) rectangle.
    Returns (cand, valid, n_dist_evals)."""
    nq = Q_sap.shape[0]
    evals0 = index.n_dist_evals
    cand = np.zeros((nq, kp), np.int32)
    valid = np.zeros((nq, kp), bool)
    for qi in range(nq):
        ids, _ = index.search(np.asarray(Q_sap[qi]), kp,
                              ef=max(ef_search, kp))
        cand[qi, : ids.size] = ids
        valid[qi, : ids.size] = True
    return cand, valid, index.n_dist_evals - evals0

class FlatScanFilter:
    """Exhaustive Pallas l2_topk scan over all DCPE ciphertexts."""

    name = "flat"

    def __init__(self, use_kernel: bool = True, chunk: int = 4096):
        self.use_kernel = use_kernel
        self.chunk = chunk
        self._C = None

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        self._C = jnp.asarray(C_sap)

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        n = self._C.shape[0]
        _, idx = l2_ops.knn(jnp.asarray(Q_sap, jnp.float32), self._C,
                            min(kp, n), chunk=min(self.chunk, n),
                            use_kernel=self.use_kernel)
        cand = np.asarray(idx, np.int32)
        valid = np.ones(cand.shape, bool)
        return cand, valid, Q_sap.shape[0] * n


class IVFScanFilter:
    """Partition-pruned scan: coarse k-means probe + jitted masked scan.

    The coarse quantizer is built over DCPE ciphertexts — the same privacy
    envelope as the HNSW graph (centroids are functions of ciphertexts
    only).  Probing is host-side (`IVFIndex.probe`, tiny: nq x
    n_clusters); the per-row distance work rides the MXU path in
    `_masked_pruned_scan`.
    """

    name = "ivf"

    def __init__(self, n_partitions: int = 64, nprobe: int = 8,
                 seed: int = 0):
        self.n_partitions = n_partitions
        self.nprobe = nprobe
        self.seed = seed
        self.ivf: IVFIndex | None = None
        self._C = None

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        self._C = jnp.asarray(C_sap)
        self.ivf = IVFIndex(n_clusters=min(self.n_partitions,
                                           C_sap.shape[0]),
                            seed=self.seed).build(C_sap)

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        Q = np.asarray(Q_sap, np.float32)
        nq = Q.shape[0]
        pools = [self.ivf.probe(q, self.nprobe) for q in Q]
        ids, vout = scan_ivf_pools(self._C, Q, pools, kp)
        evals = sum(p.size for p in pools) \
            + nq * self.ivf.centroids.shape[0]
        return ids, vout, evals


class HNSWGraphFilter:
    """Host-side HNSW traversal over DCPE ciphertexts (DESIGN.md §3).

    Graph walks are sequential pointer chasing and stay on CPU even in
    the TPU deployment; only the filter phase loops over queries — the
    refine phase is batched regardless of backend.
    """

    name = "hnsw"

    def __init__(self, index: HNSW):
        self.index = index

    def attach(self, C_sap: np.ndarray, engine: "SecureSearchEngine"):
        pass                      # the graph already stores its ciphertexts

    def candidates(self, Q_sap: np.ndarray, kp: int, ef_search: int):
        return traverse_graph_candidates(self.index, Q_sap, kp, ef_search)


_BACKENDS = {"flat": FlatScanFilter, "ivf": IVFScanFilter}


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------

class SecureSearchEngine:
    """Batched filter-and-refine over an encrypted database.

    backend: "flat" | "ivf" | a filter-backend instance (e.g.
    `HNSWGraphFilter(index)` — pass the HNSW built by the data owner).
    use_kernel=False drops to the einsum refine (GSPMD-safe / debugging).
    """

    def __init__(self, C_sap: np.ndarray, C_dce: np.ndarray, *,
                 backend="flat", use_kernel: bool = True, **backend_kw):
        if isinstance(backend, str):
            if backend == "hnsw":
                raise ValueError(
                    "pass HNSWGraphFilter(index) explicitly: the graph is "
                    "built by the data owner, not the engine")
            backend = _BACKENDS[backend](**backend_kw)
        self.backend = backend
        self.use_kernel = use_kernel
        self.update_database(C_sap, C_dce)

    # -------------------------------------------------------------- state

    @property
    def n(self) -> int:
        return self._C_sap.shape[0]

    def update_database(self, C_sap: np.ndarray, C_dce: np.ndarray):
        """(Re)load ciphertexts, e.g. after owner-side insert (§V-D).

        Cheap: only marks backend acceleration state (device copies, IVF
        centroids) dirty; the rebuild happens lazily on the next search,
        so a burst of maintenance ops pays one refresh, not one per op."""
        self._C_sap = np.asarray(C_sap)
        self._C_dce = np.asarray(C_dce)
        self._dirty = True

    def _ensure_attached(self):
        if self._dirty:
            # a backend may manage the refine array's device residency
            # itself (the runtime's mutable store ships only appended
            # rows, DESIGN.md §8); default is a full upload
            provider = getattr(self.backend, "dce_device", None)
            self._C_dce_dev = (jnp.asarray(self._C_dce) if provider is None
                               else provider(self._C_dce))
            self.backend.attach(self._C_sap, self)
            self._dirty = False

    # ------------------------------------------------------------- search

    def search_batch(self, Q_sap: np.ndarray, T_q: np.ndarray, k: int,
                     ratio_k: float = 8.0, ef_search: int = 96,
                     refine: str = "tournament"):
        """Algorithm 2, batched: k'-ANN filter then exact DCE refine.

        Q_sap: (nq, d) DCPE query ciphertexts; T_q: (nq, 2d+16) trapdoors.
        Returns (ids (nq, k) int64, SearchStats); id -1 fills slots where
        a query had fewer than k real candidates (tiny database, sparse
        IVF probe).  refine: "tournament" (batched MXU tournament,
        default) | "none" (filter-only baseline, Fig. 6).  The paper's
        sequential heap refine is per-query only — use
        `search(..., refine="heap")`.
        """
        t0 = time.perf_counter()
        self._ensure_attached()
        Q_sap = np.atleast_2d(np.asarray(Q_sap))
        T_q = np.atleast_2d(np.asarray(T_q))
        nq = Q_sap.shape[0]
        kp = int(max(k, round(ratio_k * k)))
        cand, valid, dist_evals = self.backend.candidates(
            Q_sap, kp, ef_search)
        if cand.shape[1] < k:       # uniform (nq, k) contract: -1 fill
            pad = ((0, 0), (0, k - cand.shape[1]))
            cand = np.pad(cand, pad)
            valid = np.pad(valid, pad)

        if refine == "tournament":
            # a backend may supply its own batched refine (the sharded
            # backend's tournament runs the candidate gather under the
            # mesh, serving/sharded.py); semantics are identical
            refine_fn = getattr(self.backend, "refine_batch", None)
            if refine_fn is not None:
                out = refine_fn(self._C_dce_dev, jnp.asarray(cand),
                                jnp.asarray(T_q), jnp.asarray(valid), k)
            else:
                out = refine_candidates(
                    self._C_dce_dev, jnp.asarray(cand), jnp.asarray(T_q),
                    jnp.asarray(valid), k, self.use_kernel)
            ids = np.asarray(out, np.int64)
            nv = valid.sum(axis=1)
            ncmp = int((nv * (nv - 1)).sum())
        elif refine == "none":          # filter-only baseline
            ids = np.where(valid[:, :k], cand[:, :k], -1).astype(np.int64)
            ncmp = 0
        else:
            raise ValueError(f"batched refine must be 'tournament' or "
                             f"'none', got {refine!r}")

        stats = SearchStats(
            latency_s=time.perf_counter() - t0,
            filter_dist_evals=int(dist_evals),
            refine_comparisons=ncmp,
            bytes_up=Q_sap.nbytes + T_q.nbytes + 4 * nq,
            bytes_down=ids.nbytes,          # int64 ids: 8 bytes per slot
            n_queries=nq,
            backend=self.backend.name,
        )
        return ids, stats

    def search(self, C_sap_q: np.ndarray, T_q: np.ndarray, k: int,
               ratio_k: float = 8.0, ef_search: int = 96,
               refine: str = "tournament"):
        """Single-query search: a batch-of-one view of `search_batch`
        (identical ids by construction), plus the paper-faithful
        sequential refine modes ("heap")."""
        if refine in ("tournament", "none"):
            ids, stats = self.search_batch(
                C_sap_q[None], np.asarray(T_q)[None], k, ratio_k=ratio_k,
                ef_search=ef_search, refine=refine)
            return ids[0], stats

        if refine != "heap":
            raise ValueError(refine)
        # paper Algorithm 2: max-heap keyed by DCE comparison signs
        t0 = time.perf_counter()
        self._ensure_attached()
        kp = int(max(k, round(ratio_k * k)))
        cand, valid, dist_evals = self.backend.candidates(
            np.asarray(C_sap_q)[None], kp, ef_search)
        cids = cand[0][valid[0]].astype(np.int64)
        ids, ncmp = secure_knn.refine_heap(
            self._C_dce[cids], cids, np.asarray(T_q), k)
        stats = SearchStats(
            latency_s=time.perf_counter() - t0,
            filter_dist_evals=int(dist_evals),
            refine_comparisons=int(ncmp),
            bytes_up=np.asarray(C_sap_q).nbytes + np.asarray(T_q).nbytes + 4,
            bytes_down=np.asarray(ids, np.int64).nbytes,
            n_queries=1,
            backend=self.backend.name,
        )
        return ids, stats
