"""Serving layer: the unified secure-search engine, its mesh-sharded
deployment, and the LM server.

Exports resolve lazily so that light-weight users (e.g. core.ppanns
importing the search engine) do not pull in the LM model stack.
"""

import importlib

_EXPORTS = {
    "LMServer": ".engine",
    "DistributedSecureANN": ".ann_server",
    "ShardedBackend": ".sharded",
    "SecureSearchEngine": ".search_engine",
    "SearchStats": ".search_engine",
    "FlatScanFilter": ".search_engine",
    "IVFScanFilter": ".search_engine",
    "HNSWGraphFilter": ".search_engine",
    "CollectionManager": ".runtime",
    "Collection": ".runtime",
    "MicroBatcher": ".runtime",
    "QueueFullError": ".runtime",
    "TenantIsolationError": ".runtime",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(_EXPORTS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
