from .engine import LMServer  # noqa: F401
from .ann_server import DistributedSecureANN  # noqa: F401
