"""LM serving engine: batched prefill + greedy/temperature decode with a
KV cache, jitted end-to-end."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..models.model import Model

__all__ = ["LMServer"]


class LMServer:
    def __init__(self, model: Model, params, mesh=None, rules=None):
        self.model = model
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c, mesh, rules))
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, mesh, rules))

    def generate(self, batch: dict, max_new_tokens: int,
                 temperature: float = 0.0, key=None):
        """batch: {'tokens': (B, S), ...frontend stubs}.  Greedy when
        temperature == 0.  Returns (B, max_new_tokens) int32."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        t_max = S + max_new_tokens + \
            (self.model.cfg.n_vision_tokens
             if self.model.cfg.family == "vlm" else 0)
        cache = self.model.init_cache(B, t_max)
        logits, cache = self._prefill(self.params, batch, cache)

        out = []
        for i in range(max_new_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature,
                                             axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)[:, None]
            out.append(nxt)
            if i + 1 < max_new_tokens:
                logits, cache = self._decode(self.params, nxt, cache)
        return jnp.concatenate(out, axis=1)
