"""Role-typed clients and service (DESIGN.md §9) — the paper's three
roles (Fig. 1) as first-class API objects:

  DataOwnerClient   holds the secret keys: keygen, corpus encryption,
                    IndexSpec-driven index build, key export/import
                    through the on-disk `Keystore`.
  QueryClient       trusted user: per-query O(d^2) encryption into an
                    `EncryptedQuery`, result post-processing.
  SecureAnnService  the honest-but-curious server: wraps the runtime's
                    `CollectionManager` + micro-batcher behind
                    `create_collection(IndexSpec)` and
                    `submit(SearchRequest) -> SearchResult`, and can
                    `save`/`load` its collections — ciphertexts and
                    filter graphs only, never keys — so it survives
                    restarts.

Every payload that crosses between the roles is one of the protocol
types (`protocol.py`), so owner, user, and service can live in three
different processes.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import threading
import urllib.parse

import numpy as np

from ..core import ppanns
from ..core.wireformat import WireFormatError, pack, unpack
from ..obs import Observability
from ..sec import DEFAULT_PROFILE, get_profile
from ..serving.runtime import CollectionManager, QueueFullError  # noqa: F401
from ..serving.runtime import TenantIsolationError               # noqa: F401
from ..serving.runtime.collections import Collection
from .keystore import Keystore
from .protocol import (PROTOCOL_VERSION, EncryptedCorpus, EncryptedQuery,
                       IndexSpec, PlacementSpec, SearchParams,
                       SearchRequest, SearchResult)

__all__ = ["DataOwnerClient", "QueryClient", "SecureAnnService",
           "TenantIsolationError", "QueueFullError"]

_COLLECTION_SUFFIX = ".ppcol"


# ---------------------------------------------------------------------------
# Data owner.
# ---------------------------------------------------------------------------

class DataOwnerClient:
    """The key-holding role.  Created from an `IndexSpec` (keygen) or
    from previously exported keys; everything it hands to the service is
    ciphertext."""

    def __init__(self, spec: IndexSpec, *, keys: ppanns.Keys | None = None):
        spec.validate()
        self.spec = spec
        if spec.seed is None:
            # fresh entropy per owner: two owners must never derive the
            # same key pair just because neither pinned a seed
            seed = int(np.random.SeedSequence().entropy % (2 ** 31))
        else:
            seed = int(spec.seed)
        if keys is None:
            self._owner = ppanns.DataOwner(
                d=spec.d, sap_beta=spec.sap_beta, sap_s=spec.sap_s,
                seed=seed)
        else:
            if keys.d != spec.d:
                raise WireFormatError(
                    f"keys are for d={keys.d}, spec has d={spec.d}")
            self._owner = ppanns.DataOwner.from_keys(keys, seed=seed)
        self._seed = seed

    # ------------------------------------------------------------- keys

    @property
    def keys(self) -> ppanns.Keys:
        return self._owner.keys

    def share_keys(self) -> ppanns.Keys:
        """Owner -> trusted user key handoff (threat model §II-B)."""
        return self._owner.keys

    def query_client(self, seed: int | None = None) -> "QueryClient":
        return QueryClient(self.share_keys(), seed=seed)

    def export_keys(self, keystore: Keystore | str | os.PathLike,
                    name: str | None = None) -> pathlib.Path:
        """Write this owner's keys into an on-disk keystore (owner-side
        storage — the service never sees this directory)."""
        if not isinstance(keystore, Keystore):
            keystore = Keystore(keystore)
        return keystore.save(name or f"{self.spec.tenant}__{self.spec.name}",
                             self.keys)

    @classmethod
    def from_keystore(cls, spec: IndexSpec,
                      keystore: Keystore | str | os.PathLike,
                      name: str | None = None) -> "DataOwnerClient":
        if not isinstance(keystore, Keystore):
            keystore = Keystore(keystore)
        keys = keystore.load(name or f"{spec.tenant}__{spec.name}",
                             expect_d=spec.d)
        return cls(spec, keys=keys)

    # ------------------------------------------------------- encryption

    def encrypt_vectors(self, P: np.ndarray, seed: int | None = None
                        ) -> tuple[np.ndarray, np.ndarray]:
        """Streaming-ingest encryption (jitted, bucketed DCPE + DCE —
        DESIGN.md §8).  Returns (C_sap (m, d), C_dce (m, 4, 2d+16)) ready
        for `SecureAnnService.insert`."""
        return self._owner.encrypt_vectors(P, seed=seed)

    def encrypt_corpus(self, P: np.ndarray, *, progress_every: int = 0
                       ) -> EncryptedCorpus:
        """Bulk outsourcing (paper §V-A): encrypt the whole database and
        — when the spec's backend is "hnsw" or "graph" — build the
        filter graph over the DCPE ciphertexts.  Delegates to
        `DataOwner.encrypt_database`, so the legacy and typed paths
        share one randomness schedule (identical ciphertexts for the
        same seed) by construction, not by convention."""
        P = np.atleast_2d(np.asarray(P))
        if P.shape[1] != self.spec.d:
            raise ValueError(f"corpus dim {P.shape[1]} != spec d="
                             f"{self.spec.d}")
        db = self._owner.encrypt_database(
            P, M=self.spec.hnsw_M,
            ef_construction=self.spec.hnsw_ef_construction,
            progress_every=progress_every,
            build_index=self.spec.backend in ("hnsw", "graph"))
        return EncryptedCorpus(
            C_sap=db.C_sap, C_dce=db.C_dce,
            index=None if db.index is None else db.index.to_arrays())


# ---------------------------------------------------------------------------
# Querying user.
# ---------------------------------------------------------------------------

class QueryClient:
    """The trusted-user role: holds the shared keys, produces
    `EncryptedQuery` payloads (the only user-side work, O(d^2) per
    query), and post-processes `SearchResult`s.

    seed=None (default) starts the query-randomness counter from fresh
    entropy: two clients sharing one key pair — or one client restarted
    — must never re-draw the same DCPE noise for different plaintext
    queries, or the server could difference the ciphertexts.  Pin a
    seed only for reproducible tests/benchmarks."""

    def __init__(self, keys: ppanns.Keys, seed: int | None = None):
        self.keys = keys
        if seed is None:
            seed = int(np.random.SeedSequence().entropy % (2 ** 31))
        self._user = ppanns.User(keys, seed=seed)

    @classmethod
    def from_keystore(cls, keystore: Keystore | str | os.PathLike,
                      name: str, *, expect_d: int | None = None,
                      seed: int | None = None) -> "QueryClient":
        if not isinstance(keystore, Keystore):
            keystore = Keystore(keystore)
        return cls(keystore.load(name, expect_d=expect_d), seed=seed)

    def encrypt_query(self, q: np.ndarray) -> EncryptedQuery:
        """One plaintext query -> nq=1 EncryptedQuery."""
        c, t = self._user.encrypt_query(np.asarray(q))
        return EncryptedQuery(C_sap=c[None], T=t[None])

    def encrypt_queries(self, Q: np.ndarray) -> EncryptedQuery:
        """A batch of queries -> one batch-native EncryptedQuery."""
        pairs = [self._user.encrypt_query(q) for q in np.atleast_2d(Q)]
        return EncryptedQuery(C_sap=np.stack([c for c, _ in pairs]),
                              T=np.stack([t for _, t in pairs]))

    def request(self, tenant: str, collection: str, q: np.ndarray,
                params=None, **params_kw) -> SearchRequest:
        """Convenience: encrypt + wrap into a routed SearchRequest."""
        q = np.asarray(q)
        query = (self.encrypt_query(q) if q.ndim == 1
                 else self.encrypt_queries(q))
        if params is None:
            params = SearchParams(**params_kw)
        elif params_kw:
            params = dataclasses.replace(params, **params_kw)
        return SearchRequest(tenant=tenant, collection=collection,
                             query=query, params=params)

    @staticmethod
    def postprocess(result: SearchResult) -> list[np.ndarray]:
        """Per-query neighbor ids with the -1 padding stripped."""
        return result.ids_lists()


# ---------------------------------------------------------------------------
# The service.
# ---------------------------------------------------------------------------

class SecureAnnService:
    """The untrusted search server behind one typed surface.

    Collections created through this API are *keyless* — the service
    stores ciphertexts, filter state, and specs, never keys; plaintext
    ingestion is structurally impossible (the runtime raises).  The
    request scheduler (`IndexSpec.scheduler`: flush micro-batcher or
    continuous slot loop — DESIGN.md §12), tenant isolation, live
    ingestion, and telemetry of the serving runtime (DESIGN.md §8) all
    ride underneath unchanged.

    Observability (DESIGN.md §13): `obs=True` (or a pre-built
    `repro.obs.Observability`) turns on per-request tracing and the
    cross-collection Prometheus metrics registry for every collection
    this service creates — exposed through `metrics_text()`,
    `export_chrome_trace()`, and `trace_events()`.  Default off: no
    recorder exists and the runtime records nothing.
    """

    def __init__(self, *, result_timeout: float = 120.0, obs=None,
                 **default_kw):
        if obs is True:
            obs = Observability(clock=default_kw.get("clock"))
        self.obs: Observability | None = obs
        if obs is not None:
            # every collection inherits the service-wide recorder and
            # registry unless the caller overrides per collection
            default_kw.setdefault("tracer", obs.recorder)
            default_kw.setdefault("metrics", obs.metrics)
        self._mgr = CollectionManager(**default_kw)
        self._specs: dict[tuple[str, str], IndexSpec] = {}
        self._placements: dict[tuple[str, str], PlacementSpec] = {}
        self._lock = threading.Lock()
        self.result_timeout = result_timeout

    # ------------------------------------------------------ collections

    def create_collection(self, spec: IndexSpec,
                          corpus: EncryptedCorpus | None = None, *,
                          placement: PlacementSpec | None = None
                          ) -> IndexSpec:
        """Create a (keyless) collection per the spec; optionally load an
        owner-uploaded `EncryptedCorpus` (ciphertexts + owner-built
        index) in the same call.  `placement` chooses the deployment
        (DESIGN.md §10): the default single-device engine, or
        `PlacementSpec(kind="sharded", ...)` for row-sharded mesh
        execution behind the same `submit` surface.  Returns the
        effective spec (seed resolved), which is what `save` persists
        (alongside the resolved placement)."""
        if placement is None:
            placement = PlacementSpec()
        if placement.is_sharded:
            if spec.backend == "hnsw":
                raise ValueError(
                    "hnsw collections cannot be sharded: graph "
                    "traversal does not shard (DESIGN.md §3); use a "
                    "flat or ivf backend with sharded placement")
            import jax                    # resolve n_shards=None NOW so
            placement = placement.resolve(len(jax.devices()))   # save()
            # persists the exact shard count this collection ran with
        if corpus is not None:        # validate BEFORE creating: a bad
            if corpus.d != spec.d:    # corpus must not orphan an empty
                raise ValueError(     # collection under this name
                    f"corpus d={corpus.d} != spec d={spec.d}")
            if spec.backend in ("hnsw", "graph") and corpus.index is None:
                raise ValueError("hnsw/graph-backed collection needs an "
                                 "owner-built index in the corpus")
        col = self._mgr.create_collection(
            spec.tenant, spec.name, spec.d, keyless=True,
            placement=placement, **spec.collection_kwargs())
        if spec.seed is None:
            spec = dataclasses.replace(spec, seed=col.seed)
        with self._lock:
            self._specs[(spec.tenant, spec.name)] = spec
            self._placements[(spec.tenant, spec.name)] = placement
        if corpus is not None:
            col.load_snapshot(corpus.C_sap, corpus.C_dce,
                              graph_arrays=corpus.index)
        return spec

    def placement(self, tenant: str, name: str) -> PlacementSpec:
        self._mgr.collection(tenant, name)      # tenancy check first
        with self._lock:
            return self._placements[(tenant, name)]

    def drop_collection(self, tenant: str, name: str):
        self._mgr.drop_collection(tenant, name)
        with self._lock:
            self._specs.pop((tenant, name), None)
            self._placements.pop((tenant, name), None)

    def collection(self, tenant: str, name: str) -> Collection:
        """The underlying runtime collection — advanced/observability
        access (policy benches, telemetry); searches should go through
        `submit`."""
        return self._mgr.collection(tenant, name)

    # -------------------------------------------------------- ingestion

    def insert(self, tenant: str, name: str, C_sap: np.ndarray,
               C_dce: np.ndarray) -> np.ndarray:
        """Append owner-encrypted rows (the wire-format ingestion entry).
        Returns stable row ids; the rows are visible to the next search."""
        return self._mgr.collection(tenant, name).insert_encrypted(
            C_sap, C_dce)

    def delete(self, tenant: str, name: str, ids) -> int:
        return self._mgr.collection(tenant, name).delete(ids)

    def compact(self, tenant: str, name: str):
        self._mgr.collection(tenant, name).compact()

    def warmup(self, tenant: str, name: str, k: int = 10, **kw):
        self._mgr.collection(tenant, name).warmup(k, **kw)

    def stats(self, tenant: str, name: str) -> dict:
        return self._mgr.collection(tenant, name).stats()

    # ----------------------------------------------------------- search

    def submit(self, req: SearchRequest) -> SearchResult:
        """The one search entry.  Single-query requests with
        coalesce=True ride the collection's micro-batcher (concurrent
        submitters share flushes); batch requests and coalesce=False go
        straight to one locked engine call.

        Under a padding security profile (DESIGN.md §14) the returned id
        matrix is widened to the profile's fixed result width with -1
        columns, so the response size leaks the width class, not k; the
        real ids and their order are bit-identical to the "perf" tier,
        and `SearchResult.ids_lists()` strips the padding client-side."""
        col = self._mgr.collection(req.tenant, req.collection)
        p = req.params
        if req.coalesce and req.query.nq == 1 and p.refine == "tournament":
            fut = col.submit(req.query.C_sap[0], req.query.T[0], p.k,
                             ratio_k=p.ratio_k, ef_search=p.ef_search,
                             want_stats=True, trace_id=req.trace_id)
            ids_row, stats = fut.result(timeout=self.result_timeout)
            ids = ids_row[None]
        else:
            ids, stats = col.search_batch(
                req.query.C_sap, req.query.T, p.k, ratio_k=p.ratio_k,
                ef_search=p.ef_search, refine=p.refine)
        ids = self._pad_result(req, col, np.asarray(ids, np.int64), p.k)
        return SearchResult(ids=ids, stats=stats)

    def _pad_result(self, req: SearchRequest, col: Collection,
                    ids: np.ndarray, k: int) -> np.ndarray:
        """Widen the id matrix to the collection profile's fixed result
        width (-1 padding).  The padding bytes feed the telemetry's
        `ann_padded_bytes_total`; the engine-side `bytes_down` keeps
        counting the unpadded payload (the two counters separate the
        scheme's communication model from the profile's overhead)."""
        with self._lock:
            spec = self._specs.get((req.tenant, req.collection))
        profile = (get_profile(spec.security_profile)
                   if spec is not None else DEFAULT_PROFILE)
        width = profile.result_width(k)
        if width <= ids.shape[1]:
            return ids
        pad = np.full((ids.shape[0], width - ids.shape[1]), -1, np.int64)
        col.telemetry.record_padded_bytes(pad.size * pad.itemsize)
        return np.concatenate([ids, pad], axis=1)

    # ------------------------------------------------------ persistence

    @staticmethod
    def _collection_filename(tenant: str, name: str) -> str:
        quote = lambda s: urllib.parse.quote(s, safe="")     # noqa: E731
        return f"{quote(tenant)}__{quote(name)}{_COLLECTION_SUFFIX}"

    def save(self, root: str | os.PathLike) -> list[pathlib.Path]:
        """Persist every collection to `<root>/<tenant>__<name>.ppcol`.

        Each file is a versioned wire payload holding the ciphertext
        store (with tombstone encoding), the main/delta bookkeeping, the
        hnsw filter graph when there is one, and the effective spec.  No
        key material exists anywhere in the service, so none can leak
        into the snapshot."""
        root = pathlib.Path(root)
        root.mkdir(parents=True, exist_ok=True)
        with self._lock:
            specs = dict(self._specs)
            placements = dict(self._placements)
        paths = []
        for (tenant, name), spec in sorted(specs.items()):
            arrays, bookkeeping = self._mgr.collection(tenant,
                                                       name).snapshot()
            placement = placements[(tenant, name)]
            # a sharded collection's bookkeeping carries its per-shard
            # manifest (global row span + live count per shard), taken
            # under the same lock hold as the arrays — the record a
            # multi-host loader would map shard files from
            meta = {"spec": spec.to_dict(),
                    "placement": placement.to_dict(), **bookkeeping}
            path = root / self._collection_filename(tenant, name)
            tmp = path.with_suffix(_COLLECTION_SUFFIX + ".tmp")
            tmp.write_bytes(pack("encrypted-collection", PROTOCOL_VERSION,
                                 arrays=arrays, meta=meta))
            os.replace(tmp, path)
            paths.append(path)
        return paths

    @classmethod
    def load(cls, root: str | os.PathLike, *, result_timeout: float = 120.0,
             **default_kw) -> "SecureAnnService":
        """Rebuild a service from `save` output in a fresh process.  A
        reloaded collection answers searches bit-identically: the store
        (ids, tombstones, main/delta split), the hnsw graph, and the
        seed-keyed flat/ivf state all come back exactly."""
        root = pathlib.Path(root)
        svc = cls(result_timeout=result_timeout, **default_kw)
        files = sorted(root.glob(f"*{_COLLECTION_SUFFIX}"))
        if not files:
            raise FileNotFoundError(f"no {_COLLECTION_SUFFIX} files "
                                    f"under {root}")
        for f in files:
            arrays, meta = unpack(f.read_bytes(), "encrypted-collection",
                                  PROTOCOL_VERSION)
            spec = IndexSpec.from_dict(meta["spec"])
            # pre-placement snapshots carry no placement key -> single
            placement = (PlacementSpec.from_dict(meta["placement"])
                         if meta.get("placement") else None)
            svc.create_collection(spec, placement=placement)
            graph_arrays = {k[len("graph__"):]: v for k, v in arrays.items()
                            if k.startswith("graph__")} or None
            ivf_state = None
            if "ivf__centroids" in arrays:
                ivf_state = {
                    "centroids": arrays["ivf__centroids"],
                    "list_flat": arrays["ivf__list_flat"],
                    "list_offsets": arrays["ivf__list_offsets"],
                    "built_upto": meta["ivf_built_upto"],
                    "attached_gen": meta["ivf_attached_gen"],
                }
            adc_arrays = {k[len("adc__"):]: v for k, v in arrays.items()
                          if k.startswith("adc__")}
            adc_state = ({"arrays": adc_arrays,
                          "trained_gen": meta["adc_trained_gen"]}
                         if adc_arrays else None)
            svc._mgr.collection(spec.tenant, spec.name).load_snapshot(
                arrays["C_sap"], arrays["C_dce"], alive=arrays["alive"],
                n_main=int(meta["n_main"]), main_gen=int(meta["main_gen"]),
                graph_arrays=graph_arrays, ivf_state=ivf_state,
                adc_state=adc_state)
        return svc

    # ---------------------------------------------------- observability

    def metrics_text(self) -> str:
        """Prometheus text exposition of the service-wide registry
        (DESIGN.md §13).  With observability off, a parseable
        comment-only document — a scrape target that is wired up but
        dark, rather than an error."""
        if self.obs is None:
            return ("# observability disabled "
                    "(construct SecureAnnService with obs=True)\n")
        return self.obs.metrics_text()

    def trace_events(self) -> list[dict]:
        """The recorder's structured event log ([] with obs off)."""
        return [] if self.obs is None else self.obs.events()

    def export_chrome_trace(self, path: str | os.PathLike) -> str:
        """Write the recorded spans as Chrome-trace/Perfetto JSON."""
        if self.obs is None:
            raise RuntimeError("observability is off: construct "
                               "SecureAnnService with obs=True")
        return self.obs.export_chrome_trace(path)

    # ------------------------------------------------------------- misc

    def close(self):
        self._mgr.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
