"""DEPRECATED mesh wrapper (DESIGN.md §10) + dry-run builder re-exports.

`DistributedSecureAnnService` predates placement-aware collections: it
was a second, weaker service class (exhaustive flat scan only, a
`search(query, params)` surface instead of `submit(SearchRequest)`, no
batching/tenancy/ingestion/persistence).  Deployment is now a parameter
of the one public API:

    svc.create_collection(spec, corpus=corpus,
                          placement=PlacementSpec(kind="sharded"))

This module keeps the old class as a thin `DeprecationWarning` shim over
exactly that path (parity-tested to the id in tests/test_api.py), and
keeps re-exporting the explicit-collective dry-run builders
(`serving.secure_scan`) so launch tooling still reaches them through
the public surface.
"""

from __future__ import annotations

import warnings

import numpy as np

from ..serving.secure_scan import (build_secure_scan_step,          # noqa: F401
                                   build_secure_scan_step_gspmd,    # noqa: F401
                                   secure_scan_input_specs,         # noqa: F401
                                   secure_scan_pspecs)              # noqa: F401
from .protocol import (EncryptedCorpus, EncryptedQuery, IndexSpec,
                       PlacementSpec, SearchParams, SearchRequest,
                       SearchResult)
from .roles import SecureAnnService

__all__ = ["DistributedSecureAnnService", "build_secure_scan_step",
           "build_secure_scan_step_gspmd", "secure_scan_input_specs",
           "secure_scan_pspecs"]

_TENANT, _NAME = "_legacy", "mesh"


class DistributedSecureAnnService:
    """DEPRECATED: a sharded collection behind the unified service.

    Construct `SecureAnnService` and pass
    `placement=PlacementSpec(kind="sharded", ...)` to
    `create_collection` instead — that path adds batching, tenancy,
    live ingestion, and persistence on top of the same sharded
    execution.  This shim routes `search` through it unchanged."""

    def __init__(self, corpus, C_dce=None, *, mesh=None, axis=None):
        warnings.warn(
            "DistributedSecureAnnService is deprecated; create a "
            "sharded collection through repro.api instead: "
            "SecureAnnService.create_collection(spec, corpus=corpus, "
            "placement=PlacementSpec(kind='sharded', ...)) — same ids, "
            "one service surface", DeprecationWarning, stacklevel=2)
        if not isinstance(corpus, EncryptedCorpus):
            if C_dce is None:
                raise ValueError("pass an EncryptedCorpus or both "
                                 "(C_sap, C_dce) arrays")
            corpus = EncryptedCorpus(C_sap=np.asarray(corpus),
                                     C_dce=np.asarray(C_dce))
        if mesh is not None:
            # legacy semantics: shard over the named axis only, or over
            # every axis when none is named
            axes = tuple(mesh.axis_names) if axis is None else (axis,)
            n_shards = int(np.prod([mesh.shape[a] for a in axes]))
            axis_name = axes[0]
        else:
            n_shards, axis_name = 1, "data"
        # sap_beta/sap_s never matter here: the collection is keyless
        # and ingests the given ciphertexts as-is
        spec = IndexSpec(tenant=_TENANT, name=_NAME, d=corpus.d,
                         backend="flat", seed=0)
        self._svc = SecureAnnService()
        self._svc.create_collection(
            spec, corpus=corpus,
            placement=PlacementSpec(kind="sharded", data_axis=axis_name,
                                    n_shards=n_shards))
        self._n = corpus.n

    @property
    def n(self) -> int:
        return self._n

    def search(self, query: EncryptedQuery,
               params: SearchParams = SearchParams()) -> SearchResult:
        return self._svc.submit(SearchRequest(
            tenant=_TENANT, collection=_NAME, query=query, params=params,
            coalesce=False))

    def close(self):
        self._svc.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
