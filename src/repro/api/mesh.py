"""Mesh-sharded deployment behind the protocol types (DESIGN.md §3, §9).

`DistributedSecureAnnService` is the typed face of
`serving.ann_server.DistributedSecureANN`: the encrypted database is
sharded row-wise across every mesh device, queries arrive as
`EncryptedQuery`, results leave as `SearchResult` — same protocol
vocabulary as the single-host `SecureAnnService`, different deployment.

The explicit-collective dry-run builders (`serving.secure_scan`) are
re-exported here so that launch tooling reaches them through the one
public surface.
"""

from __future__ import annotations

import time

import numpy as np

from ..serving.ann_server import DistributedSecureANN
from ..serving.search_engine import SearchStats
from ..serving.secure_scan import (build_secure_scan_step,          # noqa: F401
                                   build_secure_scan_step_gspmd,    # noqa: F401
                                   secure_scan_input_specs,         # noqa: F401
                                   secure_scan_pspecs)              # noqa: F401
from .protocol import EncryptedCorpus, EncryptedQuery, SearchParams, \
    SearchResult

__all__ = ["DistributedSecureAnnService", "build_secure_scan_step",
           "build_secure_scan_step_gspmd", "secure_scan_input_specs",
           "secure_scan_pspecs"]


class DistributedSecureAnnService:
    """Sharded exhaustive filter + batched exact DCE refine, typed.

    Construct from an owner-uploaded `EncryptedCorpus` (or raw
    ciphertext arrays) and an optional mesh; `search` is the whole
    surface."""

    def __init__(self, corpus, C_dce=None, *, mesh=None, axis=None):
        if isinstance(corpus, EncryptedCorpus):
            C_sap, C_dce = corpus.C_sap, corpus.C_dce
        else:
            C_sap = corpus
            if C_dce is None:
                raise ValueError("pass an EncryptedCorpus or both "
                                 "(C_sap, C_dce) arrays")
        self._impl = DistributedSecureANN(np.asarray(C_sap),
                                          np.asarray(C_dce),
                                          mesh=mesh, axis=axis)

    @property
    def n(self) -> int:
        return self._impl.n

    def search(self, query: EncryptedQuery,
               params: SearchParams = SearchParams()) -> SearchResult:
        t0 = time.perf_counter()
        ids = self._impl.query_batch(query.C_sap, query.T, params.k,
                                     ratio_k=params.ratio_k)
        nq = query.nq
        kp = min(int(max(params.k, round(params.ratio_k * params.k))),
                 self._impl.n_padded)
        nv = min(kp, self._impl.n)        # pad rows never reach the refine
        stats = SearchStats(
            latency_s=time.perf_counter() - t0,
            filter_dist_evals=nq * self._impl.n,
            refine_comparisons=nq * nv * (nv - 1),
            bytes_up=query.nbytes + 4 * nq,
            bytes_down=4 * int(np.asarray(ids).size),
            n_queries=nq,
            backend="mesh-flat",
        )
        return SearchResult(ids=np.asarray(ids, np.int64), stats=stats)
