"""repro.api — the public, role-typed client/service surface
(DESIGN.md §9, §10).

The paper's threat model has three roles — data owner, user, untrusted
server — and this package is their protocol: typed dataclasses
(`IndexSpec`, `PlacementSpec`, `SearchParams`, `EncryptedQuery`,
`SearchRequest`, `SearchResult`, `EncryptedCorpus`) with versioned
`to_bytes`/`from_bytes` wire round-trips, role objects
(`DataOwnerClient`, `QueryClient`, `SecureAnnService`), an on-disk
`Keystore` (owner-side), and persistent encrypted collections
(`SecureAnnService.save`/`load` — ciphertexts only, never keys).

Deployment is a *parameter*, not a class: `create_collection(spec,
placement=PlacementSpec(kind="sharded", ...))` runs the same
`submit(SearchRequest)` surface mesh-sharded (DESIGN.md §10).  The old
`DistributedSecureAnnService` remains as a deprecated shim over that
path.

Everything an example, launcher, or downstream user needs lives here;
`scripts/check_api.py` enforces that they import nothing deeper.
Exports resolve lazily so `import repro.api` stays light.
"""

import importlib

_EXPORTS = {
    # protocol types + wire format
    "PROTOCOL_VERSION": ".protocol",
    "WireFormatError": ".protocol",
    "IndexSpec": ".protocol",
    "PlacementSpec": ".protocol",
    "SearchParams": ".protocol",
    "EncryptedQuery": ".protocol",
    "EncryptedCorpus": ".protocol",
    "SearchRequest": ".protocol",
    "SearchResult": ".protocol",
    "SearchStats": ".protocol",
    "Keys": ".protocol",
    "suggest_beta": ".protocol",
    # roles
    "DataOwnerClient": ".roles",
    "QueryClient": ".roles",
    "SecureAnnService": ".roles",
    "TenantIsolationError": ".roles",
    "QueueFullError": ".roles",
    # key custody
    "Keystore": ".keystore",
    # deprecated mesh wrapper + dry-run builders
    "DistributedSecureAnnService": ".mesh",
    "build_secure_scan_step": ".mesh",
    "build_secure_scan_step_gspmd": ".mesh",
    "secure_scan_input_specs": ".mesh",
    "secure_scan_pspecs": ".mesh",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        mod = importlib.import_module(_EXPORTS[name], __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
